"""L2 tuner-graph tests: decision semantics and physically-sane winners."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def fast_ethernet_table(t=32):
    """Gap table shaped like the paper's testbed (switched 100 Mb/s)."""
    sizes = np.geomspace(1, 4 << 20, t).astype(np.float32)
    # ~12.5 MB/s wire rate -> 0.08 us/byte, plus per-message overhead.
    gaps = (55e-6 + 0.085e-6 * sizes).astype(np.float32)
    return sizes, gaps


GRID = dict(
    lat=np.array([55e-6], np.float32),
    p_grid=np.arange(2, 50, 3, dtype=np.float32),
    m_grid=np.geomspace(1, 1 << 20, 48).astype(np.float32),
    s_grid=np.geomspace(64, 128 << 10, 32).astype(np.float32),
)


@pytest.fixture(scope="module")
def tuned():
    sizes, gaps = fast_ethernet_table()
    return [np.asarray(x) for x in model.tune(sizes, gaps, **GRID)]


class TestDecisionLayer:
    def test_winner_ranges(self, tuned):
        _, _, bw, sw = tuned
        assert bw.min() >= 0 and bw.max() <= 9
        assert sw.min() >= 10 and sw.max() <= 12

    def test_winner_is_argmin(self, tuned):
        times, _, bw, sw = tuned
        np.testing.assert_array_equal(bw, np.argmin(times[:10], 0))
        np.testing.assert_array_equal(sw, np.argmin(times[10:], 0) + 10)

    def test_matches_reference_graph(self):
        sizes, gaps = fast_ethernet_table()
        got = model.tune(sizes, gaps, **GRID)
        want = model.tune_reference(sizes, gaps, GRID["lat"][0],
                                    GRID["p_grid"], GRID["m_grid"],
                                    GRID["s_grid"])
        # times and segments agree numerically
        for g, w in zip(got[:2], want[:2]):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-9)
        # winners may differ only at exact ties (1-ulp argmin flips between
        # the kernel's and the oracle's differently-fused arithmetic):
        # where they disagree, the two chosen strategies' times must match.
        times = np.asarray(want[0])
        q_ix, m_ix = np.indices(times.shape[1:])
        for gw, ww in ((got[2], want[2]), (got[3], want[3])):
            gw, ww = np.asarray(gw).astype(int), np.asarray(ww).astype(int)
            dis = gw != ww
            if dis.any():
                tg = times[gw[dis], q_ix[dis], m_ix[dis]]
                tw = times[ww[dis], q_ix[dis], m_ix[dis]]
                np.testing.assert_allclose(tg, tw, rtol=1e-4)


class TestPaperShapedConclusions:
    """The qualitative results of section 4 must fall out of the models."""

    def test_seg_chain_wins_bcast_large_messages(self, tuned):
        """Fig 1/2: Segmented Chain broadcast wins for large m, many P."""
        times, _, bw, _ = tuned
        q = GRID["p_grid"].shape[0] - 1   # P = 47
        m = GRID["m_grid"].shape[0] - 1   # m = 1 MB
        assert bw[q, m] == 5  # bcast/seg_chain

    def test_latency_bound_small_messages_prefer_binomial_family(self, tuned):
        """Small m: log-depth trees beat (P-1)-depth chains."""
        times, _, _, _ = tuned
        q = GRID["p_grid"].shape[0] - 1
        assert times[7, q, 0] < times[3, q, 0]  # binomial < chain at m=1B

    def test_binomial_scatter_beats_flat_at_scale(self, tuned):
        """Fig 3/4: Binomial Scatter overtakes Flat for this network.

        The binomial model moves sum_{j} 2^j = 2^ceil(log2 P) - 1 message
        units versus flat's P-1, so the comparison is cleanest at a power
        of two (P=32: same wire bytes, 5 overhead terms vs 31). The paper's
        testbed sweeps hit the same effect (their Fig 3).
        """
        times, _, _, _ = tuned
        q = int(np.where(GRID["p_grid"] == 32.0)[0][0])
        m = GRID["m_grid"].shape[0] - 1
        assert times[12, q, m] < times[10, q, m]
        # and the win grows with P at fixed m among powers of two reachable
        # in the grid: check P=8 wins less than P=32 wins (relative).
        q8 = int(np.where(GRID["p_grid"] == 8.0)[0][0])
        rel32 = times[10, q, m] / times[12, q, m]
        rel8 = times[10, q8, m] / times[12, q8, m]
        assert rel32 > rel8

    def test_scatter_flat_wins_tiny_clusters(self, tuned):
        """P=2: flat scatter is a single send; binomial equals it."""
        times, _, _, _ = tuned
        np.testing.assert_allclose(times[10, 0, :], times[12, 0, :],
                                   rtol=1e-5)

    def test_rendezvous_never_beats_eager_same_tree(self, tuned):
        """Rendezvous adds 2 g(1) + 3L-L of pure overhead in the model."""
        times, _, _, _ = tuned
        assert np.all(times[1] >= times[0] - 1e-9)
        assert np.all(times[4] >= times[3] - 1e-9)
        assert np.all(times[8] >= times[7] - 1e-9)

    def test_segmentation_never_hurts_when_grid_covers_m(self, tuned):
        """For m <= max(s_grid) the candidate s >= m degenerates to the
        unsegmented model, so the segmented rows are pointwise <= their
        unsegmented siblings there. (Beyond the grid the tuner is *forced*
        to segment, which can lose — that is a property of the search
        space, not a bug; the Rust tuner extends the s-grid with m itself.)
        """
        times, _, _, _ = tuned
        cover = GRID["m_grid"] <= GRID["s_grid"].max()
        assert np.all(times[2][:, cover] <= times[0][:, cover] + 1e-9)
        assert np.all(times[5][:, cover] <= times[3][:, cover] + 1e-9)
        assert np.all(times[9][:, cover] <= times[7][:, cover] + 1e-9)

    def test_crossover_exists_for_bcast(self, tuned):
        """The paper's whole point: no single strategy wins everywhere."""
        _, _, bw, _ = tuned
        assert len(np.unique(bw)) >= 2

    def test_chosen_segments_reasonable(self, tuned):
        _, segs, _, _ = tuned
        m = GRID["m_grid"][None, None, :]
        assert np.all(segs <= m + 1e-6)
        assert np.all(segs >= 0)


class TestExampleArgs:
    def test_shapes(self):
        args = model.example_args(8, 4, 6, 5)
        assert [a.shape for a in args] == [(8,), (8,), (1,), (4,), (6,), (5,)]

    def test_strategy_name_count(self):
        assert len(ref.STRATEGY_NAMES) == ref.NUM_STRATEGIES == 13
