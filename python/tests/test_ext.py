"""Extended-collectives kernel: Pallas vs oracle vs hand values."""

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ext_models


def toy_table():
    """g(m) = 1 + m on power-of-two samples, L = 10 — hand-checkable."""
    sizes = np.array([1, 2, 4, 8, 16, 32, 64, 128, 256], np.float32)
    gaps = (1.0 + sizes).astype(np.float32)
    return sizes, gaps


def fast_ethernet_table(t=32):
    sizes = np.geomspace(1, 4 << 20, t).astype(np.float32)
    gaps = (55e-6 + 0.085e-6 * sizes).astype(np.float32)
    return sizes, gaps


class TestKernelVsOracle:
    def test_matches_reference(self):
        sizes, gaps = fast_ethernet_table()
        lat = np.array([55e-6], np.float32)
        p_grid = np.arange(2, 18, dtype=np.float32)
        m_grid = np.geomspace(1, 1 << 20, 24).astype(np.float32)
        kt = np.asarray(ext_models.ext_pallas(sizes, gaps, lat, p_grid, m_grid))
        rt = np.asarray(ext_models.ext_reference(sizes, gaps, lat[0], p_grid, m_grid))
        np.testing.assert_allclose(kt, rt, rtol=1e-4, atol=1e-9)

    def test_shapes_and_positivity(self):
        sizes, gaps = fast_ethernet_table(8)
        lat = np.array([1e-4], np.float32)
        p_grid = np.array([2.0, 7.0, 32.0], np.float32)
        m_grid = np.array([1.0, 1024.0], np.float32)
        kt = np.asarray(ext_models.ext_pallas(sizes, gaps, lat, p_grid, m_grid))
        assert kt.shape == (10, 3, 2)
        assert np.all(np.isfinite(kt)) and np.all(kt > 0)


class TestHandValues:
    """Mirrors rust models::ext hand_values exactly (P=5, m=8)."""

    def predict(self):
        sizes, gaps = toy_table()
        t = ext_models.ext_pallas(
            sizes, gaps, np.array([10.0], np.float32),
            np.array([5.0], np.float32), np.array([8.0], np.float32))
        return np.asarray(t)[:, 0, 0]

    def test_all_rows(self):
        t = self.predict()
        want = [
            4 * 9 + 10,              # gather flat
            89,                      # gather binomial
            2 * 9 + 30,              # reduce binomial
            2 * (2 * 2 + 30),        # barrier tree
            3 * 12,                  # barrier dissemination
            89 + 2 * 41 + 30,        # allgather gather+bcast
            4 * 19,                  # allgather ring
            89,                      # allgather rec doubling
            2 * (2 * 9 + 30),        # allreduce reduce+bcast
            3 * 19,                  # allreduce rec doubling
        ]
        np.testing.assert_allclose(t, np.array(want, np.float32), rtol=1e-6)


class TestWinners:
    def test_tune_ext_winner_ranges(self):
        sizes, gaps = fast_ethernet_table()
        lat = np.array([55e-6], np.float32)
        p_grid = np.arange(2, 34, 2, dtype=np.float32)
        m_grid = np.geomspace(1, 1 << 20, 16).astype(np.float32)
        times, winners = model.tune_ext(sizes, gaps, lat, p_grid, m_grid)
        winners = np.asarray(winners).astype(int)
        for row, (lo, hi) in enumerate(
            [(0, 2), (3, 5), (5, 8), (8, 10)]
        ):
            assert winners[row].min() >= lo and winners[row].max() < hi

    def test_dissemination_wins_barrier(self):
        sizes, gaps = fast_ethernet_table()
        lat = np.array([55e-6], np.float32)
        p_grid = np.array([16.0, 32.0], np.float32)
        m_grid = np.array([1.0], np.float32)
        _, winners = model.tune_ext(sizes, gaps, lat, p_grid, m_grid)
        assert np.all(np.asarray(winners)[1] == 4)  # barrier/dissemination


class TestExtAot:
    def test_lowering(self):
        text = aot.build_ext(8, 2, 6)
        assert "HloModule" in text
        assert "f32[10,2,6]" in text.replace(" ", "")

    def test_layout_constants(self):
        assert ext_models.NUM_EXT == 10
        assert len(ext_models.EXT_NAMES) == 10
        spans = sorted(v for v in ext_models.FAMILIES.values())
        assert spans == [(0, 2), (3, 5), (5, 8), (8, 10)]
