"""Kernel-vs-oracle correctness: the CORE signal for the L1 Pallas kernel.

Every test compares ``cost_models.tune_pallas`` (the kernel that gets
AOT-lowered into the Rust coordinator's artifact) against ``ref`` (the
pure-jnp transliteration of Tables 1 and 2).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cost_models, ref


def make_gap_table(t=32, g0=50e-6, per_byte=0.09e-6, max_size=4 << 20):
    """Synthetic but realistic Fast-Ethernet-ish gap table.

    g(m) = g0 + per_byte * m sampled on a log grid — 100 Mb/s is about
    0.08 us/byte on the wire; 0.09 us/byte models protocol overhead.
    """
    sizes = np.unique(np.geomspace(1, max_size, t).astype(np.float32))
    while sizes.shape[0] < t:  # re-pad after unique collapsed duplicates
        sizes = np.unique(np.concatenate(
            [sizes, sizes[-1:] * 1.37]).astype(np.float32))
    sizes = sizes[:t]
    gaps = (g0 + per_byte * sizes).astype(np.float32)
    return sizes, gaps


DEFAULT = dict(
    lat=np.array([60e-6], np.float32),
    p_grid=np.arange(2, 18, dtype=np.float32),
    m_grid=np.geomspace(1, 1 << 20, 48).astype(np.float32),
    s_grid=np.geomspace(64, 64 << 10, 32).astype(np.float32),
)


def run_both(sizes, gaps, lat, p_grid, m_grid, s_grid):
    kt, ks = cost_models.tune_pallas(sizes, gaps, lat, p_grid, m_grid, s_grid)
    rt, rs = ref.predict_all(sizes, gaps, lat[0], p_grid, m_grid, s_grid)
    return (np.asarray(kt), np.asarray(ks)), (np.asarray(rt), np.asarray(rs))


class TestKernelMatchesOracle:
    def test_default_grid_times(self):
        sizes, gaps = make_gap_table()
        (kt, _), (rt, _) = run_both(sizes, gaps, **DEFAULT)
        np.testing.assert_allclose(kt, rt, rtol=1e-5, atol=1e-9)

    def test_default_grid_segments(self):
        sizes, gaps = make_gap_table()
        (_, ks), (_, rs) = run_both(sizes, gaps, **DEFAULT)
        np.testing.assert_allclose(ks, rs, rtol=1e-5, atol=0)

    def test_output_shapes(self):
        sizes, gaps = make_gap_table()
        kt, ks = cost_models.tune_pallas(sizes, gaps, **DEFAULT)
        q = DEFAULT["p_grid"].shape[0]
        m = DEFAULT["m_grid"].shape[0]
        assert kt.shape == (ref.NUM_STRATEGIES, q, m)
        assert ks.shape == (ref.NUM_STRATEGIES, q, m)

    def test_times_positive_finite(self):
        sizes, gaps = make_gap_table()
        kt, _ = cost_models.tune_pallas(sizes, gaps, **DEFAULT)
        kt = np.asarray(kt)
        assert np.all(np.isfinite(kt))
        assert np.all(kt > 0)

    def test_single_p_single_m(self):
        sizes, gaps = make_gap_table(t=8)
        args = dict(
            lat=np.array([10e-6], np.float32),
            p_grid=np.array([8.0], np.float32),
            m_grid=np.array([1024.0], np.float32),
            s_grid=np.array([256.0, 1024.0], np.float32),
        )
        (kt, ks), (rt, rs) = run_both(sizes, gaps, **args)
        np.testing.assert_allclose(kt, rt, rtol=1e-5)
        np.testing.assert_allclose(ks, rs, rtol=1e-5)

    def test_non_power_of_two_p(self):
        sizes, gaps = make_gap_table()
        args = dict(DEFAULT)
        args["p_grid"] = np.array([3, 5, 7, 11, 13, 24, 50], np.float32)
        (kt, _), (rt, _) = run_both(sizes, gaps, **args)
        np.testing.assert_allclose(kt, rt, rtol=1e-5, atol=1e-9)

    def test_concave_gap_table(self):
        """Sub-linear (concave) gap curves favour segmentation differently."""
        sizes, _ = make_gap_table()
        gaps = (20e-6 + 2e-6 * np.sqrt(sizes)).astype(np.float32)
        (kt, ks), (rt, rs) = run_both(sizes, gaps, **DEFAULT)
        np.testing.assert_allclose(kt, rt, rtol=1e-5, atol=1e-9)
        np.testing.assert_allclose(ks, rs, rtol=1e-5)


class TestModelSemantics:
    """Hand-checked values of the Table 1 / Table 2 formulas."""

    def setup_method(self):
        # Exact-arithmetic gap table: g(m) = 1 + m (seconds, fictional),
        # L = 10, so every model value can be checked by hand.
        self.sizes = np.array([1, 2, 4, 8, 16, 32, 64, 128], np.float32)
        self.gaps = (1.0 + self.sizes).astype(np.float32)
        self.lat = np.array([10.0], np.float32)

    def predict(self, p, m, s_grid=None):
        if s_grid is None:
            s_grid = np.array([128.0], np.float32)  # s>=m -> unsegmented
        t, s = cost_models.tune_pallas(
            self.sizes, self.gaps, self.lat,
            np.array([p], np.float32), np.array([m], np.float32),
            np.asarray(s_grid, np.float32))
        return np.asarray(t)[:, 0, 0], np.asarray(s)[:, 0, 0]

    def test_flat_bcast(self):
        t, _ = self.predict(5.0, 8.0)
        # (P-1) g(m) + L = 4 * 9 + 10 = 46
        assert t[0] == pytest.approx(46.0)

    def test_flat_rdv_bcast(self):
        t, _ = self.predict(5.0, 8.0)
        # (P-1) g(m) + 2 g(1) + 3 L = 36 + 4 + 30 = 70
        assert t[1] == pytest.approx(70.0)

    def test_chain_bcast(self):
        t, _ = self.predict(5.0, 8.0)
        # (P-1)(g(m)+L) = 4 * 19 = 76
        assert t[3] == pytest.approx(76.0)

    def test_chain_rdv_bcast(self):
        t, _ = self.predict(5.0, 8.0)
        # (P-1)(g(m) + 2 g(1) + 3L) = 4 * (9 + 4 + 30) = 172
        assert t[4] == pytest.approx(172.0)

    def test_binary_bcast(self):
        t, _ = self.predict(5.0, 8.0)
        # ceil(log2 5) (2 g(m) + L) = 3 * 28 = 84
        assert t[6] == pytest.approx(84.0)

    def test_binomial_bcast(self):
        t, _ = self.predict(5.0, 8.0)
        # floor(log2 5) g(m) + ceil(log2 5) L = 2*9 + 3*10 = 48
        assert t[7] == pytest.approx(48.0)

    def test_binomial_rdv_bcast(self):
        t, _ = self.predict(5.0, 8.0)
        # 2*9 + 3*(2*2 + 30) = 18 + 102 = 120
        assert t[8] == pytest.approx(120.0)

    def test_binomial_bcast_power_of_two(self):
        t, _ = self.predict(8.0, 8.0)
        # floor = ceil = 3: 3*9 + 3*10 = 57
        assert t[7] == pytest.approx(57.0)

    def test_seg_chain_bcast(self):
        t, _ = self.predict(5.0, 8.0, s_grid=[2.0])
        # s=2, k=4, g(2)=3: (P-1)(g(s)+L) + g(s)(k-1) = 4*13 + 9 = 61
        assert t[5] == pytest.approx(61.0)

    def test_seg_flat_bcast(self):
        t, _ = self.predict(5.0, 8.0, s_grid=[2.0])
        # (P-1)(g(s) k) + L = 4 * 12 + 10 = 58
        assert t[2] == pytest.approx(58.0)

    def test_seg_binomial_bcast(self):
        t, _ = self.predict(5.0, 8.0, s_grid=[2.0])
        # floor(log2 5) g(s) k + ceil(log2 5) L = 2*3*4 + 30 = 54
        assert t[9] == pytest.approx(54.0)

    def test_seg_picks_min_over_grid(self):
        t_one, _ = self.predict(5.0, 8.0, s_grid=[2.0])
        t_many, s_many = self.predict(5.0, 8.0, s_grid=[1.0, 2.0, 4.0, 8.0])
        assert t_many[5] <= t_one[5] + 1e-6
        assert s_many[5] in (1.0, 2.0, 4.0, 8.0)

    def test_segmented_degenerates_when_s_exceeds_m(self):
        """s >= m must reproduce the unsegmented model exactly."""
        t, s = self.predict(5.0, 8.0, s_grid=[64.0])
        assert t[2] == pytest.approx(t[0])   # seg_flat == flat
        assert s[2] == pytest.approx(8.0)    # clamped to m

    def test_scatter_flat(self):
        t, _ = self.predict(5.0, 8.0)
        assert t[10] == pytest.approx(46.0)

    def test_scatter_chain(self):
        t, _ = self.predict(5.0, 8.0)
        # sum_{j=1}^{4} g(8j) + 4 L = g(8)+g(16)+g(24)+g(32) + 40
        #   = 9 + 17 + 25 + 33 + 40 = 124
        assert t[11] == pytest.approx(124.0)

    def test_scatter_binomial(self):
        t, _ = self.predict(5.0, 8.0)
        # sum_{j=0}^{2} g(8 * 2^j) + 3 L = 9 + 17 + 33 + 30 = 89
        assert t[12] == pytest.approx(89.0)

    def test_scatter_binomial_p2(self):
        t, _ = self.predict(2.0, 8.0)
        # ceil(log2 2) = 1: g(8) + L = 19
        assert t[12] == pytest.approx(19.0)

    def test_p2_all_trees_one_send(self):
        """P=2: flat, chain and binomial broadcast all cost g(m)+L."""
        t, _ = self.predict(2.0, 8.0)
        assert t[0] == pytest.approx(19.0)
        assert t[3] == pytest.approx(19.0)
        assert t[7] == pytest.approx(19.0)


class TestGapInterp:
    def test_exact_at_table_points(self):
        sizes = np.array([1, 10, 100, 1000], np.float32)
        gaps = np.array([5, 6, 9, 20], np.float32)
        out = np.asarray(ref.gap_interp(sizes, sizes, gaps))
        np.testing.assert_allclose(out, gaps, rtol=1e-6)

    def test_midpoint(self):
        sizes = np.array([0, 10], np.float32)
        gaps = np.array([0, 100], np.float32)
        assert float(ref.gap_interp(5.0, sizes, gaps)) == pytest.approx(50.0)

    def test_clamp_below(self):
        sizes = np.array([10, 20], np.float32)
        gaps = np.array([7, 9], np.float32)
        assert float(ref.gap_interp(1.0, sizes, gaps)) == pytest.approx(7.0)

    def test_extrapolate_above(self):
        sizes = np.array([10, 20], np.float32)
        gaps = np.array([7, 9], np.float32)
        assert float(ref.gap_interp(30.0, sizes, gaps)) == pytest.approx(11.0)


# Shapes are FIXED across hypothesis examples so the interpret-mode kernel
# compiles exactly once (a fresh shape costs ~10 s of tracing each).
# Values (tables, grids, latency) vary freely. Tolerance is rtol=1e-3:
# g(m) far above the gap table is linear *extrapolation*, which magnifies
# last-segment f32 rounding differences between the kernel's and the
# oracle's (differently fused) interpolation arithmetic.
_HT, _HQ, _HM, _HS = 16, 4, 8, 6


@settings(max_examples=30, deadline=None)
@given(
    g0=st.floats(1e-6, 1e-3),
    per_byte=st.floats(1e-9, 1e-6),
    lat=st.floats(1e-6, 1e-2),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle_hypothesis(g0, per_byte, lat, seed):
    """Random (monotone) gap tables and random grids on a fixed shape."""
    rng = np.random.default_rng(seed)
    sizes = np.cumsum(rng.uniform(1, 1000, _HT)).astype(np.float32)
    gaps = (g0 + per_byte * sizes
            + rng.uniform(0, g0, _HT)).astype(np.float32)
    p_grid = rng.integers(2, 63, _HQ).astype(np.float32)
    m_grid = rng.uniform(1, 1 << 22, _HM).astype(np.float32)
    s_grid = rng.uniform(1, 1 << 16, _HS).astype(np.float32)
    latv = np.array([lat], np.float32)

    kt, ks = cost_models.tune_pallas(sizes, gaps, latv, p_grid, m_grid, s_grid)
    rt, rs = ref.predict_all(sizes, gaps, lat, p_grid, m_grid, s_grid)
    np.testing.assert_allclose(np.asarray(kt), np.asarray(rt),
                               rtol=1e-3, atol=1e-8)
    # Chosen segment sizes may legitimately differ where two candidates
    # give times within f32 noise of each other; require agreement OR a
    # time difference below tolerance at disagreeing points.
    ks, rs = np.asarray(ks), np.asarray(rs)
    disagree = ~np.isclose(ks, rs, rtol=1e-5)
    if disagree.any():
        np.testing.assert_allclose(np.asarray(kt)[disagree],
                                   np.asarray(rt)[disagree], rtol=1e-3)
