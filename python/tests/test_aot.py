"""AOT path smoke tests: the tuner graph must lower to parseable HLO text."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_build_small(self):
        text = aot.build(t=8, q=2, m=6, s=4)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_build_is_deterministic(self):
        a = aot.build(t=8, q=2, m=6, s=4)
        b = aot.build(t=8, q=2, m=6, s=4)
        assert a == b

    def test_no_custom_calls(self):
        """interpret=True must lower Pallas to plain HLO (no Mosaic)."""
        text = aot.build(t=8, q=2, m=6, s=4)
        assert "custom-call" not in text.lower().replace("_", "-") or \
            "mosaic" not in text.lower()

    def test_tuple_outputs(self):
        """4 outputs: times, segs, bcast_winner, scatter_winner."""
        text = aot.build(t=8, q=2, m=6, s=4)
        # the ENTRY root is a 4-tuple of f32 arrays
        assert "(f32[13,2,6]" in text.replace(" ", "")


class TestCliAndSidecar:
    def test_main_writes_artifact_and_meta(self, tmp_path):
        out = tmp_path / "tuner.hlo.txt"
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(out),
             "--table", "8", "--pgrid", "2", "--mgrid", "6", "--sgrid", "4"],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            check=True, env=env,
        )
        assert out.exists()
        meta = json.loads((tmp_path / "tuner.meta.json").read_text())
        assert meta["num_strategies"] == 13
        assert meta["table_len"] == 8
        assert len(meta["strategy_names"]) == 13
