"""L2: the full tuner compute graph, AOT-lowered to the Rust coordinator.

The paper tunes a collective operation by evaluating every candidate
implementation's pLogP model and picking the argmin. This module wraps the
L1 Pallas kernel (``kernels.cost_models``) with the decision layer:

  inputs : gap table (sizes, gaps), latency L, P-grid, m-grid, s-grid
  outputs: times[13, Q, M]      per-strategy best predicted completion time
           segs[13, Q, M]       chosen segment size (0 for unsegmented)
           bcast_winner[Q, M]   argmin strategy over the 10 broadcast rows
           scatter_winner[Q, M] argmin strategy (10..12) over scatter rows

Everything is float32; winners are returned as float32 indices because the
whole artifact crosses the PJRT boundary as a flat tuple of f32 buffers.

This file never runs at request time: ``aot.py`` lowers ``tune`` once to
``artifacts/tuner.hlo.txt`` and the Rust coordinator executes it via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cost_models, ref

NUM_BCAST = 10
NUM_SCATTER = 3


def tune(sizes, gaps, lat, p_grid, m_grid, s_grid):
    """Full tuning pass: strategy surfaces + winner decision tensors."""
    times, segs = cost_models.tune_pallas(sizes, gaps, lat, p_grid, m_grid,
                                          s_grid)
    bcast_winner = jnp.argmin(times[:NUM_BCAST], axis=0).astype(jnp.float32)
    scatter_winner = (jnp.argmin(times[NUM_BCAST:], axis=0)
                      + NUM_BCAST).astype(jnp.float32)
    return times, segs, bcast_winner, scatter_winner


def tune_reference(sizes, gaps, lat, p_grid, m_grid, s_grid):
    """Same decision layer over the pure-jnp oracle (for tests)."""
    times, segs = ref.predict_all(sizes, gaps, lat, p_grid, m_grid, s_grid)
    bcast_winner = jnp.argmin(times[:NUM_BCAST], axis=0).astype(jnp.float32)
    scatter_winner = (jnp.argmin(times[NUM_BCAST:], axis=0)
                      + NUM_BCAST).astype(jnp.float32)
    return times, segs, bcast_winner, scatter_winner


def tune_ext(sizes, gaps, lat, p_grid, m_grid):
    """Extended-ops tuning pass: strategy times + per-family winners.

    Returns ``(times[10, Q, M], winners[4, Q, M])`` where winners rows
    are the argmin strategy index for gather, barrier, allgather and
    allreduce respectively (absolute indices into the 10-row layout).
    """
    from .kernels import ext_models

    times = ext_models.ext_pallas(sizes, gaps, lat, p_grid, m_grid)
    winners = []
    for fam in ("gather", "barrier", "allgather", "allreduce"):
        lo, hi = ext_models.FAMILIES[fam]
        winners.append(
            (jnp.argmin(times[lo:hi], axis=0) + lo).astype(jnp.float32))
    return times, jnp.stack(winners)


def example_args_ext(t=32, q=16, m=48):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((t,), f32),   # sizes
        jax.ShapeDtypeStruct((t,), f32),   # gaps
        jax.ShapeDtypeStruct((1,), f32),   # L
        jax.ShapeDtypeStruct((q,), f32),   # p_grid
        jax.ShapeDtypeStruct((m,), f32),   # m_grid
    )


def example_args(t=32, q=16, m=48, s=32):
    """ShapeDtypeStructs used by aot.py to lower the artifact."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((t,), f32),   # sizes
        jax.ShapeDtypeStruct((t,), f32),   # gaps
        jax.ShapeDtypeStruct((1,), f32),   # L
        jax.ShapeDtypeStruct((q,), f32),   # p_grid
        jax.ShapeDtypeStruct((m,), f32),   # m_grid
        jax.ShapeDtypeStruct((s,), f32),   # s_grid
    )
