"""L1 Pallas kernel #2: pLogP cost models for the extended collectives.

The paper's §3 notes that practical MPI implementations construct
Barrier, Reduce and Gather "in a very similar way" to Broadcast/Scatter;
this kernel extends the tuner to those operations (plus AllGather and
AllReduce with the classic ring / recursive-doubling alternatives of
Thakur & Gropp, the paper's ref [12]).

Strategy index layout (shared with ``rust/src/models/ext.rs``):

==  =======================  ==========================================
id  name                     model (pLogP)
==  =======================  ==========================================
0   gather/flat              (P-1) g(m) + L
1   gather/binomial          sum_j g(2^j m) + ceil(log2 P) L
2   reduce/binomial          floor(log2 P) g(m) + ceil(log2 P) L
3   barrier/tree             2 (floor(log2 P) g(1) + ceil(log2 P) L)
4   barrier/dissemination    ceil(log2 P) (g(1) + L)
5   allgather/gather+bcast   [1] + floor(log2 P) g(P m) + ceil(log2 P) L
6   allgather/ring           (P-1) (g(m) + L)
7   allgather/rec_doubling   sum_j (g(2^j m) + L)
8   allreduce/reduce+bcast   2 (floor(log2 P) g(m) + ceil(log2 P) L)
9   allreduce/rec_doubling   ceil(log2 P) (g(m) + L)
==  =======================  ==========================================

Families (for the winner argmins): gather = {0,1}, barrier = {3,4},
allgather = {5,6,7}, allreduce = {8,9}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NUM_EXT = 10
BINOMIAL_TERMS = ref.BINOMIAL_TERMS

EXT_NAMES = [
    "gather/flat",
    "gather/binomial",
    "reduce/binomial",
    "barrier/tree",
    "barrier/dissemination",
    "allgather/gather+bcast",
    "allgather/ring",
    "allgather/rec_doubling",
    "allreduce/reduce+bcast",
    "allreduce/rec_doubling",
]

# family slices for the winner argmins
FAMILIES = {
    "gather": (0, 2),
    "barrier": (3, 5),
    "allgather": (5, 8),
    "allreduce": (8, 10),
}


def _ext_kernel(sizes_ref, gaps_ref, lat_ref, p_ref, m_ref, times_ref):
    from .cost_models import _gap_interp

    sizes = sizes_ref[...]
    gaps = gaps_ref[...]
    lat = lat_ref[0]
    p = p_ref[0]
    m = m_ref[...]  # [M]

    g_m = _gap_interp(m, sizes, gaps)
    g_1 = _gap_interp(jnp.float32(1.0), sizes, gaps)
    lg = jnp.log2(p)
    fl = jnp.floor(lg + 1e-6)
    ce = jnp.ceil(lg - 1e-6)
    pm1 = p - 1.0

    # doubling sum: sum_{j=0}^{ce-1} g(2^j m)
    jj = jnp.arange(0, BINOMIAL_TERMS, dtype=jnp.float32)
    g_2jm = _gap_interp((2.0 ** jj)[:, None] * m[None, :], sizes, gaps)
    mask = (jj <= ce - 1.0).astype(jnp.float32)
    dsum = jnp.sum(mask[:, None] * g_2jm, axis=0)  # [M]

    g_pm = _gap_interp(p * m, sizes, gaps)

    ones = jnp.ones_like(m)
    times = jnp.stack([
        pm1 * g_m + lat,                                  # 0 gather flat
        dsum + ce * lat,                                  # 1 gather binomial
        fl * g_m + ce * lat,                              # 2 reduce binomial
        2.0 * (fl * g_1 + ce * lat) * ones,               # 3 barrier tree
        ce * (g_1 + lat) * ones,                          # 4 barrier diss
        dsum + ce * lat + fl * g_pm + ce * lat,           # 5 ag gather+bcast
        pm1 * (g_m + lat),                                # 6 ag ring
        dsum + ce * lat,                                  # 7 ag rec doubling
        2.0 * (fl * g_m + ce * lat),                      # 8 ar reduce+bcast
        ce * (g_m + lat),                                 # 9 ar rec doubling
    ])  # [10, M]
    times_ref[...] = times[:, None, :]


@jax.jit
def ext_pallas(sizes, gaps, lat, p_grid, m_grid):
    """Evaluate the 10 extended models on the (P, m) grid.

    Returns float32[NUM_EXT, Q, M].
    """
    q = p_grid.shape[0]
    mm = m_grid.shape[0]
    t = sizes.shape[0]
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        _ext_kernel,
        grid=(q,),
        in_specs=[
            full((t,)),
            full((t,)),
            full((1,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            full((mm,)),
        ],
        out_specs=pl.BlockSpec((NUM_EXT, 1, mm), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((NUM_EXT, q, mm), jnp.float32),
        interpret=True,
    )(sizes, gaps, lat, p_grid, m_grid)


def ext_reference(sizes, gaps, lat, p_grid, m_grid):
    """Pure-jnp oracle for :func:`ext_pallas`."""
    lat = jnp.float32(lat)
    p = jnp.asarray(p_grid, jnp.float32)[:, None]  # [Q,1]
    m = jnp.asarray(m_grid, jnp.float32)[None, :]  # [1,M]
    q, mm = p.shape[0], m.shape[1]
    g_m = ref.gap_interp(m, sizes, gaps)
    g_1 = ref.gap_interp(jnp.float32(1.0), sizes, gaps)
    fl, ce = ref.log2_floor_ceil(p)
    pm1 = p - 1.0

    jj = jnp.arange(0, BINOMIAL_TERMS, dtype=jnp.float32)
    g_2jm = ref.gap_interp((2.0 ** jj)[:, None] * m[0][None, :], sizes, gaps)
    maskq = (jj[None, :] <= ce - 1.0).astype(jnp.float32)  # [Q,B]
    dsum = jnp.einsum("qj,jm->qm", maskq, g_2jm)  # [Q,M]

    g_pm = ref.gap_interp(p * m, sizes, gaps)  # [Q,M]
    bc = lambda x: jnp.broadcast_to(x, (q, mm))

    return jnp.stack([
        bc(pm1 * g_m + lat),
        dsum + ce * lat,
        bc(fl * g_m + ce * lat),
        bc(2.0 * (fl * g_1 + ce * lat)),
        bc(ce * (g_1 + lat)),
        dsum + ce * lat + fl * g_pm + ce * lat,
        bc(pm1 * (g_m + lat)),
        dsum + ce * lat,
        bc(2.0 * (fl * g_m + ce * lat)),
        bc(ce * (g_m + lat)),
    ])
