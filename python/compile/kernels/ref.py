"""Pure-jnp oracle for the pLogP cost-model tuner kernel.

This module is the correctness reference for the Pallas kernel in
``cost_models.py``: it implements Tables 1 and 2 of Barchet-Estefanel &
Mounie (2004) directly, with no Pallas, no tiling, and no cleverness.
pytest asserts the kernel matches this module to float32 tolerance.

Strategy index layout (shared with the Rust side, see
``rust/src/tuner/layout.rs``):

==  =====================  =========================================
id  name                   model (pLogP)
==  =====================  =========================================
0   bcast/flat             (P-1) g(m) + L
1   bcast/flat_rdv         (P-1) g(m) + 2 g(1) + 3 L
2   bcast/seg_flat         (P-1) (g(s) k) + L
3   bcast/chain            (P-1) (g(m) + L)
4   bcast/chain_rdv        (P-1) (g(m) + 2 g(1) + 3 L)
5   bcast/seg_chain        (P-1) (g(s) + L) + g(s) (k-1)
6   bcast/binary           ceil(log2 P) (2 g(m) + L)
7   bcast/binomial         floor(log2 P) g(m) + ceil(log2 P) L
8   bcast/binomial_rdv     floor(log2 P) g(m) + ceil(log2 P)(2 g(1) + 3 L)
9   bcast/seg_binomial     floor(log2 P) g(s) k + ceil(log2 P) L
10  scatter/flat           (P-1) g(m) + L
11  scatter/chain          sum_{j=1}^{P-1} g(j m) + (P-1) L
12  scatter/binomial       sum_{j=0}^{ceil(log2 P)-1} g(2^j m) + ceil(log2 P) L
==  =====================  =========================================

Segmented strategies (2, 5, 9) are minimised over the segment-size grid;
a candidate segment ``s`` is clamped to ``min(s, m)`` so that ``s >= m``
degenerates exactly to the unsegmented model (k = 1, g(s) = g(m)).
"""

from __future__ import annotations

import jax.numpy as jnp

NUM_STRATEGIES = 13
BCAST_STRATEGIES = list(range(10))
SCATTER_STRATEGIES = [10, 11, 12]
SEGMENTED = (2, 5, 9)
# scatter/chain partial sums are evaluated up to this many ranks; matches
# the JMAX constant baked into the kernel and the AOT artifact metadata.
JMAX = 64
# scatter/binomial needs ceil(log2 P) terms; 10 covers P <= 1024.
BINOMIAL_TERMS = 10

STRATEGY_NAMES = [
    "bcast/flat",
    "bcast/flat_rdv",
    "bcast/seg_flat",
    "bcast/chain",
    "bcast/chain_rdv",
    "bcast/seg_chain",
    "bcast/binary",
    "bcast/binomial",
    "bcast/binomial_rdv",
    "bcast/seg_binomial",
    "scatter/flat",
    "scatter/chain",
    "scatter/binomial",
]


def gap_interp(m, sizes, gaps):
    """Piecewise-linear g(m) over the measured gap table.

    ``sizes`` must be strictly increasing. Below ``sizes[0]`` the value is
    clamped to ``gaps[0]``; above ``sizes[-1]`` the last segment's slope is
    extrapolated (the pLogP gap is asymptotically linear in m — the
    per-byte cost of a saturated link), but never below the last sample
    (a noisy table must not extrapolate the gap negative).
    """
    m = jnp.asarray(m, jnp.float32)
    sizes = jnp.asarray(sizes, jnp.float32)
    gaps = jnp.asarray(gaps, jnp.float32)
    # index of the table segment containing m: sum of (m >= sizes) - 1
    idx = jnp.sum(m[..., None] >= sizes, axis=-1) - 1
    idx = jnp.clip(idx, 0, sizes.shape[0] - 2)
    lo_s = sizes[idx]
    hi_s = sizes[idx + 1]
    lo_g = gaps[idx]
    hi_g = gaps[idx + 1]
    t = (m - lo_s) / (hi_s - lo_s)
    # clamp below the table, extrapolate above it
    t = jnp.maximum(t, 0.0)
    g = lo_g + t * (hi_g - lo_g)
    return jnp.where(t > 1.0, jnp.maximum(g, hi_g), g)


def log2_floor_ceil(p):
    """(floor(log2 P), ceil(log2 P)) as float32, exact for P in [1, 2^20]."""
    p = jnp.asarray(p, jnp.float32)
    # float log2 of an exact-integer float is bit-exact at powers of two,
    # but guard against 1-ulp noise either side before floor/ceil.
    lg = jnp.log2(p)
    fl = jnp.floor(lg + 1e-6)
    ce = jnp.ceil(lg - 1e-6)
    return fl, ce


def predict_all(sizes, gaps, lat, p_grid, m_grid, s_grid):
    """Evaluate all 13 strategy models on the (P, m) grid.

    Returns ``(times, segs)``, both float32 of shape
    ``[NUM_STRATEGIES, Q, M]``. ``segs[i]`` is the segment size chosen for
    segmented strategies (0 where the strategy does not segment).
    """
    lat = jnp.float32(lat)
    p = jnp.asarray(p_grid, jnp.float32)[:, None]  # [Q,1]
    m = jnp.asarray(m_grid, jnp.float32)[None, :]  # [1,M]
    q, mm = p.shape[0], m.shape[1]

    g_m = gap_interp(m, sizes, gaps)  # [1,M]
    g_1 = gap_interp(jnp.float32(1.0), sizes, gaps)  # scalar
    fl, ce = log2_floor_ceil(p)  # [Q,1]
    pm1 = p - 1.0
    rdv = 2.0 * g_1 + 3.0 * lat

    # --- segmented candidates: clamp s to m, k = ceil(m/s) ---------------
    s = jnp.asarray(s_grid, jnp.float32)[None, None, :]  # [1,1,S]
    m3 = m[..., None]  # [1,M,1]
    s_eff = jnp.minimum(s, m3)  # [1,M,S]
    k = jnp.ceil(m3 / s_eff)  # [1,M,S]
    g_s = gap_interp(s_eff, sizes, gaps)  # [1,M,S]

    def min_over_s(t3):
        """t3: [Q,M,S] -> (best time [Q,M], chosen seg size [Q,M])."""
        best = jnp.min(t3, axis=-1)
        arg = jnp.argmin(t3, axis=-1)
        s_flat = jnp.asarray(s_grid, jnp.float32)
        chosen = jnp.minimum(s_flat[arg], jnp.broadcast_to(m, (q, mm)))
        return best, chosen

    zeros = jnp.zeros((q, mm), jnp.float32)
    times = []
    segs = []

    # 0 flat
    times.append(jnp.broadcast_to(pm1 * g_m + lat, (q, mm)))
    segs.append(zeros)
    # 1 flat rendezvous
    times.append(jnp.broadcast_to(pm1 * g_m + rdv, (q, mm)))
    segs.append(zeros)
    # 2 segmented flat
    t, sv = min_over_s(pm1[:, :, None] * (g_s * k) + lat)
    times.append(t)
    segs.append(sv)
    # 3 chain
    times.append(jnp.broadcast_to(pm1 * (g_m + lat), (q, mm)))
    segs.append(zeros)
    # 4 chain rendezvous
    times.append(jnp.broadcast_to(pm1 * (g_m + rdv), (q, mm)))
    segs.append(zeros)
    # 5 segmented chain (pipeline)
    t, sv = min_over_s(pm1[:, :, None] * (g_s + lat) + g_s * (k - 1.0))
    times.append(t)
    segs.append(sv)
    # 6 binary tree (upper bound)
    times.append(jnp.broadcast_to(ce * (2.0 * g_m + lat), (q, mm)))
    segs.append(zeros)
    # 7 binomial tree
    times.append(jnp.broadcast_to(fl * g_m + ce * lat, (q, mm)))
    segs.append(zeros)
    # 8 binomial rendezvous
    times.append(jnp.broadcast_to(fl * g_m + ce * rdv, (q, mm)))
    segs.append(zeros)
    # 9 segmented binomial
    t, sv = min_over_s(fl[:, :, None] * g_s * k + ce[:, :, None] * lat)
    times.append(t)
    segs.append(sv)

    # 10 scatter flat
    times.append(jnp.broadcast_to(pm1 * g_m + lat, (q, mm)))
    segs.append(zeros)
    # 11 scatter chain: sum_{j=1}^{P-1} g(j m) + (P-1) L
    j = jnp.arange(1, JMAX, dtype=jnp.float32)  # [J]
    g_jm = gap_interp(j[:, None] * m[0][None, :], sizes, gaps)  # [J,M]
    maskqj = (j[None, :] <= pm1).astype(jnp.float32)  # [Q,J]
    chain_sum = jnp.einsum("qj,jm->qm", maskqj, g_jm)
    times.append(chain_sum + pm1 * lat)
    segs.append(zeros)
    # 12 scatter binomial: sum_{j=0}^{ceil(log2 P)-1} g(2^j m) + ceil log2 P L
    jj = jnp.arange(0, BINOMIAL_TERMS, dtype=jnp.float32)
    g_2jm = gap_interp((2.0**jj)[:, None] * m[0][None, :], sizes, gaps)  # [B,M]
    maskq = (jj[None, :] <= ce - 1.0).astype(jnp.float32)  # [Q,B]
    bin_sum = jnp.einsum("qj,jm->qm", maskq, g_2jm)
    times.append(bin_sum + ce * lat)
    segs.append(zeros)

    return jnp.stack(times), jnp.stack(segs)
