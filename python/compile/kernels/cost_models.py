"""L1 Pallas kernel: pLogP cost-surface evaluation for all 13 strategies.

The paper's "fast tuning" contribution is replacing empirical benchmark
sweeps with closed-form pLogP model evaluation. This kernel is that hot
spot: one fused pass evaluates every strategy of Tables 1 and 2 on the
whole (P-grid x m-grid) plane, folding the segment-size search (min over
the s-grid) into the kernel so only small decision tensors leave the
device.

Layout / tiling
---------------
The launch grid is one program per P value (the Q axis): each program
holds the full gap table (tiny: <= 64 entries), the full m-grid row and
the full s-grid in VMEM and computes a [13, 1, M] tile of the output.
The (M, S) plane is the vector workload; the s-axis reduction (min /
argmin for segmented strategies) happens in-register before writeback.
On a real TPU the same BlockSpec tiles the (M, S) plane onto (8, 128)
VMEM lanes; the kernel is VPU-bound (no MXU), so the roofline is VMEM
bandwidth — see DESIGN.md section "Hardware-Adaptation".

interpret=True is mandatory here: the artifact must run on the CPU PJRT
client inside the Rust coordinator, and Mosaic custom-calls do not.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NUM_STRATEGIES = ref.NUM_STRATEGIES
JMAX = ref.JMAX
BINOMIAL_TERMS = ref.BINOMIAL_TERMS


def _gap_interp(m, sizes, gaps):
    """In-kernel piecewise-linear g(m); mirrors ref.gap_interp exactly."""
    idx = jnp.sum(m[..., None] >= sizes, axis=-1) - 1
    idx = jnp.clip(idx, 0, sizes.shape[0] - 2)
    lo_s = jnp.take(sizes, idx)
    hi_s = jnp.take(sizes, idx + 1)
    lo_g = jnp.take(gaps, idx)
    hi_g = jnp.take(gaps, idx + 1)
    t = jnp.maximum((m - lo_s) / (hi_s - lo_s), 0.0)
    g = lo_g + t * (hi_g - lo_g)
    # above-table extrapolation never goes below the last sample
    return jnp.where(t > 1.0, jnp.maximum(g, hi_g), g)


def _tune_kernel(sizes_ref, gaps_ref, lat_ref, p_ref, m_ref, s_ref,
                 times_ref, segs_ref):
    """One program = one P value; computes a [13, 1, M] output tile."""
    sizes = sizes_ref[...]
    gaps = gaps_ref[...]
    lat = lat_ref[0]
    p = p_ref[0]
    m = m_ref[...]  # [M]
    s = s_ref[...]  # [S]

    g_m = _gap_interp(m, sizes, gaps)  # [M]
    g_1 = _gap_interp(jnp.float32(1.0), sizes, gaps)
    lg = jnp.log2(p)
    fl = jnp.floor(lg + 1e-6)
    ce = jnp.ceil(lg - 1e-6)
    pm1 = p - 1.0
    rdv = 2.0 * g_1 + 3.0 * lat

    # segmented plane: [M, S]
    s_eff = jnp.minimum(s[None, :], m[:, None])
    k = jnp.ceil(m[:, None] / s_eff)
    g_s = _gap_interp(s_eff, sizes, gaps)

    def min_over_s(t2):
        """[M, S] -> (best [M], chosen segment size [M])."""
        best = jnp.min(t2, axis=-1)
        arg = jnp.argmin(t2, axis=-1)
        chosen = jnp.minimum(jnp.take(s, arg), m)
        return best, chosen

    zero = jnp.zeros_like(m)

    # Broadcast, Table 1.
    t_flat = pm1 * g_m + lat
    t_flat_rdv = pm1 * g_m + rdv
    t_segflat, s_segflat = min_over_s(pm1 * (g_s * k) + lat)
    t_chain = pm1 * (g_m + lat)
    t_chain_rdv = pm1 * (g_m + rdv)
    t_segchain, s_segchain = min_over_s(pm1 * (g_s + lat) + g_s * (k - 1.0))
    t_binary = ce * (2.0 * g_m + lat)
    t_binom = fl * g_m + ce * lat
    t_binom_rdv = fl * g_m + ce * rdv
    t_segbinom, s_segbinom = min_over_s(fl * g_s * k + ce * lat)

    # Scatter, Table 2.
    t_sc_flat = pm1 * g_m + lat
    j = jnp.arange(1, JMAX, dtype=jnp.float32)  # [J]
    g_jm = _gap_interp(j[:, None] * m[None, :], sizes, gaps)  # [J, M]
    mask = (j <= pm1).astype(jnp.float32)  # [J]
    t_sc_chain = jnp.sum(mask[:, None] * g_jm, axis=0) + pm1 * lat
    jj = jnp.arange(0, BINOMIAL_TERMS, dtype=jnp.float32)
    g_2jm = _gap_interp((2.0 ** jj)[:, None] * m[None, :], sizes, gaps)
    maskb = (jj <= ce - 1.0).astype(jnp.float32)
    t_sc_binom = jnp.sum(maskb[:, None] * g_2jm, axis=0) + ce * lat

    times = jnp.stack([
        t_flat, t_flat_rdv, t_segflat, t_chain, t_chain_rdv, t_segchain,
        t_binary, t_binom, t_binom_rdv, t_segbinom,
        t_sc_flat, t_sc_chain, t_sc_binom,
    ])  # [13, M]
    segs = jnp.stack([
        zero, zero, s_segflat, zero, zero, s_segchain,
        zero, zero, zero, s_segbinom,
        zero, zero, zero,
    ])
    times_ref[...] = times[:, None, :]
    segs_ref[...] = segs[:, None, :]


@functools.partial(jax.jit, static_argnames=())
def tune_pallas(sizes, gaps, lat, p_grid, m_grid, s_grid):
    """Evaluate all strategy models; see ref.predict_all for semantics.

    Args:
      sizes, gaps: float32[T] measured gap table (sizes strictly increasing).
      lat: float32[1] pLogP latency L.
      p_grid: float32[Q] process counts to tune for.
      m_grid: float32[M] message sizes (bytes).
      s_grid: float32[S] candidate segment sizes (bytes).

    Returns:
      (times, segs): float32[13, Q, M] each.
    """
    q = p_grid.shape[0]
    mm = m_grid.shape[0]
    t = sizes.shape[0]
    s = s_grid.shape[0]
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    out_shape = (
        jax.ShapeDtypeStruct((NUM_STRATEGIES, q, mm), jnp.float32),
        jax.ShapeDtypeStruct((NUM_STRATEGIES, q, mm), jnp.float32),
    )
    return pl.pallas_call(
        _tune_kernel,
        grid=(q,),
        in_specs=[
            full((t,)),
            full((t,)),
            full((1,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            full((mm,)),
            full((s,)),
        ],
        out_specs=(
            pl.BlockSpec((NUM_STRATEGIES, 1, mm), lambda i: (0, i, 0)),
            pl.BlockSpec((NUM_STRATEGIES, 1, mm), lambda i: (0, i, 0)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(sizes, gaps, lat, p_grid, m_grid, s_grid)
