"""AOT-lower the L2 tuner graph to HLO text for the Rust coordinator.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Usage::

    cd python && python -m compile.aot --out ../artifacts/tuner.hlo.txt

Writes the HLO text plus a JSON metadata sidecar (``tuner.meta.json``)
recording the baked tensor shapes and the strategy index layout, which the
Rust side reads to pad its inputs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(t: int, q: int, m: int, s: int) -> str:
    lowered = jax.jit(model.tune).lower(*model.example_args(t, q, m, s))
    return to_hlo_text(lowered)


def build_ext(t: int, q: int, m: int) -> str:
    lowered = jax.jit(model.tune_ext).lower(*model.example_args_ext(t, q, m))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/tuner.hlo.txt")
    ap.add_argument("--table", type=int, default=32,
                    help="gap-table entries (T)")
    ap.add_argument("--pgrid", type=int, default=16,
                    help="process-count grid points (Q)")
    ap.add_argument("--mgrid", type=int, default=48,
                    help="message-size grid points (M)")
    ap.add_argument("--sgrid", type=int, default=32,
                    help="segment-size grid points (S)")
    args = ap.parse_args()

    text = build(args.table, args.pgrid, args.mgrid, args.sgrid)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)

    meta = {
        "table_len": args.table,
        "p_grid_len": args.pgrid,
        "m_grid_len": args.mgrid,
        "s_grid_len": args.sgrid,
        "num_strategies": ref.NUM_STRATEGIES,
        "num_bcast": model.NUM_BCAST,
        "num_scatter": model.NUM_SCATTER,
        "jmax": ref.JMAX,
        "binomial_terms": ref.BINOMIAL_TERMS,
        "strategy_names": ref.STRATEGY_NAMES,
        "outputs": ["times[13,Q,M]", "segs[13,Q,M]",
                    "bcast_winner[Q,M]", "scatter_winner[Q,M]"],
    }
    meta_path = os.path.splitext(args.out)[0]
    meta_path = meta_path[:-len(".hlo")] if meta_path.endswith(".hlo") else meta_path
    meta_path += ".meta.json"
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {len(text)} chars to {args.out} (+ {meta_path})")

    # Second artifact: the extended-collectives tuner (gather / barrier /
    # allgather / allreduce), same gap table and grids, no segment axis.
    from .kernels import ext_models

    ext_out = os.path.join(os.path.dirname(os.path.abspath(args.out)),
                           "tuner_ext.hlo.txt")
    ext_text = build_ext(args.table, args.pgrid, args.mgrid)
    with open(ext_out, "w") as f:
        f.write(ext_text)
    ext_meta = {
        "table_len": args.table,
        "p_grid_len": args.pgrid,
        "m_grid_len": args.mgrid,
        "num_strategies": ext_models.NUM_EXT,
        "strategy_names": ext_models.EXT_NAMES,
        "families": {k: list(v) for k, v in ext_models.FAMILIES.items()},
        "outputs": ["times[10,Q,M]", "winners[4,Q,M]"],
    }
    with open(os.path.join(os.path.dirname(ext_out), "tuner_ext.meta.json"),
              "w") as f:
        json.dump(ext_meta, f, indent=2)
    print(f"wrote {len(ext_text)} chars to {ext_out}")


if __name__ == "__main__":
    main()
