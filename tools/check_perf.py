#!/usr/bin/env python3
"""Perf regression gate for the committed BENCH_*.json baselines.

Compares a freshly-run bench JSON against the committed baseline,
result by result (matched on ``name``), and fails when any fresh
``mean_s`` exceeds the baseline's by more than ``--tolerance``
(default 25%).

The benches write ``BENCH_*.candidate.json`` next to the committed
baseline by default (pass ``-- --write-baseline`` to a bench to
overwrite the committed file deliberately), so the gate compares the
two in place with no stashing:

    cargo bench --bench tuner_sweep
    tools/check_perf.py BENCH_tuner.json BENCH_tuner.candidate.json

Besides the wall-time ``results``, a bench may emit a ``metrics`` list
of deterministic counters (eval counts, reduction factors, hit rates),
each entry ``{"name", "value", "larger_is_better"}``. Those are gated
direction-aware with their own much tighter ``--metrics-tolerance``
(default 5%): a smaller-is-better metric fails when the fresh value
exceeds baseline*(1+tol), a larger-is-better metric fails when it
drops below baseline*(1-tol). Counters are exact, so regressions there
are sharp signals rather than machine noise — and a baselined metric
that disappears from the fresh run fails the gate outright (dropping
the emission must not silently disable it).

Baseline entries whose ``mean_s`` (or metric ``value``) is null (the
original "pending" placeholders) are skipped with a note; the gate
fails outright if *nothing* was comparable, so an accidentally emptied
baseline cannot silently disable the gate.
"""

import argparse
import json
import sys


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("fresh", help="freshly-run BENCH_*.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed relative regression (0.25 = fail at >25%% over baseline)",
    )
    ap.add_argument(
        "--metrics-tolerance",
        type=float,
        default=0.05,
        help="allowed relative regression for deterministic 'metrics' entries "
        "(counters are exact, so this is much tighter than the wall-time "
        "tolerance; default 5%%)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    fresh = load(args.fresh)
    name = fresh.get("benchmark", args.fresh)
    base_by_name = {r.get("name"): r for r in base.get("results", [])}

    failures = []
    compared = 0
    compared_results = 0
    print(f"== perf gate: {name} (tolerance {args.tolerance:.0%}) ==")
    for r in fresh.get("results", []):
        rname = r.get("name")
        b = base_by_name.get(rname)
        if b is None:
            print(f"  {rname}: NEW (no baseline entry, not gated)")
            continue
        b_mean = b.get("mean_s")
        f_mean = r.get("mean_s")
        if b_mean is None:
            print(f"  {rname}: baseline pending, not gated")
            continue
        if f_mean is None:
            failures.append(f"{rname}: fresh run produced no mean_s")
            continue
        compared += 1
        compared_results += 1
        limit = b_mean * (1.0 + args.tolerance)
        ratio = f_mean / b_mean if b_mean > 0 else float("inf")
        verdict = "ok" if f_mean <= limit else "REGRESSION"
        print(
            f"  {rname}: fresh {f_mean:.6g}s vs baseline {b_mean:.6g}s "
            f"({ratio:.2f}x, limit {limit:.6g}s) -> {verdict}"
        )
        if f_mean > limit:
            failures.append(
                f"{rname}: {f_mean:.6g}s exceeds baseline {b_mean:.6g}s "
                f"by more than {args.tolerance:.0%}"
            )

    base_metrics = {m.get("name"): m for m in base.get("metrics", [])}
    seen_metrics = set()
    for m in fresh.get("metrics", []):
        mname = m.get("name")
        seen_metrics.add(mname)
        b = base_metrics.get(mname)
        if b is None:
            print(f"  {mname}: NEW metric (no baseline entry, not gated)")
            continue
        b_val = b.get("value")
        f_val = m.get("value")
        if b_val is None:
            print(f"  {mname}: baseline pending, not gated")
            continue
        if f_val is None:
            failures.append(f"{mname}: fresh run produced no value")
            continue
        compared += 1
        larger_is_better = bool(b.get("larger_is_better", m.get("larger_is_better", False)))
        if larger_is_better:
            limit = b_val * (1.0 - args.metrics_tolerance)
            ok = f_val >= limit
            direction = "floor"
        else:
            limit = b_val * (1.0 + args.metrics_tolerance)
            ok = f_val <= limit
            direction = "ceiling"
        ratio = f_val / b_val if b_val else float("inf")
        print(
            f"  {mname}: fresh {f_val:.6g} vs baseline {b_val:.6g} "
            f"({ratio:.2f}x, {direction} {limit:.6g}) -> "
            f"{'ok' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append(
                f"{mname}: {f_val:.6g} breaks the baseline {direction} {limit:.6g}"
            )
    # a baselined (non-pending) metric the fresh run stopped emitting is a
    # gate-disabling change, not a pass
    for mname, b in base_metrics.items():
        if b.get("value") is not None and mname not in seen_metrics:
            failures.append(f"{mname}: baselined metric missing from the fresh run")

    # metrics passing must not mask a disabled wall-time gate: if the
    # baseline defines any non-pending wall-time result, at least one
    # must have been compared
    baseline_gates_walltime = any(
        r.get("mean_s") is not None for r in base.get("results", [])
    )
    if baseline_gates_walltime and compared_results == 0:
        failures.append(
            "no comparable wall-time results despite a non-pending baseline: "
            "the wall-time gate is silently disabled"
        )
    if compared == 0:
        failures.append("no comparable results: the baseline gates nothing")
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)
    print(f"perf gate passed ({compared} result(s) within tolerance)")


if __name__ == "__main__":
    main()
