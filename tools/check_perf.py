#!/usr/bin/env python3
"""Perf regression gate for the committed BENCH_*.json baselines.

Compares a freshly-run bench JSON against the committed baseline,
result by result (matched on ``name``), and fails when any fresh
``mean_s`` exceeds the baseline's by more than ``--tolerance``
(default 25%).

The benches overwrite their JSON in place, so CI stashes the committed
file first:

    cp BENCH_tuner.json /tmp/baseline.json
    cargo bench --bench tuner_sweep
    tools/check_perf.py /tmp/baseline.json BENCH_tuner.json

Baseline entries whose ``mean_s`` is null (the original "pending"
placeholders) are skipped with a note; the gate fails outright if
*nothing* was comparable, so an accidentally emptied baseline cannot
silently disable the gate.
"""

import argparse
import json
import sys


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("fresh", help="freshly-run BENCH_*.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed relative regression (0.25 = fail at >25%% over baseline)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    fresh = load(args.fresh)
    name = fresh.get("benchmark", args.fresh)
    base_by_name = {r.get("name"): r for r in base.get("results", [])}

    failures = []
    compared = 0
    print(f"== perf gate: {name} (tolerance {args.tolerance:.0%}) ==")
    for r in fresh.get("results", []):
        rname = r.get("name")
        b = base_by_name.get(rname)
        if b is None:
            print(f"  {rname}: NEW (no baseline entry, not gated)")
            continue
        b_mean = b.get("mean_s")
        f_mean = r.get("mean_s")
        if b_mean is None:
            print(f"  {rname}: baseline pending, not gated")
            continue
        if f_mean is None:
            failures.append(f"{rname}: fresh run produced no mean_s")
            continue
        compared += 1
        limit = b_mean * (1.0 + args.tolerance)
        ratio = f_mean / b_mean if b_mean > 0 else float("inf")
        verdict = "ok" if f_mean <= limit else "REGRESSION"
        print(
            f"  {rname}: fresh {f_mean:.6g}s vs baseline {b_mean:.6g}s "
            f"({ratio:.2f}x, limit {limit:.6g}s) -> {verdict}"
        )
        if f_mean > limit:
            failures.append(
                f"{rname}: {f_mean:.6g}s exceeds baseline {b_mean:.6g}s "
                f"by more than {args.tolerance:.0%}"
            )

    if compared == 0:
        failures.append("no comparable results: the baseline gates nothing")
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)
    print(f"perf gate passed ({compared} result(s) within tolerance)")


if __name__ == "__main__":
    main()
