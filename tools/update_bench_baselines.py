#!/usr/bin/env python3
"""Adopt a green CI run's measured BENCH_*.json as the committed baselines.

The ``perf`` CI job uploads the freshly-measured ``bench-json`` artifact
on every run. This script turns "replace the authored ceilings with CI
numbers" (a ROADMAP item) into one command:

    gh run download <run-id> --name bench-json --dir /tmp/bench-json
    python3 tools/update_bench_baselines.py /tmp/bench-json
    git add BENCH_*.json && git commit

For every ``BENCH_*.json`` in the artifact directory it rewrites the
matching committed file, taking the measured ``results`` (wall times)
and ``metrics`` (deterministic counters) from the CI run while keeping
the committed file's ``benchmark``/``description``/``unit`` prose, and
stamps ``status`` with the provenance. A measured wall time may only
*tighten* a committed ceiling unless ``--allow-looser`` is passed — a
slow runner must not quietly widen the gate.
"""

import argparse
import glob
import json
import os
import sys


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def adopt(committed_path, fresh_path, allow_looser):
    committed = load(committed_path)
    fresh = load(fresh_path)
    out = dict(fresh)
    for key in ("benchmark", "description", "unit"):
        if key in committed:
            out[key] = committed[key]

    loosened = []
    by_name = {r.get("name"): r for r in committed.get("results", [])}
    for r in out.get("results", []):
        b = by_name.get(r.get("name"))
        if b and b.get("mean_s") is not None and r.get("mean_s") is not None:
            if r["mean_s"] > b["mean_s"]:
                loosened.append(
                    f"{r['name']}: measured {r['mean_s']:.6g}s > committed "
                    f"ceiling {b['mean_s']:.6g}s"
                )
    # the deterministic metrics gate the same way, direction-aware: a
    # larger-is-better floor must not ratchet down, a smaller-is-better
    # ceiling must not ratchet up, and a numeric baseline must never be
    # replaced by null (check_perf treats null as "pending, not gated")
    metrics_by_name = {m.get("name"): m for m in committed.get("metrics", [])}
    for m in out.get("metrics", []):
        b = metrics_by_name.get(m.get("name"))
        if b is None or b.get("value") is None:
            continue
        if m.get("value") is None:
            loosened.append(
                f"{m['name']}: measured value is null but the committed "
                f"baseline is {b['value']:.6g} (adoption would disable the gate)"
            )
        elif bool(b.get("larger_is_better")) and m["value"] < b["value"]:
            loosened.append(
                f"{m['name']}: measured {m['value']:.6g} < committed "
                f"floor {b['value']:.6g}"
            )
        elif not b.get("larger_is_better") and m["value"] > b["value"]:
            loosened.append(
                f"{m['name']}: measured {m['value']:.6g} > committed "
                f"ceiling {b['value']:.6g}"
            )
    if loosened and not allow_looser:
        for line in loosened:
            print(f"REFUSED: {line}", file=sys.stderr)
        return None

    out["status"] = (
        "CI-measured baselines adopted via tools/update_bench_baselines.py "
        f"from {os.path.basename(fresh_path)}; the bench overwrites this "
        "file in place on every run — re-adopt newer green-run artifacts "
        "to keep tightening the gate"
    )
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact_dir", help="directory holding a CI run's BENCH_*.json")
    ap.add_argument(
        "--repo-root",
        default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        help="repository root holding the committed baselines",
    )
    ap.add_argument(
        "--allow-looser",
        action="store_true",
        help="accept measured wall times above the committed ceilings",
    )
    args = ap.parse_args()

    fresh_files = sorted(glob.glob(os.path.join(args.artifact_dir, "BENCH_*.json")))
    if not fresh_files:
        print(f"no BENCH_*.json under {args.artifact_dir}", file=sys.stderr)
        sys.exit(2)
    # two-phase (check everything, then write everything) so one refused
    # file never leaves the baselines partially adopted
    pending = []
    refused = False
    for fresh in fresh_files:
        # benches emit BENCH_x.candidate.json by default; it adopts onto
        # the committed BENCH_x.json
        basename = os.path.basename(fresh).replace(".candidate.json", ".json")
        committed = os.path.join(args.repo_root, basename)
        if not os.path.exists(committed):
            print(f"skipping {fresh}: no committed counterpart", file=sys.stderr)
            continue
        out = adopt(committed, fresh, args.allow_looser)
        if out is None:
            refused = True
        else:
            pending.append((committed, fresh, out))
    if refused:
        print(
            "measured numbers are looser than the committed baselines; "
            "nothing was written — re-run with --allow-looser to adopt anyway",
            file=sys.stderr,
        )
        sys.exit(1)
    for committed, fresh, out in pending:
        with open(committed, "w", encoding="utf-8") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"adopted {fresh} -> {committed}")
    sys.exit(0)


if __name__ == "__main__":
    main()
