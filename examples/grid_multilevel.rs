//! Multi-level collectives over islands of clusters — the system the
//! paper's intra-cluster tuning plugs into (§1, §5). Two Fast-Ethernet
//! clusters joined by a WAN: tune each cluster separately, compose a
//! MagPIe-style two-level broadcast, and compare with naive single-level
//! strategies that ignore the topology.
//!
//! ```bash
//! cargo run --release --example grid_multilevel
//! ```

use collective_tuner::collectives::{multilevel, Strategy};
use collective_tuner::harness::experiments::measure_net;
use collective_tuner::models;
use collective_tuner::mpi::World;
use collective_tuner::netsim::NetConfig;
use collective_tuner::topology::{ClusterSpec, GridSpec};
use collective_tuner::tuner::grids;
use collective_tuner::util::table::{fmt_bytes, fmt_time, Table};

fn main() {
    // A small grid: 12 + 8 nodes, 100 Mb/s inside clusters, a 4 MB/s /
    // 5 ms WAN between them.
    let grid = GridSpec::new(
        vec![
            ClusterSpec::new("alpha", 12, NetConfig::fast_ethernet_icluster1()),
            ClusterSpec::new("beta", 8, NetConfig::fast_ethernet_icluster1()),
        ],
        NetConfig::wan_link(),
    );
    println!(
        "grid: {} nodes in {} clusters, WAN {} MB/s / {:.1} ms\n",
        grid.total_nodes(),
        grid.clusters.len(),
        grid.wan.bandwidth_bps / 1e6,
        grid.wan.prop_delay * 1e3
    );

    // Tune each cluster's broadcast strategy from its own pLogP
    // parameters (intra-cluster tuning is exactly the paper's point).
    let net = measure_net(&grid.clusters[0].net);
    let s_grid = grids::default_s_grid();
    let m = 256 * 1024u64;
    let intra: Vec<(Strategy, Option<u64>)> = grid
        .clusters
        .iter()
        .map(|c| {
            let ranked =
                models::rank_strategies(&Strategy::BCAST, &net, c.nodes, m, &s_grid);
            let (s, _, seg) = ranked[0];
            println!(
                "cluster {:<6} (P={:>2}): tuned intra strategy {} (segment {:?})",
                c.name, c.nodes, s.name(), seg
            );
            (s, seg)
        })
        .collect();

    // Compose and run the two-level broadcast.
    let mut table = Table::new(vec!["broadcast", "completion", "WAN crossings"]);
    let ml = multilevel::bcast(&grid, m, &intra);
    let mut world = World::new(grid.build_sim());
    let rep = world.run(&ml);
    assert!(rep.verify(&ml).is_empty());
    let wan_crossings = ml
        .ranks
        .iter()
        .enumerate()
        .flat_map(|(r, rs)| rs.sends.iter().map(move |s| (r, s.to)))
        .filter(|&(a, b)| grid.cluster_of(a as u32) != grid.cluster_of(b))
        .count();
    table.row(vec![
        "two-level (tuned intra + binomial inter)".to_string(),
        fmt_time(rep.completion.as_secs()),
        wan_crossings.to_string(),
    ]);

    // Naive single-level alternatives that ignore the topology.
    for strat in [Strategy::BcastFlat, Strategy::BcastBinomial, Strategy::BcastSegChain] {
        let seg = strat
            .is_segmented()
            .then(|| models::best_segment(strat, &net, grid.total_nodes(), m, &s_grid).1);
        let sched = strat.build(grid.total_nodes(), 0, m, seg);
        let mut w = World::new(grid.build_sim());
        let r = w.run(&sched);
        let crossings = sched
            .ranks
            .iter()
            .enumerate()
            .flat_map(|(rk, rs)| rs.sends.iter().map(move |s| (rk, s.to)))
            .filter(|&(a, b)| grid.cluster_of(a as u32) != grid.cluster_of(b))
            .count();
        table.row(vec![
            format!("single-level {}", strat.name()),
            fmt_time(r.completion.as_secs()),
            crossings.to_string(),
        ]);
    }
    println!("\nbroadcast of {} to all {} nodes:", fmt_bytes(m as f64), grid.total_nodes());
    println!("{}", table.to_ascii());

    // Multi-level barrier for good measure.
    let bar = multilevel::barrier(&grid);
    let mut w = World::new(grid.build_sim());
    let r = w.run(&bar);
    assert!(r.verify(&bar).is_empty());
    println!("two-level barrier: {}", fmt_time(r.completion.as_secs()));
}
