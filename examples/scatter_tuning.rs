//! Scatter tuning walkthrough — the paper's §4.2 study: Flat vs
//! Binomial Scatter, where the flat tree's "bulk transmission" beats its
//! own model, and where the binomial tree wins anyway.
//!
//! ```bash
//! cargo run --release --example scatter_tuning
//! ```

use collective_tuner::collectives::Strategy;
use collective_tuner::eval::SimEval;
use collective_tuner::models;
use collective_tuner::netsim::NetConfig;
use collective_tuner::util::table::{fmt_bytes, fmt_time, Table};

fn main() {
    let cfg = NetConfig::fast_ethernet_icluster1();
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    println!("network: {}\n", net.summary());

    // Flat vs Binomial across P at a fixed chunk size (paper Fig 3b/4).
    let m = 32 * 1024u64;
    let mut table = Table::new(vec![
        "P", "flat meas", "flat pred", "binom meas", "binom pred", "winner",
    ]);
    for &p in &[2usize, 4, 8, 12, 16, 24, 32, 40, 48] {
        let fm = eval.measure(Strategy::ScatterFlat, p, m, None);
        let fp = models::predict(Strategy::ScatterFlat, &net, p, m, None);
        let bm = eval.measure(Strategy::ScatterBinomial, p, m, None);
        let bp = models::predict(Strategy::ScatterBinomial, &net, p, m, None);
        table.row(vec![
            p.to_string(),
            fmt_time(fm),
            fmt_time(fp),
            fmt_time(bm),
            fmt_time(bp),
            if bm < fm { "binomial" } else { "flat" }.to_string(),
        ]);
    }
    println!("{}", table.to_ascii());

    // The §4.2 anomaly: the flat root streams its sends, so the measured
    // flat scatter beats the model fed by per-message pLogP parameters.
    println!("bulk-transmission effect at P=24 (measured / predicted):");
    for &m in &[1024u64, 8192, 65536] {
        let fm = eval.measure(Strategy::ScatterFlat, 24, m, None);
        let fp = models::predict(Strategy::ScatterFlat, &net, 24, m, None);
        let bm = eval.measure(Strategy::ScatterBinomial, 24, m, None);
        let bp = models::predict(Strategy::ScatterBinomial, &net, 24, m, None);
        println!(
            "  m={:>8}: flat {:.2} (streams!)   binomial {:.2} (follows model)",
            fmt_bytes(m as f64),
            fm / fp,
            bm / bp
        );
    }

    // And with the TCP behaviours disabled, both follow their models.
    let eval_i = SimEval::new(NetConfig::fast_ethernet_ideal());
    let net_i = eval_i.measure_net();
    println!("\nsame ratios on the ideal (no-TCP-anomaly) network:");
    for &m in &[1024u64, 8192, 65536] {
        let fm = eval_i.measure(Strategy::ScatterFlat, 24, m, None);
        let fp = models::predict(Strategy::ScatterFlat, &net_i, 24, m, None);
        println!("  m={:>8}: flat {:.3}", fmt_bytes(m as f64), fm / fp);
    }
}
