//! END-TO-END DRIVER — the full system on the paper's workload.
//!
//! Proves all layers compose on one real run:
//!
//!   1. simulate the ID/HP icluster-1 (50 nodes, switched Fast Ethernet,
//!      Linux-2.2 TCP behaviours);
//!   2. measure its pLogP parameters with the LogP-benchmark procedure
//!      (L3 `plogp::bench` over the L3 `netsim`);
//!   3. tune broadcast + scatter with ONE execution of the AOT-compiled
//!      XLA tuner (L1 Pallas kernel inside the L2 jax graph, loaded via
//!      PJRT by the L3 `runtime`) — falling back to native models if the
//!      artifact is missing;
//!   4. validate every decision against exhaustive empirical search over
//!      all 13 strategies on the simulated cluster;
//!   5. regenerate the paper's figures and write CSVs to `results/`.
//!
//! The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_icluster
//! ```

use std::time::Instant;

use collective_tuner::collectives::Strategy;
use collective_tuner::harness::experiments;
use collective_tuner::netsim::{NetConfig, Netsim};
use collective_tuner::plogp;
use collective_tuner::runtime::TunerArtifact;
use collective_tuner::tuner::validate::{validate_selection, ValidateOptions};
use collective_tuner::tuner::{grids, Op, Tuner};
use collective_tuner::util::table::{fmt_bytes, fmt_time, Table};

fn main() -> anyhow::Result<()> {
    println!("================================================================");
    println!(" e2e: Fast Tuning of Intra-Cluster Collective Communications");
    println!(" testbed: simulated ID/HP icluster-1 (50x P3/850, 100 Mb/s)");
    println!("================================================================\n");

    // ---- 1+2. cluster + pLogP measurement -----------------------------
    let cfg = NetConfig::fast_ethernet_icluster1();
    let t0 = Instant::now();
    let mut probe = Netsim::new(2, cfg.clone());
    let net = plogp::bench::measure(&mut probe);
    let t_measure = t0.elapsed();
    println!("[1] pLogP measured in {:?}: {}", t_measure, net.summary());

    // ---- 3. fast tuning through the XLA artifact -----------------------
    let tuner = Tuner::auto(&TunerArtifact::default_dir());
    println!("[2] tuner backend: {} ({} sweep worker(s))", tuner.backend_name(), tuner.jobs);
    let p_grid = grids::default_p_grid();
    let m_grid = grids::default_m_grid();
    let t1 = Instant::now();
    let (bcast_table, scatter_table) = tuner.tune(&net, &p_grid, &m_grid)?;
    let t_tune = t1.elapsed();
    println!(
        "[3] tuned {} (P, m) points x 13 strategies x 32 segment sizes in {:?}",
        p_grid.len() * m_grid.len(),
        t_tune
    );
    for table in [&bcast_table, &scatter_table] {
        print!("    {} winners:", table.op.name());
        for (s, frac) in table.share() {
            print!(" {} {:.0}%", s.name(), frac * 100.0);
        }
        println!();
    }

    // ---- 4. validation against exhaustive empirical search -------------
    println!("\n[4] validating selection against exhaustive empirical search");
    let opts = ValidateOptions::default();
    let p_list = [4usize, 8, 16, 24, 32, 48];
    let m_list = [256u64, 4096, 65536, 1 << 18, 1 << 20];
    let mut summary = Table::new(vec![
        "op", "grid", "selection accuracy", "accuracy where >10% margin",
        "mean |pred-meas|/meas", "max regret",
    ]);
    let mut all_meaningful_ok = true;
    for (op, family) in
        [(Op::Bcast, &Strategy::BCAST[..]), (Op::Scatter, &Strategy::SCATTER[..])]
    {
        let t2 = Instant::now();
        let rep = validate_selection(&cfg, &net, family, &p_list, &m_list, &opts);
        println!(
            "    {}: {} strategies x {} points empirically in {:?}",
            op.name(),
            family.len(),
            rep.points,
            t2.elapsed()
        );
        summary.row(vec![
            op.name().to_string(),
            format!("{}x{}", p_list.len(), m_list.len()),
            format!("{:.0}%", rep.accuracy() * 100.0),
            format!("{:.0}%", rep.meaningful_accuracy() * 100.0),
            format!("{:.1}%", rep.mean_rel_err * 100.0),
            format!("{:.1}%", rep.max_regret * 100.0),
        ]);
        all_meaningful_ok &= rep.meaningful_accuracy() >= 0.9;
    }
    println!("{}", summary.to_ascii());

    // ---- headline sanity: the paper's two conclusions ------------------
    let d_big = bcast_table.lookup(48, 1 << 20);
    println!(
        "broadcast @ (P=48, m=1MB): {} seg {:?} — paper: Segmented Chain wins",
        d_big.strategy.name(),
        d_big.segment.map(|s| fmt_bytes(s as f64))
    );
    let d_sc = scatter_table.lookup(32, 32 * 1024);
    println!(
        "scatter   @ (P=32, m=32kB): {} — paper: Binomial can beat Flat\n",
        d_sc.strategy.name()
    );

    // ---- 5. regenerate the paper's figures -----------------------------
    println!("[5] regenerating paper figures -> results/");
    let out = std::path::Path::new("results");
    let mut timing = Table::new(vec!["experiment", "wall time", "csv"]);
    for id in experiments::ALL_IDS {
        let t3 = Instant::now();
        let r = experiments::run(id, &cfg).unwrap();
        let path = r.write_csv(out)?;
        timing.row(vec![
            id.to_string(),
            format!("{:?}", t3.elapsed()),
            path.display().to_string(),
        ]);
        for n in &r.notes {
            println!("    [{id}] {n}");
        }
    }
    println!("\n{}", timing.to_ascii());

    // ---- verdict --------------------------------------------------------
    println!("tuning wall-time: measurement {:?} + model evaluation {:?}", t_measure, t_tune);
    println!(
        "an exhaustive empirical search at ONE (P, m) point costs more than \
         the entire model-based tuning of {} points — that is the paper's claim.",
        p_grid.len() * m_grid.len()
    );
    if all_meaningful_ok {
        println!("\nE2E RESULT: OK — selection correct wherever the margin is meaningful");
        Ok(())
    } else {
        anyhow::bail!("E2E RESULT: selection accuracy below threshold");
    }
}
