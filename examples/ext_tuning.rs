//! Extended-collectives tuning walkthrough: gather, barrier, allgather,
//! and allreduce selected through the *same* evaluation framework as the
//! paper's broadcast and scatter — the unified cost-model registry, the
//! parallel sweep, and the simulator as ground truth.
//!
//! ```bash
//! cargo run --release --example ext_tuning
//! ```

use collective_tuner::eval::SimEval;
use collective_tuner::models;
use collective_tuner::mpi::World;
use collective_tuner::netsim::{NetConfig, Netsim};
use collective_tuner::tuner::ext::{build_ext_schedule, ExtTuner};
use collective_tuner::tuner::grids;
use collective_tuner::util::table::{fmt_bytes, fmt_time, Table};

fn main() {
    let cfg = NetConfig::fast_ethernet_icluster1();
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    println!("network: {}\n", net.summary());

    // One parallel sweep per extended op, all through Box<dyn Evaluator>.
    let tuner = ExtTuner::native().jobs(0);
    let p_grid = vec![2usize, 4, 8, 16, 24, 32, 48];
    let m_grid = grids::log_grid(1, 1 << 20, 12);
    let tables = tuner
        .tune(&net, &p_grid, &m_grid)
        .expect("native ext tune is infallible");

    // Model matrix at P = 16: predicted vs simulated for every strategy.
    let p = 16usize;
    let m_list = [1024u64, 32 * 1024, 1024 * 1024];
    let mut matrix = Table::new(vec!["strategy", "m", "predicted", "measured", "rel err"]);
    for table in &tables {
        for &m in &m_list {
            for &strat in table.op.family() {
                let t_pred = models::predict(strat, &net, p, m, None);
                let t_meas = eval.measure(strat, p, m, None);
                matrix.row(vec![
                    strat.name().to_string(),
                    fmt_bytes(m as f64),
                    fmt_time(t_pred),
                    fmt_time(t_meas),
                    format!("{:.1}%", (t_pred - t_meas).abs() / t_meas * 100.0),
                ]);
            }
        }
    }
    println!("{}", matrix.to_ascii());

    // Decision-table summary: winner share per op, and the model-picked
    // winner at a probe point agrees with the measured winner.
    let mut agree = 0usize;
    let mut probes = 0usize;
    for table in &tables {
        println!("== {} decision table ==", table.op.name());
        let mut share = Table::new(vec!["strategy", "share"]);
        for (st, frac) in table.share() {
            share.row(vec![st.name().to_string(), format!("{:.0}%", frac * 100.0)]);
        }
        println!("{}", share.to_ascii());

        for &m in &m_list {
            let chosen = table.lookup(p, m).strategy;
            let measured_best = table
                .op
                .family()
                .iter()
                .map(|&s| (s, eval.measure(s, p, m, None)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0;
            probes += 1;
            if chosen == measured_best {
                agree += 1;
            }
            println!(
                "  {} @ (P={p}, m={:>7}): model {:<24} measured best {:<24} {}",
                table.op.name(),
                fmt_bytes(m as f64),
                chosen.name(),
                measured_best.name(),
                if chosen == measured_best { "AGREE" } else { "differ" }
            );
        }
        println!();
    }
    println!("selection agreement: {agree}/{probes} probe points\n");

    // Every tuned decision builds a schedule that runs and verifies.
    for table in &tables {
        let d = table.lookup(p, 32 * 1024);
        let sched = build_ext_schedule(table.op, d.strategy, p, 32 * 1024)
            .expect("tuned decision must schedule");
        let mut world = World::new(Netsim::new(p, cfg.clone()));
        let rep = world.run(&sched);
        assert!(
            rep.verify(&sched).is_empty(),
            "{}: {:?}",
            sched.name,
            rep.verify(&sched)
        );
        println!(
            "verified {:<24} on {p} ranks: completion {}",
            sched.name,
            fmt_time(rep.completion.as_secs())
        );
    }
}
