//! NET SERVICE — the coordinator on the wire (`ct/1` over TCP).
//!
//! Demonstrates the network layer end-to-end in one process, printing
//! evidence at each step:
//!
//!   1. register two islands and start a `CoordServer` on an ephemeral
//!      loopback port (the same server `collective-tuner coordd` runs);
//!   2. connect a `NetClient` over real TCP and round-trip a batched
//!      query, checking every remote answer against the in-process
//!      `decision()` it mirrors;
//!   3. ask about an unregistered cluster — a structured `unregistered`
//!      error reply, not a dropped connection;
//!   4. subscribe to decision points and force a drift refresh: the
//!      server pushes a TABLEUPDATE carrying the *new* table's
//!      decisions without being asked;
//!   5. shut the server down remotely (opt-in) and dump the `net.*`
//!      observability counters the connection accumulated.
//!
//! ```bash
//! cargo run --release --example net_service
//! ```

use std::sync::Arc;
use std::time::Duration;

use collective_tuner::coordinator::net::{CoordServer, NetClient, Point, Push, Query, ServerOptions};
use collective_tuner::coordinator::{Coordinator, CoordinatorConfig, RefreshPolicy};
use collective_tuner::netsim::{NetConfig, Netsim};
use collective_tuner::obs;
use collective_tuner::plogp::bench;
use collective_tuner::tuner::{grids, Op};

fn main() -> anyhow::Result<()> {
    obs::set_enabled(true);
    println!("=================================================================");
    println!(" net service: the coordinator behind the ct/1 wire protocol");
    println!("=================================================================\n");

    // ---- 1. a coordinator with two islands, served over TCP -------------
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        p_grid: vec![2, 8, 24],
        m_grid: grids::log_grid(1, 1 << 20, 8),
        ..CoordinatorConfig::default()
    }));
    let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
    coord.register("fe-island", 24, bench::measure(&mut sim));
    let mut sim = Netsim::new(2, NetConfig::gigabit_ethernet());
    coord.register("ge-island", 16, bench::measure(&mut sim));
    let server = CoordServer::start(
        Arc::clone(&coord),
        "127.0.0.1:0",
        ServerOptions { allow_remote_shutdown: true, ..ServerOptions::default() },
    )?;
    let addr = server.local_addr().to_string();
    println!("[1] serving 2 islands on {addr}");

    // ---- 2. a batched query over real TCP -------------------------------
    let client = NetClient::connect(&addr)?;
    println!("    connected: {}", client.banner());
    let queries: Vec<Query> = [
        (Op::Bcast, "fe-island", 24usize, 64 * 1024u64),
        (Op::Scatter, "fe-island", 8, 1024),
        (Op::AllReduce, "ge-island", 16, 1 << 20),
    ]
    .iter()
    .map(|&(op, cluster, p, m)| Query { op, cluster: cluster.to_string(), p, m })
    .collect();
    let replies = client.query_batch(&queries)?;
    for (q, r) in queries.iter().zip(&replies) {
        let d = r.as_ref().expect("registered clusters answer");
        let local = coord.decision(q.op, &q.cluster, q.p, q.m)?;
        assert_eq!(*d, local, "remote and in-process answers must agree");
        println!(
            "[2] {:?} {} P={} m={} -> {} (remote == in-process)",
            q.op,
            q.cluster,
            q.p,
            q.m,
            d.strategy.name()
        );
    }

    // ---- 3. structured errors for unknown clusters -----------------------
    let ghost = client.query_batch(&[Query {
        op: Op::Bcast,
        cluster: "ghost".into(),
        p: 8,
        m: 4096,
    }])?;
    let err = ghost[0].as_ref().unwrap_err();
    println!("[3] unknown cluster answered with a structured error: {err}");
    assert_eq!(err.code, "unregistered");

    // ---- 4. subscribe, then force a drift refresh ------------------------
    let points = [
        Point { op: Op::Bcast, p: 24, m: 64 * 1024 },
        Point { op: Op::Scatter, p: 8, m: 1024 },
    ];
    let (signature, epoch) = client.subscribe("fe-island", &points)?;
    let initial = client.wait_pushes(1, Duration::from_secs(10))?;
    let initial_rows = match &initial[..] {
        [Push::TableUpdate { rows, .. }] => rows.len(),
        other => anyhow::bail!("expected the initial TABLEUPDATE, got {other:?}"),
    };
    println!("[4] subscribed to fe-island (sig {signature}, epoch {epoch}): {initial_rows} rows");
    // drift the island to a different hardware class; the refresh
    // re-tunes, republishes, and the server pushes the fresh table
    let mut sim = Netsim::new(2, NetConfig::gigabit_ethernet());
    let outcome = coord.refresh("fe-island", &mut sim, &RefreshPolicy::default())?;
    println!("    refresh: drift {:.3} -> refreshed {}", outcome.drift(), outcome.refreshed());
    let pushes = client.wait_pushes(1, Duration::from_secs(10))?;
    match &pushes[..] {
        [Push::TableUpdate { epoch: e, cluster, rows }] => {
            println!(
                "    server pushed TABLEUPDATE for {cluster} at epoch {e}: {} row(s)",
                rows.len()
            );
            for (pt, d) in rows {
                println!("      {:?} P={} m={} -> {}", pt.op, pt.p, pt.m, d.strategy.name());
            }
        }
        other => anyhow::bail!("expected one TABLEUPDATE push, got {other:?}"),
    }

    // ---- 5. remote shutdown + the counters the wire accumulated ----------
    client.shutdown_server()?;
    println!("[5] server acknowledged the remote shutdown");
    server.shutdown();
    println!("OBS_SNAPSHOT_JSON {}", obs::registry().snapshot_json());

    println!("\nNET SERVICE RESULT: OK — remote answers match, pushes follow publishes");
    Ok(())
}
