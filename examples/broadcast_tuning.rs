//! Broadcast tuning walkthrough: compare all ten Table-1 strategies,
//! measured against predicted, and show where the crossovers fall — the
//! paper's §4.1 study.
//!
//! ```bash
//! cargo run --release --example broadcast_tuning
//! ```

use collective_tuner::collectives::Strategy;
use collective_tuner::eval::SimEval;
use collective_tuner::models;
use collective_tuner::netsim::NetConfig;
use collective_tuner::tuner::grids;
use collective_tuner::util::table::{fmt_bytes, fmt_time, Table};

fn main() {
    let cfg = NetConfig::fast_ethernet_icluster1();
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    println!("network: {}\n", net.summary());
    let s_grid = grids::default_s_grid();

    // Full strategy matrix at P = 24 over four message sizes.
    let p = 24usize;
    let m_list = [1024u64, 16 * 1024, 128 * 1024, 1024 * 1024];
    let mut table = Table::new(vec![
        "strategy", "m", "segment", "predicted", "measured", "rel err",
    ]);
    for &m in &m_list {
        let mut rows: Vec<(Strategy, f64, f64, Option<u64>)> = Vec::new();
        for strat in Strategy::BCAST {
            let (t_pred, seg) = if strat.is_segmented() {
                let (t, s) = models::best_segment(strat, &net, p, m, &s_grid);
                (t, Some(s))
            } else {
                (models::predict(strat, &net, p, m, None), None)
            };
            let t_meas = eval.measure(strat, p, m, seg);
            rows.push((strat, t_pred, t_meas, seg));
        }
        rows.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
        for (strat, t_pred, t_meas, seg) in rows {
            table.row(vec![
                strat.name().to_string(),
                fmt_bytes(m as f64),
                seg.map(|s| fmt_bytes(s as f64)).unwrap_or_else(|| "-".into()),
                fmt_time(t_pred),
                fmt_time(t_meas),
                format!("{:.1}%", (t_pred - t_meas).abs() / t_meas * 100.0),
            ]);
        }
    }
    println!("{}", table.to_ascii());

    // Where does the winner change? Sweep m at fixed P.
    println!("winner by message size at P={p} (model-tuned):");
    let mut last: Option<Strategy> = None;
    for &m in grids::default_m_grid().iter() {
        let ranked = models::rank_strategies(&Strategy::BCAST, &net, p, m, &s_grid);
        let win = ranked[0].0;
        if last != Some(win) {
            println!("  from m = {:>9}: {}", fmt_bytes(m as f64), win.name());
            last = Some(win);
        }
    }

    // Does the model pick the measured winner at the probe points?
    let mut agree = 0;
    for &m in &m_list {
        let model_win = models::rank_strategies(&Strategy::BCAST, &net, p, m, &s_grid)[0].0;
        let measured_win = Strategy::BCAST
            .iter()
            .map(|&s| {
                let seg = s
                    .is_segmented()
                    .then(|| models::best_segment(s, &net, p, m, &s_grid).1);
                (s, eval.measure(s, p, m, seg))
            })
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0;
        if model_win == measured_win {
            agree += 1;
        }
        println!(
            "  m={:>9}: model picks {:<20} measured best {:<20} {}",
            fmt_bytes(m as f64),
            model_win.name(),
            measured_win.name(),
            if model_win == measured_win { "AGREE" } else { "differ" }
        );
    }
    println!("\nselection agreement: {agree}/{} probe points", m_list.len());
}
