//! COORDINATOR STRESS — the L3 decision service under concurrent load.
//!
//! Demonstrates the acceptance path of the coordinator subsystem
//! end-to-end, printing evidence at each step:
//!
//!   1. build a 4-island grid (two hardware classes, so two islands
//!      share each signature);
//!   2. register the islands (pLogP probe per island);
//!   3. hammer the service from worker threads with a mixed
//!      `(op, cluster, P, m)` workload — cold misses coalesce, the hot
//!      path is lock-free snapshot reads;
//!   4. build and run a multi-level broadcast whose per-island
//!      strategies are fetched from the coordinator (NOT tuned inline);
//!   5. persist, warm-start a second coordinator, and show it answers
//!      identically with zero tuner runs.
//!
//! ```bash
//! cargo run --release --example coordinator_stress
//! # with live observability: a registry snapshot every N seconds, a
//! # final OBS_SNAPSHOT_JSON line, and the decision flight recorder
//! cargo run --release --example coordinator_stress -- --metrics-interval 1
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use collective_tuner::collectives::multilevel;
use collective_tuner::coordinator::{Coordinator, CoordinatorConfig};
use collective_tuner::mpi::World;
use collective_tuner::netsim::NetConfig;
use collective_tuner::obs;
use collective_tuner::topology::{ClusterSpec, GridSpec};
use collective_tuner::tuner::{grids, Op};
use collective_tuner::util::prng::Prng;
use collective_tuner::util::table::fmt_time;

const THREADS: usize = 8;
const REQUESTS_PER_THREAD: usize = 25_000;

/// Parse `--metrics-interval N` (seconds) from the example's argv.
/// 0 (or absent) leaves observability disabled — the default run is
/// byte-for-byte what it was before the obs layer existed.
fn metrics_interval() -> u64 {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--metrics-interval")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let interval = metrics_interval();
    if interval > 0 {
        obs::set_enabled(true);
    }
    println!("=================================================================");
    println!(" coordinator stress: concurrent cached decision-table service");
    println!("=================================================================\n");

    // ---- 1. a grid of four islands, two hardware classes ---------------
    let grid = GridSpec::new(
        vec![
            ClusterSpec::new("fe-0", 12, NetConfig::fast_ethernet_icluster1()),
            ClusterSpec::new("ge-0", 8, NetConfig::gigabit_ethernet()),
            ClusterSpec::new("fe-1", 12, NetConfig::fast_ethernet_icluster1()),
            ClusterSpec::new("ge-1", 8, NetConfig::gigabit_ethernet()),
        ],
        NetConfig::wan_link(),
    );

    let coord = Coordinator::new(CoordinatorConfig {
        p_grid: vec![2, 4, 8, 12, 16, 24],
        m_grid: grids::log_grid(1, 1 << 20, 16),
        ..CoordinatorConfig::default()
    });

    // ---- 2. registration (probe each island) ----------------------------
    let t0 = Instant::now();
    let sigs = coord.register_islands(&grid);
    println!(
        "[1] registered {} islands in {:?}; {} distinct signature(s): fe-0/fe-1 \
         and ge-0/ge-1 pair up: {}",
        sigs.len(),
        t0.elapsed(),
        {
            let mut s = sigs.clone();
            s.sort();
            s.dedup();
            s.len()
        },
        sigs[0] == sigs[2] && sigs[1] == sigs[3] && sigs[0] != sigs[1]
    );

    // ---- 3. concurrent mixed load ---------------------------------------
    let names: Vec<String> = grid.clusters.iter().map(|c| c.name.clone()).collect();
    let served = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let t1 = Instant::now();
    std::thread::scope(|s| {
        let done = &done;
        if interval > 0 {
            s.spawn(move || {
                let tick = Duration::from_millis(50);
                let period = Duration::from_secs(interval);
                let mut last = Instant::now();
                while !done.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    if last.elapsed() >= period {
                        println!("metrics: {}", obs::registry().snapshot_json());
                        last = Instant::now();
                    }
                }
            });
        }
        let workers: Vec<_> = (0..THREADS)
            .map(|t| {
                let coord = &coord;
                let names = &names;
                let served = &served;
                s.spawn(move || {
                    let mut rng = Prng::new(0x5712E55 ^ t as u64);
                    for _ in 0..REQUESTS_PER_THREAD {
                        let name = rng.pick(names);
                        let op = if rng.chance(0.5) { Op::Bcast } else { Op::Scatter };
                        let p = rng.range_usize(2, 25);
                        let m = rng.range(1, 1 << 20);
                        let d = coord.decision(op, name, p, m).expect("registered");
                        std::hint::black_box(d);
                        served.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().expect("stress worker panicked");
        }
        done.store(true, Ordering::Relaxed);
    });
    let dt = t1.elapsed().as_secs_f64();
    let st = coord.stats();
    println!(
        "[2] served {} queries from {THREADS} threads in {:.2} s ({:.0} kq/s)",
        served.load(Ordering::Relaxed),
        dt,
        served.load(Ordering::Relaxed) as f64 / dt / 1e3
    );
    println!(
        "    cache: {} entries, {} hits / {} misses / {} evictions",
        st.cache.entries, st.cache.hits, st.cache.misses, st.cache.evictions
    );
    println!(
        "    tuner runs: {} (4 islands, 2 signatures — coalescing + sharing held)",
        st.tunes
    );
    assert_eq!(st.tunes, 2, "exactly one tune per distinct signature");

    // ---- 4. multilevel broadcast from coordinator tables ----------------
    let sched = multilevel::tuned_bcast(&grid, 256 * 1024, &coord)?;
    let mut world = World::new(grid.build_sim());
    let rep = world.run(&sched);
    let problems = rep.verify(&sched);
    println!(
        "[3] multilevel bcast over {} nodes via coordinator tables: \
         completion {}, verified {}",
        grid.total_nodes(),
        fmt_time(rep.completion.as_secs()),
        if problems.is_empty() { "ok" } else { "FAILED" }
    );
    assert!(problems.is_empty(), "{problems:?}");
    assert_eq!(coord.tune_count(), 2, "schedule build must not tune inline");

    // ---- 5. persist → warm start ----------------------------------------
    let dir = std::env::temp_dir().join("ct-coordinator-stress");
    let saved = coord.persist_to(&dir)?;
    let warm = Coordinator::new(coord.config().clone());
    let loaded = warm.warm_start_from(&dir)?;
    let d_cold = coord.decision(Op::Bcast, "fe-0", 12, 1 << 18)?;
    let d_warm = warm.decision(Op::Bcast, "fe-0", 12, 1 << 18)?;
    println!(
        "[4] persisted {saved} table set(s); warm-started coordinator loaded \
         {loaded} and answered {} (tuner runs: {})",
        d_warm.strategy.name(),
        warm.tune_count()
    );
    assert_eq!(d_cold.strategy, d_warm.strategy);
    assert_eq!(warm.tune_count(), 0);
    let _ = std::fs::remove_dir_all(&dir);

    // ---- 6. final observability dump (only with --metrics-interval) -----
    if interval > 0 {
        // Single-line marker so CI (and humans piping to python) can
        // grab the final snapshot without any multi-line parsing.
        println!("OBS_SNAPSHOT_JSON {}", obs::registry().snapshot_json());
        let fr = obs::flight();
        println!(
            "[5] flight recorder: {} event(s), {} dropped, {} total",
            fr.len(),
            fr.dropped(),
            fr.total()
        );
        print!("{}", fr.to_tsv());
        assert!(!fr.is_empty(), "load ran, so the flight ring must hold events");
        assert_eq!(fr.dropped() + fr.len() as u64, fr.total(), "ring drop accounting");
    }

    println!("\nSTRESS RESULT: OK — one tune per signature under {THREADS}-way load");
    Ok(())
}
