//! Quickstart: measure the network, tune, and run a broadcast with the
//! selected strategy — the whole paper in thirty lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use collective_tuner::mpi::World;
use collective_tuner::netsim::{NetConfig, Netsim};
use collective_tuner::plogp;
use collective_tuner::runtime::TunerArtifact;
use collective_tuner::tuner::{grids, Tuner};
use collective_tuner::util::table::fmt_time;

fn main() -> anyhow::Result<()> {
    // 1. The cluster: the paper's testbed — 24 ranks of a 50-node
    //    switched Fast Ethernet cluster running Linux-2.2-era TCP.
    let cfg = NetConfig::fast_ethernet_icluster1();
    let (p, m) = (24usize, 256 * 1024u64);

    // 2. Measure pLogP parameters once (the LogP benchmark procedure).
    let mut probe = Netsim::new(2, cfg.clone());
    let net = plogp::bench::measure(&mut probe);
    println!("measured  : {}", net.summary());

    // 3. Tune: evaluate all Table-1/Table-2 models; prefer the
    //    AOT-compiled XLA artifact, falling back to the native models.
    let tuner = Tuner::auto(&TunerArtifact::default_dir());
    let (bcast_table, _scatter_table) =
        tuner.tune(&net, &grids::default_p_grid(), &grids::default_m_grid())?;
    let choice = bcast_table.lookup(p, m);
    println!(
        "tuned     : {} (segment {:?}) predicted {}",
        choice.strategy.name(),
        choice.segment,
        fmt_time(choice.predicted)
    );

    // 4. Run the chosen strategy on the simulated cluster and verify.
    let sched = choice.strategy.build(p, 0, m, choice.segment);
    let mut world = World::new(Netsim::new(p, cfg));
    let report = world.run(&sched);
    assert!(report.verify(&sched).is_empty(), "payload verification failed");
    println!(
        "measured  : {} ({} messages, {} ack stalls)",
        fmt_time(report.completion.as_secs()),
        report.messages,
        report.ack_stalls
    );
    println!(
        "model err : {:.1}%",
        (choice.predicted - report.completion.as_secs()).abs()
            / report.completion.as_secs()
            * 100.0
    );
    Ok(())
}
