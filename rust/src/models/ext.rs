//! pLogP cost models for the extended operations (Gather, Reduce,
//! Barrier, AllGather, AllReduce) — derived exactly the way the paper
//! derives Tables 1 and 2, so the tuner can select among implementations
//! of *every* collective, not just Broadcast and Scatter.
//!
//! Index layout is shared with `python/compile/kernels/ext_models.py`
//! (the second AOT artifact) — see `ExtStrategy`.

use crate::collectives::tree::{ceil_log2, floor_log2};
use crate::plogp::PLogP;

/// Extended-operation strategies, numbered identically to the Python
/// kernel `ext_models.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(usize)]
pub enum ExtStrategy {
    /// Gather, flat tree: every rank sends its block straight to the
    /// root; the root's NIC serializes. `(P-1) g(m) + L`.
    GatherFlat = 0,
    /// Gather, binomial fan-in: combined blocks double per round.
    /// `sum_{j=0}^{ceil(log2 P)-1} g(2^j m) + ceil(log2 P) L`.
    GatherBinomial = 1,
    /// Reduce, binomial fan-in of m-sized partials:
    /// `floor(log2 P) g(m) + ceil(log2 P) L` (paper §3: constructed like
    /// the binomial broadcast, reversed).
    ReduceBinomial = 2,
    /// Barrier, binomial fan-in + fan-out: `2 (floor(log2 P) g(1) +
    /// ceil(log2 P) L)`.
    BarrierTree = 3,
    /// Barrier, dissemination: `ceil(log2 P) (g(1) + L)`.
    BarrierDissemination = 4,
    /// AllGather as gather + broadcast of the P·m result (MagPIe-style,
    /// the paper's §3 example): `gather_binomial(m) + binomial(P·m)`.
    AllGatherGatherBcast = 5,
    /// AllGather, ring: `(P-1)(g(m) + L)`.
    AllGatherRing = 6,
    /// AllGather, recursive doubling:
    /// `sum_{j=0}^{log2 P - 1} (g(2^j m) + L)`.
    AllGatherRecDoubling = 7,
    /// AllReduce as reduce + broadcast:
    /// `2 floor(log2 P) g(m) + 2 ceil(log2 P) L`.
    AllReduceReduceBcast = 8,
    /// AllReduce, recursive doubling: `log2 P (g(m) + L)`.
    AllReduceRecDoubling = 9,
}

impl ExtStrategy {
    pub const COUNT: usize = 10;

    pub const ALL: [ExtStrategy; 10] = [
        ExtStrategy::GatherFlat,
        ExtStrategy::GatherBinomial,
        ExtStrategy::ReduceBinomial,
        ExtStrategy::BarrierTree,
        ExtStrategy::BarrierDissemination,
        ExtStrategy::AllGatherGatherBcast,
        ExtStrategy::AllGatherRing,
        ExtStrategy::AllGatherRecDoubling,
        ExtStrategy::AllReduceReduceBcast,
        ExtStrategy::AllReduceRecDoubling,
    ];

    pub const GATHER: [ExtStrategy; 2] = [ExtStrategy::GatherFlat, ExtStrategy::GatherBinomial];
    pub const BARRIER: [ExtStrategy; 2] =
        [ExtStrategy::BarrierTree, ExtStrategy::BarrierDissemination];
    pub const ALLGATHER: [ExtStrategy; 3] = [
        ExtStrategy::AllGatherGatherBcast,
        ExtStrategy::AllGatherRing,
        ExtStrategy::AllGatherRecDoubling,
    ];
    pub const ALLREDUCE: [ExtStrategy; 2] = [
        ExtStrategy::AllReduceReduceBcast,
        ExtStrategy::AllReduceRecDoubling,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Option<ExtStrategy> {
        ExtStrategy::ALL.get(i).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            ExtStrategy::GatherFlat => "gather/flat",
            ExtStrategy::GatherBinomial => "gather/binomial",
            ExtStrategy::ReduceBinomial => "reduce/binomial",
            ExtStrategy::BarrierTree => "barrier/tree",
            ExtStrategy::BarrierDissemination => "barrier/dissemination",
            ExtStrategy::AllGatherGatherBcast => "allgather/gather+bcast",
            ExtStrategy::AllGatherRing => "allgather/ring",
            ExtStrategy::AllGatherRecDoubling => "allgather/rec_doubling",
            ExtStrategy::AllReduceReduceBcast => "allreduce/reduce+bcast",
            ExtStrategy::AllReduceRecDoubling => "allreduce/rec_doubling",
        }
    }
}

/// Predicted completion time (seconds) of an extended strategy. `m` is
/// the per-rank block size (gather/allgather) or vector size
/// (reduce/allreduce); ignored for barriers.
pub fn predict_ext(strategy: ExtStrategy, net: &PLogP, procs: usize, m: u64) -> f64 {
    assert!(procs >= 1);
    let l = net.l;
    let p = procs as f64;
    let mf = m.max(1) as f64;
    let g_m = net.gap(mf);
    let g_1 = net.gap(1.0);
    let fl = floor_log2(procs) as f64;
    let ce = ceil_log2(procs) as f64;

    let doubling_sum = |unit: f64| -> f64 {
        (0..ceil_log2(procs)).map(|j| net.gap((1u64 << j) as f64 * unit)).sum()
    };

    match strategy {
        ExtStrategy::GatherFlat => (p - 1.0) * g_m + l,
        ExtStrategy::GatherBinomial => doubling_sum(mf) + ce * l,
        ExtStrategy::ReduceBinomial => fl * g_m + ce * l,
        ExtStrategy::BarrierTree => 2.0 * (fl * g_1 + ce * l),
        ExtStrategy::BarrierDissemination => ce * (g_1 + l),
        ExtStrategy::AllGatherGatherBcast => {
            // gather of m-blocks + broadcast of the P·m result
            (doubling_sum(mf) + ce * l) + (fl * net.gap(p * mf) + ce * l)
        }
        ExtStrategy::AllGatherRing => (p - 1.0) * (g_m + l),
        ExtStrategy::AllGatherRecDoubling => {
            (0..ceil_log2(procs))
                .map(|j| net.gap((1u64 << j) as f64 * mf) + l)
                .sum()
        }
        ExtStrategy::AllReduceReduceBcast => 2.0 * (fl * g_m + ce * l),
        ExtStrategy::AllReduceRecDoubling => ce * (g_m + l),
    }
}

/// Rank the strategies of one extended-op family, ascending by predicted
/// time.
pub fn rank_ext(
    family: &[ExtStrategy],
    net: &PLogP,
    procs: usize,
    m: u64,
) -> Vec<(ExtStrategy, f64)> {
    let mut out: Vec<(ExtStrategy, f64)> = family
        .iter()
        .map(|&s| (s, predict_ext(s, net, procs, m)))
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plogp::GapTable;

    /// g(m) = 1 + m, L = 10 (hand-checkable toy network).
    fn toy() -> PLogP {
        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128., 256.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        PLogP::new(10.0, GapTable::new(sizes, gaps))
    }

    #[test]
    fn hand_values() {
        let n = toy();
        // P=5, m=8: ce=3, fl=2, g(8)=9, g(1)=2
        assert_eq!(predict_ext(ExtStrategy::GatherFlat, &n, 5, 8), 4.0 * 9.0 + 10.0);
        // gather binomial: g(8)+g(16)+g(32) + 3L = 9+17+33+30 = 89
        assert_eq!(predict_ext(ExtStrategy::GatherBinomial, &n, 5, 8), 89.0);
        assert_eq!(predict_ext(ExtStrategy::ReduceBinomial, &n, 5, 8), 2.0 * 9.0 + 30.0);
        assert_eq!(predict_ext(ExtStrategy::BarrierTree, &n, 5, 1), 2.0 * (2.0 * 2.0 + 30.0));
        assert_eq!(predict_ext(ExtStrategy::BarrierDissemination, &n, 5, 1), 3.0 * 12.0);
        assert_eq!(predict_ext(ExtStrategy::AllGatherRing, &n, 5, 8), 4.0 * 19.0);
        // rec doubling allgather: (9+10)+(17+10)+(33+10) = 89
        assert_eq!(predict_ext(ExtStrategy::AllGatherRecDoubling, &n, 5, 8), 89.0);
        assert_eq!(predict_ext(ExtStrategy::AllReduceRecDoubling, &n, 5, 8), 3.0 * 19.0);
        assert_eq!(
            predict_ext(ExtStrategy::AllReduceReduceBcast, &n, 5, 8),
            2.0 * (2.0 * 9.0 + 30.0)
        );
        // allgather gather+bcast: 89 + (2*g(40) + 30) = 89 + 2*41 + 30
        assert_eq!(
            predict_ext(ExtStrategy::AllGatherGatherBcast, &n, 5, 8),
            89.0 + 2.0 * 41.0 + 30.0
        );
    }

    #[test]
    fn indices_and_names_roundtrip() {
        for (i, s) in ExtStrategy::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert_eq!(ExtStrategy::from_index(i), Some(*s));
        }
        assert_eq!(ExtStrategy::from_index(10), None);
    }

    #[test]
    fn dissemination_beats_tree_in_model() {
        let n = toy();
        for p in [4usize, 8, 16, 32] {
            assert!(
                predict_ext(ExtStrategy::BarrierDissemination, &n, p, 1)
                    < predict_ext(ExtStrategy::BarrierTree, &n, p, 1),
                "p={p}"
            );
        }
    }

    #[test]
    fn ring_vs_rec_doubling_crossover_in_model() {
        // latency-dominated: rec doubling wins; bandwidth-dominated:
        // comparable (ring within ~2x) — check the small-m ordering
        let n = toy();
        let p = 16;
        let small = rank_ext(&ExtStrategy::ALLGATHER, &n, p, 1);
        assert_eq!(small[0].0, ExtStrategy::AllGatherRecDoubling);
    }

    #[test]
    fn rank_ext_sorted() {
        let n = toy();
        let r = rank_ext(&ExtStrategy::ALL, &n, 9, 64);
        for w in r.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(r.len(), 10);
    }

    #[test]
    fn all_models_finite_positive() {
        let n = toy();
        for p in [1usize, 2, 3, 17, 64] {
            for m in [1u64, 100, 1 << 20] {
                for s in ExtStrategy::ALL {
                    let t = predict_ext(s, &n, p, m);
                    assert!(t.is_finite() && t >= 0.0, "{} p={p} m={m}", s.name());
                }
            }
        }
    }
}
