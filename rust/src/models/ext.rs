//! pLogP cost models for the extended operations (Gather, Reduce,
//! Barrier, AllGather, AllReduce) — derived exactly the way the paper
//! derives Tables 1 and 2, so the tuner selects among implementations of
//! *every* collective, not just Broadcast and Scatter.
//!
//! These are plain [`super::CostFn`] entries of the unified
//! strategy-indexed [`super::COST_MODELS`] registry; evaluate them
//! through [`super::predict`] with the extended
//! [`crate::collectives::Strategy`] variants. The index layout
//! (ext-artifact winner index = `Strategy::index() -
//! Strategy::EXT_BASE`) is shared with
//! `python/compile/kernels/ext_models.py`, the second AOT artifact.
//!
//! `m` is the per-rank block size (gather/allgather) or vector size
//! (reduce/allreduce); barriers ignore it. None of the extended
//! strategies segment, so the segment fields of [`CostInputs`] are
//! ignored throughout.

use crate::collectives::tree::ceil_log2;

use super::CostInputs;

/// `sum_{j=0}^{ceil(log2 P)-1} g(2^j · unit)` — the fan-in/fan-out
/// doubling sum shared by the binomial gather and recursive-doubling
/// models.
fn doubling_sum(x: &CostInputs, unit: f64) -> f64 {
    (0..ceil_log2(x.procs)).map(|j| x.net.gap((1u64 << j) as f64 * unit)).sum()
}

/// Gather, flat tree: every rank sends its block straight to the root;
/// the root's NIC serializes. `(P-1) g(m) + L`.
pub(super) fn cost_gather_flat(x: &CostInputs) -> f64 {
    (x.p - 1.0) * x.g_m + x.l
}

/// Gather, binomial fan-in: combined blocks double per round.
/// `sum_{j} g(2^j m) + ceil(log2 P) L`.
pub(super) fn cost_gather_binomial(x: &CostInputs) -> f64 {
    doubling_sum(x, x.mf) + x.ce * x.l
}

/// Reduce, binomial fan-in of m-sized partials:
/// `floor(log2 P) g(m) + ceil(log2 P) L` (paper §3: constructed like the
/// binomial broadcast, reversed).
pub(super) fn cost_reduce_binomial(x: &CostInputs) -> f64 {
    x.fl * x.g_m + x.ce * x.l
}

/// Barrier, binomial fan-in + fan-out:
/// `2 (floor(log2 P) g(1) + ceil(log2 P) L)`.
pub(super) fn cost_barrier_tree(x: &CostInputs) -> f64 {
    2.0 * (x.fl * x.net.gap(1.0) + x.ce * x.l)
}

/// Barrier, dissemination: `ceil(log2 P) (g(1) + L)`.
pub(super) fn cost_barrier_dissemination(x: &CostInputs) -> f64 {
    x.ce * (x.net.gap(1.0) + x.l)
}

/// AllGather as gather + broadcast of the P·m result (MagPIe-style, the
/// paper's §3 example): `gather_binomial(m) + binomial(P·m)`.
pub(super) fn cost_allgather_gather_bcast(x: &CostInputs) -> f64 {
    (doubling_sum(x, x.mf) + x.ce * x.l) + (x.fl * x.net.gap(x.p * x.mf) + x.ce * x.l)
}

/// AllGather, ring: `(P-1)(g(m) + L)`.
pub(super) fn cost_allgather_ring(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_m + x.l)
}

/// AllGather, recursive doubling:
/// `sum_{j=0}^{log2 P - 1} (g(2^j m) + L)`.
pub(super) fn cost_allgather_rec_doubling(x: &CostInputs) -> f64 {
    (0..ceil_log2(x.procs))
        .map(|j| x.net.gap((1u64 << j) as f64 * x.mf) + x.l)
        .sum()
}

/// AllReduce as reduce + broadcast:
/// `2 floor(log2 P) g(m) + 2 ceil(log2 P) L`.
pub(super) fn cost_allreduce_reduce_bcast(x: &CostInputs) -> f64 {
    2.0 * (x.fl * x.g_m + x.ce * x.l)
}

/// AllReduce, recursive doubling: `log2 P (g(m) + L)`.
pub(super) fn cost_allreduce_rec_doubling(x: &CostInputs) -> f64 {
    x.ce * (x.g_m + x.l)
}

// ---- lower bounds ([`super::LOWER_BOUNDS`] entries) --------------------
//
// None of the extended strategies segment, so these bounds exist to
// skip whole model evaluations (the doubling/triangular sums cost a
// log-P chain of gap interpolations) once an incumbent is tight, never
// to skip segment searches. `g(m) >= gap_min` because `m` lies in the
// `[1, m]` statistics interval; the doubling sums evaluate `g` beyond
// `m`, where only the table-wide `gap_floor` is sound. The two barrier
// models depend on `g(1)` and `L` alone, so their tightest bounds are
// the models themselves.

pub(super) fn lb_gather_flat(b: &super::BoundInputs) -> f64 {
    (b.p - 1.0) * b.gap_min + b.l
}

pub(super) fn lb_gather_binomial(b: &super::BoundInputs) -> f64 {
    b.ce * (b.gap_floor + b.l)
}

pub(super) fn lb_reduce_binomial(b: &super::BoundInputs) -> f64 {
    b.fl * b.gap_min + b.ce * b.l
}

pub(super) fn lb_barrier_tree(b: &super::BoundInputs) -> f64 {
    2.0 * (b.fl * b.g1 + b.ce * b.l)
}

pub(super) fn lb_barrier_dissemination(b: &super::BoundInputs) -> f64 {
    b.ce * (b.g1 + b.l)
}

pub(super) fn lb_allgather_gather_bcast(b: &super::BoundInputs) -> f64 {
    (b.ce * b.gap_floor + b.ce * b.l) + (b.fl * b.gap_floor + b.ce * b.l)
}

pub(super) fn lb_allgather_ring(b: &super::BoundInputs) -> f64 {
    (b.p - 1.0) * (b.gap_min + b.l)
}

pub(super) fn lb_allgather_rec_doubling(b: &super::BoundInputs) -> f64 {
    b.ce * (b.gap_floor + b.l)
}

pub(super) fn lb_allreduce_reduce_bcast(b: &super::BoundInputs) -> f64 {
    2.0 * (b.fl * b.gap_min + b.ce * b.l)
}

pub(super) fn lb_allreduce_rec_doubling(b: &super::BoundInputs) -> f64 {
    b.ce * (b.gap_min + b.l)
}

#[cfg(test)]
mod tests {
    use crate::collectives::Strategy;
    use crate::models::predict;
    use crate::plogp::{GapTable, PLogP};

    /// g(m) = 1 + m, L = 10 (hand-checkable toy network).
    fn toy() -> PLogP {
        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128., 256.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        PLogP::new(10.0, GapTable::new(sizes, gaps))
    }

    #[test]
    fn hand_values() {
        let n = toy();
        // P=5, m=8: ce=3, fl=2, g(8)=9, g(1)=2
        assert_eq!(predict(Strategy::GatherFlat, &n, 5, 8, None), 4.0 * 9.0 + 10.0);
        // gather binomial: g(8)+g(16)+g(32) + 3L = 9+17+33+30 = 89
        assert_eq!(predict(Strategy::GatherBinomial, &n, 5, 8, None), 89.0);
        assert_eq!(predict(Strategy::ReduceBinomial, &n, 5, 8, None), 2.0 * 9.0 + 30.0);
        assert_eq!(
            predict(Strategy::BarrierTree, &n, 5, 1, None),
            2.0 * (2.0 * 2.0 + 30.0)
        );
        assert_eq!(predict(Strategy::BarrierDissemination, &n, 5, 1, None), 3.0 * 12.0);
        assert_eq!(predict(Strategy::AllGatherRing, &n, 5, 8, None), 4.0 * 19.0);
        // rec doubling allgather: (9+10)+(17+10)+(33+10) = 89
        assert_eq!(predict(Strategy::AllGatherRecDoubling, &n, 5, 8, None), 89.0);
        assert_eq!(predict(Strategy::AllReduceRecDoubling, &n, 5, 8, None), 3.0 * 19.0);
        assert_eq!(
            predict(Strategy::AllReduceReduceBcast, &n, 5, 8, None),
            2.0 * (2.0 * 9.0 + 30.0)
        );
        // allgather gather+bcast: 89 + (2*g(40) + 30) = 89 + 2*41 + 30
        assert_eq!(
            predict(Strategy::AllGatherGatherBcast, &n, 5, 8, None),
            89.0 + 2.0 * 41.0 + 30.0
        );
    }

    #[test]
    fn dissemination_beats_tree_in_model() {
        let n = toy();
        for p in [4usize, 8, 16, 32] {
            assert!(
                predict(Strategy::BarrierDissemination, &n, p, 1, None)
                    < predict(Strategy::BarrierTree, &n, p, 1, None),
                "p={p}"
            );
        }
    }

    #[test]
    fn ring_vs_rec_doubling_crossover_in_model() {
        // latency-dominated: rec doubling wins — check the small-m ordering
        let n = toy();
        let ranked = crate::models::rank_strategies(&Strategy::ALLGATHER, &n, 16, 1, &[]);
        assert_eq!(ranked[0].0, Strategy::AllGatherRecDoubling);
    }

    #[test]
    fn ext_models_finite_positive() {
        let n = toy();
        for p in [1usize, 2, 3, 17, 64, 200] {
            for m in [1u64, 100, 1 << 20] {
                for s in Strategy::EXT {
                    let t = predict(s, &n, p, m, None);
                    assert!(t.is_finite() && t >= 0.0, "{} p={p} m={m}", s.name());
                }
            }
        }
    }

    #[test]
    fn ext_models_ignore_segment_inputs() {
        let n = toy();
        for s in Strategy::EXT {
            assert_eq!(
                predict(s, &n, 9, 64, None),
                predict(s, &n, 9, 64, Some(4)),
                "{}",
                s.name()
            );
        }
    }
}
