//! Analytic pLogP cost models — Tables 1 and 2 of the paper, in Rust.
//!
//! These are the same formulas the AOT-compiled XLA artifact evaluates
//! (`python/compile/kernels/cost_models.py`); the Rust mirror exists for
//! unit tests, one-off queries, and as the tuner's fallback when no
//! artifact is available. Cross-agreement between the two is asserted by
//! `rust/tests/artifact_roundtrip.rs`.
//!
//! Segment-size semantics match the kernel: a candidate segment `s` is
//! clamped to `min(s, m)` and `k = ceil(m/s)`, so `s >= m` degenerates to
//! the unsegmented model exactly.

pub mod ext;

use crate::collectives::Strategy;
use crate::plogp::PLogP;

/// ceil(log2 p) as f64 (0 for p = 1).
fn ceil_log2(p: usize) -> f64 {
    crate::collectives::tree::ceil_log2(p) as f64
}

/// floor(log2 p) as f64.
fn floor_log2(p: usize) -> f64 {
    crate::collectives::tree::floor_log2(p) as f64
}

/// Predicted completion time of `strategy` on a `procs`-rank cluster for
/// message size `m`, with optional segment size (segmented strategies
/// only; `None` means one segment).
///
/// For scatter strategies `m` is the per-rank chunk size.
pub fn predict(strategy: Strategy, net: &PLogP, procs: usize, m: u64, seg: Option<u64>) -> f64 {
    assert!(procs >= 1);
    assert!(m >= 1);
    let l = net.l;
    let p = procs as f64;
    let mf = m as f64;
    let g_m = net.gap(mf);
    let g_1 = net.gap(1.0);
    let fl = floor_log2(procs);
    let ce = ceil_log2(procs);
    let rdv = 2.0 * g_1 + 3.0 * l;

    // segmented quantities
    let s_eff = seg.unwrap_or(m).clamp(1, m) as f64;
    let k = (mf / s_eff).ceil();
    let g_s = net.gap(s_eff);

    match strategy {
        Strategy::BcastFlat => (p - 1.0) * g_m + l,
        Strategy::BcastFlatRdv => (p - 1.0) * g_m + rdv,
        Strategy::BcastSegFlat => (p - 1.0) * (g_s * k) + l,
        Strategy::BcastChain => (p - 1.0) * (g_m + l),
        Strategy::BcastChainRdv => (p - 1.0) * (g_m + rdv),
        Strategy::BcastSegChain => (p - 1.0) * (g_s + l) + g_s * (k - 1.0),
        Strategy::BcastBinary => ce * (2.0 * g_m + l),
        Strategy::BcastBinomial => fl * g_m + ce * l,
        Strategy::BcastBinomialRdv => fl * g_m + ce * rdv,
        Strategy::BcastSegBinomial => fl * g_s * k + ce * l,
        Strategy::ScatterFlat => (p - 1.0) * g_m + l,
        Strategy::ScatterChain => {
            let sum: f64 = (1..procs).map(|j| net.gap(j as f64 * mf)).sum();
            sum + (p - 1.0) * l
        }
        Strategy::ScatterBinomial => {
            let sum: f64 = (0..ceil_log2(procs) as u32)
                .map(|j| net.gap((1u64 << j) as f64 * mf))
                .sum();
            sum + ce * l
        }
    }
}

/// Search the segment-size grid for the best segment of a segmented
/// strategy at `(procs, m)`. Returns `(best_time, best_segment)`. The
/// message size itself is always included as a candidate (so the
/// unsegmented case is in the search space — see DESIGN.md).
pub fn best_segment(
    strategy: Strategy,
    net: &PLogP,
    procs: usize,
    m: u64,
    s_grid: &[u64],
) -> (f64, u64) {
    assert!(strategy.is_segmented());
    let mut best = (predict(strategy, net, procs, m, Some(m)), m);
    for &s in s_grid {
        let s = s.clamp(1, m);
        let t = predict(strategy, net, procs, m, Some(s));
        if t < best.0 {
            best = (t, s);
        }
    }
    best
}

/// Evaluate every strategy of one operation family and return
/// `(strategy, time, segment)` sorted ascending by time. Segmented
/// entries report their tuned segment.
pub fn rank_strategies(
    family: &[Strategy],
    net: &PLogP,
    procs: usize,
    m: u64,
    s_grid: &[u64],
) -> Vec<(Strategy, f64, Option<u64>)> {
    let mut out: Vec<(Strategy, f64, Option<u64>)> = family
        .iter()
        .map(|&s| {
            if s.is_segmented() {
                let (t, seg) = best_segment(s, net, procs, m, s_grid);
                (s, t, Some(seg))
            } else {
                (s, predict(s, net, procs, m, None), None)
            }
        })
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plogp::GapTable;

    /// The hand-checkable network from the Python tests:
    /// g(m) = 1 + m, L = 10 (fictional seconds).
    fn toy() -> PLogP {
        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        PLogP::new(10.0, GapTable::new(sizes, gaps))
    }

    #[test]
    fn matches_python_hand_values() {
        // identical cases to python/tests/test_kernel.py TestModelSemantics
        let n = toy();
        let cases: Vec<(Strategy, f64)> = vec![
            (Strategy::BcastFlat, 46.0),
            (Strategy::BcastFlatRdv, 70.0),
            (Strategy::BcastChain, 76.0),
            (Strategy::BcastChainRdv, 172.0),
            (Strategy::BcastBinary, 84.0),
            (Strategy::BcastBinomial, 48.0),
            (Strategy::BcastBinomialRdv, 120.0),
            (Strategy::ScatterFlat, 46.0),
            (Strategy::ScatterChain, 124.0),
            (Strategy::ScatterBinomial, 89.0),
        ];
        for (s, want) in cases {
            let got = predict(s, &n, 5, 8, None);
            assert!((got - want).abs() < 1e-9, "{}: got {got} want {want}", s.name());
        }
    }

    #[test]
    fn segmented_hand_values() {
        let n = toy();
        assert!((predict(Strategy::BcastSegChain, &n, 5, 8, Some(2)) - 61.0).abs() < 1e-9);
        assert!((predict(Strategy::BcastSegFlat, &n, 5, 8, Some(2)) - 58.0).abs() < 1e-9);
        assert!((predict(Strategy::BcastSegBinomial, &n, 5, 8, Some(2)) - 54.0).abs() < 1e-9);
    }

    #[test]
    fn segment_clamps_to_message() {
        let n = toy();
        let unseg = predict(Strategy::BcastFlat, &n, 5, 8, None);
        let clamped = predict(Strategy::BcastSegFlat, &n, 5, 8, Some(64));
        assert!((unseg - clamped).abs() < 1e-12);
    }

    #[test]
    fn binomial_power_of_two() {
        let n = toy();
        // floor = ceil = 3 at P=8: 3*9 + 3*10 = 57
        assert!((predict(Strategy::BcastBinomial, &n, 8, 8, None) - 57.0).abs() < 1e-9);
    }

    #[test]
    fn scatter_binomial_p2() {
        let n = toy();
        assert!((predict(Strategy::ScatterBinomial, &n, 2, 8, None) - 19.0).abs() < 1e-9);
    }

    #[test]
    fn best_segment_includes_m_itself() {
        let n = toy();
        // with a steep per-message cost, segmentation hurts; the search
        // must fall back to s = m (unsegmented)
        let sizes = vec![1.0, 1024.0];
        let gaps = vec![100.0, 101.0]; // all overhead, no bandwidth term
        let nn = PLogP::new(1.0, GapTable::new(sizes, gaps));
        let (t, s) = best_segment(Strategy::BcastSegChain, &nn, 4, 1024, &[16, 64, 256]);
        assert_eq!(s, 1024);
        assert!((t - predict(Strategy::BcastSegChain, &nn, 4, 1024, Some(1024))).abs() < 1e-12);
        let _ = n;
    }

    #[test]
    fn best_segment_picks_minimum() {
        let n = toy();
        let grid = [1u64, 2, 4, 8];
        let (t, s) = best_segment(Strategy::BcastSegBinomial, &n, 5, 8, &grid);
        for &cand in &grid {
            assert!(t <= predict(Strategy::BcastSegBinomial, &n, 5, 8, Some(cand)) + 1e-12);
        }
        assert!(grid.contains(&s) || s == 8);
    }

    #[test]
    fn rank_strategies_sorted_and_complete() {
        let n = toy();
        let ranked = rank_strategies(&Strategy::BCAST, &n, 5, 8, &[2, 4]);
        assert_eq!(ranked.len(), 10);
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        // segmented entries carry a segment
        for (s, _, seg) in &ranked {
            assert_eq!(seg.is_some(), s.is_segmented());
        }
    }

    #[test]
    fn p1_collectives_cost_only_latency_terms() {
        let n = toy();
        // P=1: no sends; flat model (P-1)g+L degenerates to L
        assert!((predict(Strategy::BcastFlat, &n, 1, 8, None) - 10.0).abs() < 1e-9);
        assert_eq!(predict(Strategy::BcastBinomial, &n, 1, 8, None), 0.0);
    }

    #[test]
    fn scatter_chain_sums_triangular_gaps() {
        let n = toy();
        // P=3, m=4: g(4)+g(8) + 2L = 5 + 9 + 20 = 34
        assert!((predict(Strategy::ScatterChain, &n, 3, 4, None) - 34.0).abs() < 1e-9);
    }
}
