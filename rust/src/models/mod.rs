//! Analytic pLogP cost models — Tables 1 and 2 of the paper, in Rust,
//! plus the extended-collective models ([`ext`]) derived the same way —
//! one strategy-indexed registry ([`COST_MODELS`]) for every collective.
//!
//! These are the same formulas the AOT-compiled XLA artifact evaluates
//! (`python/compile/kernels/cost_models.py`); the Rust mirror exists for
//! unit tests, one-off queries, and as the tuner's fallback when no
//! artifact is available. Cross-agreement between the two is asserted by
//! `rust/tests/artifact_roundtrip.rs`.
//!
//! Segment-size semantics match the kernel: a candidate segment `s` is
//! clamped to `min(s, m)` and `k = ceil(m/s)`, so `s >= m` degenerates to
//! the unsegmented model exactly.

pub mod correct;
pub mod ext;

pub use correct::CorrectionTable;

use crate::collectives::Strategy;
use crate::plogp::{GapRange, PLogP};

/// ceil(log2 p) as f64 (0 for p = 1).
fn ceil_log2(p: usize) -> f64 {
    crate::collectives::tree::ceil_log2(p) as f64
}

/// floor(log2 p) as f64.
fn floor_log2(p: usize) -> f64 {
    crate::collectives::tree::floor_log2(p) as f64
}

/// Pre-computed quantities shared by every per-strategy cost function.
/// Built once per [`predict`] call, so the registry entries stay tiny
/// closed-form expressions.
pub struct CostInputs<'a> {
    pub net: &'a PLogP,
    pub procs: usize,
    /// P as f64.
    pub p: f64,
    /// Message size as f64.
    pub mf: f64,
    pub l: f64,
    pub g_m: f64,
    /// floor(log2 P) and ceil(log2 P) as f64.
    pub fl: f64,
    pub ce: f64,
    /// Rendezvous handshake cost `2 g(1) + 3 L`.
    pub rdv: f64,
    /// Effective segment size, clamped to `[1, m]`.
    pub s_eff: f64,
    /// Segment count `k = ceil(m / s_eff)`.
    pub k: f64,
    /// Per-segment gap `g(s_eff)`.
    pub g_s: f64,
}

impl<'a> CostInputs<'a> {
    pub fn new(net: &'a PLogP, procs: usize, m: u64, seg: Option<u64>) -> CostInputs<'a> {
        assert!(procs >= 1);
        assert!(m >= 1);
        let mf = m as f64;
        let s_eff = seg.unwrap_or(m).clamp(1, m) as f64;
        CostInputs {
            net,
            procs,
            p: procs as f64,
            mf,
            l: net.l,
            g_m: net.gap(mf),
            fl: floor_log2(procs),
            ce: ceil_log2(procs),
            rdv: 2.0 * net.gap(1.0) + 3.0 * net.l,
            s_eff,
            k: (mf / s_eff).ceil(),
            g_s: net.gap(s_eff),
        }
    }

    /// Build from pre-interpolated gap values — the per-tune
    /// [`crate::plogp::GapCache`] fast path. The caller supplies
    /// `g_m = g(m)`, the *already clamped* segment `s_eff` with its gap
    /// `g_s = g(s_eff)`, and the rendezvous constant `rdv`, all
    /// produced once per tune by exactly the arithmetic
    /// [`CostInputs::new`] would use — so the resulting costs are
    /// bit-identical to the uncached path.
    pub fn from_parts(
        net: &'a PLogP,
        procs: usize,
        m: u64,
        s_eff: u64,
        g_m: f64,
        g_s: f64,
        rdv: f64,
    ) -> CostInputs<'a> {
        assert!(procs >= 1);
        assert!(m >= 1);
        debug_assert!(s_eff >= 1 && s_eff <= m, "s_eff must be pre-clamped to [1, m]");
        let mf = m as f64;
        let se = s_eff as f64;
        CostInputs {
            net,
            procs,
            p: procs as f64,
            mf,
            l: net.l,
            g_m,
            fl: floor_log2(procs),
            ce: ceil_log2(procs),
            rdv,
            s_eff: se,
            k: (mf / se).ceil(),
            g_s,
        }
    }
}

/// One closed-form cost model (an entry of [`COST_MODELS`]).
pub type CostFn = fn(&CostInputs) -> f64;

fn cost_bcast_flat(x: &CostInputs) -> f64 {
    (x.p - 1.0) * x.g_m + x.l
}

fn cost_bcast_flat_rdv(x: &CostInputs) -> f64 {
    (x.p - 1.0) * x.g_m + x.rdv
}

fn cost_bcast_seg_flat(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_s * x.k) + x.l
}

fn cost_bcast_chain(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_m + x.l)
}

fn cost_bcast_chain_rdv(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_m + x.rdv)
}

fn cost_bcast_seg_chain(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_s + x.l) + x.g_s * (x.k - 1.0)
}

fn cost_bcast_binary(x: &CostInputs) -> f64 {
    x.ce * (2.0 * x.g_m + x.l)
}

fn cost_bcast_binomial(x: &CostInputs) -> f64 {
    x.fl * x.g_m + x.ce * x.l
}

fn cost_bcast_binomial_rdv(x: &CostInputs) -> f64 {
    x.fl * x.g_m + x.ce * x.rdv
}

fn cost_bcast_seg_binomial(x: &CostInputs) -> f64 {
    x.fl * x.g_s * x.k + x.ce * x.l
}

fn cost_scatter_flat(x: &CostInputs) -> f64 {
    (x.p - 1.0) * x.g_m + x.l
}

fn cost_scatter_chain(x: &CostInputs) -> f64 {
    let sum: f64 = (1..x.procs).map(|j| x.net.gap(j as f64 * x.mf)).sum();
    sum + (x.p - 1.0) * x.l
}

fn cost_scatter_binomial(x: &CostInputs) -> f64 {
    let sum: f64 = (0..ceil_log2(x.procs) as u32)
        .map(|j| x.net.gap((1u64 << j) as f64 * x.mf))
        .sum();
    sum + x.ce * x.l
}

/// Strategy-indexed cost registry: entry `i` models
/// `Strategy::from_index(i)`. One registry covers every collective —
/// broadcast and scatter (Tables 1 and 2) and the extended operations
/// (gather / reduce / barrier / allgather / allreduce, [`ext`]) — so new
/// backends and tools (the `eval` layer, ablations, docs generators)
/// index this table instead of growing per-op match ladders.
pub const COST_MODELS: [CostFn; Strategy::COUNT] = [
    cost_bcast_flat,
    cost_bcast_flat_rdv,
    cost_bcast_seg_flat,
    cost_bcast_chain,
    cost_bcast_chain_rdv,
    cost_bcast_seg_chain,
    cost_bcast_binary,
    cost_bcast_binomial,
    cost_bcast_binomial_rdv,
    cost_bcast_seg_binomial,
    cost_scatter_flat,
    cost_scatter_chain,
    cost_scatter_binomial,
    ext::cost_gather_flat,
    ext::cost_gather_binomial,
    ext::cost_reduce_binomial,
    ext::cost_barrier_tree,
    ext::cost_barrier_dissemination,
    ext::cost_allgather_gather_bcast,
    ext::cost_allgather_ring,
    ext::cost_allgather_rec_doubling,
    ext::cost_allreduce_reduce_bcast,
    ext::cost_allreduce_rec_doubling,
];

/// The cost model of one strategy.
pub fn cost_fn(strategy: Strategy) -> CostFn {
    COST_MODELS[strategy.index()]
}

/// Pre-computed quantities shared by every per-strategy lower bound at
/// one `(P, m)` cell: the usual scalar shape terms plus extremum
/// statistics of the gap function over the candidate-segment interval
/// `[1, m]` ([`crate::plogp::GapTable::range_stats`]) and the
/// table-wide gap floor. Cheap to build from a
/// [`crate::plogp::GapCache`] row; [`BoundInputs::new`] computes the
/// statistics directly for one-off queries.
pub struct BoundInputs {
    pub procs: usize,
    /// P as f64.
    pub p: f64,
    /// Message size as f64.
    pub mf: f64,
    pub l: f64,
    /// floor(log2 P) and ceil(log2 P) as f64.
    pub fl: f64,
    pub ce: f64,
    /// Rendezvous handshake cost `2 g(1) + 3 L`.
    pub rdv: f64,
    /// `g(1)`.
    pub g1: f64,
    /// `min g(s)` over candidate segments `s ∈ [1, m]`.
    pub gap_min: f64,
    /// `max g(s)` over `s ∈ [1, m]`.
    pub gap_max: f64,
    /// `min g(s)/s` over `s ∈ [1, m]` — the subadditive per-byte rate.
    pub rate_min: f64,
    /// `min` of the sampled gaps: a sound bound on `g` at *any* size
    /// (the doubling/triangular sums evaluate `g` beyond `m`).
    pub gap_floor: f64,
}

impl BoundInputs {
    pub fn new(net: &PLogP, procs: usize, m: u64) -> BoundInputs {
        let range = net.table.range_stats(1.0, m.max(1) as f64);
        BoundInputs::from_stats(procs, m, net.l, net.gap(1.0), range, net.table.min_gap())
    }

    /// Assemble from cached statistics (the sweep hot path).
    pub fn from_stats(
        procs: usize,
        m: u64,
        l: f64,
        g1: f64,
        range: GapRange,
        gap_floor: f64,
    ) -> BoundInputs {
        assert!(procs >= 1);
        assert!(m >= 1);
        BoundInputs {
            procs,
            p: procs as f64,
            mf: m as f64,
            l,
            fl: floor_log2(procs),
            ce: ceil_log2(procs),
            rdv: 2.0 * g1 + 3.0 * l,
            g1,
            gap_min: range.gap_min,
            gap_max: range.gap_max,
            rate_min: range.rate_min,
            gap_floor,
        }
    }
}

/// One strategy's m-aware lower bound (an entry of [`LOWER_BOUNDS`]).
pub type BoundFn = fn(&BoundInputs) -> f64;

/// Lower bound on `k · g(s)` over any candidate segment `s ∈ [1, m]`:
/// `k >= 1` gives the min-gap term, and `k >= m/s` gives the
/// subadditive per-byte term `m · min g(s)/s` — streaming `m` bytes in
/// segments is never cheaper than `m` times the best per-byte rate.
/// This is what makes the segmented bounds m-aware: the old min-gap
/// bound ignored the message size entirely.
fn seg_stream_lb(b: &BoundInputs) -> f64 {
    b.gap_min.max(b.mf * b.rate_min)
}

fn lb_bcast_flat(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * b.gap_min + b.l
}

fn lb_bcast_flat_rdv(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * b.gap_min + b.rdv
}

fn lb_bcast_seg_flat(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * seg_stream_lb(b) + b.l
}

fn lb_bcast_chain(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * (b.gap_min + b.l)
}

fn lb_bcast_chain_rdv(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * (b.gap_min + b.rdv)
}

/// `(P-1)(g(s)+L) + (k-1) g(s)`: the per-stage terms bound through
/// `gap_min`, the pipeline tail through `(k-1) g(s) = k g(s) - g(s) >=
/// m·rate_min - gap_max` (clamped at zero).
fn lb_bcast_seg_chain(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * (b.gap_min + b.l) + (b.mf * b.rate_min - b.gap_max).max(0.0)
}

fn lb_bcast_binary(b: &BoundInputs) -> f64 {
    b.ce * (2.0 * b.gap_min + b.l)
}

fn lb_bcast_binomial(b: &BoundInputs) -> f64 {
    b.fl * b.gap_min + b.ce * b.l
}

fn lb_bcast_binomial_rdv(b: &BoundInputs) -> f64 {
    b.fl * b.gap_min + b.ce * b.rdv
}

fn lb_bcast_seg_binomial(b: &BoundInputs) -> f64 {
    b.fl * seg_stream_lb(b) + b.ce * b.l
}

fn lb_scatter_flat(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * b.gap_min + b.l
}

/// The triangular sum evaluates `g` at `j·m` beyond the candidate
/// interval, so only the table-wide floor is sound.
fn lb_scatter_chain(b: &BoundInputs) -> f64 {
    (b.p - 1.0) * (b.gap_floor + b.l)
}

fn lb_scatter_binomial(b: &BoundInputs) -> f64 {
    b.ce * (b.gap_floor + b.l)
}

/// Strategy-indexed lower-bound registry, aligned index-for-index with
/// [`COST_MODELS`]: entry `i` is a sound lower bound on *any* cost
/// entry `i` can achieve at `(P, m)` — over every candidate segment
/// size for the segmented strategies — and each entry is O(1) to
/// evaluate from cached [`BoundInputs`], where the models themselves
/// cost a segment-grid scan (segmented broadcast) or a log/linear sum
/// of gap interpolations. The sweep uses these to skip strategies (and
/// whole segment-grid searches) that provably cannot beat the
/// incumbent; exact ties are never skipped (see [`prunes`]), so pruned
/// tables stay byte-identical to the exhaustive argmin.
pub const LOWER_BOUNDS: [BoundFn; Strategy::COUNT] = [
    lb_bcast_flat,
    lb_bcast_flat_rdv,
    lb_bcast_seg_flat,
    lb_bcast_chain,
    lb_bcast_chain_rdv,
    lb_bcast_seg_chain,
    lb_bcast_binary,
    lb_bcast_binomial,
    lb_bcast_binomial_rdv,
    lb_bcast_seg_binomial,
    lb_scatter_flat,
    lb_scatter_chain,
    lb_scatter_binomial,
    ext::lb_gather_flat,
    ext::lb_gather_binomial,
    ext::lb_reduce_binomial,
    ext::lb_barrier_tree,
    ext::lb_barrier_dissemination,
    ext::lb_allgather_gather_bcast,
    ext::lb_allgather_ring,
    ext::lb_allgather_rec_doubling,
    ext::lb_allreduce_reduce_bcast,
    ext::lb_allreduce_rec_doubling,
];

/// The m-aware lower bound of one strategy at `(P, m)`.
pub fn lower_bound(strategy: Strategy, b: &BoundInputs) -> f64 {
    LOWER_BOUNDS[strategy.index()](b)
}

/// Relative safety margin of the pruning test. The bounds are
/// mathematically below every achievable cost, but the piecewise-linear
/// gap interpolation can round a handful of ulps past a sampled
/// extremum; the margin keeps knife-edge cells on the evaluate side so
/// pruned tables stay byte-identical to the exhaustive argmin.
pub const PRUNE_MARGIN: f64 = 1e-9;

/// Should a candidate with lower bound `bound` be skipped against an
/// incumbent that already achieved `incumbent`? Strict inequality plus
/// [`PRUNE_MARGIN`]: ties are always evaluated, so family-order
/// tie-breaking is preserved exactly.
pub fn prunes(bound: f64, incumbent: f64) -> bool {
    bound > incumbent + incumbent.abs() * PRUNE_MARGIN
}

/// Predicted completion time of `strategy` on a `procs`-rank cluster for
/// message size `m`, with optional segment size (segmented strategies
/// only; `None` means one segment).
///
/// For scatter strategies `m` is the per-rank chunk size; for
/// gather/allgather it is the per-rank block, for reduce/allreduce the
/// vector size, and barriers ignore it.
pub fn predict(strategy: Strategy, net: &PLogP, procs: usize, m: u64, seg: Option<u64>) -> f64 {
    cost_fn(strategy)(&CostInputs::new(net, procs, m, seg))
}

/// Conservative lower bound on a segmented strategy's best achievable
/// time over *any* segment size — the original min-gap pruning test,
/// kept as the reference the m-aware [`LOWER_BOUNDS`] must dominate
/// (asserted by the property tests below); the sweep itself now prunes
/// through [`lower_bound`].
///
/// Sound because interpolated and extrapolated gaps never drop below the
/// table's minimum sampled gap (`GapTable::gap` clamps below the first
/// sample, stays between bracketing samples inside, and floors at the
/// last sample above), and the segment count satisfies `k >= 1`; so
/// replacing every `k·g(s)` / `g(s)` term by `min(samples)` bounds each
/// model from below.
pub fn segmented_lower_bound(strategy: Strategy, net: &PLogP, procs: usize) -> f64 {
    assert!(strategy.is_segmented());
    let g_min = net.table.gaps().iter().copied().fold(f64::INFINITY, f64::min);
    let l = net.l;
    let p = procs as f64;
    match strategy {
        Strategy::BcastSegFlat => (p - 1.0) * g_min + l,
        Strategy::BcastSegChain => (p - 1.0) * (g_min + l),
        Strategy::BcastSegBinomial => floor_log2(procs) * g_min + ceil_log2(procs) * l,
        _ => unreachable!("is_segmented() covers exactly the three Seg variants"),
    }
}

/// Search the segment-size grid for the best segment of a segmented
/// strategy at `(procs, m)`. Returns `(best_time, best_segment)`. The
/// message size itself is always included as a candidate (so the
/// unsegmented case is in the search space — see DESIGN.md).
pub fn best_segment(
    strategy: Strategy,
    net: &PLogP,
    procs: usize,
    m: u64,
    s_grid: &[u64],
) -> (f64, u64) {
    assert!(strategy.is_segmented());
    let mut best = (predict(strategy, net, procs, m, Some(m)), m);
    for &s in s_grid {
        let s = s.clamp(1, m);
        let t = predict(strategy, net, procs, m, Some(s));
        if t < best.0 {
            best = (t, s);
        }
    }
    best
}

/// Evaluate every strategy of one operation family and return
/// `(strategy, time, segment)` sorted ascending by time. Segmented
/// entries report their tuned segment.
pub fn rank_strategies(
    family: &[Strategy],
    net: &PLogP,
    procs: usize,
    m: u64,
    s_grid: &[u64],
) -> Vec<(Strategy, f64, Option<u64>)> {
    let mut out: Vec<(Strategy, f64, Option<u64>)> = family
        .iter()
        .map(|&s| {
            if s.is_segmented() {
                let (t, seg) = best_segment(s, net, procs, m, s_grid);
                (s, t, Some(seg))
            } else {
                (s, predict(s, net, procs, m, None), None)
            }
        })
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plogp::GapTable;

    /// The hand-checkable network from the Python tests:
    /// g(m) = 1 + m, L = 10 (fictional seconds).
    fn toy() -> PLogP {
        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        PLogP::new(10.0, GapTable::new(sizes, gaps))
    }

    #[test]
    fn matches_python_hand_values() {
        // identical cases to python/tests/test_kernel.py TestModelSemantics
        let n = toy();
        let cases: Vec<(Strategy, f64)> = vec![
            (Strategy::BcastFlat, 46.0),
            (Strategy::BcastFlatRdv, 70.0),
            (Strategy::BcastChain, 76.0),
            (Strategy::BcastChainRdv, 172.0),
            (Strategy::BcastBinary, 84.0),
            (Strategy::BcastBinomial, 48.0),
            (Strategy::BcastBinomialRdv, 120.0),
            (Strategy::ScatterFlat, 46.0),
            (Strategy::ScatterChain, 124.0),
            (Strategy::ScatterBinomial, 89.0),
        ];
        for (s, want) in cases {
            let got = predict(s, &n, 5, 8, None);
            assert!((got - want).abs() < 1e-9, "{}: got {got} want {want}", s.name());
        }
    }

    #[test]
    fn segmented_hand_values() {
        let n = toy();
        assert!((predict(Strategy::BcastSegChain, &n, 5, 8, Some(2)) - 61.0).abs() < 1e-9);
        assert!((predict(Strategy::BcastSegFlat, &n, 5, 8, Some(2)) - 58.0).abs() < 1e-9);
        assert!((predict(Strategy::BcastSegBinomial, &n, 5, 8, Some(2)) - 54.0).abs() < 1e-9);
    }

    #[test]
    fn segment_clamps_to_message() {
        let n = toy();
        let unseg = predict(Strategy::BcastFlat, &n, 5, 8, None);
        let clamped = predict(Strategy::BcastSegFlat, &n, 5, 8, Some(64));
        assert!((unseg - clamped).abs() < 1e-12);
    }

    #[test]
    fn binomial_power_of_two() {
        let n = toy();
        // floor = ceil = 3 at P=8: 3*9 + 3*10 = 57
        assert!((predict(Strategy::BcastBinomial, &n, 8, 8, None) - 57.0).abs() < 1e-9);
    }

    #[test]
    fn scatter_binomial_p2() {
        let n = toy();
        assert!((predict(Strategy::ScatterBinomial, &n, 2, 8, None) - 19.0).abs() < 1e-9);
    }

    #[test]
    fn best_segment_includes_m_itself() {
        let n = toy();
        // with a steep per-message cost, segmentation hurts; the search
        // must fall back to s = m (unsegmented)
        let sizes = vec![1.0, 1024.0];
        let gaps = vec![100.0, 101.0]; // all overhead, no bandwidth term
        let nn = PLogP::new(1.0, GapTable::new(sizes, gaps));
        let (t, s) = best_segment(Strategy::BcastSegChain, &nn, 4, 1024, &[16, 64, 256]);
        assert_eq!(s, 1024);
        assert!((t - predict(Strategy::BcastSegChain, &nn, 4, 1024, Some(1024))).abs() < 1e-12);
        let _ = n;
    }

    #[test]
    fn best_segment_picks_minimum() {
        let n = toy();
        let grid = [1u64, 2, 4, 8];
        let (t, s) = best_segment(Strategy::BcastSegBinomial, &n, 5, 8, &grid);
        for &cand in &grid {
            assert!(t <= predict(Strategy::BcastSegBinomial, &n, 5, 8, Some(cand)) + 1e-12);
        }
        assert!(grid.contains(&s) || s == 8);
    }

    #[test]
    fn rank_strategies_sorted_and_complete() {
        let n = toy();
        let ranked = rank_strategies(&Strategy::BCAST, &n, 5, 8, &[2, 4]);
        assert_eq!(ranked.len(), 10);
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        // segmented entries carry a segment
        for (s, _, seg) in &ranked {
            assert_eq!(seg.is_some(), s.is_segmented());
        }
    }

    #[test]
    fn p1_collectives_cost_only_latency_terms() {
        let n = toy();
        // P=1: no sends; flat model (P-1)g+L degenerates to L
        assert!((predict(Strategy::BcastFlat, &n, 1, 8, None) - 10.0).abs() < 1e-9);
        assert_eq!(predict(Strategy::BcastBinomial, &n, 1, 8, None), 0.0);
    }

    #[test]
    fn registry_is_indexed_by_strategy() {
        // every registry entry reproduces predict() for its own strategy
        let n = toy();
        for s in Strategy::ALL {
            let x = CostInputs::new(&n, 5, 8, Some(2));
            assert_eq!(
                cost_fn(s)(&x),
                predict(s, &n, 5, 8, Some(2)),
                "{} registry/predict mismatch",
                s.name()
            );
        }
    }

    #[test]
    fn segmented_lower_bound_is_a_true_lower_bound() {
        let nets = [
            toy(),
            // steep, non-monotone-ish table: all overhead, no bandwidth
            PLogP::new(1.0, GapTable::new(vec![1.0, 1024.0], vec![100.0, 101.0])),
        ];
        for net in &nets {
            for procs in [1usize, 2, 5, 8, 31, 64] {
                for m in [1u64, 7, 8, 1024] {
                    for strat in Strategy::ALL.iter().filter(|s| s.is_segmented()) {
                        let bound = segmented_lower_bound(*strat, net, procs);
                        for s in [1u64, 2, 3, 8, 64, 1024, 1 << 20] {
                            let t = predict(*strat, net, procs, m, Some(s));
                            assert!(
                                bound <= t + 1e-12,
                                "{} P={procs} m={m} s={s}: bound {bound} > time {t}",
                                strat.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn scatter_chain_sums_triangular_gaps() {
        let n = toy();
        // P=3, m=4: g(4)+g(8) + 2L = 5 + 9 + 20 = 34
        assert!((predict(Strategy::ScatterChain, &n, 3, 4, None) - 34.0).abs() < 1e-9);
    }

    /// A random pLogP net with an adversarial (non-monotone) gap table.
    fn random_net(rng: &mut crate::util::prng::Prng) -> PLogP {
        crate::plogp::adversarial_net(rng, 16, 60_000.0)
    }

    /// Property (ISSUE 4 satellite): the m-aware [`LOWER_BOUNDS`]
    /// dominate the legacy min-gap bound on the segmented strategies —
    /// never looser — across randomized networks, process counts, and
    /// message sizes. (Up to a relative ulp slack: the min-gap bound
    /// uses the raw sampled minimum while the m-aware bound evaluates
    /// the interpolant, which can round a few ulps at sample points.)
    #[test]
    fn m_aware_bound_dominates_the_min_gap_bound() {
        let mut rng = crate::util::prng::Prng::new(0xB0DD_0001);
        for _ in 0..60 {
            let net = random_net(&mut rng);
            for procs in [1usize, 2, 5, 17, 48] {
                for m in [1u64, 7, 256, 65_536, 1 << 20] {
                    let bi = BoundInputs::new(&net, procs, m);
                    for strat in Strategy::ALL.iter().filter(|s| s.is_segmented()) {
                        let new = lower_bound(*strat, &bi);
                        let old = segmented_lower_bound(*strat, &net, procs);
                        assert!(
                            new >= old - old.abs() * 1e-12,
                            "{} P={procs} m={m}: m-aware {new} looser than min-gap {old}",
                            strat.name()
                        );
                    }
                }
            }
        }
    }

    /// Property (ISSUE 4 satellite): every [`LOWER_BOUNDS`] entry is a
    /// true lower bound — densely sampling segment sizes (the segmented
    /// strategies' whole search space; unsegmented models ignore the
    /// segment) never finds a cost below the bound, on randomized nets.
    #[test]
    fn lower_bounds_hold_against_dense_segment_sampling() {
        let mut rng = crate::util::prng::Prng::new(0xB0DD_0002);
        for _ in 0..40 {
            let net = random_net(&mut rng);
            for procs in [1usize, 2, 5, 17, 48] {
                for m in [1u64, 7, 256, 65_536, 1 << 20] {
                    let bi = BoundInputs::new(&net, procs, m);
                    // dense log-ish sample of [1, m] plus the endpoints
                    let mut segs: Vec<u64> = vec![1, m];
                    let mut s = 1u64;
                    while s < m {
                        segs.push(s);
                        s = (s * 3 / 2).max(s + 1);
                    }
                    for _ in 0..16 {
                        segs.push(rng.range(1, m + 1));
                    }
                    for strat in Strategy::ALL {
                        let lb = lower_bound(strat, &bi);
                        for &seg in &segs {
                            let t = predict(strat, &net, procs, m, Some(seg));
                            assert!(
                                lb <= t + t.abs() * 1e-9,
                                "{} P={procs} m={m} s={seg}: bound {lb} > cost {t}",
                                strat.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn bound_inputs_match_cached_stats_assembly() {
        let net = toy();
        let direct = BoundInputs::new(&net, 5, 8);
        let range = net.table.range_stats(1.0, 8.0);
        let cached =
            BoundInputs::from_stats(5, 8, net.l, net.gap(1.0), range, net.table.min_gap());
        assert_eq!(direct.gap_min, cached.gap_min);
        assert_eq!(direct.gap_max, cached.gap_max);
        assert_eq!(direct.rate_min, cached.rate_min);
        assert_eq!(direct.gap_floor, cached.gap_floor);
        assert_eq!(direct.rdv, cached.rdv);
        assert_eq!(direct.fl, cached.fl);
        assert_eq!(direct.ce, cached.ce);
    }

    #[test]
    fn prune_test_never_fires_on_ties() {
        assert!(!prunes(1.0, 1.0));
        assert!(!prunes(0.0, 0.0));
        assert!(!prunes(1.0 + 1e-12, 1.0), "sub-margin excess must not prune");
        assert!(prunes(1.1, 1.0));
        assert!(prunes(1.0, 0.0));
        assert!(!prunes(5.0, f64::INFINITY));
    }

    #[test]
    fn cost_inputs_from_parts_is_bit_identical_to_new() {
        let n = toy();
        for (procs, m, seg) in [(5usize, 8u64, 2u64), (1, 1, 1), (48, 1 << 20, 4096)] {
            let a = CostInputs::new(&n, procs, m, Some(seg));
            let rdv = 2.0 * n.gap(1.0) + 3.0 * n.l;
            let s_eff = seg.clamp(1, m);
            let b = CostInputs::from_parts(
                &n,
                procs,
                m,
                s_eff,
                n.gap(m as f64),
                n.gap(s_eff as f64),
                rdv,
            );
            for s in Strategy::ALL {
                assert_eq!(cost_fn(s)(&a), cost_fn(s)(&b), "{} P={procs} m={m}", s.name());
            }
        }
    }
}
