//! Analytic pLogP cost models — Tables 1 and 2 of the paper, in Rust,
//! plus the extended-collective models ([`ext`]) derived the same way —
//! one strategy-indexed registry ([`COST_MODELS`]) for every collective.
//!
//! These are the same formulas the AOT-compiled XLA artifact evaluates
//! (`python/compile/kernels/cost_models.py`); the Rust mirror exists for
//! unit tests, one-off queries, and as the tuner's fallback when no
//! artifact is available. Cross-agreement between the two is asserted by
//! `rust/tests/artifact_roundtrip.rs`.
//!
//! Segment-size semantics match the kernel: a candidate segment `s` is
//! clamped to `min(s, m)` and `k = ceil(m/s)`, so `s >= m` degenerates to
//! the unsegmented model exactly.

pub mod ext;

use crate::collectives::Strategy;
use crate::plogp::PLogP;

/// ceil(log2 p) as f64 (0 for p = 1).
fn ceil_log2(p: usize) -> f64 {
    crate::collectives::tree::ceil_log2(p) as f64
}

/// floor(log2 p) as f64.
fn floor_log2(p: usize) -> f64 {
    crate::collectives::tree::floor_log2(p) as f64
}

/// Pre-computed quantities shared by every per-strategy cost function.
/// Built once per [`predict`] call, so the registry entries stay tiny
/// closed-form expressions.
pub struct CostInputs<'a> {
    pub net: &'a PLogP,
    pub procs: usize,
    /// P as f64.
    pub p: f64,
    /// Message size as f64.
    pub mf: f64,
    pub l: f64,
    pub g_m: f64,
    /// floor(log2 P) and ceil(log2 P) as f64.
    pub fl: f64,
    pub ce: f64,
    /// Rendezvous handshake cost `2 g(1) + 3 L`.
    pub rdv: f64,
    /// Effective segment size, clamped to `[1, m]`.
    pub s_eff: f64,
    /// Segment count `k = ceil(m / s_eff)`.
    pub k: f64,
    /// Per-segment gap `g(s_eff)`.
    pub g_s: f64,
}

impl<'a> CostInputs<'a> {
    pub fn new(net: &'a PLogP, procs: usize, m: u64, seg: Option<u64>) -> CostInputs<'a> {
        assert!(procs >= 1);
        assert!(m >= 1);
        let mf = m as f64;
        let s_eff = seg.unwrap_or(m).clamp(1, m) as f64;
        CostInputs {
            net,
            procs,
            p: procs as f64,
            mf,
            l: net.l,
            g_m: net.gap(mf),
            fl: floor_log2(procs),
            ce: ceil_log2(procs),
            rdv: 2.0 * net.gap(1.0) + 3.0 * net.l,
            s_eff,
            k: (mf / s_eff).ceil(),
            g_s: net.gap(s_eff),
        }
    }
}

/// One closed-form cost model (an entry of [`COST_MODELS`]).
pub type CostFn = fn(&CostInputs) -> f64;

fn cost_bcast_flat(x: &CostInputs) -> f64 {
    (x.p - 1.0) * x.g_m + x.l
}

fn cost_bcast_flat_rdv(x: &CostInputs) -> f64 {
    (x.p - 1.0) * x.g_m + x.rdv
}

fn cost_bcast_seg_flat(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_s * x.k) + x.l
}

fn cost_bcast_chain(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_m + x.l)
}

fn cost_bcast_chain_rdv(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_m + x.rdv)
}

fn cost_bcast_seg_chain(x: &CostInputs) -> f64 {
    (x.p - 1.0) * (x.g_s + x.l) + x.g_s * (x.k - 1.0)
}

fn cost_bcast_binary(x: &CostInputs) -> f64 {
    x.ce * (2.0 * x.g_m + x.l)
}

fn cost_bcast_binomial(x: &CostInputs) -> f64 {
    x.fl * x.g_m + x.ce * x.l
}

fn cost_bcast_binomial_rdv(x: &CostInputs) -> f64 {
    x.fl * x.g_m + x.ce * x.rdv
}

fn cost_bcast_seg_binomial(x: &CostInputs) -> f64 {
    x.fl * x.g_s * x.k + x.ce * x.l
}

fn cost_scatter_flat(x: &CostInputs) -> f64 {
    (x.p - 1.0) * x.g_m + x.l
}

fn cost_scatter_chain(x: &CostInputs) -> f64 {
    let sum: f64 = (1..x.procs).map(|j| x.net.gap(j as f64 * x.mf)).sum();
    sum + (x.p - 1.0) * x.l
}

fn cost_scatter_binomial(x: &CostInputs) -> f64 {
    let sum: f64 = (0..ceil_log2(x.procs) as u32)
        .map(|j| x.net.gap((1u64 << j) as f64 * x.mf))
        .sum();
    sum + x.ce * x.l
}

/// Strategy-indexed cost registry: entry `i` models
/// `Strategy::from_index(i)`. One registry covers every collective —
/// broadcast and scatter (Tables 1 and 2) and the extended operations
/// (gather / reduce / barrier / allgather / allreduce, [`ext`]) — so new
/// backends and tools (the `eval` layer, ablations, docs generators)
/// index this table instead of growing per-op match ladders.
pub const COST_MODELS: [CostFn; Strategy::COUNT] = [
    cost_bcast_flat,
    cost_bcast_flat_rdv,
    cost_bcast_seg_flat,
    cost_bcast_chain,
    cost_bcast_chain_rdv,
    cost_bcast_seg_chain,
    cost_bcast_binary,
    cost_bcast_binomial,
    cost_bcast_binomial_rdv,
    cost_bcast_seg_binomial,
    cost_scatter_flat,
    cost_scatter_chain,
    cost_scatter_binomial,
    ext::cost_gather_flat,
    ext::cost_gather_binomial,
    ext::cost_reduce_binomial,
    ext::cost_barrier_tree,
    ext::cost_barrier_dissemination,
    ext::cost_allgather_gather_bcast,
    ext::cost_allgather_ring,
    ext::cost_allgather_rec_doubling,
    ext::cost_allreduce_reduce_bcast,
    ext::cost_allreduce_rec_doubling,
];

/// The cost model of one strategy.
pub fn cost_fn(strategy: Strategy) -> CostFn {
    COST_MODELS[strategy.index()]
}

/// Predicted completion time of `strategy` on a `procs`-rank cluster for
/// message size `m`, with optional segment size (segmented strategies
/// only; `None` means one segment).
///
/// For scatter strategies `m` is the per-rank chunk size; for
/// gather/allgather it is the per-rank block, for reduce/allreduce the
/// vector size, and barriers ignore it.
pub fn predict(strategy: Strategy, net: &PLogP, procs: usize, m: u64, seg: Option<u64>) -> f64 {
    cost_fn(strategy)(&CostInputs::new(net, procs, m, seg))
}

/// Conservative lower bound on a segmented strategy's best achievable
/// time over *any* segment size — the tuner's per-cell pruning test.
///
/// Sound because interpolated and extrapolated gaps never drop below the
/// table's minimum sampled gap (`GapTable::gap` clamps below the first
/// sample, stays between bracketing samples inside, and floors at the
/// last sample above), and the segment count satisfies `k >= 1`; so
/// replacing every `k·g(s)` / `g(s)` term by `min(samples)` bounds each
/// model from below.
pub fn segmented_lower_bound(strategy: Strategy, net: &PLogP, procs: usize) -> f64 {
    assert!(strategy.is_segmented());
    let g_min = net.table.gaps().iter().copied().fold(f64::INFINITY, f64::min);
    let l = net.l;
    let p = procs as f64;
    match strategy {
        Strategy::BcastSegFlat => (p - 1.0) * g_min + l,
        Strategy::BcastSegChain => (p - 1.0) * (g_min + l),
        Strategy::BcastSegBinomial => floor_log2(procs) * g_min + ceil_log2(procs) * l,
        _ => unreachable!("is_segmented() covers exactly the three Seg variants"),
    }
}

/// Search the segment-size grid for the best segment of a segmented
/// strategy at `(procs, m)`. Returns `(best_time, best_segment)`. The
/// message size itself is always included as a candidate (so the
/// unsegmented case is in the search space — see DESIGN.md).
pub fn best_segment(
    strategy: Strategy,
    net: &PLogP,
    procs: usize,
    m: u64,
    s_grid: &[u64],
) -> (f64, u64) {
    assert!(strategy.is_segmented());
    let mut best = (predict(strategy, net, procs, m, Some(m)), m);
    for &s in s_grid {
        let s = s.clamp(1, m);
        let t = predict(strategy, net, procs, m, Some(s));
        if t < best.0 {
            best = (t, s);
        }
    }
    best
}

/// Evaluate every strategy of one operation family and return
/// `(strategy, time, segment)` sorted ascending by time. Segmented
/// entries report their tuned segment.
pub fn rank_strategies(
    family: &[Strategy],
    net: &PLogP,
    procs: usize,
    m: u64,
    s_grid: &[u64],
) -> Vec<(Strategy, f64, Option<u64>)> {
    let mut out: Vec<(Strategy, f64, Option<u64>)> = family
        .iter()
        .map(|&s| {
            if s.is_segmented() {
                let (t, seg) = best_segment(s, net, procs, m, s_grid);
                (s, t, Some(seg))
            } else {
                (s, predict(s, net, procs, m, None), None)
            }
        })
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plogp::GapTable;

    /// The hand-checkable network from the Python tests:
    /// g(m) = 1 + m, L = 10 (fictional seconds).
    fn toy() -> PLogP {
        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        PLogP::new(10.0, GapTable::new(sizes, gaps))
    }

    #[test]
    fn matches_python_hand_values() {
        // identical cases to python/tests/test_kernel.py TestModelSemantics
        let n = toy();
        let cases: Vec<(Strategy, f64)> = vec![
            (Strategy::BcastFlat, 46.0),
            (Strategy::BcastFlatRdv, 70.0),
            (Strategy::BcastChain, 76.0),
            (Strategy::BcastChainRdv, 172.0),
            (Strategy::BcastBinary, 84.0),
            (Strategy::BcastBinomial, 48.0),
            (Strategy::BcastBinomialRdv, 120.0),
            (Strategy::ScatterFlat, 46.0),
            (Strategy::ScatterChain, 124.0),
            (Strategy::ScatterBinomial, 89.0),
        ];
        for (s, want) in cases {
            let got = predict(s, &n, 5, 8, None);
            assert!((got - want).abs() < 1e-9, "{}: got {got} want {want}", s.name());
        }
    }

    #[test]
    fn segmented_hand_values() {
        let n = toy();
        assert!((predict(Strategy::BcastSegChain, &n, 5, 8, Some(2)) - 61.0).abs() < 1e-9);
        assert!((predict(Strategy::BcastSegFlat, &n, 5, 8, Some(2)) - 58.0).abs() < 1e-9);
        assert!((predict(Strategy::BcastSegBinomial, &n, 5, 8, Some(2)) - 54.0).abs() < 1e-9);
    }

    #[test]
    fn segment_clamps_to_message() {
        let n = toy();
        let unseg = predict(Strategy::BcastFlat, &n, 5, 8, None);
        let clamped = predict(Strategy::BcastSegFlat, &n, 5, 8, Some(64));
        assert!((unseg - clamped).abs() < 1e-12);
    }

    #[test]
    fn binomial_power_of_two() {
        let n = toy();
        // floor = ceil = 3 at P=8: 3*9 + 3*10 = 57
        assert!((predict(Strategy::BcastBinomial, &n, 8, 8, None) - 57.0).abs() < 1e-9);
    }

    #[test]
    fn scatter_binomial_p2() {
        let n = toy();
        assert!((predict(Strategy::ScatterBinomial, &n, 2, 8, None) - 19.0).abs() < 1e-9);
    }

    #[test]
    fn best_segment_includes_m_itself() {
        let n = toy();
        // with a steep per-message cost, segmentation hurts; the search
        // must fall back to s = m (unsegmented)
        let sizes = vec![1.0, 1024.0];
        let gaps = vec![100.0, 101.0]; // all overhead, no bandwidth term
        let nn = PLogP::new(1.0, GapTable::new(sizes, gaps));
        let (t, s) = best_segment(Strategy::BcastSegChain, &nn, 4, 1024, &[16, 64, 256]);
        assert_eq!(s, 1024);
        assert!((t - predict(Strategy::BcastSegChain, &nn, 4, 1024, Some(1024))).abs() < 1e-12);
        let _ = n;
    }

    #[test]
    fn best_segment_picks_minimum() {
        let n = toy();
        let grid = [1u64, 2, 4, 8];
        let (t, s) = best_segment(Strategy::BcastSegBinomial, &n, 5, 8, &grid);
        for &cand in &grid {
            assert!(t <= predict(Strategy::BcastSegBinomial, &n, 5, 8, Some(cand)) + 1e-12);
        }
        assert!(grid.contains(&s) || s == 8);
    }

    #[test]
    fn rank_strategies_sorted_and_complete() {
        let n = toy();
        let ranked = rank_strategies(&Strategy::BCAST, &n, 5, 8, &[2, 4]);
        assert_eq!(ranked.len(), 10);
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        // segmented entries carry a segment
        for (s, _, seg) in &ranked {
            assert_eq!(seg.is_some(), s.is_segmented());
        }
    }

    #[test]
    fn p1_collectives_cost_only_latency_terms() {
        let n = toy();
        // P=1: no sends; flat model (P-1)g+L degenerates to L
        assert!((predict(Strategy::BcastFlat, &n, 1, 8, None) - 10.0).abs() < 1e-9);
        assert_eq!(predict(Strategy::BcastBinomial, &n, 1, 8, None), 0.0);
    }

    #[test]
    fn registry_is_indexed_by_strategy() {
        // every registry entry reproduces predict() for its own strategy
        let n = toy();
        for s in Strategy::ALL {
            let x = CostInputs::new(&n, 5, 8, Some(2));
            assert_eq!(
                cost_fn(s)(&x),
                predict(s, &n, 5, 8, Some(2)),
                "{} registry/predict mismatch",
                s.name()
            );
        }
    }

    #[test]
    fn segmented_lower_bound_is_a_true_lower_bound() {
        let nets = [
            toy(),
            // steep, non-monotone-ish table: all overhead, no bandwidth
            PLogP::new(1.0, GapTable::new(vec![1.0, 1024.0], vec![100.0, 101.0])),
        ];
        for net in &nets {
            for procs in [1usize, 2, 5, 8, 31, 64] {
                for m in [1u64, 7, 8, 1024] {
                    for strat in Strategy::ALL.iter().filter(|s| s.is_segmented()) {
                        let bound = segmented_lower_bound(*strat, net, procs);
                        for s in [1u64, 2, 3, 8, 64, 1024, 1 << 20] {
                            let t = predict(*strat, net, procs, m, Some(s));
                            assert!(
                                bound <= t + 1e-12,
                                "{} P={procs} m={m} s={s}: bound {bound} > time {t}",
                                strat.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn scatter_chain_sums_triangular_gaps() {
        let n = toy();
        // P=3, m=4: g(4)+g(8) + 2L = 5 + 9 + 20 = 34
        assert!((predict(Strategy::ScatterChain, &n, 3, 4, None) - 34.0).abs() < 1e-9);
    }
}
