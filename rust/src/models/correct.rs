//! Trace-fitted correction factors — the calibration layer that closes
//! the model–simulator gap.
//!
//! The analytic pLogP models ([`super::COST_MODELS`]) deviate from
//! measured runs in strategy- and size-dependent ways (the
//! characterisation companion paper maps exactly where). The production
//! answer — NCCL's `treeCorrectionFactor` — is a static table of
//! per-(algorithm, size-regime) multipliers fitted from measurements
//! and applied on top of the analytic model. This module is that table:
//!
//! * [`CorrectionTable`] maps `(strategy, octave(m))` to a multiplier,
//!   identity (`1.0`) for every unfitted cell. Buckets are log-spaced
//!   octaves (`floor(log2 m)`), the same geometric spacing the
//!   signature probe sizes use.
//! * [`CorrectionTable::fit`] estimates each bucket's multiplier by a
//!   least-squares ratio of captured [`TraceSet`] critical paths to the
//!   uncorrected model predictions: with `q = predicted/measured`, the
//!   `c` minimising `Σ (c·q − 1)²` (the summed squared *relative*
//!   error) is `Σq / Σq²`.
//! * The table persists as a versioned TSV (`corrections v1`) that
//!   round-trips byte-identically, mirroring `trace v1` and the
//!   decision-table format.
//!
//! Correctness under pruning: within one `(p, m)` cell the factor of a
//! strategy is a single known constant (it depends only on `octave(m)`),
//! so a corrected cost is exactly `factor × uncorrected cost` and a
//! strategy's screening bound scales by the same factor — the
//! byte-identical-to-exhaustive-argmin guarantee survives correction
//! (property-tested in `rust/tests/properties.rs`).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::collectives::Strategy;
use crate::netsim::TraceSet;
use crate::plogp::{GapTable, PLogP};
use crate::tuner::Op;

const HEADER: &str = "# collective-tuner corrections v1";

/// File name used inside a corrections directory.
pub const FILE_NAME: &str = "corrections.tsv";

/// Fitted multipliers are clamped to this range — wide enough for any
/// plausible model/simulator gap, tight enough that one corrupt trace
/// cannot turn the model upside down.
pub const FACTOR_CLAMP: (f64, f64) = (1e-3, 1e3);

/// Octave bucket of a message size: `floor(log2(max(m, 1)))`. Log-
/// spaced like the signature probe sizes, so one bucket covers one
/// doubling of the message size.
pub fn octave(m: u64) -> u32 {
    63 - m.max(1).leading_zeros()
}

/// Per-(strategy, m-octave) multiplicative correction of the analytic
/// models. The empty table is the identity: `factor()` returns `1.0`
/// for every cell that was never fitted.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CorrectionTable {
    /// `(strategy index, octave) -> multiplier`. A `BTreeMap` so the
    /// TSV emit order is sorted and byte-stable.
    factors: BTreeMap<(usize, u32), f64>,
}

impl CorrectionTable {
    /// The identity table (every factor `1.0`).
    pub fn identity() -> CorrectionTable {
        CorrectionTable::default()
    }

    pub fn is_empty(&self) -> bool {
        self.factors.is_empty()
    }

    /// Number of fitted `(strategy, octave)` cells.
    pub fn len(&self) -> usize {
        self.factors.len()
    }

    /// Set one cell's multiplier. Factors must be positive and finite.
    pub fn set(&mut self, strategy: Strategy, octave: u32, factor: f64) {
        assert!(
            factor.is_finite() && factor > 0.0,
            "correction factor must be positive and finite, got {factor}"
        );
        self.factors.insert((strategy.index(), octave), factor);
    }

    /// The multiplier for `strategy` at message size `m` — `1.0` when
    /// the cell was never fitted.
    pub fn factor(&self, strategy: Strategy, m: u64) -> f64 {
        *self.factors.get(&(strategy.index(), octave(m))).unwrap_or(&1.0)
    }

    /// The smallest multiplier `strategy` can ever receive, over every
    /// fitted octave *and* the implicit identity of unfitted ones.
    /// Scaling a strategy's lower bound by this is sound at any `m`;
    /// the evaluator uses the exact per-cell [`Self::factor`] (tighter,
    /// equally sound) because `m` is fixed inside a cell.
    pub fn min_factor(&self, strategy: Strategy) -> f64 {
        let i = strategy.index();
        self.factors
            .range((i, 0)..=(i, u32::MAX))
            .map(|(_, &f)| f)
            .fold(1.0, f64::min)
    }

    /// Iterate fitted cells as `(strategy, octave, factor)` in sorted
    /// (strategy index, octave) order.
    pub fn entries(&self) -> impl Iterator<Item = (Strategy, u32, f64)> + '_ {
        self.factors.iter().map(|(&(si, b), &f)| {
            (
                Strategy::from_index(si).expect("table holds valid strategy indices"),
                b,
                f,
            )
        })
    }

    /// Fit a table from captured traces against `net`'s uncorrected
    /// model predictions. Returns the table plus a [`FitReport`] of
    /// mean relative error before/after at bucket, strategy, and op
    /// granularity. Records with unknown strategies or degenerate
    /// (non-positive / non-finite) measurements or predictions are
    /// skipped and counted.
    pub fn fit(traces: &TraceSet, net: &PLogP) -> (CorrectionTable, FitReport) {
        // (strategy index, octave) -> q samples, q = predicted/measured
        let mut samples: BTreeMap<(usize, u32), Vec<f64>> = BTreeMap::new();
        let mut skipped = 0usize;
        for rec in traces.records() {
            let Some(strategy) = Strategy::from_name(&rec.meta.strategy) else {
                skipped += 1;
                continue;
            };
            if rec.meta.p == 0 {
                skipped += 1;
                continue;
            }
            let measured = rec.critical_path().as_secs();
            let predicted =
                super::predict(strategy, net, rec.meta.p, rec.meta.m.max(1), rec.meta.segment);
            if !(measured.is_finite() && measured > 0.0 && predicted.is_finite() && predicted > 0.0)
            {
                skipped += 1;
                continue;
            }
            samples
                .entry((strategy.index(), octave(rec.meta.m)))
                .or_default()
                .push(predicted / measured);
        }

        let mut table = CorrectionTable::default();
        let mut report = FitReport { skipped, ..FitReport::default() };
        for (&(si, b), qs) in &samples {
            let strategy = Strategy::from_index(si).expect("indices come from Strategy::index");
            let (sum_q, sum_q2) = qs.iter().fold((0.0, 0.0), |(s, s2), &q| (s + q, s2 + q * q));
            // argmin_c Σ (c·q − 1)²  =  Σq / Σq²
            let c = sum_q / sum_q2;
            if !c.is_finite() || c <= 0.0 {
                report.skipped += qs.len();
                continue;
            }
            let c = c.clamp(FACTOR_CLAMP.0, FACTOR_CLAMP.1);
            table.factors.insert((si, b), c);
            let stats = ErrStats::of(qs, c);
            report.push(strategy, b, c, stats);
        }
        report.finish();
        (table, report)
    }

    /// Serialize as `corrections v1` TSV. Deterministic: cells emit in
    /// sorted (strategy index, octave) order with shortest-roundtrip
    /// float formatting, so save → load → save is byte-identical.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from(HEADER);
        out.push('\n');
        out.push_str("# strategy\toctave\tfactor\n");
        for (strategy, b, f) in self.entries() {
            writeln!(out, "{}\t{}\t{}", strategy.name(), b, f).expect("writing to String");
        }
        out
    }

    /// Parse the `corrections v1` TSV format.
    pub fn from_tsv(text: &str) -> Result<CorrectionTable> {
        let mut lines = text.lines().enumerate();
        match lines.next() {
            Some((_, h)) if h.trim_end() == HEADER => {}
            other => bail!(
                "not a corrections v1 file (expected {HEADER:?}, got {:?})",
                other.map(|(_, h)| h)
            ),
        }
        let mut table = CorrectionTable::default();
        for (i, line) in lines {
            let line = line.trim_end();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let (name, octave, factor) = match (parts.next(), parts.next(), parts.next()) {
                (Some(n), Some(b), Some(f)) => (n, b, f),
                _ => bail!("line {}: expected 3 tab-separated fields: {line:?}", i + 1),
            };
            let strategy = Strategy::from_name(name)
                .with_context(|| format!("line {}: unknown strategy {name:?}", i + 1))?;
            let octave: u32 = octave
                .parse()
                .with_context(|| format!("line {}: bad octave {octave:?}", i + 1))?;
            let factor: f64 = factor
                .parse()
                .with_context(|| format!("line {}: bad factor {factor:?}", i + 1))?;
            if !(factor.is_finite() && factor > 0.0) {
                bail!("line {}: factor must be positive and finite, got {factor}", i + 1);
            }
            table.factors.insert((strategy.index(), octave), factor);
        }
        Ok(table)
    }

    /// Write `corrections.tsv` into `dir` (created if missing).
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating corrections dir {}", dir.display()))?;
        let path = dir.join(FILE_NAME);
        std::fs::write(&path, self.to_tsv())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    /// Load from a corrections directory (reads `corrections.tsv`
    /// inside it) or directly from a TSV file path.
    pub fn load(path: &Path) -> Result<CorrectionTable> {
        let file = if path.is_dir() { path.join(FILE_NAME) } else { path.to_path_buf() };
        let text = std::fs::read_to_string(&file)
            .with_context(|| format!("reading corrections table {}", file.display()))?;
        CorrectionTable::from_tsv(&text)
            .with_context(|| format!("parsing corrections table {}", file.display()))
    }
}

/// The pLogP network a trace set was captured on, rebuilt from the
/// first record's embedded signature — the same reconstruction
/// `ReplayEval::new` performs. `None` for an empty set.
pub fn net_of(traces: &TraceSet) -> Option<PLogP> {
    let first = traces.records().next()?;
    Some(PLogP::new(
        first.meta.plogp_l,
        GapTable::new(first.meta.plogp_sizes.clone(), first.meta.plogp_gaps.clone()),
    ))
}

/// Mean relative error of one sample population, before and after its
/// fitted factor is applied.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ErrStats {
    pub samples: usize,
    /// mean |predicted/measured − 1| (uncorrected).
    pub mape_before: f64,
    /// mean |factor·predicted/measured − 1| (corrected).
    pub mape_after: f64,
}

impl ErrStats {
    fn of(qs: &[f64], c: f64) -> ErrStats {
        let n = qs.len() as f64;
        ErrStats {
            samples: qs.len(),
            mape_before: qs.iter().map(|q| (q - 1.0).abs()).sum::<f64>() / n,
            mape_after: qs.iter().map(|q| (c * q - 1.0).abs()).sum::<f64>() / n,
        }
    }

    fn absorb(&mut self, other: &ErrStats) {
        let n = (self.samples + other.samples) as f64;
        if n == 0.0 {
            return;
        }
        let (a, b) = (self.samples as f64, other.samples as f64);
        self.mape_before = (self.mape_before * a + other.mape_before * b) / n;
        self.mape_after = (self.mape_after * a + other.mape_after * b) / n;
        self.samples += other.samples;
    }
}

/// What [`CorrectionTable::fit`] measured: per-bucket factors plus mean
/// relative error before/after at every granularity the CLI reports.
#[derive(Debug, Clone, Default)]
pub struct FitReport {
    /// One row per fitted `(strategy, octave)` cell.
    pub buckets: Vec<(Strategy, u32, f64, ErrStats)>,
    /// Aggregated per strategy (sample-weighted).
    pub strategies: Vec<(Strategy, ErrStats)>,
    /// Aggregated per op family (sample-weighted).
    pub ops: Vec<(Op, ErrStats)>,
    /// Aggregated over every fitted sample.
    pub overall: ErrStats,
    /// Records not used by the fit (unknown strategy, degenerate
    /// measurement or prediction).
    pub skipped: usize,
}

impl FitReport {
    fn push(&mut self, strategy: Strategy, octave: u32, factor: f64, stats: ErrStats) {
        self.buckets.push((strategy, octave, factor, stats));
    }

    /// Roll bucket rows up into the strategy / op / overall aggregates.
    fn finish(&mut self) {
        let mut per_strategy: BTreeMap<usize, ErrStats> = BTreeMap::new();
        let mut per_op: BTreeMap<usize, (Op, ErrStats)> = BTreeMap::new();
        for (strategy, _, _, stats) in &self.buckets {
            per_strategy.entry(strategy.index()).or_default().absorb(stats);
            let op = Op::of(*strategy);
            per_op.entry(op.index()).or_insert((op, ErrStats::default())).1.absorb(stats);
            self.overall.absorb(stats);
        }
        self.strategies = per_strategy
            .into_iter()
            .map(|(si, stats)| {
                (Strategy::from_index(si).expect("valid strategy index"), stats)
            })
            .collect();
        self.ops = per_op.into_values().collect();
    }

    /// Human-readable summary (the `calibrate` subcommand's output).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "fitted {} bucket(s) from {} sample(s) ({} skipped)",
            self.buckets.len(),
            self.overall.samples,
            self.skipped
        );
        let _ = writeln!(out, "\nper-strategy mean relative error (before -> after):");
        for (strategy, stats) in &self.strategies {
            let _ = writeln!(
                out,
                "  {:28} {:>3} samples  {:.4} -> {:.4}",
                strategy.name(),
                stats.samples,
                stats.mape_before,
                stats.mape_after
            );
        }
        let _ = writeln!(out, "\nper-op mean relative error (before -> after):");
        for (op, stats) in &self.ops {
            let _ = writeln!(
                out,
                "  {:28} {:>3} samples  {:.4} -> {:.4}",
                op.name(),
                stats.samples,
                stats.mape_before,
                stats.mape_after
            );
        }
        let _ = writeln!(
            out,
            "\noverall: {:.4} -> {:.4} over {} samples",
            self.overall.mape_before, self.overall.mape_after, self.overall.samples
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{TraceMeta, TraceRecord};
    use crate::tuner::Op;

    fn toy_net() -> PLogP {
        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        PLogP::new(10.0, GapTable::new(sizes, gaps))
    }

    /// A trace record whose measured critical path is `scale ×` the
    /// model prediction for its cell.
    fn scaled_record(net: &PLogP, strategy: Strategy, p: usize, m: u64, scale: f64) -> TraceRecord {
        let predicted = crate::models::predict(strategy, net, p, m, None);
        TraceRecord {
            meta: TraceMeta {
                op: Op::of(strategy).name().to_string(),
                strategy: strategy.name().to_string(),
                p,
                m,
                segment: None,
                completion_ns: (predicted * scale * 1e9).round() as u64,
                dropped: 0,
                plogp_l: net.l,
                plogp_sizes: net.table.sizes().to_vec(),
                plogp_gaps: net.table.gaps().to_vec(),
                fault_plan: None,
            },
            events: Vec::new(),
        }
    }

    #[test]
    fn octave_is_floor_log2() {
        assert_eq!(octave(0), 0);
        assert_eq!(octave(1), 0);
        assert_eq!(octave(2), 1);
        assert_eq!(octave(3), 1);
        assert_eq!(octave(4), 2);
        assert_eq!(octave(1023), 9);
        assert_eq!(octave(1024), 10);
        assert_eq!(octave(1 << 20), 20);
        assert_eq!(octave(u64::MAX), 63);
    }

    #[test]
    fn identity_table_is_all_ones() {
        let t = CorrectionTable::identity();
        assert!(t.is_empty());
        for s in Strategy::ALL {
            for m in [1u64, 7, 1024, 1 << 20] {
                assert_eq!(t.factor(s, m), 1.0);
            }
            assert_eq!(t.min_factor(s), 1.0);
        }
    }

    #[test]
    fn factor_hits_its_bucket_and_min_factor_includes_identity() {
        let mut t = CorrectionTable::identity();
        t.set(Strategy::BcastFlat, octave(1024), 2.5);
        t.set(Strategy::BcastFlat, octave(64), 0.5);
        // inside fitted octaves
        assert_eq!(t.factor(Strategy::BcastFlat, 1024), 2.5);
        assert_eq!(t.factor(Strategy::BcastFlat, 2047), 2.5);
        assert_eq!(t.factor(Strategy::BcastFlat, 64), 0.5);
        // unfitted octave and unfitted strategy stay identity
        assert_eq!(t.factor(Strategy::BcastFlat, 1), 1.0);
        assert_eq!(t.factor(Strategy::BcastChain, 1024), 1.0);
        // min over fitted factors and the implicit identity
        assert_eq!(t.min_factor(Strategy::BcastFlat), 0.5);
        assert_eq!(t.min_factor(Strategy::BcastChain), 1.0);
        let mut up = CorrectionTable::identity();
        up.set(Strategy::BcastFlat, 3, 4.0);
        // all fitted factors above 1: identity caps the min
        assert_eq!(up.min_factor(Strategy::BcastFlat), 1.0);
    }

    #[test]
    fn tsv_round_trips_byte_identically() {
        let mut t = CorrectionTable::identity();
        t.set(Strategy::BcastFlat, 0, 1.25);
        t.set(Strategy::BcastFlat, 10, 0.07300000000000001);
        t.set(Strategy::AllReduceRecDoubling, 20, 1.0 / 3.0);
        t.set(Strategy::ScatterBinomial, 5, 17.0);
        let first = t.to_tsv();
        let reloaded = CorrectionTable::from_tsv(&first).unwrap();
        assert_eq!(reloaded, t);
        assert_eq!(reloaded.to_tsv(), first, "save -> load -> save must be byte-identical");
    }

    #[test]
    fn tsv_rejects_garbage() {
        assert!(CorrectionTable::from_tsv("").is_err());
        assert!(CorrectionTable::from_tsv("# wrong header\n").is_err());
        let bad_strategy = format!("{HEADER}\nno-such-strategy\t3\t1.5\n");
        assert!(CorrectionTable::from_tsv(&bad_strategy).is_err());
        let bad_factor = format!("{HEADER}\nbcast/flat\t3\t-1.5\n");
        assert!(CorrectionTable::from_tsv(&bad_factor).is_err());
        let short = format!("{HEADER}\nbcast/flat\t3\n");
        assert!(CorrectionTable::from_tsv(&short).is_err());
    }

    #[test]
    fn save_and_load_accept_dir_or_file() {
        let dir = std::env::temp_dir().join("ct-corrections-roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let mut t = CorrectionTable::identity();
        t.set(Strategy::BcastBinomial, 7, 1.75);
        let path = t.save(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), FILE_NAME);
        assert_eq!(CorrectionTable::load(&dir).unwrap(), t);
        assert_eq!(CorrectionTable::load(&path).unwrap(), t);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fit_recovers_a_systematic_scale_and_reduces_error() {
        let net = toy_net();
        let mut set = TraceSet::default();
        // the simulator runs bcast-flat 2x slower than the model says,
        // and bcast-binomial 1.5x, across several cells in one octave
        for m in [8u64, 9, 10, 12, 15] {
            set.insert(scaled_record(&net, Strategy::BcastFlat, 5, m, 2.0));
            set.insert(scaled_record(&net, Strategy::BcastBinomial, 5, m, 1.5));
        }
        let (table, report) = CorrectionTable::fit(&set, &net);
        assert_eq!(report.skipped, 0);
        // measured = 2x predicted -> factor ~ 2 (up to the integer-ns
        // quantisation of completion_ns)
        let f = table.factor(Strategy::BcastFlat, 8);
        assert!((f - 2.0).abs() < 1e-3, "factor {f} should be ~2.0");
        let f = table.factor(Strategy::BcastBinomial, 8);
        assert!((f - 1.5).abs() < 1e-3, "factor {f} should be ~1.5");
        // untouched cells stay identity
        assert_eq!(table.factor(Strategy::BcastFlat, 1024), 1.0);
        assert_eq!(table.factor(Strategy::BcastChain, 8), 1.0);
        // the fit strictly reduces mean relative error at every level
        for (_, _, _, stats) in &report.buckets {
            assert!(stats.mape_after < stats.mape_before);
        }
        for (_, stats) in &report.strategies {
            assert!(stats.mape_after < stats.mape_before);
        }
        for (_, stats) in &report.ops {
            assert!(stats.mape_after < stats.mape_before);
        }
        assert!(report.overall.mape_after < report.overall.mape_before);
        assert!(!report.to_text().is_empty());
    }

    #[test]
    fn fit_skips_degenerate_records() {
        let net = toy_net();
        let mut set = TraceSet::default();
        let mut rec = scaled_record(&net, Strategy::BcastFlat, 5, 8, 2.0);
        rec.meta.strategy = "no-such-strategy".to_string();
        set.insert(rec);
        let mut zero = scaled_record(&net, Strategy::BcastChain, 5, 8, 2.0);
        zero.meta.completion_ns = 0; // degenerate measurement
        set.insert(zero);
        let (table, report) = CorrectionTable::fit(&set, &net);
        assert!(table.is_empty());
        assert_eq!(report.skipped, 2);
        assert_eq!(report.overall.samples, 0);
    }

    #[test]
    fn fit_on_an_empty_set_is_identity() {
        let net = toy_net();
        let (table, report) = CorrectionTable::fit(&TraceSet::default(), &net);
        assert!(table.is_empty());
        assert_eq!(report.overall.samples, 0);
    }

    #[test]
    fn net_of_rebuilds_the_captured_network() {
        let net = toy_net();
        let mut set = TraceSet::default();
        set.insert(scaled_record(&net, Strategy::BcastFlat, 5, 8, 1.0));
        let rebuilt = net_of(&set).unwrap();
        assert_eq!(rebuilt.l, net.l);
        assert_eq!(rebuilt.gap(8.0), net.gap(8.0));
        assert!(net_of(&TraceSet::default()).is_none());
    }
}
