//! Hand-rolled CLI argument handling (clap is unavailable offline).
//!
//! Grammar: `collective-tuner <command> [--key value | --flag]...`
//! The `obs` command additionally takes one positional subcommand
//! (`obs dump`); every other command still rejects positionals.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::netsim::NetConfig;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut subcommand = None;
        if command == "obs" {
            if let Some(v) = it.peek() {
                if !v.starts_with("--") {
                    subcommand = it.next();
                }
            }
        }
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}' (options are --key value)");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    opts.insert(key.to_string(), it.next().unwrap());
                }
                _ => flags.push(key.to_string()),
            }
        }
        Ok(Args { command, subcommand, opts, flags })
    }

    /// The positional subcommand (only the `obs` command takes one).
    pub fn subcommand(&self) -> Option<&str> {
        self.subcommand.as_deref()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_size(v),
        }
    }

    /// Comma-separated usize list.
    pub fn usize_list(&self, name: &str) -> Result<Option<Vec<usize>>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("--{name}: bad entry '{t}'"))
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }

    /// The `--log-level` option parsed to a [`log::Level`] (any
    /// command takes it; `main` installs the stderr sink).
    pub fn log_level(&self) -> Result<Option<log::Level>> {
        match self.get("log-level") {
            None => Ok(None),
            Some(v) => log::Level::from_name(v).map(Some).ok_or_else(|| {
                anyhow::anyhow!(
                    "--log-level: '{v}' is not a level (error, warn, info, debug, trace)"
                )
            }),
        }
    }

    /// Network preset by name.
    pub fn net_config(&self) -> Result<NetConfig> {
        let preset = self.get_or("preset", "icluster1");
        let mut cfg = match preset.as_str() {
            "icluster1" | "fast-ethernet" => NetConfig::fast_ethernet_icluster1(),
            "ideal" => NetConfig::fast_ethernet_ideal(),
            "gigabit" | "gige" => NetConfig::gigabit_ethernet(),
            "myrinet" => NetConfig::myrinet_like(),
            other => bail!(
                "unknown --preset '{other}' (icluster1, ideal, gigabit, myrinet)"
            ),
        };
        match self.get_or("tcp", "default").as_str() {
            "default" => {}
            "ideal" => cfg.tcp = crate::netsim::TcpConfig::ideal(),
            "linux22" => cfg.tcp = crate::netsim::TcpConfig::linux22(),
            other => bail!("unknown --tcp '{other}' (default, ideal, linux22)"),
        }
        Ok(cfg)
    }
}

/// Parse a byte size with optional k/M suffix ("64k", "1M", "512").
pub fn parse_size(s: &str) -> Result<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('k') | Some('K') => (&s[..s.len() - 1], 1024u64),
        Some('m') | Some('M') => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    let v: f64 = num
        .parse()
        .map_err(|_| anyhow::anyhow!("'{s}' is not a size (try 512, 64k, 1M)"))?;
    Ok((v * mult as f64).round() as u64)
}

pub const USAGE: &str = "\
collective-tuner — fast tuning of intra-cluster collective communications
(reproduction of Barchet-Estefanel & Mounié, 2004)

USAGE:
  collective-tuner <command> [options]

GLOBAL OPTIONS:
  --log-level error|warn|info|debug|trace
                install the built-in stderr logger (timestamped lines,
                level filter); without it only warn/error are printed

COMMANDS:
  bench-plogp   measure pLogP parameters (L and the g(m) table)
                  --preset icluster1|ideal|gigabit|myrinet  --tcp default|ideal|linux22
  tune          build decision tables for any collective family
                  --op bcast,scatter|gather|barrier|allgather|allreduce|all
                      (comma-separated; default bcast,scatter)
                  --procs 2,8,24,48   --backend auto|native|artifact|replay
                  --trace-dir dir/    (replay backend: tune from captured
                                       traces over the captured grids)
                  --jobs N            (parallel sweep workers; 0 = all cores)
                  --corrections dir/  (apply a fitted corrections table to
                                       the native models — see calibrate)
                  --save results/     (persist tables as TSV)
                  --stats             (sweep counters: model invocations,
                                       pruned searches, warm-start hits)
  record        capture message traces: run every strategy of each op on
                the traced simulator and persist one trace per
                (op, strategy, P, m) cell — the replay backend's input
                  --trace-dir dir/    (output; required)
                  --op <list|all>     (default bcast,scatter)
                  --procs 2,4,8,16,32 --mpoints 9   (capture grids)
                  --capacity 65536    (per-run trace ring capacity)
  replay        tune from captured traces (deterministic regression mode):
                exact scores for captured cells, gap-model interpolation
                in between, +inf for anything unobserved
                  --trace-dir dir/    (required)  --op <list|all>
                  --jobs N  --save results/  --stats  (replay coverage)
  calibrate     fit trace-derived correction factors — one multiplier per
                (strategy, size-octave) least-squares ratio of captured
                completion times to model predictions — and write the
                versioned corrections TSV other commands accept via
                --corrections
                  --trace-dir dir/    (captured traces; required)
                  --save dir/         (write dir/corrections.tsv)
  validate      cross-check two evaluation backends: the candidate picks
                per-cell winners, the reference judges them
                  --candidate native|sim|replay     (default native)
                  --reference sim|replay            (default sim)
                  --trace-dir dir/    (required when either side is replay;
                                       grids default to the captured ones)
                  --op <list|all>     (default bcast,scatter)
                  --corrections dir/  (calibration report instead: the same
                                       reference judges the uncorrected vs
                                       the corrected native model)
  run           execute one collective on the simulated cluster
                  --op bcast|scatter|gather|reduce|barrier|allgather|allreduce
                  --strategy <name|auto>  --procs 24  --bytes 64k  --segment 8k
  experiment    regenerate a paper figure/table
                  --id tables|fig1a|fig1b|fig2|fig3a|fig3b|fig4|validate|all
                  --out results/
  discover      recover islands-of-clusters from latency probes
                  --nodes 12  --clusters 2
  serve         run the L3 tuning coordinator under concurrent load:
                register islands, serve (op, cluster, P, m) queries — a
                mix of all seven op families — from worker threads, then
                run one drift-refresh pass
                  --clusters 3   --nodes 16        (islands, nodes per island)
                  --threads 8    --requests 10000  (load per thread)
                  --shards 8     --capacity 32     (decision-table cache)
                  --jobs N       (tuner sweep workers; 0 = all cores)
                  --backend auto|native|artifact   --save dir/  --warm dir/
                  --corrections dir/  (tune with a fitted corrections table;
                                       pins the native backend)
                  --stats        (one JSON blob: cache hit/miss + sweep counters)
                  --metrics-interval N   (print an obs registry snapshot every
                                          N seconds while serving, plus a final
                                          snapshot and flight-recorder dump)
  coordd        run the coordinator as a network service: the ct/1
                TSV-over-TCP protocol (docs/PROTOCOL.md) — batched
                queries, subscriptions, INVALIDATE/TABLEUPDATE pushes
                on drift re-publish, graceful shutdown on SIGTERM-free
                platforms via --allow-remote-shutdown
                  --listen 127.0.0.1:7177   (port 0 = ephemeral; the bound
                                             address is printed as
                                             'COORDD_LISTENING <addr>')
                  --clusters 3   --nodes 16  (islands to register up front)
                  --shards 8     --capacity 32   --jobs N
                  --backend auto|native|artifact  --warm dir/
                  --corrections dir/  (fitted corrections table; pins the
                                       native backend)
                  --churn-ms N   (background drift loop: alternate one
                                  island's hardware class every N ms and
                                  refresh, driving real pushes)
                  --allow-remote-shutdown  (accept the SHUTDOWN frame)
                  --metrics-interval N     (print an obs snapshot every N
                                            seconds, plus a final
                                            OBS_SNAPSHOT_JSON line on exit)
                  --idle-timeout SECS      (reap connections idle that long;
                                            0/absent = never)
                  --max-connections N      (shed new connections past N live
                                            ones with a retryable NACK busy)
                  --max-staleness SECS     (serve evicted tables this long
                                            when a re-tune fails, default 300)
                  --inject-tune-failure-at N  (chaos hook: arm one injected
                                               tuner failure at churn pass N;
                                               needs --churn-ms)
  query         one-shot coordinator query (tunes on first use, cached after)
                  --op bcast|scatter|gather|reduce|barrier|allgather|allreduce
                  --procs 24  --bytes 64k
                  --cluster default   --nodes 50  --preset icluster1
                  --save dir/  --warm dir/        (persist / warm-start tables)
                  --traces dir/  (warm-start from captured traces: replay-tune
                                  the recorded workload, needs --op all capture)
                  --stats        (one JSON blob: cache hit/miss + sweep counters)
                  --connect HOST:PORT  (query a running coordd over ct/1
                                        instead of tuning in-process;
                                        --procs takes a comma list and
                                        becomes one batched request)
                  with --connect:
                    --resilient          (socket deadlines + bounded-backoff
                                          retries; rides out a coordd restart)
                    --subscribe          (subscribe to the queried points)
                    --wait-pushes K      (poll until K pushes arrive)
                    --push-timeout SECS  (poll deadline, default 10)
                    --shutdown           (ask the server to exit; needs
                                          --allow-remote-shutdown there)
                    --repeat N           (re-issue the batch N times,
                                          default 1)
                    --interval-ms N      (sleep between repeats)
                  exit codes with --connect: 0 ok, 3 transport failure
                  (retryable: back off and redial), 4 unregistered cluster
                  (fatal), 1 anything else; a one-line retryable/fatal
                  classification is printed to stderr alongside the error
  obs           observability inspection
                  obs dump: exercise a miniature coordinator workload and
                  print the metrics registry snapshot (JSON), the
                  Prometheus text exposition, and the decision
                  flight-recorder ring (TSV)
  info          show artifact metadata and presets
  help          this text

EXAMPLES:
  collective-tuner bench-plogp --preset icluster1
  collective-tuner tune --procs 8,24,48 --backend auto
  collective-tuner tune --op allreduce --jobs 8
  collective-tuner record --op all --trace-dir traces/ --procs 2,4,8,16
  collective-tuner replay --trace-dir traces/ --op bcast --stats
  collective-tuner validate --candidate native --reference replay --trace-dir traces/
  collective-tuner calibrate --trace-dir traces/ --save corrections/
  collective-tuner tune --corrections corrections/ --procs 8,24,48
  collective-tuner validate --reference replay --trace-dir traces/ \\
      --corrections corrections/
  collective-tuner run --op bcast --strategy auto --procs 24 --bytes 256k
  collective-tuner run --op allgather --strategy ring --procs 16 --bytes 64k
  collective-tuner query --op barrier --procs 32 --nodes 32
  collective-tuner experiment --id fig2 --out results/
  collective-tuner serve --clusters 4 --threads 16 --requests 50000
  collective-tuner serve --threads 8 --metrics-interval 1 --log-level info
  collective-tuner obs dump
  collective-tuner query --op bcast --procs 48 --bytes 1M --save tables/
  collective-tuner coordd --listen 127.0.0.1:7177 --clusters 3 --churn-ms 200
  collective-tuner query --connect 127.0.0.1:7177 --cluster island-0 \\
      --op bcast --procs 4,8,16 --bytes 64k
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_opts_flags() {
        let a = parse(&["tune", "--procs", "2,8", "--verbose"]);
        assert_eq!(a.command, "tune");
        assert_eq!(a.get("procs"), Some("2,8"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn empty_means_help() {
        let a = Args::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(["run".into(), "oops".into()]).is_err());
    }

    #[test]
    fn obs_takes_one_subcommand_word() {
        let a = parse(&["obs", "dump"]);
        assert_eq!(a.command, "obs");
        assert_eq!(a.subcommand(), Some("dump"));
        // bare `obs` is fine (main prints usage), options still parse
        let b = parse(&["obs"]);
        assert_eq!(b.subcommand(), None);
        let c = parse(&["obs", "dump", "--log-level", "debug"]);
        assert_eq!(c.subcommand(), Some("dump"));
        assert_eq!(c.get("log-level"), Some("debug"));
        // a second positional is still rejected
        assert!(Args::parse(["obs".into(), "dump".into(), "oops".into()]).is_err());
        // other commands never absorb a positional
        assert_eq!(parse(&["tune"]).subcommand(), None);
    }

    #[test]
    fn log_level_parses_or_errors() {
        assert_eq!(parse(&["tune"]).log_level().unwrap(), None);
        let a = parse(&["tune", "--log-level", "debug"]);
        assert_eq!(a.log_level().unwrap(), Some(log::Level::Debug));
        let b = parse(&["tune", "--log-level", "loud"]);
        assert!(b.log_level().is_err());
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_size("512").unwrap(), 512);
        assert_eq!(parse_size("64k").unwrap(), 64 * 1024);
        assert_eq!(parse_size("1M").unwrap(), 1024 * 1024);
        assert_eq!(parse_size("1.5k").unwrap(), 1536);
        assert!(parse_size("abc").is_err());
    }

    #[test]
    fn usize_list_parses() {
        let a = parse(&["tune", "--procs", "2, 8,24"]);
        assert_eq!(a.usize_list("procs").unwrap(), Some(vec![2, 8, 24]));
        assert_eq!(a.usize_list("other").unwrap(), None);
    }

    #[test]
    fn presets_resolve() {
        let a = parse(&["x", "--preset", "gigabit"]);
        assert!(a.net_config().unwrap().bandwidth_bps > 100e6);
        let b = parse(&["x", "--preset", "nope"]);
        assert!(b.net_config().is_err());
    }

    #[test]
    fn tcp_override() {
        let a = parse(&["x", "--preset", "icluster1", "--tcp", "ideal"]);
        assert_eq!(a.net_config().unwrap().tcp.delayed_ack_penalty, 0.0);
    }
}
