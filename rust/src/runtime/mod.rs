//! PJRT runtime: load and execute the AOT-compiled tuner artifact.
//!
//! `python/compile/aot.py` lowers the L2 tuner graph once to HLO *text*
//! (`artifacts/tuner.hlo.txt`) plus a JSON metadata sidecar with the
//! baked tensor shapes. This module loads the text through
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client
//! (once), and exposes a typed `execute` for the L3 tuner. Python never
//! runs here — the binary is self-contained after `make artifacts`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json;

/// Shapes and layout of the compiled artifact (from `tuner.meta.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub table_len: usize,
    pub p_grid_len: usize,
    pub m_grid_len: usize,
    pub s_grid_len: usize,
    pub num_strategies: usize,
    pub num_bcast: usize,
    pub strategy_names: Vec<String>,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let v = json::parse(text).context("parsing tuner.meta.json")?;
        let field = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("meta field {k}"))
        };
        let names = v
            .get("strategy_names")
            .and_then(|x| x.as_arr())
            .context("meta field strategy_names")?
            .iter()
            .map(|x| x.as_str().unwrap_or("?").to_string())
            .collect();
        Ok(ArtifactMeta {
            table_len: field("table_len")?,
            p_grid_len: field("p_grid_len")?,
            m_grid_len: field("m_grid_len")?,
            s_grid_len: field("s_grid_len")?,
            num_strategies: field("num_strategies")?,
            num_bcast: field("num_bcast")?,
            strategy_names: names,
        })
    }
}

/// Output tensors of one tuner execution (row-major).
#[derive(Debug, Clone)]
pub struct TunerOutput {
    /// `[num_strategies, Q, M]` predicted times (seconds).
    pub times: Vec<f32>,
    /// `[num_strategies, Q, M]` chosen segment sizes (0 = unsegmented).
    pub segs: Vec<f32>,
    /// `[Q, M]` best broadcast strategy index.
    pub bcast_winner: Vec<f32>,
    /// `[Q, M]` best scatter strategy index (10..12).
    pub scatter_winner: Vec<f32>,
    pub num_strategies: usize,
    pub q: usize,
    pub m: usize,
}

impl TunerOutput {
    pub fn time(&self, strategy: usize, qi: usize, mi: usize) -> f32 {
        self.times[(strategy * self.q + qi) * self.m + mi]
    }

    pub fn seg(&self, strategy: usize, qi: usize, mi: usize) -> f32 {
        self.segs[(strategy * self.q + qi) * self.m + mi]
    }

    pub fn bcast_win(&self, qi: usize, mi: usize) -> usize {
        self.bcast_winner[qi * self.m + mi] as usize
    }

    pub fn scatter_win(&self, qi: usize, mi: usize) -> usize {
        self.scatter_winner[qi * self.m + mi] as usize
    }
}

/// The loaded, compiled tuner executable.
pub struct TunerArtifact {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl TunerArtifact {
    /// Default artifact directory (`artifacts/` next to the manifest, or
    /// `$ARTIFACTS_DIR`).
    pub fn default_dir() -> PathBuf {
        std::env::var("ARTIFACTS_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    /// Load `tuner.hlo.txt` + `tuner.meta.json` from a directory and
    /// compile on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<TunerArtifact> {
        let hlo = dir.join("tuner.hlo.txt");
        let meta_path = dir.join("tuner.meta.json");
        if !hlo.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                hlo.display()
            );
        }
        let meta = ArtifactMeta::parse(
            &std::fs::read_to_string(&meta_path)
                .with_context(|| format!("reading {}", meta_path.display()))?,
        )?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .with_context(|| format!("parsing HLO text {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling tuner HLO")?;
        Ok(TunerArtifact { exe, meta })
    }

    /// Execute the tuner. Inputs must match the artifact's baked shapes
    /// exactly (pad with [`pad_f32`] if needed).
    pub fn execute(
        &self,
        sizes: &[f32],
        gaps: &[f32],
        l: f32,
        p_grid: &[f32],
        m_grid: &[f32],
        s_grid: &[f32],
    ) -> Result<TunerOutput> {
        let m = &self.meta;
        let check = |name: &str, got: usize, want: usize| -> Result<()> {
            if got != want {
                bail!("{name}: length {got} != artifact shape {want}");
            }
            Ok(())
        };
        check("sizes", sizes.len(), m.table_len)?;
        check("gaps", gaps.len(), m.table_len)?;
        check("p_grid", p_grid.len(), m.p_grid_len)?;
        check("m_grid", m_grid.len(), m.m_grid_len)?;
        check("s_grid", s_grid.len(), m.s_grid_len)?;

        let lit = |v: &[f32]| xla::Literal::vec1(v);
        let args = [
            lit(sizes),
            lit(gaps),
            lit(&[l]),
            lit(p_grid),
            lit(m_grid),
            lit(s_grid),
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        // lowered with return_tuple=True: a 4-tuple of f32 arrays
        let parts = result.to_tuple()?;
        if parts.len() != 4 {
            bail!("artifact returned {} outputs, expected 4", parts.len());
        }
        let mut it = parts.into_iter();
        let times = it.next().unwrap().to_vec::<f32>()?;
        let segs = it.next().unwrap().to_vec::<f32>()?;
        let bcast_winner = it.next().unwrap().to_vec::<f32>()?;
        let scatter_winner = it.next().unwrap().to_vec::<f32>()?;
        let want = m.num_strategies * m.p_grid_len * m.m_grid_len;
        if times.len() != want {
            bail!("times tensor has {} elements, expected {want}", times.len());
        }
        Ok(TunerOutput {
            times,
            segs,
            bcast_winner,
            scatter_winner,
            num_strategies: m.num_strategies,
            q: m.p_grid_len,
            m: m.m_grid_len,
        })
    }
}

/// Metadata of the extended-collectives artifact (`tuner_ext.meta.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct ExtMeta {
    pub table_len: usize,
    pub p_grid_len: usize,
    pub m_grid_len: usize,
    pub num_strategies: usize,
    pub strategy_names: Vec<String>,
}

/// Output of the extended tuner: times `[10, Q, M]` + per-family winner
/// rows `[4, Q, M]` (gather, barrier, allgather, allreduce).
#[derive(Debug, Clone)]
pub struct ExtOutput {
    pub times: Vec<f32>,
    pub winners: Vec<f32>,
    pub num_strategies: usize,
    pub q: usize,
    pub m: usize,
}

impl ExtOutput {
    pub fn time(&self, strategy: usize, qi: usize, mi: usize) -> f32 {
        self.times[(strategy * self.q + qi) * self.m + mi]
    }

    /// family: 0 gather, 1 barrier, 2 allgather, 3 allreduce.
    pub fn winner(&self, family: usize, qi: usize, mi: usize) -> usize {
        self.winners[(family * self.q + qi) * self.m + mi] as usize
    }
}

/// The loaded, compiled extended-collectives tuner.
pub struct ExtArtifact {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ExtMeta,
}

impl ExtArtifact {
    /// Load `tuner_ext.hlo.txt` + `tuner_ext.meta.json` from `dir`.
    pub fn load(dir: &Path) -> Result<ExtArtifact> {
        let hlo = dir.join("tuner_ext.hlo.txt");
        if !hlo.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                hlo.display()
            );
        }
        let meta_text = std::fs::read_to_string(dir.join("tuner_ext.meta.json"))?;
        let v = json::parse(&meta_text).context("parsing tuner_ext.meta.json")?;
        let field = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("ext meta field {k}"))
        };
        let meta = ExtMeta {
            table_len: field("table_len")?,
            p_grid_len: field("p_grid_len")?,
            m_grid_len: field("m_grid_len")?,
            num_strategies: field("num_strategies")?,
            strategy_names: v
                .get("strategy_names")
                .and_then(|x| x.as_arr())
                .context("ext strategy_names")?
                .iter()
                .map(|x| x.as_str().unwrap_or("?").to_string())
                .collect(),
        };
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling ext tuner HLO")?;
        Ok(ExtArtifact { exe, meta })
    }

    /// Execute; inputs must match the artifact's baked shapes.
    pub fn execute(
        &self,
        sizes: &[f32],
        gaps: &[f32],
        l: f32,
        p_grid: &[f32],
        m_grid: &[f32],
    ) -> Result<ExtOutput> {
        let m = &self.meta;
        if sizes.len() != m.table_len
            || gaps.len() != m.table_len
            || p_grid.len() != m.p_grid_len
            || m_grid.len() != m.m_grid_len
        {
            bail!("ext artifact input shapes mismatch");
        }
        let lit = |v: &[f32]| xla::Literal::vec1(v);
        let args = [lit(sizes), lit(gaps), lit(&[l]), lit(p_grid), lit(m_grid)];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (times_l, winners_l) = result.to_tuple2()?;
        let times = times_l.to_vec::<f32>()?;
        let winners = winners_l.to_vec::<f32>()?;
        if times.len() != m.num_strategies * m.p_grid_len * m.m_grid_len {
            bail!("ext times tensor has wrong size {}", times.len());
        }
        Ok(ExtOutput {
            times,
            winners,
            num_strategies: m.num_strategies,
            q: m.p_grid_len,
            m: m.m_grid_len,
        })
    }
}

/// Pad or truncate a vector to exactly `n` entries, repeating the last
/// value (monotone tails keep interpolation harmless).
pub fn pad_f32(mut v: Vec<f32>, n: usize) -> Vec<f32> {
    assert!(!v.is_empty());
    while v.len() < n {
        v.push(*v.last().unwrap());
    }
    v.truncate(n);
    v
}

/// Pad a strictly-increasing grid to exactly `n` entries by continuing
/// the last step, preserving strict monotonicity.
pub fn pad_grid_f32(mut v: Vec<f32>, n: usize) -> Vec<f32> {
    assert!(v.len() >= 2 || n <= v.len());
    while v.len() < n {
        let last = v[v.len() - 1];
        let step = (last - v[v.len() - 2]).max(1.0);
        v.push(last + step);
    }
    v.truncate(n);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
        "table_len": 32, "p_grid_len": 16, "m_grid_len": 48,
        "s_grid_len": 32, "num_strategies": 13, "num_bcast": 10,
        "num_scatter": 3, "jmax": 64, "binomial_terms": 10,
        "strategy_names": ["bcast/flat","bcast/flat_rdv","bcast/seg_flat",
            "bcast/chain","bcast/chain_rdv","bcast/seg_chain","bcast/binary",
            "bcast/binomial","bcast/binomial_rdv","bcast/seg_binomial",
            "scatter/flat","scatter/chain","scatter/binomial"],
        "outputs": ["times[13,Q,M]"]
    }"#;

    #[test]
    fn meta_parses() {
        let m = ArtifactMeta::parse(META).unwrap();
        assert_eq!(m.table_len, 32);
        assert_eq!(m.num_strategies, 13);
        assert_eq!(m.strategy_names.len(), 13);
        assert_eq!(m.strategy_names[5], "bcast/seg_chain");
    }

    #[test]
    fn meta_rejects_missing_fields() {
        assert!(ArtifactMeta::parse("{}").is_err());
    }

    #[test]
    fn output_indexing() {
        let q = 2;
        let m = 3;
        let ns = 13;
        let mut times = vec![0f32; ns * q * m];
        times[(5 * q + 1) * m + 2] = 42.0;
        let out = TunerOutput {
            times,
            segs: vec![0.0; ns * q * m],
            bcast_winner: vec![7.0; q * m],
            scatter_winner: vec![12.0; q * m],
            num_strategies: ns,
            q,
            m,
        };
        assert_eq!(out.time(5, 1, 2), 42.0);
        assert_eq!(out.bcast_win(0, 0), 7);
        assert_eq!(out.scatter_win(1, 2), 12);
    }

    #[test]
    fn pad_repeats_last() {
        assert_eq!(pad_f32(vec![1.0, 2.0], 4), vec![1.0, 2.0, 2.0, 2.0]);
        assert_eq!(pad_f32(vec![1.0, 2.0, 3.0], 2), vec![1.0, 2.0]);
    }

    #[test]
    fn pad_grid_stays_strictly_increasing() {
        let v = pad_grid_f32(vec![1.0, 3.0], 5);
        assert_eq!(v.len(), 5);
        assert!(v.windows(2).all(|w| w[0] < w[1]), "{v:?}");
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = match TunerArtifact::load(Path::new("/nonexistent")) {
            Err(e) => e,
            Ok(_) => panic!("load of missing artifact succeeded"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
