//! Decision tables: the tuner's product.
//!
//! A [`DecisionTable`] maps grid points `(P, m)` to the winning strategy,
//! its tuned segment size, and the predicted completion time. Lookups off
//! the grid snap to the nearest grid point (log-distance for `m`), which
//! is how the collective runtime consults the table at call time without
//! re-tuning.

use crate::collectives::Strategy;

/// Which operation family a table covers — the paper's two core
/// operations plus the extended collectives its §3 constructs the same
/// way. Discriminants index per-op table sets (see
/// [`crate::coordinator::TableSet`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Op {
    Bcast = 0,
    Scatter = 1,
    Gather = 2,
    Reduce = 3,
    Barrier = 4,
    AllGather = 5,
    AllReduce = 6,
}

impl Op {
    pub const COUNT: usize = 7;

    /// Every operation family, in discriminant order.
    pub const ALL: [Op; 7] = [
        Op::Bcast,
        Op::Scatter,
        Op::Gather,
        Op::Reduce,
        Op::Barrier,
        Op::AllGather,
        Op::AllReduce,
    ];

    /// The four extended ops the ext tuner sweeps (in the ext artifact's
    /// winner-row order; Reduce has a single implementation and no
    /// artifact row, so it is not part of the sweep set).
    pub const EXT: [Op; 4] = [Op::Gather, Op::Barrier, Op::AllGather, Op::AllReduce];

    /// The operation family a strategy belongs to.
    pub fn of(strategy: Strategy) -> Op {
        // index ranges match the Strategy enum layout (asserted by
        // `op_of_partitions_families` below)
        match strategy.index() {
            0..=9 => Op::Bcast,
            10..=12 => Op::Scatter,
            13..=14 => Op::Gather,
            15 => Op::Reduce,
            16..=17 => Op::Barrier,
            18..=20 => Op::AllGather,
            _ => Op::AllReduce,
        }
    }

    pub fn family(self) -> &'static [Strategy] {
        match self {
            Op::Bcast => &Strategy::BCAST,
            Op::Scatter => &Strategy::SCATTER,
            Op::Gather => &Strategy::GATHER,
            Op::Reduce => &Strategy::REDUCE,
            Op::Barrier => &Strategy::BARRIER,
            Op::AllGather => &Strategy::ALLGATHER,
            Op::AllReduce => &Strategy::ALLREDUCE,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Op::Bcast => "bcast",
            Op::Scatter => "scatter",
            Op::Gather => "gather",
            Op::Reduce => "reduce",
            Op::Barrier => "barrier",
            Op::AllGather => "allgather",
            Op::AllReduce => "allreduce",
        }
    }

    /// Inverse of [`Op::name`] (CLI parsing, table deserialization).
    pub fn from_name(name: &str) -> Option<Op> {
        Op::ALL.iter().copied().find(|o| o.name() == name)
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Option<Op> {
        Op::ALL.get(i).copied()
    }

    /// Is this one of the extended operations (everything beyond the
    /// paper's broadcast/scatter)?
    pub fn is_ext(self) -> bool {
        self.index() >= 2
    }

    /// This op's winner row in the extended AOT artifact (`[4, Q, M]`:
    /// gather, barrier, allgather, allreduce). `None` for the core ops
    /// (which the core artifact covers) and for Reduce.
    pub fn ext_artifact_row(self) -> Option<usize> {
        match self {
            Op::Gather => Some(0),
            Op::Barrier => Some(1),
            Op::AllGather => Some(2),
            Op::AllReduce => Some(3),
            _ => None,
        }
    }
}

/// One tuned choice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub strategy: Strategy,
    /// Tuned segment size (None for unsegmented strategies).
    pub segment: Option<u64>,
    /// Model-predicted completion time (seconds).
    pub predicted: f64,
}

/// The tuner's output for one operation family on one network.
#[derive(Debug, Clone)]
pub struct DecisionTable {
    pub op: Op,
    pub p_grid: Vec<usize>,
    pub m_grid: Vec<u64>,
    /// Row-major `[p_grid.len() × m_grid.len()]`.
    pub entries: Vec<Decision>,
}

impl DecisionTable {
    pub fn new(op: Op, p_grid: Vec<usize>, m_grid: Vec<u64>, entries: Vec<Decision>) -> Self {
        assert_eq!(entries.len(), p_grid.len() * m_grid.len());
        assert!(p_grid.windows(2).all(|w| w[0] < w[1]));
        assert!(m_grid.windows(2).all(|w| w[0] < w[1]));
        DecisionTable { op, p_grid, m_grid, entries }
    }

    pub fn at(&self, qi: usize, mi: usize) -> &Decision {
        &self.entries[qi * self.m_grid.len() + mi]
    }

    /// Index of the nearest `p_grid` entry (absolute distance, first
    /// entry on ties). Public because the coordinator's dense snapshot
    /// tables precompute this mapping at publish time and must agree
    /// with it exactly.
    pub fn nearest_p_index(&self, p: usize) -> usize {
        self.p_grid
            .iter()
            .enumerate()
            .min_by_key(|(_, &g)| g.abs_diff(p))
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Index of the nearest `m_grid` entry in log space (first entry on
    /// ties) — the `m` half of the snap-to-nearest contract.
    pub fn nearest_m_index(&self, m: u64) -> usize {
        // nearest in log space: minimize |ln(m) - ln(grid)|
        let lm = (m.max(1)) as f64;
        self.m_grid
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| {
                let da = ((a as f64) / lm).ln().abs();
                let db = ((b as f64) / lm).ln().abs();
                da.partial_cmp(&db).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Snap-to-nearest lookup.
    pub fn lookup(&self, p: usize, m: u64) -> &Decision {
        self.at(self.nearest_p_index(p), self.nearest_m_index(m))
    }

    /// Fraction of grid points won by each strategy (diagnostics).
    pub fn share(&self) -> Vec<(Strategy, f64)> {
        let mut counts = std::collections::BTreeMap::new();
        for d in &self.entries {
            *counts.entry(d.strategy).or_insert(0usize) += 1;
        }
        let n = self.entries.len() as f64;
        counts.into_iter().map(|(s, c)| (s, c as f64 / n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> DecisionTable {
        let p_grid = vec![2usize, 8, 32];
        let m_grid = vec![1u64, 1024, 1 << 20];
        let mut entries = Vec::new();
        for (qi, _) in p_grid.iter().enumerate() {
            for (mi, _) in m_grid.iter().enumerate() {
                let strategy = if mi == 2 {
                    Strategy::BcastSegChain
                } else {
                    Strategy::BcastBinomial
                };
                entries.push(Decision {
                    strategy,
                    segment: if mi == 2 { Some(8192) } else { None },
                    predicted: (qi * 3 + mi) as f64,
                });
            }
        }
        DecisionTable::new(Op::Bcast, p_grid, m_grid, entries)
    }

    #[test]
    fn exact_lookup() {
        let t = table();
        assert_eq!(t.lookup(8, 1024).strategy, Strategy::BcastBinomial);
        assert_eq!(t.lookup(8, 1 << 20).strategy, Strategy::BcastSegChain);
        assert_eq!(t.lookup(8, 1 << 20).segment, Some(8192));
    }

    #[test]
    fn nearest_lookup_snaps() {
        let t = table();
        // p=9 -> 8; m=2000 is nearer 1024 than 1M in log space
        assert_eq!(t.lookup(9, 2000).strategy, Strategy::BcastBinomial);
        // m = 600k -> 1M
        assert_eq!(t.lookup(30, 600_000).strategy, Strategy::BcastSegChain);
    }

    #[test]
    fn share_sums_to_one() {
        let t = table();
        let total: f64 = t.share().iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn wrong_entry_count_panics() {
        DecisionTable::new(Op::Bcast, vec![2], vec![1, 2], vec![]);
    }

    #[test]
    fn op_of_partitions_families() {
        // every strategy maps to exactly the family that contains it
        for op in Op::ALL {
            for &s in op.family() {
                assert_eq!(Op::of(s), op, "{}", s.name());
            }
        }
        // and the families cover the strategy space exactly once
        let total: usize = Op::ALL.iter().map(|o| o.family().len()).sum();
        assert_eq!(total, Strategy::COUNT);
    }

    #[test]
    fn op_names_and_indices_roundtrip() {
        for (i, op) in Op::ALL.iter().enumerate() {
            assert_eq!(op.index(), i);
            assert_eq!(Op::from_index(i), Some(*op));
            assert_eq!(Op::from_name(op.name()), Some(*op));
        }
        assert_eq!(Op::from_name("warp"), None);
        assert_eq!(Op::from_index(Op::COUNT), None);
        // ext rows match the ext artifact's winner layout
        assert_eq!(Op::EXT.map(|o| o.ext_artifact_row().unwrap()), [0, 1, 2, 3]);
        assert_eq!(Op::Bcast.ext_artifact_row(), None);
        assert_eq!(Op::Reduce.ext_artifact_row(), None);
    }
}
