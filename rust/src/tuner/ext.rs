//! Tuning for the extended collectives (gather / barrier / allgather /
//! allreduce) — a thin driver over the same [`Tuner`] engine the core
//! ops use. All scoring goes through [`crate::eval::Evaluator`] (the
//! unified cost-model registry, the simulator, or the second AOT
//! artifact via [`crate::eval::ArtifactEval`]); the sweep runs on the
//! engine's `thread::scope` work queue, so `--jobs N` and per-cell
//! pruning apply uniformly and `--jobs 1` vs `--jobs 8` tables are
//! byte-identical (asserted in `rust/tests/evaluator.rs`).
//!
//! This module used to carry its own artifact plumbing and private
//! `ExtStrategy`/`ExtDecisionTable` types; the extended strategies now
//! live in [`Strategy`] (indices `Strategy::EXT_BASE..`), the ops in
//! [`Op`], and the tables are ordinary [`DecisionTable`]s.

use std::path::Path;

use anyhow::{ensure, Result};

use crate::collectives::Strategy;
use crate::eval::Evaluator;
use crate::plogp::PLogP;

use super::decision::{DecisionTable, Op};
use super::engine::Tuner;

/// The extended ops, in ext-artifact winner-row order (see [`Op::EXT`]).
pub const EXT_OPS: [Op; 4] = Op::EXT;

/// The extended-collectives tuner: a [`Tuner`] restricted to
/// [`EXT_OPS`]. Kept as a named façade so callers that only care about
/// the extended family don't thread `Op` lists around.
pub struct ExtTuner {
    inner: Tuner,
}

impl ExtTuner {
    /// Native (pure Rust model) tuner.
    pub fn native() -> ExtTuner {
        ExtTuner { inner: Tuner::native() }
    }

    /// Load the AOT artifacts from `dir` (the ext artifact is optional;
    /// ext ops fall back to the native models without it).
    pub fn with_artifact(dir: &Path) -> Result<ExtTuner> {
        Ok(ExtTuner { inner: Tuner::with_artifact(dir)? })
    }

    /// Prefer the artifact; fall back to native (logging the reason).
    pub fn auto(dir: &Path) -> ExtTuner {
        ExtTuner { inner: Tuner::auto(dir) }
    }

    /// Build on any evaluation backend.
    pub fn with_evaluator(evaluator: Box<dyn Evaluator>) -> ExtTuner {
        ExtTuner { inner: Tuner::with_evaluator(evaluator) }
    }

    /// Set the sweep worker count (`0` = one per core).
    pub fn jobs(mut self, n: usize) -> ExtTuner {
        self.inner = self.inner.jobs(n);
        self
    }

    /// The underlying engine (shared with the core ops).
    pub fn tuner(&self) -> &Tuner {
        &self.inner
    }

    pub fn backend_name(&self) -> &'static str {
        self.inner.backend_name()
    }

    /// Tune all four extended ops over the grid, one [`DecisionTable`]
    /// per [`EXT_OPS`] entry.
    pub fn tune(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<Vec<DecisionTable>> {
        self.inner.tune_ext(net, p_grid, m_grid)
    }
}

/// Build the schedule for an extended decision. Reduction strategies
/// error when `p` exceeds the contributor-mask capacity
/// (see [`crate::mpi::Payload::MAX_MASK_RANKS`]).
pub fn build_ext_schedule(
    op: Op,
    strategy: Strategy,
    p: usize,
    m: u64,
) -> Result<crate::mpi::CommSchedule> {
    ensure!(
        op.family().contains(&strategy),
        "{} is not a {} strategy",
        strategy.name(),
        op.name()
    );
    strategy.try_build(p, 0, m, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;
    use crate::tuner::grids;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn native_ext_tuner_produces_tables_for_all_ops() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[4, 16, 32], &grids::log_grid(1, 1 << 18, 8)).unwrap();
        assert_eq!(tables.len(), 4);
        for (table, op) in tables.iter().zip(EXT_OPS) {
            assert_eq!(table.op, op);
            assert_eq!(table.entries.len(), 24);
            for d in &table.entries {
                assert!(d.predicted > 0.0);
                assert!(table.op.family().contains(&d.strategy), "{:?}", d);
                assert!(d.segment.is_none(), "ext strategies never segment");
            }
        }
    }

    #[test]
    fn barrier_tuner_picks_dissemination() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[16, 32], &[1]).unwrap();
        let barrier = &tables[1]; // EXT_OPS order: gather, barrier, ...
        assert_eq!(barrier.op, Op::Barrier);
        for d in &barrier.entries {
            assert_eq!(d.strategy, Strategy::BarrierDissemination);
        }
    }

    #[test]
    fn allgather_tuner_latency_bound_picks_rec_doubling() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[32], &[1, 1 << 20]).unwrap();
        let ag = &tables[2];
        assert_eq!(ag.op, Op::AllGather);
        assert_eq!(ag.at(0, 0).strategy, Strategy::AllGatherRecDoubling);
    }

    #[test]
    fn ext_decisions_run_and_verify() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[8], &[4096]).unwrap();
        for table in &tables {
            let d = table.at(0, 0);
            let sched = build_ext_schedule(table.op, d.strategy, 8, 4096).unwrap();
            let mut world = World::new(Netsim::new(8, NetConfig::fast_ethernet_ideal()));
            let rep = world.run(&sched);
            assert!(rep.verify(&sched).is_empty(), "{}: {:?}", sched.name, rep.verify(&sched));
        }
    }

    #[test]
    fn ext_model_accuracy_against_sim() {
        // predicted vs measured for each family's winner within 30 %
        let cfg = NetConfig::fast_ethernet_ideal();
        let net = measured();
        let t = ExtTuner::native();
        let p = 16;
        let m = 32 * 1024;
        let tables = t.tune(&net, &[p], &[m]).unwrap();
        for table in &tables {
            let d = table.at(0, 0);
            let sched = build_ext_schedule(table.op, d.strategy, p, m).unwrap();
            let mut world = World::new(Netsim::new(p, cfg.clone()));
            let meas = world.run(&sched).completion.as_secs();
            let rel = (d.predicted - meas).abs() / meas;
            assert!(
                rel < 0.30,
                "{} {}: predicted {} vs measured {meas} (rel {rel})",
                table.op.name(),
                d.strategy.name(),
                d.predicted
            );
        }
    }

    #[test]
    fn lookup_snaps() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[4, 32], &[1024, 1 << 20]).unwrap();
        let g = &tables[0];
        let d = g.lookup(30, 900_000);
        assert!(g.op.family().contains(&d.strategy));
    }

    #[test]
    fn build_rejects_cross_family_pairs() {
        assert!(build_ext_schedule(Op::Barrier, Strategy::GatherFlat, 8, 64).is_err());
        assert!(build_ext_schedule(Op::Gather, Strategy::GatherFlat, 8, 64).is_ok());
    }
}
