//! Tuning for the extended collectives (gather / barrier / allgather /
//! allreduce) — same argmin machinery as the Broadcast/Scatter tuner,
//! over the [`crate::models::ext`] model set, with the second AOT
//! artifact (`tuner_ext.hlo.txt`) as fast path.

use std::path::Path;

use anyhow::Result;

use crate::models::ext::{predict_ext, rank_ext, ExtStrategy};
use crate::plogp::PLogP;
use crate::runtime::{pad_grid_f32, ExtArtifact};

/// Extended-op families, in the artifact's winner-row order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExtOp {
    Gather = 0,
    Barrier = 1,
    AllGather = 2,
    AllReduce = 3,
}

impl ExtOp {
    pub const ALL: [ExtOp; 4] =
        [ExtOp::Gather, ExtOp::Barrier, ExtOp::AllGather, ExtOp::AllReduce];

    pub fn family(self) -> &'static [ExtStrategy] {
        match self {
            ExtOp::Gather => &ExtStrategy::GATHER,
            ExtOp::Barrier => &ExtStrategy::BARRIER,
            ExtOp::AllGather => &ExtStrategy::ALLGATHER,
            ExtOp::AllReduce => &ExtStrategy::ALLREDUCE,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExtOp::Gather => "gather",
            ExtOp::Barrier => "barrier",
            ExtOp::AllGather => "allgather",
            ExtOp::AllReduce => "allreduce",
        }
    }
}

/// One tuned extended decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExtDecision {
    pub strategy: ExtStrategy,
    pub predicted: f64,
}

/// Decision table for one extended op.
#[derive(Debug, Clone)]
pub struct ExtDecisionTable {
    pub op: ExtOp,
    pub p_grid: Vec<usize>,
    pub m_grid: Vec<u64>,
    pub entries: Vec<ExtDecision>,
}

impl ExtDecisionTable {
    pub fn at(&self, qi: usize, mi: usize) -> &ExtDecision {
        &self.entries[qi * self.m_grid.len() + mi]
    }

    /// Snap-to-nearest lookup (same semantics as the core tables).
    pub fn lookup(&self, p: usize, m: u64) -> &ExtDecision {
        let qi = self
            .p_grid
            .iter()
            .enumerate()
            .min_by_key(|(_, &g)| g.abs_diff(p))
            .map(|(i, _)| i)
            .unwrap();
        let lm = m.max(1) as f64;
        let mi = self
            .m_grid
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| {
                let da = ((a as f64) / lm).ln().abs();
                let db = ((b as f64) / lm).ln().abs();
                da.partial_cmp(&db).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        self.at(qi, mi)
    }
}

/// The extended tuner.
pub struct ExtTuner {
    artifact: Option<ExtArtifact>,
}

impl ExtTuner {
    pub fn native() -> ExtTuner {
        ExtTuner { artifact: None }
    }

    pub fn with_artifact(dir: &Path) -> Result<ExtTuner> {
        Ok(ExtTuner { artifact: Some(ExtArtifact::load(dir)?) })
    }

    /// Prefer the artifact; fall back to native.
    pub fn auto(dir: &Path) -> ExtTuner {
        match Self::with_artifact(dir) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("ext artifact unavailable ({e:#}); using native models");
                ExtTuner::native()
            }
        }
    }

    pub fn backend_name(&self) -> &'static str {
        if self.artifact.is_some() {
            "artifact"
        } else {
            "native"
        }
    }

    /// Tune all four extended ops over the grid.
    pub fn tune(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<Vec<ExtDecisionTable>> {
        match &self.artifact {
            None => Ok(self.tune_native(net, p_grid, m_grid)),
            Some(art) => self.tune_artifact(art, net, p_grid, m_grid),
        }
    }

    fn tune_native(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Vec<ExtDecisionTable> {
        ExtOp::ALL
            .iter()
            .map(|&op| {
                let mut entries = Vec::with_capacity(p_grid.len() * m_grid.len());
                for &p in p_grid {
                    for &m in m_grid {
                        let (strategy, predicted) = rank_ext(op.family(), net, p, m)[0];
                        entries.push(ExtDecision { strategy, predicted });
                    }
                }
                ExtDecisionTable {
                    op,
                    p_grid: p_grid.to_vec(),
                    m_grid: m_grid.to_vec(),
                    entries,
                }
            })
            .collect()
    }

    fn tune_artifact(
        &self,
        art: &ExtArtifact,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<Vec<ExtDecisionTable>> {
        let meta = &art.meta;
        assert!(p_grid.len() <= meta.p_grid_len && m_grid.len() <= meta.m_grid_len);
        let sizes: Vec<f32> = net.table.sizes().iter().map(|&x| x as f32).collect();
        let gaps: Vec<f32> = net.table.gaps().iter().map(|&x| x as f32).collect();
        assert_eq!(sizes.len(), meta.table_len, "gap table length mismatch");
        let pf = pad_grid_f32(p_grid.iter().map(|&p| p as f32).collect(), meta.p_grid_len);
        let mf = pad_grid_f32(m_grid.iter().map(|&m| m as f32).collect(), meta.m_grid_len);
        let out = art.execute(&sizes, &gaps, net.l as f32, &pf, &mf)?;
        Ok(ExtOp::ALL
            .iter()
            .map(|&op| {
                let mut entries = Vec::with_capacity(p_grid.len() * m_grid.len());
                for qi in 0..p_grid.len() {
                    for mi in 0..m_grid.len() {
                        let widx = out.winner(op as usize, qi, mi);
                        let strategy = ExtStrategy::from_index(widx).expect("winner");
                        entries.push(ExtDecision {
                            strategy,
                            predicted: out.time(widx, qi, mi) as f64,
                        });
                    }
                }
                ExtDecisionTable {
                    op,
                    p_grid: p_grid.to_vec(),
                    m_grid: m_grid.to_vec(),
                    entries,
                }
            })
            .collect())
    }
}

/// Build the schedule for an extended decision. Reduction strategies
/// error when `p` exceeds the contributor-mask capacity
/// (see [`crate::mpi::Payload::MAX_MASK_RANKS`]).
pub fn build_ext_schedule(
    _op: ExtOp,
    strategy: ExtStrategy,
    p: usize,
    m: u64,
) -> Result<crate::mpi::CommSchedule> {
    use crate::collectives::{composed, extended};
    Ok(match strategy {
        ExtStrategy::GatherFlat => composed::gather_flat(p, 0, m),
        ExtStrategy::GatherBinomial => composed::gather_binomial(p, 0, m),
        ExtStrategy::ReduceBinomial => composed::reduce_binomial(p, 0, m)?,
        ExtStrategy::BarrierTree => composed::barrier_binomial(p),
        ExtStrategy::BarrierDissemination => extended::barrier_dissemination(p),
        ExtStrategy::AllGatherGatherBcast => composed::allgather(p, 0, m),
        ExtStrategy::AllGatherRing => extended::allgather_ring(p, m),
        ExtStrategy::AllGatherRecDoubling => extended::allgather_recursive_doubling(p, m),
        ExtStrategy::AllReduceReduceBcast => composed::allreduce(p, 0, m)?,
        ExtStrategy::AllReduceRecDoubling => {
            extended::allreduce_recursive_doubling(p, m)?
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;
    use crate::tuner::grids;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn native_ext_tuner_produces_tables_for_all_ops() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[4, 16, 32], &grids::log_grid(1, 1 << 18, 8)).unwrap();
        assert_eq!(tables.len(), 4);
        for table in &tables {
            assert_eq!(table.entries.len(), 24);
            for d in &table.entries {
                assert!(d.predicted > 0.0);
                assert!(table.op.family().contains(&d.strategy), "{:?}", d);
            }
        }
    }

    #[test]
    fn barrier_tuner_picks_dissemination() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[16, 32], &[1]).unwrap();
        let barrier = &tables[ExtOp::Barrier as usize];
        for d in &barrier.entries {
            assert_eq!(d.strategy, ExtStrategy::BarrierDissemination);
        }
    }

    #[test]
    fn allgather_tuner_crosses_from_rec_doubling_to_ring_family() {
        // latency-bound: rec doubling; bandwidth-bound: ring catches up.
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[32], &[1, 1 << 20]).unwrap();
        let ag = &tables[ExtOp::AllGather as usize];
        assert_eq!(ag.at(0, 0).strategy, ExtStrategy::AllGatherRecDoubling);
    }

    #[test]
    fn ext_decisions_run_and_verify() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[8], &[4096]).unwrap();
        for table in &tables {
            let d = table.at(0, 0);
            let sched = build_ext_schedule(table.op, d.strategy, 8, 4096).unwrap();
            let mut world =
                World::new(Netsim::new(8, NetConfig::fast_ethernet_ideal()));
            let rep = world.run(&sched);
            assert!(rep.verify(&sched).is_empty(), "{}: {:?}", sched.name, rep.verify(&sched));
        }
    }

    #[test]
    fn ext_model_accuracy_against_sim() {
        // predicted vs measured for each family's winner within 30 %
        let cfg = NetConfig::fast_ethernet_ideal();
        let net = measured();
        let t = ExtTuner::native();
        let p = 16;
        let m = 32 * 1024;
        let tables = t.tune(&net, &[p], &[m]).unwrap();
        for table in &tables {
            let d = table.at(0, 0);
            let sched = build_ext_schedule(table.op, d.strategy, p, m).unwrap();
            let mut world = World::new(Netsim::new(p, cfg.clone()));
            let meas = world.run(&sched).completion.as_secs();
            let rel = (d.predicted - meas).abs() / meas;
            assert!(
                rel < 0.30,
                "{} {}: predicted {} vs measured {meas} (rel {rel})",
                table.op.name(),
                d.strategy.name(),
                d.predicted
            );
        }
    }

    #[test]
    fn lookup_snaps() {
        let net = measured();
        let t = ExtTuner::native();
        let tables = t.tune(&net, &[4, 32], &[1024, 1 << 20]).unwrap();
        let g = &tables[0];
        let d = g.lookup(30, 900_000);
        assert!(g.op.family().contains(&d.strategy));
    }
}
