//! Default tuning grids, matched to the AOT artifact's baked shapes.

/// Log-spaced u64 grid from `lo` to `hi` inclusive with exactly `n`
/// strictly increasing entries.
pub fn log_grid(lo: u64, hi: u64, n: usize) -> Vec<u64> {
    assert!(lo >= 1 && hi > lo && n >= 2);
    let mut out: Vec<u64> = (0..n)
        .map(|i| {
            let t = i as f64 / (n - 1) as f64;
            ((lo as f64) * ((hi as f64) / (lo as f64)).powf(t)).round() as u64
        })
        .collect();
    // enforce strict monotonicity after rounding
    for i in 1..out.len() {
        if out[i] <= out[i - 1] {
            out[i] = out[i - 1] + 1;
        }
    }
    out
}

/// Default message-size grid: 48 points, 1 B .. 1 MB (the paper's
/// experimental range).
pub fn default_m_grid() -> Vec<u64> {
    log_grid(1, 1 << 20, 48)
}

/// Default segment-size grid: 32 points, 64 B .. 4 MB. The top end
/// exceeds the m-grid so the unsegmented case (s >= m) is always in the
/// search space.
pub fn default_s_grid() -> Vec<u64> {
    log_grid(64, 4 << 20, 32)
}

/// Default process-count grid: 2..=50 in 16 roughly-even steps (the
/// paper's cluster has 50 nodes).
pub fn default_p_grid() -> Vec<usize> {
    let mut v: Vec<usize> = (0..16).map(|i| 2 + (i * 48) / 15).collect();
    v.dedup();
    while v.len() < 16 {
        let last = *v.last().unwrap();
        v.push(last + 1);
    }
    v.truncate(16);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_grid_endpoints_and_monotonicity() {
        let g = log_grid(1, 1 << 20, 48);
        assert_eq!(g.len(), 48);
        assert_eq!(g[0], 1);
        assert_eq!(*g.last().unwrap(), 1 << 20);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn default_grids_match_artifact_shapes() {
        assert_eq!(default_m_grid().len(), 48);
        assert_eq!(default_s_grid().len(), 32);
        assert_eq!(default_p_grid().len(), 16);
    }

    #[test]
    fn default_p_grid_spans_cluster() {
        let p = default_p_grid();
        assert_eq!(p[0], 2);
        assert_eq!(*p.last().unwrap(), 50);
        assert!(p.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn s_grid_covers_m_grid() {
        assert!(default_s_grid().last().unwrap() >= default_m_grid().last().unwrap());
    }
}
