//! The paper's contribution: fast, model-driven strategy selection.
//!
//! Given measured pLogP parameters, the tuner evaluates every candidate
//! implementation over a `(P, m)` grid — including the segment-size
//! search for segmented strategies — and materializes
//! [`decision::DecisionTable`]s that the collective runtime consults at
//! call time. One selection framework covers every collective family
//! ([`decision::Op::ALL`]): broadcast and scatter (the paper's Tables 1
//! and 2) and the extended ops (gather / reduce / barrier / allgather /
//! allreduce, driven by [`ext`]). All scoring goes through the
//! [`crate::eval::Evaluator`] trait:
//!
//! * **artifact** ([`crate::eval::ArtifactEval`]) — one AOT-compiled XLA
//!   execution evaluates the entire core decision tensor (13 strategies
//!   × P-grid × m-grid × segment grid) in a single call, and a second
//!   execution of the ext artifact serves all four extended ops; this is
//!   the "fast" in *Fast Tuning*.
//! * **native** ([`crate::eval::ModelEval`]) — the Rust model mirror,
//!   swept in parallel across worker threads (`--jobs N`) with per-cell
//!   pruning; used when no artifact is present and for cross-validation
//!   (the two must agree, see `rust/tests/artifact_roundtrip.rs`).
//! * **sim** ([`crate::eval::SimEval`]) — empirical ground truth for
//!   [`validate`]'s model-vs-measurement cross-checks.
//! * **replay** ([`crate::eval::ReplayEval`]) — captured-trace replay
//!   ([`engine::Tuner::with_replay`], `tune --trace-dir`): tuning and
//!   validation against a fixed, recorded workload for reproducible
//!   regression suites (the golden-trace CI gate).

pub mod decision;
pub mod ext;
pub mod engine;
pub mod grids;
pub mod persist;
pub mod validate;

pub use decision::{Decision, DecisionTable, Op};
pub use engine::Tuner;
