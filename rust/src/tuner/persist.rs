//! Decision-table persistence: a deployed runtime tunes once per
//! network, saves the tables, and loads them at startup — the paper's
//! "static techniques" operating mode (§5: "because the intra-cluster
//! communication is based on static techniques, the complexity ... is
//! restricted only to the inter-cluster communication").
//!
//! Format: a simple self-describing TSV (serde is unavailable offline):
//!
//! ```text
//! # collective-tuner decision table v1
//! op	bcast
//! p_grid	2,8,24
//! m_grid	1,1024,1048576
//! entry	<qi>	<mi>	<strategy-name>	<segment|-- >	<predicted>
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::collectives::Strategy;

use super::decision::{Decision, DecisionTable, Op};

const HEADER: &str = "# collective-tuner decision table v1";

/// Serialize a decision table.
pub fn to_string(table: &DecisionTable) -> String {
    let mut out = String::new();
    out.push_str(HEADER);
    out.push('\n');
    out.push_str(&format!("op\t{}\n", table.op.name()));
    out.push_str(&format!(
        "p_grid\t{}\n",
        table.p_grid.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(",")
    ));
    out.push_str(&format!(
        "m_grid\t{}\n",
        table.m_grid.iter().map(|m| m.to_string()).collect::<Vec<_>>().join(",")
    ));
    for (qi, _) in table.p_grid.iter().enumerate() {
        for (mi, _) in table.m_grid.iter().enumerate() {
            let d = table.at(qi, mi);
            out.push_str(&format!(
                "entry\t{qi}\t{mi}\t{}\t{}\t{:.9e}\n",
                d.strategy.name(),
                d.segment.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
                d.predicted
            ));
        }
    }
    out
}

/// Parse a decision table.
pub fn from_str(text: &str) -> Result<DecisionTable> {
    let mut lines = text.lines();
    if lines.next() != Some(HEADER) {
        bail!("not a decision-table file (missing header)");
    }
    let mut op = None;
    let mut p_grid: Vec<usize> = Vec::new();
    let mut m_grid: Vec<u64> = Vec::new();
    let mut raw_entries: Vec<(usize, usize, Decision)> = Vec::new();
    for (ln, line) in lines.enumerate() {
        let mut f = line.split('\t');
        match f.next() {
            Some("op") => {
                let tok = f.next().context("op name")?;
                op = Some(
                    Op::from_name(tok)
                        .with_context(|| format!("line {}: bad op '{tok}'", ln + 2))?,
                );
            }
            Some("p_grid") => {
                p_grid = f
                    .next()
                    .context("p_grid values")?
                    .split(',')
                    .map(|t| t.parse().context("p_grid entry"))
                    .collect::<Result<_>>()?;
            }
            Some("m_grid") => {
                m_grid = f
                    .next()
                    .context("m_grid values")?
                    .split(',')
                    .map(|t| t.parse().context("m_grid entry"))
                    .collect::<Result<_>>()?;
            }
            Some("entry") => {
                let qi: usize = f.next().context("qi")?.parse()?;
                let mi: usize = f.next().context("mi")?.parse()?;
                let name = f.next().context("strategy")?;
                let strategy = Strategy::from_name(name)
                    .with_context(|| format!("unknown strategy '{name}'"))?;
                let seg_tok = f.next().context("segment")?;
                let segment = if seg_tok == "-" {
                    None
                } else {
                    Some(seg_tok.parse::<u64>()?)
                };
                let predicted: f64 = f.next().context("predicted")?.parse()?;
                raw_entries.push((qi, mi, Decision { strategy, segment, predicted }));
            }
            Some("") | None => {}
            Some(other) => bail!("line {}: unknown record '{other}'", ln + 2),
        }
    }
    let op = op.context("missing op record")?;
    if p_grid.is_empty() || m_grid.is_empty() {
        bail!("missing grids");
    }
    let mut entries = vec![
        Decision {
            strategy: Strategy::BcastFlat,
            segment: None,
            predicted: -1.0
        };
        p_grid.len() * m_grid.len()
    ];
    for (qi, mi, d) in raw_entries {
        if qi >= p_grid.len() || mi >= m_grid.len() {
            bail!("entry ({qi},{mi}) out of grid bounds");
        }
        entries[qi * m_grid.len() + mi] = d;
    }
    if entries.iter().any(|d| d.predicted < 0.0) {
        bail!("decision table is missing entries");
    }
    Ok(DecisionTable::new(op, p_grid, m_grid, entries))
}

/// Save to a file.
pub fn save(table: &DecisionTable, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, to_string(table))
        .with_context(|| format!("writing {}", path.display()))
}

/// Load from a file.
pub fn load(path: &Path) -> Result<DecisionTable> {
    from_str(
        &std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;
    use crate::tuner::{grids, Tuner};

    fn sample_table() -> DecisionTable {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        let net = plogp::bench::measure(&mut sim);
        let t = Tuner::native();
        let (b, _) = t
            .tune(&net, &[2, 8, 24], &grids::log_grid(1, 1 << 20, 8))
            .unwrap();
        b
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let table = sample_table();
        let text = to_string(&table);
        let back = from_str(&text).unwrap();
        assert_eq!(back.op, table.op);
        assert_eq!(back.p_grid, table.p_grid);
        assert_eq!(back.m_grid, table.m_grid);
        for (a, b) in table.entries.iter().zip(&back.entries) {
            assert_eq!(a.strategy, b.strategy);
            assert_eq!(a.segment, b.segment);
            // 9 significant decimal digits survive the text round trip
            assert!((a.predicted - b.predicted).abs() <= 1e-8 * a.predicted.abs());
        }
    }

    #[test]
    fn file_roundtrip() {
        let table = sample_table();
        let path = std::env::temp_dir().join("ct-persist-test/bcast.tsv");
        save(&table, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.p_grid, table.p_grid);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lookup_identical_after_roundtrip() {
        let table = sample_table();
        let back = from_str(&to_string(&table)).unwrap();
        for (p, m) in [(3usize, 500u64), (20, 1 << 19), (48, 77)] {
            assert_eq!(table.lookup(p, m).strategy, back.lookup(p, m).strategy);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str("hello").is_err());
        assert!(from_str(HEADER).is_err()); // no grids
        let table = sample_table();
        let text = to_string(&table);
        // drop one entry line -> incomplete
        let truncated: Vec<&str> = text.lines().filter(|l| !l.contains("entry\t0\t0")).collect();
        assert!(from_str(&truncated.join("\n")).is_err());
    }

    #[test]
    fn ext_table_roundtrips() {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        let net = plogp::bench::measure(&mut sim);
        for table in Tuner::native().tune_ext(&net, &[2, 8, 24], &[1, 1024, 1 << 20]).unwrap()
        {
            let back = from_str(&to_string(&table)).unwrap();
            assert_eq!(back.op, table.op);
            for (a, b) in table.entries.iter().zip(&back.entries) {
                assert_eq!(a.strategy, b.strategy);
                assert_eq!(a.segment, None);
            }
        }
    }

    #[test]
    fn rejects_unknown_strategy() {
        let table = sample_table();
        let text = to_string(&table).replace("bcast/seg_chain", "bcast/warp_drive");
        assert!(from_str(&text).is_err());
    }
}
