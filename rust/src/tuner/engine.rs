//! The tuning engine: evaluate all models over the grid, take the argmin.

use std::path::Path;

use anyhow::Result;

use crate::collectives::Strategy;
use crate::models;
use crate::plogp::PLogP;
use crate::runtime::{pad_grid_f32, TunerArtifact};

use super::decision::{Decision, DecisionTable, Op};
use super::grids;

/// Which evaluator produces the decision tensor.
pub enum Backend {
    /// One PJRT execution of the AOT-compiled kernel — the fast path.
    Artifact(Box<TunerArtifact>),
    /// The Rust model mirror — fallback and cross-check.
    Native,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Artifact(_) => "artifact",
            Backend::Native => "native",
        }
    }
}

/// The tuner: a backend plus a segment-size search grid.
pub struct Tuner {
    pub backend: Backend,
    pub s_grid: Vec<u64>,
}

impl Tuner {
    /// Native (pure Rust) tuner.
    pub fn native() -> Tuner {
        Tuner { backend: Backend::Native, s_grid: grids::default_s_grid() }
    }

    /// Load the AOT artifact from `dir`.
    pub fn with_artifact(dir: &Path) -> Result<Tuner> {
        let art = TunerArtifact::load(dir)?;
        Ok(Tuner { backend: Backend::Artifact(Box::new(art)), s_grid: grids::default_s_grid() })
    }

    /// Prefer the artifact; fall back to native (logging the reason).
    pub fn auto(dir: &Path) -> Tuner {
        match Self::with_artifact(dir) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("tuner artifact unavailable ({e:#}); using native models");
                Tuner::native()
            }
        }
    }

    /// Tune both operations over the given grids. Returns the broadcast
    /// and scatter decision tables.
    pub fn tune(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<(DecisionTable, DecisionTable)> {
        match &self.backend {
            Backend::Native => Ok(self.tune_native(net, p_grid, m_grid)),
            Backend::Artifact(art) => self.tune_artifact(art, net, p_grid, m_grid),
        }
    }

    fn decide(
        &self,
        op: Op,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
        pick: impl Fn(usize, u64) -> Decision,
    ) -> DecisionTable {
        let _ = net;
        let mut entries = Vec::with_capacity(p_grid.len() * m_grid.len());
        for &p in p_grid {
            for &m in m_grid {
                entries.push(pick(p, m));
            }
        }
        DecisionTable::new(op, p_grid.to_vec(), m_grid.to_vec(), entries)
    }

    fn tune_native(&self, net: &PLogP, p_grid: &[usize], m_grid: &[u64]) -> (DecisionTable, DecisionTable) {
        let pick = |family: &'static [Strategy]| {
            move |net: &PLogP, s_grid: &[u64], p: usize, m: u64| -> Decision {
                let ranked = models::rank_strategies(family, net, p, m, s_grid);
                let (strategy, predicted, segment) = ranked[0];
                Decision { strategy, segment, predicted }
            }
        };
        let pick_b = pick(&Strategy::BCAST);
        let pick_s = pick(&Strategy::SCATTER);
        let b = self.decide(Op::Bcast, net, p_grid, m_grid, |p, m| {
            pick_b(net, &self.s_grid, p, m)
        });
        let s = self.decide(Op::Scatter, net, p_grid, m_grid, |p, m| {
            pick_s(net, &self.s_grid, p, m)
        });
        (b, s)
    }

    fn tune_artifact(
        &self,
        art: &TunerArtifact,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<(DecisionTable, DecisionTable)> {
        let meta = &art.meta;
        assert!(
            p_grid.len() <= meta.p_grid_len && m_grid.len() <= meta.m_grid_len,
            "grid larger than artifact shape ({} x {} vs {} x {})",
            p_grid.len(),
            m_grid.len(),
            meta.p_grid_len,
            meta.m_grid_len
        );
        // pad every input to the artifact's baked shapes
        let sizes: Vec<f32> = net.table.sizes().iter().map(|&x| x as f32).collect();
        let gaps: Vec<f32> = net.table.gaps().iter().map(|&x| x as f32).collect();
        assert_eq!(
            sizes.len(),
            meta.table_len,
            "gap table has {} samples but the artifact expects {} — \
             measure with plogp::default_size_grid({})",
            sizes.len(),
            meta.table_len,
            meta.table_len
        );
        let pf = pad_grid_f32(p_grid.iter().map(|&p| p as f32).collect(), meta.p_grid_len);
        let mf = pad_grid_f32(m_grid.iter().map(|&m| m as f32).collect(), meta.m_grid_len);
        let sf = pad_grid_f32(
            self.s_grid.iter().map(|&s| s as f32).collect(),
            meta.s_grid_len,
        );
        let out = art.execute(&sizes, &gaps, net.l as f32, &pf, &mf, &sf)?;

        let build = |op: Op| -> DecisionTable {
            let mut entries = Vec::with_capacity(p_grid.len() * m_grid.len());
            for qi in 0..p_grid.len() {
                for mi in 0..m_grid.len() {
                    let widx = match op {
                        Op::Bcast => out.bcast_win(qi, mi),
                        Op::Scatter => out.scatter_win(qi, mi),
                    };
                    let strategy = Strategy::from_index(widx).expect("winner index");
                    let seg = out.seg(widx, qi, mi);
                    let segment = if strategy.is_segmented() && seg > 0.0 {
                        Some(seg as u64)
                    } else {
                        None
                    };
                    entries.push(Decision {
                        strategy,
                        segment,
                        predicted: out.time(widx, qi, mi) as f64,
                    });
                }
            }
            DecisionTable::new(op, p_grid.to_vec(), m_grid.to_vec(), entries)
        };
        Ok((build(Op::Bcast), build(Op::Scatter)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn native_tuner_produces_full_tables() {
        let net = measured();
        let t = Tuner::native();
        let p_grid = vec![2usize, 8, 24, 48];
        let m_grid = grids::log_grid(1, 1 << 20, 12);
        let (b, s) = t.tune(&net, &p_grid, &m_grid).unwrap();
        assert_eq!(b.entries.len(), 48);
        assert_eq!(s.entries.len(), 48);
        for d in b.entries.iter().chain(&s.entries) {
            assert!(d.predicted > 0.0 && d.predicted.is_finite());
        }
    }

    #[test]
    fn native_tuner_bcast_decisions_are_paper_shaped() {
        let net = measured();
        let t = Tuner::native();
        let (b, _) = t
            .tune(&net, &[24], &grids::log_grid(1, 1 << 20, 16))
            .unwrap();
        // large messages: segmented chain; the winner set contains it
        let last = b.at(0, 15);
        assert_eq!(last.strategy, Strategy::BcastSegChain, "{last:?}");
        assert!(last.segment.is_some());
        // small messages: a log-depth eager tree, never a rendezvous one
        let first = b.at(0, 0);
        assert!(
            matches!(first.strategy, Strategy::BcastBinomial | Strategy::BcastBinary
                | Strategy::BcastSegBinomial | Strategy::BcastSegFlat | Strategy::BcastFlat),
            "{first:?}"
        );
    }

    #[test]
    fn scatter_decisions_flat_or_binomial_never_chain() {
        let net = measured();
        let t = Tuner::native();
        let (_, s) = t
            .tune(&net, &[4, 16, 48], &grids::log_grid(64, 1 << 20, 10))
            .unwrap();
        for d in &s.entries {
            assert_ne!(d.strategy, Strategy::ScatterChain, "{d:?}");
        }
    }

    #[test]
    fn decisions_match_exhaustive_native_argmin() {
        let net = measured();
        let t = Tuner::native();
        let p_grid = [8usize, 32];
        let m_grid = [1024u64, 1 << 18];
        let (b, _) = t.tune(&net, &p_grid, &m_grid).unwrap();
        for (qi, &p) in p_grid.iter().enumerate() {
            for (mi, &m) in m_grid.iter().enumerate() {
                let want =
                    models::rank_strategies(&Strategy::BCAST, &net, p, m, &t.s_grid)[0].0;
                assert_eq!(b.at(qi, mi).strategy, want);
            }
        }
    }
}
