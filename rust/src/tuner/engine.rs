//! The tuning engine: sweep the `(P, m)` grid through an
//! [`Evaluator`] and take the per-cell argmin.
//!
//! The engine is backend-agnostic — it owns a `Box<dyn Evaluator>`
//! (analytic models, the simulator, or the AOT artifact; see
//! [`crate::eval`]) — and parallel: non-batched evaluators are swept by
//! a hand-rolled `std::thread::scope` work queue (`--jobs N` on the
//! CLI), with per-cell early pruning of segmented variants whose
//! segment-independent lower bound already loses
//! ([`crate::models::segmented_lower_bound`]). Batched evaluators (the
//! artifact) receive the whole grid in one call instead. Results are
//! bit-identical regardless of the worker count: every cell is computed
//! independently and merged by index.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::Result;

use crate::eval::{ArtifactEval, Evaluator, ModelEval};
use crate::plogp::PLogP;

use super::decision::{Decision, DecisionTable, Op};
use super::grids;

/// One sweep worker per core by default.
fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The tuner: an evaluator, a segment-size search grid, and a worker
/// count for the parallel sweep.
pub struct Tuner {
    evaluator: Box<dyn Evaluator>,
    pub s_grid: Vec<u64>,
    /// Sweep workers (1 = sequential). Set via [`Tuner::jobs`].
    pub jobs: usize,
}

impl Tuner {
    /// Native (pure Rust model) tuner.
    pub fn native() -> Tuner {
        Tuner::with_evaluator(Box::new(ModelEval))
    }

    /// Load the AOT artifact from `dir`.
    pub fn with_artifact(dir: &Path) -> Result<Tuner> {
        Ok(Tuner::with_evaluator(Box::new(ArtifactEval::load(dir)?)))
    }

    /// Prefer the artifact; fall back to native (logging the reason).
    pub fn auto(dir: &Path) -> Tuner {
        match Self::with_artifact(dir) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("tuner artifact unavailable ({e:#}); using native models");
                Tuner::native()
            }
        }
    }

    /// Build on any evaluation backend.
    pub fn with_evaluator(evaluator: Box<dyn Evaluator>) -> Tuner {
        Tuner { evaluator, s_grid: grids::default_s_grid(), jobs: default_jobs() }
    }

    /// Set the sweep worker count (`0` = one per core).
    pub fn jobs(mut self, n: usize) -> Tuner {
        self.jobs = if n == 0 { default_jobs() } else { n };
        self
    }

    pub fn evaluator(&self) -> &dyn Evaluator {
        self.evaluator.as_ref()
    }

    /// Backend name for logs and CLI output.
    pub fn backend_name(&self) -> &'static str {
        self.evaluator.name()
    }

    /// Tune both core operations over the given grids. Returns the
    /// broadcast and scatter decision tables.
    pub fn tune(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<(DecisionTable, DecisionTable)> {
        Ok((
            self.tune_op(Op::Bcast, net, p_grid, m_grid)?,
            self.tune_op(Op::Scatter, net, p_grid, m_grid)?,
        ))
    }

    /// Tune the four extended ops ([`Op::EXT`]: gather, barrier,
    /// allgather, allreduce) over the grid — same parallel work queue,
    /// one table per op in `Op::EXT` order.
    pub fn tune_ext(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<Vec<DecisionTable>> {
        Op::EXT.iter().map(|&op| self.tune_op(op, net, p_grid, m_grid)).collect()
    }

    /// Tune every operation family ([`Op::ALL`] order, one table each).
    pub fn tune_all(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<Vec<DecisionTable>> {
        Op::ALL.iter().map(|&op| self.tune_op(op, net, p_grid, m_grid)).collect()
    }

    /// Tune one operation over the grid.
    pub fn tune_op(
        &self,
        op: Op,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<DecisionTable> {
        let cells = p_grid.len() * m_grid.len();
        let entries = if self.evaluator.batched() || self.jobs <= 1 || cells <= 1 {
            self.evaluator.predict_grid(op, net, p_grid, m_grid, &self.s_grid)?
        } else {
            self.sweep_parallel(op, net, p_grid, m_grid)
        };
        Ok(DecisionTable::new(op, p_grid.to_vec(), m_grid.to_vec(), entries))
    }

    /// The parallel grid sweep: a shared atomic cursor hands cells to
    /// `jobs` scoped workers; each worker's `(index, decision)` pairs
    /// are merged by index afterwards, so scheduling order never
    /// influences the table.
    fn sweep_parallel(
        &self,
        op: Op,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Vec<Decision> {
        let cells = p_grid.len() * m_grid.len();
        let workers = self.jobs.min(cells).max(1);
        let cursor = AtomicUsize::new(0);
        let evaluator: &dyn Evaluator = self.evaluator.as_ref();
        let s_grid: &[u64] = &self.s_grid;
        let partials: Vec<Vec<(usize, Decision)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let cursor = &cursor;
                    scope.spawn(move || {
                        let mut mine = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= cells {
                                break;
                            }
                            let p = p_grid[i / m_grid.len()];
                            let m = m_grid[i % m_grid.len()];
                            mine.push((i, evaluator.best(op, net, p, m, s_grid)));
                        }
                        mine
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("tuner sweep worker panicked"))
                .collect()
        });
        let mut out: Vec<Option<Decision>> = vec![None; cells];
        for (i, d) in partials.into_iter().flatten() {
            out[i] = Some(d);
        }
        out.into_iter().map(|d| d.expect("every cell swept")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Strategy;
    use crate::models;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn native_tuner_produces_full_tables() {
        let net = measured();
        let t = Tuner::native();
        let p_grid = vec![2usize, 8, 24, 48];
        let m_grid = grids::log_grid(1, 1 << 20, 12);
        let (b, s) = t.tune(&net, &p_grid, &m_grid).unwrap();
        assert_eq!(b.entries.len(), 48);
        assert_eq!(s.entries.len(), 48);
        for d in b.entries.iter().chain(&s.entries) {
            assert!(d.predicted > 0.0 && d.predicted.is_finite());
        }
    }

    #[test]
    fn native_tuner_bcast_decisions_are_paper_shaped() {
        let net = measured();
        let t = Tuner::native();
        let (b, _) = t
            .tune(&net, &[24], &grids::log_grid(1, 1 << 20, 16))
            .unwrap();
        // large messages: segmented chain; the winner set contains it
        let last = b.at(0, 15);
        assert_eq!(last.strategy, Strategy::BcastSegChain, "{last:?}");
        assert!(last.segment.is_some());
        // small messages: a log-depth eager tree, never a rendezvous one
        let first = b.at(0, 0);
        assert!(
            matches!(first.strategy, Strategy::BcastBinomial | Strategy::BcastBinary
                | Strategy::BcastSegBinomial | Strategy::BcastSegFlat | Strategy::BcastFlat),
            "{first:?}"
        );
    }

    #[test]
    fn scatter_decisions_flat_or_binomial_never_chain() {
        let net = measured();
        let t = Tuner::native();
        let (_, s) = t
            .tune(&net, &[4, 16, 48], &grids::log_grid(64, 1 << 20, 10))
            .unwrap();
        for d in &s.entries {
            assert_ne!(d.strategy, Strategy::ScatterChain, "{d:?}");
        }
    }

    #[test]
    fn decisions_match_exhaustive_native_argmin() {
        let net = measured();
        let t = Tuner::native();
        let p_grid = [8usize, 32];
        let m_grid = [1024u64, 1 << 18];
        let (b, _) = t.tune(&net, &p_grid, &m_grid).unwrap();
        for (qi, &p) in p_grid.iter().enumerate() {
            for (mi, &m) in m_grid.iter().enumerate() {
                let want =
                    models::rank_strategies(&Strategy::BCAST, &net, p, m, &t.s_grid)[0].0;
                assert_eq!(b.at(qi, mi).strategy, want);
            }
        }
    }

    #[test]
    fn worker_count_never_changes_the_tables() {
        let net = measured();
        let p_grid = vec![2usize, 8, 24, 48];
        let m_grid = grids::log_grid(1, 1 << 20, 12);
        let (b1, s1) = Tuner::native().jobs(1).tune(&net, &p_grid, &m_grid).unwrap();
        for jobs in [2usize, 3, 8, 64] {
            let (bn, sn) = Tuner::native().jobs(jobs).tune(&net, &p_grid, &m_grid).unwrap();
            assert_eq!(b1.entries, bn.entries, "jobs={jobs}");
            assert_eq!(s1.entries, sn.entries, "jobs={jobs}");
        }
    }

    #[test]
    fn jobs_zero_means_all_cores() {
        let t = Tuner::native().jobs(0);
        assert!(t.jobs >= 1);
        assert_eq!(t.backend_name(), "native");
    }

    #[test]
    fn tune_all_covers_every_op_in_order() {
        let net = measured();
        let t = Tuner::native();
        let tables = t.tune_all(&net, &[4, 16], &[1, 4096]).unwrap();
        assert_eq!(tables.len(), Op::COUNT);
        for (i, table) in tables.iter().enumerate() {
            assert_eq!(table.op.index(), i);
            assert_eq!(table.entries.len(), 4);
            for d in &table.entries {
                assert!(table.op.family().contains(&d.strategy), "{:?}", d);
                assert!(d.predicted > 0.0 && d.predicted.is_finite());
            }
        }
    }

    #[test]
    fn ext_worker_count_never_changes_the_tables() {
        let net = measured();
        let p_grid = vec![2usize, 8, 24, 48];
        let m_grid = grids::log_grid(1, 1 << 20, 12);
        let ext1 = Tuner::native().jobs(1).tune_ext(&net, &p_grid, &m_grid).unwrap();
        for jobs in [2usize, 8] {
            let extn = Tuner::native().jobs(jobs).tune_ext(&net, &p_grid, &m_grid).unwrap();
            for (a, b) in ext1.iter().zip(&extn) {
                assert_eq!(a.entries, b.entries, "{:?} jobs={jobs}", a.op);
            }
        }
    }
}
