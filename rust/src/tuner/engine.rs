//! The tuning engine: sweep the `(P, m)` grid through an
//! [`Evaluator`] and take the per-cell argmin.
//!
//! The engine is backend-agnostic — it owns a `Box<dyn Evaluator>`
//! (analytic models, the simulator, or the AOT artifact; see
//! [`crate::eval`]) — and parallel: non-batched evaluators are swept by
//! a hand-rolled `std::thread::scope` work queue (`--jobs N` on the
//! CLI). Batched evaluators (the artifact) receive the whole grid in
//! one call instead.
//!
//! The sweep hot path is pruned and instrumented: each tuned op builds
//! one [`GapCache`] (every gap interpolation of the sweep, computed
//! once), each worker seeds the next cell with its previous cell's
//! winner (the warm-start hint — adjacent cells almost always share an
//! argmin, so the m-aware [`crate::models::LOWER_BOUNDS`] pruning test
//! fires early), and the shared [`EvalStats`] counters record exactly
//! how much work the bounds saved (`tune --stats`, `BENCH_tuner.json`).
//! Results are bit-identical regardless of the worker count *and* of
//! the hints: every cell's argmin is hint-independent (asserted in
//! `rust/tests/evaluator.rs`), cells are computed independently and
//! merged by index.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::Result;

use crate::collectives::Strategy;
use crate::eval::{ArtifactEval, CellCtx, EvalCounts, EvalStats, Evaluator, ModelEval, ReplayEval};
use crate::models::CorrectionTable;
use crate::obs::{self, Span};
use crate::plogp::{GapCache, PLogP};

use super::decision::{Decision, DecisionTable, Op};
use super::grids;

/// One sweep worker per core by default.
fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The tuner: an evaluator, a segment-size search grid, and a worker
/// count for the parallel sweep.
pub struct Tuner {
    evaluator: Box<dyn Evaluator>,
    pub s_grid: Vec<u64>,
    /// Sweep workers (1 = sequential). Set via [`Tuner::jobs`].
    pub jobs: usize,
    /// Cumulative sweep counters (all tunes since construction or the
    /// last [`Tuner::reset_stats`]); shared by every worker.
    stats: EvalStats,
}

impl Tuner {
    /// Native (pure Rust model) tuner.
    pub fn native() -> Tuner {
        Tuner::with_evaluator(Box::new(ModelEval::new()))
    }

    /// Native tuner with a trace-fitted [`CorrectionTable`] applied
    /// (see [`crate::models::correct`]). An empty table degrades to the
    /// plain native tuner.
    pub fn corrected(table: CorrectionTable) -> Tuner {
        Tuner::with_evaluator(Box::new(ModelEval::new().with_corrections(table)))
    }

    /// Load a corrections table from `path` (a directory holding
    /// `corrections.tsv`, or the file itself — the `calibrate`
    /// subcommand's output) and build a corrected native tuner.
    pub fn with_corrections(path: &Path) -> Result<Tuner> {
        Ok(Tuner::corrected(CorrectionTable::load(path)?))
    }

    /// Load the AOT artifact from `dir`.
    pub fn with_artifact(dir: &Path) -> Result<Tuner> {
        Ok(Tuner::with_evaluator(Box::new(ArtifactEval::load(dir)?)))
    }

    /// Replay captured traces from `dir` ([`crate::eval::ReplayEval`]):
    /// tuning against a fixed, recorded workload instead of a live
    /// backend. Tune over the captured grids (the trace set's
    /// `p_values()`/`m_values()`) — uncaptured cells score `+inf`.
    pub fn with_replay(dir: &Path) -> Result<Tuner> {
        Ok(Tuner::with_evaluator(Box::new(ReplayEval::load(dir)?)))
    }

    /// Prefer the artifact; fall back to native (logging the reason).
    pub fn auto(dir: &Path) -> Tuner {
        match Self::with_artifact(dir) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("tuner artifact unavailable ({e:#}); using native models");
                Tuner::native()
            }
        }
    }

    /// Build on any evaluation backend.
    pub fn with_evaluator(evaluator: Box<dyn Evaluator>) -> Tuner {
        Tuner {
            evaluator,
            s_grid: grids::default_s_grid(),
            jobs: default_jobs(),
            stats: EvalStats::new(),
        }
    }

    /// Snapshot of the sweep counters (model invocations, pruned
    /// cells/searches, warm-start hits — see [`EvalCounts`]).
    pub fn stats(&self) -> EvalCounts {
        self.stats.snapshot()
    }

    /// Zero the sweep counters (e.g. between bench iterations).
    pub fn reset_stats(&self) {
        self.stats.reset()
    }

    /// Fold another tuner's counters into this one's — used when a
    /// caller substitutes a fallback tuner for one run (the
    /// coordinator's artifact-failure path) but wants one cumulative
    /// cost picture.
    pub fn merge_stats(&self, d: &EvalCounts) {
        self.stats.add(d)
    }

    /// Set the sweep worker count (`0` = one per core).
    pub fn jobs(mut self, n: usize) -> Tuner {
        self.jobs = if n == 0 { default_jobs() } else { n };
        self
    }

    pub fn evaluator(&self) -> &dyn Evaluator {
        self.evaluator.as_ref()
    }

    /// Backend name for logs and CLI output.
    pub fn backend_name(&self) -> &'static str {
        self.evaluator.name()
    }

    /// Tune both core operations over the given grids. Returns the
    /// broadcast and scatter decision tables.
    pub fn tune(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<(DecisionTable, DecisionTable)> {
        Ok((
            self.tune_op(Op::Bcast, net, p_grid, m_grid)?,
            self.tune_op(Op::Scatter, net, p_grid, m_grid)?,
        ))
    }

    /// Tune the four extended ops ([`Op::EXT`]: gather, barrier,
    /// allgather, allreduce) over the grid — same parallel work queue,
    /// one table per op in `Op::EXT` order.
    pub fn tune_ext(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<Vec<DecisionTable>> {
        Op::EXT.iter().map(|&op| self.tune_op(op, net, p_grid, m_grid)).collect()
    }

    /// Tune every operation family ([`Op::ALL`] order, one table each).
    pub fn tune_all(
        &self,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<Vec<DecisionTable>> {
        Op::ALL.iter().map(|&op| self.tune_op(op, net, p_grid, m_grid)).collect()
    }

    /// Tune one operation over the grid.
    pub fn tune_op(
        &self,
        op: Op,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<DecisionTable> {
        let entries = if self.evaluator.batched() {
            self.evaluator.predict_grid(op, net, p_grid, m_grid, &self.s_grid)?
        } else {
            self.sweep(op, net, p_grid, m_grid)
        };
        Ok(DecisionTable::new(op, p_grid.to_vec(), m_grid.to_vec(), entries))
    }

    /// The pruned grid sweep. One [`GapCache`] is built per tuned op;
    /// every cell is evaluated through [`Evaluator::best_in`] with the
    /// cache, the shared counters, and a warm-start hint — the winner
    /// of the cell the same worker computed just before. Sequential
    /// (`jobs == 1`) runs inline in row-major order; the parallel path
    /// hands cells to scoped workers off a shared atomic cursor and
    /// merges `(index, decision)` pairs by index afterwards, so neither
    /// scheduling order nor the per-worker hints can influence the
    /// table (hints are advisory by the `best_in` contract).
    fn sweep(&self, op: Op, net: &PLogP, p_grid: &[usize], m_grid: &[u64]) -> Vec<Decision> {
        let _sweep_span = Span::start("tuner.sweep_ns");
        let cache = GapCache::new(net, m_grid, &self.s_grid);
        let cells = p_grid.len() * m_grid.len();
        let workers = self.jobs.min(cells).max(1);
        let evaluator: &dyn Evaluator = self.evaluator.as_ref();
        let s_grid: &[u64] = &self.s_grid;
        let stats = &self.stats;
        // per-backend cell latency: resolve the histogram once per sweep
        // so workers share one Arc and never touch the registry maps
        let cell_hist = obs::enabled()
            .then(|| obs::registry().histogram(&format!("eval.{}.cell_ns", evaluator.name())));
        let cell_hist = &cell_hist;
        let cell = |i: usize, hint: Option<Strategy>| -> Decision {
            let p = p_grid[i / m_grid.len()];
            let m = m_grid[i % m_grid.len()];
            let ctx = CellCtx { hint, cache: Some(&cache), stats: Some(stats) };
            let t0 = cell_hist.as_ref().map(|_| std::time::Instant::now());
            let d = evaluator.best_in(op, net, p, m, s_grid, &ctx);
            if let (Some(h), Some(t0)) = (cell_hist.as_ref(), t0) {
                h.record_duration(t0.elapsed());
            }
            d
        };
        if workers == 1 {
            let mut hint = None;
            return (0..cells)
                .map(|i| {
                    let d = cell(i, hint);
                    hint = Some(d.strategy);
                    d
                })
                .collect();
        }
        let cursor = AtomicUsize::new(0);
        let cell = &cell;
        let partials: Vec<Vec<(usize, Decision)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let cursor = &cursor;
                    scope.spawn(move || {
                        let mut mine = Vec::new();
                        let mut hint = None;
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= cells {
                                break;
                            }
                            let d = cell(i, hint);
                            hint = Some(d.strategy);
                            mine.push((i, d));
                        }
                        mine
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("tuner sweep worker panicked"))
                .collect()
        });
        let mut out: Vec<Option<Decision>> = vec![None; cells];
        for (i, d) in partials.into_iter().flatten() {
            out[i] = Some(d);
        }
        out.into_iter().map(|d| d.expect("every cell swept")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Strategy;
    use crate::models;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn native_tuner_produces_full_tables() {
        let net = measured();
        let t = Tuner::native();
        let p_grid = vec![2usize, 8, 24, 48];
        let m_grid = grids::log_grid(1, 1 << 20, 12);
        let (b, s) = t.tune(&net, &p_grid, &m_grid).unwrap();
        assert_eq!(b.entries.len(), 48);
        assert_eq!(s.entries.len(), 48);
        for d in b.entries.iter().chain(&s.entries) {
            assert!(d.predicted > 0.0 && d.predicted.is_finite());
        }
    }

    #[test]
    fn native_tuner_bcast_decisions_are_paper_shaped() {
        let net = measured();
        let t = Tuner::native();
        let (b, _) = t
            .tune(&net, &[24], &grids::log_grid(1, 1 << 20, 16))
            .unwrap();
        // large messages: segmented chain; the winner set contains it
        let last = b.at(0, 15);
        assert_eq!(last.strategy, Strategy::BcastSegChain, "{last:?}");
        assert!(last.segment.is_some());
        // small messages: a log-depth eager tree, never a rendezvous one
        let first = b.at(0, 0);
        assert!(
            matches!(first.strategy, Strategy::BcastBinomial | Strategy::BcastBinary
                | Strategy::BcastSegBinomial | Strategy::BcastSegFlat | Strategy::BcastFlat),
            "{first:?}"
        );
    }

    #[test]
    fn scatter_decisions_flat_or_binomial_never_chain() {
        let net = measured();
        let t = Tuner::native();
        let (_, s) = t
            .tune(&net, &[4, 16, 48], &grids::log_grid(64, 1 << 20, 10))
            .unwrap();
        for d in &s.entries {
            assert_ne!(d.strategy, Strategy::ScatterChain, "{d:?}");
        }
    }

    #[test]
    fn decisions_match_exhaustive_native_argmin() {
        let net = measured();
        let t = Tuner::native();
        let p_grid = [8usize, 32];
        let m_grid = [1024u64, 1 << 18];
        let (b, _) = t.tune(&net, &p_grid, &m_grid).unwrap();
        for (qi, &p) in p_grid.iter().enumerate() {
            for (mi, &m) in m_grid.iter().enumerate() {
                let want =
                    models::rank_strategies(&Strategy::BCAST, &net, p, m, &t.s_grid)[0].0;
                assert_eq!(b.at(qi, mi).strategy, want);
            }
        }
    }

    #[test]
    fn worker_count_never_changes_the_tables() {
        let net = measured();
        let p_grid = vec![2usize, 8, 24, 48];
        let m_grid = grids::log_grid(1, 1 << 20, 12);
        let (b1, s1) = Tuner::native().jobs(1).tune(&net, &p_grid, &m_grid).unwrap();
        for jobs in [2usize, 3, 8, 64] {
            let (bn, sn) = Tuner::native().jobs(jobs).tune(&net, &p_grid, &m_grid).unwrap();
            assert_eq!(b1.entries, bn.entries, "jobs={jobs}");
            assert_eq!(s1.entries, sn.entries, "jobs={jobs}");
        }
    }

    #[test]
    fn corrected_worker_count_never_changes_the_tables() {
        // the byte-identical sweep contract survives corrections: the
        // per-cell factor is hint- and scheduling-independent, so jobs
        // must not perturb a corrected table either
        let net = measured();
        let mut table = CorrectionTable::identity();
        for (i, &s) in Strategy::ALL.iter().enumerate() {
            for oct in [0u32, 6, 13, 17, 20] {
                table.set(s, oct, 0.4 + ((i * 7 + oct as usize * 3) % 21) as f64 * 0.1);
            }
        }
        let p_grid = vec![2usize, 8, 24];
        let m_grid = grids::log_grid(1, 1 << 20, 8);
        let base = Tuner::corrected(table.clone())
            .jobs(1)
            .tune_all(&net, &p_grid, &m_grid)
            .unwrap();
        for jobs in [2usize, 8] {
            let tn = Tuner::corrected(table.clone())
                .jobs(jobs)
                .tune_all(&net, &p_grid, &m_grid)
                .unwrap();
            for (a, b) in base.iter().zip(&tn) {
                assert_eq!(a.entries, b.entries, "{:?} jobs={jobs}", a.op);
            }
        }
        assert_eq!(Tuner::corrected(table).backend_name(), "native");
    }

    #[test]
    fn jobs_zero_means_all_cores() {
        let t = Tuner::native().jobs(0);
        assert!(t.jobs >= 1);
        assert_eq!(t.backend_name(), "native");
    }

    #[test]
    fn sweep_counters_accumulate_and_reset() {
        let net = measured();
        let t = Tuner::native().jobs(1);
        let _ = t.tune_op(Op::Bcast, &net, &[2, 8], &[64, 4096]).unwrap();
        let c = t.stats();
        assert_eq!(c.cells, 4);
        assert!(c.model_invocations > 0);
        // row-major sequential sweep: every cell after the first has a
        // warm-start hint
        assert_eq!(c.warm_hits + c.warm_misses, 3);
        let _ = t.tune_op(Op::Bcast, &net, &[2, 8], &[64, 4096]).unwrap();
        assert_eq!(t.stats().cells, 8, "counters are cumulative");
        t.reset_stats();
        assert_eq!(t.stats().cells, 0);
    }

    #[test]
    fn pruned_sweep_beats_the_exhaustive_invocation_count() {
        let net = measured();
        let t = Tuner::native().jobs(1);
        let p_grid = grids::default_p_grid();
        let m_grid = grids::default_m_grid();
        let _ = t.tune_op(Op::Bcast, &net, &p_grid, &m_grid).unwrap();
        let c = t.stats();
        let cells = (p_grid.len() * m_grid.len()) as u64;
        let baseline = cells
            * crate::eval::exhaustive_invocations_per_cell(&Strategy::BCAST, t.s_grid.len());
        assert!(
            c.model_invocations < baseline / 2,
            "pruning saved too little: {} of {baseline}",
            c.model_invocations
        );
        assert!(c.seg_searches_pruned > 0);
        assert!(c.seg_points_skipped > 0);
        assert!(c.warm_hits > c.warm_misses, "{c:?}");
    }

    #[test]
    fn tune_all_covers_every_op_in_order() {
        let net = measured();
        let t = Tuner::native();
        let tables = t.tune_all(&net, &[4, 16], &[1, 4096]).unwrap();
        assert_eq!(tables.len(), Op::COUNT);
        for (i, table) in tables.iter().enumerate() {
            assert_eq!(table.op.index(), i);
            assert_eq!(table.entries.len(), 4);
            for d in &table.entries {
                assert!(table.op.family().contains(&d.strategy), "{:?}", d);
                assert!(d.predicted > 0.0 && d.predicted.is_finite());
            }
        }
    }

    #[test]
    fn ext_worker_count_never_changes_the_tables() {
        let net = measured();
        let p_grid = vec![2usize, 8, 24, 48];
        let m_grid = grids::log_grid(1, 1 << 20, 12);
        let ext1 = Tuner::native().jobs(1).tune_ext(&net, &p_grid, &m_grid).unwrap();
        for jobs in [2usize, 8] {
            let extn = Tuner::native().jobs(jobs).tune_ext(&net, &p_grid, &m_grid).unwrap();
            for (a, b) in ext1.iter().zip(&extn) {
                assert_eq!(a.entries, b.entries, "{:?} jobs={jobs}", a.op);
            }
        }
    }
}
