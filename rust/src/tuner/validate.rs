//! Selection-quality validation, generalized: does one evaluator's
//! predicted winner match another evaluator's empirically best strategy?
//!
//! This is the paper's §4 headline claim, quantified: "the selection of
//! the best communication implementation can be made with the help of
//! the communication models", even where the models' absolute numbers
//! drift (small-message TCP anomalies). [`cross_validate`] runs the
//! check between *any* two [`Evaluator`]s — the classic configuration
//! (analytic models judged against the simulator) is wrapped by
//! [`validate_selection`]; the trace-replay backend
//! ([`crate::eval::ReplayEval`]) slots in as either side with no
//! changes here (judging models against a *committed* workload, or
//! re-judging a replayed run against the live simulator), and a future
//! real-MPI backend cross-checks the same way for free.

use crate::collectives::Strategy;
use crate::eval::{Evaluator, ModelEval, SimEval};
use crate::models::CorrectionTable;
use crate::netsim::NetConfig;
use crate::plogp::PLogP;

/// Result of validating one operation family over a grid.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    /// Grid points evaluated.
    pub points: usize,
    /// Points where predicted winner == empirical winner.
    pub correct: usize,
    /// Points where the top two empirical strategies differ by more than
    /// `meaningful_margin` (ties are noise, not decisions).
    pub meaningful: usize,
    /// Correct among the meaningful points.
    pub correct_meaningful: usize,
    /// Mean relative error |predicted - measured| / measured of the
    /// *chosen* strategy's time.
    pub mean_rel_err: f64,
    /// Worst regret: measured(chosen) / measured(best) - 1, maximized
    /// over grid points.
    pub max_regret: f64,
}

impl ValidationReport {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.points.max(1) as f64
    }

    pub fn meaningful_accuracy(&self) -> f64 {
        if self.meaningful == 0 {
            return 1.0;
        }
        self.correct_meaningful as f64 / self.meaningful as f64
    }
}

/// Options for validation sweeps.
#[derive(Debug, Clone)]
pub struct ValidateOptions {
    /// Margin below which the top-two empirical times count as a tie.
    pub meaningful_margin: f64,
    /// Segment-size search grid.
    pub s_grid: Vec<u64>,
}

impl Default for ValidateOptions {
    fn default() -> Self {
        ValidateOptions {
            meaningful_margin: 0.10,
            s_grid: super::grids::default_s_grid(),
        }
    }
}

/// Run every strategy of `family` empirically at `(p, m)` and return
/// `(strategy, measured seconds, segment)` sorted by time. The segment
/// used for segmented strategies is the model-tuned one (that is what a
/// deployed runtime would execute). Compatibility wrapper over
/// [`SimEval`]'s ranking.
pub fn empirical_ranking(
    cfg: &NetConfig,
    net: &PLogP,
    family: &[Strategy],
    p: usize,
    m: u64,
    s_grid: &[u64],
) -> Vec<(Strategy, f64, Option<u64>)> {
    SimEval::new(cfg.clone()).rank(family, net, p, m, s_grid)
}

/// Cross-check two evaluators over a `(P, m)` grid: `candidate` picks a
/// winner per cell, `reference` supplies the ground-truth ranking the
/// pick is judged against.
pub fn cross_validate(
    reference: &dyn Evaluator,
    candidate: &dyn Evaluator,
    net: &PLogP,
    family: &[Strategy],
    p_list: &[usize],
    m_list: &[u64],
    opts: &ValidateOptions,
) -> ValidationReport {
    let mut rep = ValidationReport {
        points: 0,
        correct: 0,
        meaningful: 0,
        correct_meaningful: 0,
        mean_rel_err: 0.0,
        max_regret: 0.0,
    };
    let mut err_sum = 0.0;
    for &p in p_list {
        for &m in m_list {
            let predicted = candidate.rank(family, net, p, m, &opts.s_grid);
            let measured = reference.rank(family, net, p, m, &opts.s_grid);
            let chosen = predicted[0].0;
            let best = measured[0].0;
            let chosen_measured = measured
                .iter()
                .find(|(s, _, _)| *s == chosen)
                .map(|(_, t, _)| *t)
                .unwrap();
            let best_measured = measured[0].1;
            let is_meaningful = measured.len() >= 2
                && (measured[1].1 - measured[0].1) / measured[0].1
                    > opts.meaningful_margin;

            rep.points += 1;
            if chosen == best {
                rep.correct += 1;
            }
            if is_meaningful {
                rep.meaningful += 1;
                if chosen == best {
                    rep.correct_meaningful += 1;
                }
            }
            err_sum += (predicted[0].1 - chosen_measured).abs() / chosen_measured;
            rep.max_regret =
                rep.max_regret.max(chosen_measured / best_measured - 1.0);
        }
    }
    rep.mean_rel_err = err_sum / rep.points.max(1) as f64;
    rep
}

/// Before/after view of one calibration: the same reference judged the
/// uncorrected and the corrected native models over the same grid.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub uncorrected: ValidationReport,
    pub corrected: ValidationReport,
}

impl CalibrationReport {
    /// Did the correction table reduce the mean relative error of the
    /// chosen strategy's predicted time?
    pub fn error_reduced(&self) -> bool {
        self.corrected.mean_rel_err <= self.uncorrected.mean_rel_err
    }

    /// Change in winner agreement with the reference (positive means
    /// the corrected model agrees more often).
    pub fn accuracy_delta(&self) -> f64 {
        self.corrected.accuracy() - self.uncorrected.accuracy()
    }
}

/// Judge a fitted [`CorrectionTable`]: cross-validate the uncorrected
/// and the corrected native models against the same reference over the
/// same grid (the `validate --corrections` report). A good calibration
/// shows `error_reduced()` and a non-negative `accuracy_delta()`.
pub fn validate_calibration(
    reference: &dyn Evaluator,
    table: &CorrectionTable,
    net: &PLogP,
    family: &[Strategy],
    p_list: &[usize],
    m_list: &[u64],
    opts: &ValidateOptions,
) -> CalibrationReport {
    let uncorrected =
        cross_validate(reference, &ModelEval::new(), net, family, p_list, m_list, opts);
    let corrected = cross_validate(
        reference,
        &ModelEval::new().with_corrections(table.clone()),
        net,
        family,
        p_list,
        m_list,
        opts,
    );
    CalibrationReport { uncorrected, corrected }
}

/// The classic configuration: analytic model selection judged against
/// the simulated cluster.
pub fn validate_selection(
    cfg: &NetConfig,
    net: &PLogP,
    family: &[Strategy],
    p_list: &[usize],
    m_list: &[u64],
    opts: &ValidateOptions,
) -> ValidationReport {
    cross_validate(&SimEval::new(cfg.clone()), &ModelEval::new(), net, family, p_list, m_list, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Netsim;
    use crate::plogp;

    fn setup() -> (NetConfig, PLogP) {
        let cfg = NetConfig::fast_ethernet_ideal();
        let mut sim = Netsim::new(2, cfg.clone());
        let net = plogp::bench::measure(&mut sim);
        (cfg, net)
    }

    #[test]
    fn empirical_ranking_is_sorted_and_complete() {
        let (cfg, net) = setup();
        let r = empirical_ranking(&cfg, &net, &Strategy::BCAST, 8, 65536, &[4096, 16384]);
        assert_eq!(r.len(), 10);
        for w in r.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn selection_is_accurate_on_ideal_network() {
        let (cfg, net) = setup();
        let opts = ValidateOptions::default();
        let rep = validate_selection(
            &cfg,
            &net,
            &Strategy::BCAST,
            &[4, 16],
            &[256, 65536, 1 << 20],
            &opts,
        );
        assert_eq!(rep.points, 6);
        // where the margin is meaningful the model must always pick right
        assert_eq!(
            rep.correct_meaningful, rep.meaningful,
            "meaningful accuracy {} ({rep:?})",
            rep.meaningful_accuracy()
        );
        // and regret stays small everywhere
        assert!(rep.max_regret < 0.35, "{rep:?}");
    }

    #[test]
    fn scatter_selection_validates_too() {
        let (cfg, net) = setup();
        let opts = ValidateOptions::default();
        let rep = validate_selection(
            &cfg,
            &net,
            &Strategy::SCATTER,
            &[8, 32],
            &[1024, 65536],
            &opts,
        );
        assert!(rep.meaningful_accuracy() >= 0.99, "{rep:?}");
    }

    #[test]
    fn replay_slots_into_cross_validate_as_the_reference() {
        // capture a small sweep, then judge the analytic models against
        // the *recorded* workload — replay as reference, no API changes
        let cfg = NetConfig::fast_ethernet_ideal();
        let p_list = [4usize, 8];
        let m_list = [1024u64, 1 << 18];
        let (set, net) = crate::harness::experiments::record_traces(
            &cfg,
            &[crate::tuner::Op::Bcast],
            &p_list,
            &m_list,
            &ValidateOptions::default().s_grid,
            1 << 14,
        );
        let replay = crate::eval::ReplayEval::new(set).unwrap();
        let opts = ValidateOptions::default();
        let rep = cross_validate(
            &replay,
            &ModelEval::new(),
            &net,
            &Strategy::BCAST,
            &p_list,
            &m_list,
            &opts,
        );
        assert_eq!(rep.points, 4);
        // the captured workload is the simulator's, so the models must
        // judge exactly as they do against SimEval on the same cells
        let live = validate_selection(&cfg, &net, &Strategy::BCAST, &p_list, &m_list, &opts);
        assert_eq!(rep.correct, live.correct);
        assert_eq!(rep.max_regret, live.max_regret);
    }

    #[test]
    fn calibration_closes_a_constant_factor_model_gap() {
        use crate::netsim::{TraceMeta, TraceRecord, TraceSet};
        use crate::plogp::GapTable;
        use crate::tuner::Op;

        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        let net = PLogP::new(10.0, GapTable::new(sizes, gaps));

        // a record whose measured critical path is scale × the model's
        // prediction for its cell
        let rec = |strategy: Strategy, p: usize, m: u64, scale: f64| TraceRecord {
            meta: TraceMeta {
                op: Op::of(strategy).name().to_string(),
                strategy: strategy.name().to_string(),
                p,
                m,
                segment: None,
                completion_ns: (crate::models::predict(strategy, &net, p, m, None)
                    * scale
                    * 1e9)
                    .round() as u64,
                dropped: 0,
                plogp_l: net.l,
                plogp_sizes: net.table.sizes().to_vec(),
                plogp_gaps: net.table.gaps().to_vec(),
                fault_plan: None,
            },
            events: Vec::new(),
        };

        // a "cluster" where flat bcast runs exactly 2× and binomial
        // exactly 3× slower than the analytic models claim
        let family = [Strategy::BcastFlat, Strategy::BcastBinomial];
        let scales = [2.0, 3.0];
        let p_list = [4usize, 8];
        let m_list = [8u64, 64];
        let mut set = TraceSet::new();
        for (&s, &scale) in family.iter().zip(&scales) {
            for &p in &p_list {
                for &m in &m_list {
                    set.insert(rec(s, p, m, scale));
                }
            }
        }
        let (table, _fit) = CorrectionTable::fit(&set, &net);
        let replay = crate::eval::ReplayEval::new(set).unwrap();
        let rep = validate_calibration(
            &replay,
            &table,
            &net,
            &family,
            &p_list,
            &m_list,
            &ValidateOptions::default(),
        );
        assert_eq!(rep.uncorrected.points, 4);
        // uncorrected: the chosen strategy's time is off by the hidden
        // factor — at least (2-1)/2 relative error on every cell
        assert!(rep.uncorrected.mean_rel_err > 0.4, "{:?}", rep.uncorrected);
        // corrected: the fit recovers the factors exactly (up to ns
        // quantization of the fixture), so the gap collapses
        assert!(rep.corrected.mean_rel_err < 1e-6, "{:?}", rep.corrected);
        assert!(rep.error_reduced());
        assert_eq!(rep.corrected.correct, rep.corrected.points, "{:?}", rep.corrected);
        assert!(rep.accuracy_delta() >= 0.0);
    }

    #[test]
    fn an_evaluator_validates_perfectly_against_itself() {
        // sim vs sim: deterministic simulation means identical rankings,
        // so accuracy is total and regret/error are zero
        let (cfg, net) = setup();
        let sim = SimEval::new(cfg);
        let opts = ValidateOptions::default();
        let rep = cross_validate(
            &sim,
            &sim,
            &net,
            &Strategy::BCAST,
            &[4, 16],
            &[1024, 1 << 18],
            &opts,
        );
        assert_eq!(rep.correct, rep.points);
        assert_eq!(rep.max_regret, 0.0);
        assert_eq!(rep.mean_rel_err, 0.0);
    }
}
