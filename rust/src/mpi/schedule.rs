//! Declarative communication schedules.
//!
//! A schedule is the static expansion of one collective operation: for
//! every rank, an ordered list of sends, each fired by a trigger (at
//! start, or on receipt of a tagged message), plus the set of payloads
//! the rank must have received for the operation to count as complete.
//!
//! Payloads are *descriptors*, not bytes: a broadcast moves
//! `Range{offset: 0, len: m}`, a scatter moves per-rank ranges of the
//! root buffer, a reduction moves contributor bitmasks. This keeps the
//! simulator allocation-free while letting tests verify that every rank
//! ends up with exactly the right data.

use anyhow::{bail, Result};

use super::Rank;

/// Message tag. The low 32 bits identify the logical transfer (e.g. the
/// segment index); collectives are free to use any scheme as long as tags
/// are unique per (receiver, transfer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tag(pub u64);

/// Point-to-point protocol for a data send.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Protocol {
    /// Send immediately; the receiver is assumed ready (pre-posted).
    #[default]
    Eager,
    /// RTS → CTS → DATA handshake. The handshake is non-blocking on the
    /// sender (other sends may proceed while waiting for the CTS), which
    /// is what makes `Flat Tree Rendezvous` cost
    /// `(P-1) g(m) + 2 g(1) + 3L` rather than `(P-1)(g(m)+2g(1)+3L)`.
    Rendezvous,
}

/// What a message carries (descriptor, not bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Payload {
    /// A contiguous range of the operation's root buffer.
    Range { offset: u64, len: u64 },
    /// A set of ranks whose contributions have been combined (reduction
    /// traffic), as a bitmask. Supports P <= 64.
    Ranks(u64),
    /// Pure control (barrier tokens).
    Control,
}

impl Payload {
    /// `Ranks` payloads are u64 bitmasks, so reduction schedules can
    /// track at most this many contributors.
    pub const MAX_MASK_RANKS: usize = 64;

    pub fn range(offset: u64, len: u64) -> Payload {
        Payload::Range { offset, len }
    }

    /// Gate for reduction schedule builders: a structured error (rather
    /// than a silently wrong bitmask) when `p` exceeds what a u64
    /// contributor mask can represent.
    pub fn check_mask_capacity(p: usize) -> Result<()> {
        if p > Payload::MAX_MASK_RANKS {
            bail!(
                "reduction payloads track contributors in a u64 bitmask: \
                 p = {p} exceeds the {}-rank limit",
                Payload::MAX_MASK_RANKS
            );
        }
        Ok(())
    }

    /// Bitmask of all ranks `0..p` (checked against the mask capacity).
    pub fn all_ranks_mask(p: usize) -> Result<u64> {
        Payload::check_mask_capacity(p)?;
        Ok(if p == Payload::MAX_MASK_RANKS {
            u64::MAX
        } else {
            (1u64 << p) - 1
        })
    }
}

/// When a send becomes eligible for injection.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Trigger {
    /// Eligible at operation start (root sends).
    AtStart,
    /// Eligible when a data message with this tag has been received by
    /// this rank.
    OnRecv(Tag),
    /// Eligible when *all* these tags have been received (fan-in nodes of
    /// gather/reduce trees).
    OnRecvAll(Vec<Tag>),
}

/// One send in a rank's program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SendSpec {
    pub to: Rank,
    pub tag: Tag,
    pub bytes: u64,
    pub payload: Payload,
    pub trigger: Trigger,
    pub protocol: Protocol,
}

/// A rank's part of the schedule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RankSchedule {
    /// Sends, in program order. Sends whose triggers fire earlier may be
    /// injected earlier (non-blocking semantics); the NIC serializes.
    pub sends: Vec<SendSpec>,
    /// Payloads this rank must receive for the operation to complete.
    pub expected: Vec<Payload>,
}

/// A complete static schedule for one collective operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CommSchedule {
    /// Number of participating ranks.
    pub p: usize,
    /// Human-readable operation name (e.g. "bcast/binomial").
    pub name: String,
    pub ranks: Vec<RankSchedule>,
}

impl CommSchedule {
    pub fn new(p: usize, name: impl Into<String>) -> CommSchedule {
        CommSchedule { p, name: name.into(), ranks: vec![RankSchedule::default(); p] }
    }

    /// Total bytes injected into the network by all data sends.
    pub fn total_send_bytes(&self) -> u64 {
        self.ranks.iter().flat_map(|r| &r.sends).map(|s| s.bytes).sum()
    }

    /// Total number of data sends.
    pub fn total_sends(&self) -> usize {
        self.ranks.iter().map(|r| r.sends.len()).sum()
    }

    /// Structural sanity: destinations in range, no send to self, every
    /// OnRecv trigger refers to a tag some other rank actually sends to
    /// this rank, and expected payloads are covered by incoming sends.
    /// Returns a list of problems (empty = well-formed).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        if self.ranks.len() != self.p {
            problems.push(format!(
                "schedule has {} rank entries for p={}",
                self.ranks.len(),
                self.p
            ));
            return problems;
        }
        // tags incoming to each rank
        let mut incoming: Vec<Vec<Tag>> = vec![Vec::new(); self.p];
        for (r, rs) in self.ranks.iter().enumerate() {
            for s in &rs.sends {
                if (s.to as usize) >= self.p {
                    problems.push(format!("rank {r} sends to out-of-range {}", s.to));
                    continue;
                }
                if s.to as usize == r {
                    problems.push(format!("rank {r} sends to itself (tag {:?})", s.tag));
                }
                incoming[s.to as usize].push(s.tag);
            }
        }
        for (r, rs) in self.ranks.iter().enumerate() {
            for s in &rs.sends {
                let need: Vec<&Tag> = match &s.trigger {
                    Trigger::AtStart => vec![],
                    Trigger::OnRecv(t) => vec![t],
                    Trigger::OnRecvAll(ts) => ts.iter().collect(),
                };
                for t in need {
                    if !incoming[r].contains(t) {
                        problems.push(format!(
                            "rank {r} waits on tag {t:?} that nobody sends it"
                        ));
                    }
                }
            }
        }
        problems
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn send(to: Rank, tag: u64, trigger: Trigger) -> SendSpec {
        SendSpec {
            to,
            tag: Tag(tag),
            bytes: 100,
            payload: Payload::range(0, 100),
            trigger,
            protocol: Protocol::Eager,
        }
    }

    #[test]
    fn valid_chain_schedule_passes() {
        let mut s = CommSchedule::new(3, "test/chain");
        s.ranks[0].sends.push(send(1, 0, Trigger::AtStart));
        s.ranks[1].sends.push(send(2, 0, Trigger::OnRecv(Tag(0))));
        s.ranks[1].expected.push(Payload::range(0, 100));
        s.ranks[2].expected.push(Payload::range(0, 100));
        assert!(s.validate().is_empty(), "{:?}", s.validate());
    }

    #[test]
    fn self_send_flagged() {
        let mut s = CommSchedule::new(2, "bad");
        s.ranks[0].sends.push(send(0, 0, Trigger::AtStart));
        assert!(!s.validate().is_empty());
    }

    #[test]
    fn out_of_range_dst_flagged() {
        let mut s = CommSchedule::new(2, "bad");
        s.ranks[0].sends.push(send(5, 0, Trigger::AtStart));
        assert!(!s.validate().is_empty());
    }

    #[test]
    fn dangling_trigger_flagged() {
        let mut s = CommSchedule::new(2, "bad");
        s.ranks[0].sends.push(send(1, 0, Trigger::OnRecv(Tag(42))));
        let probs = s.validate();
        assert!(probs.iter().any(|p| p.contains("waits on tag")), "{probs:?}");
    }

    #[test]
    fn totals() {
        let mut s = CommSchedule::new(3, "t");
        s.ranks[0].sends.push(send(1, 0, Trigger::AtStart));
        s.ranks[0].sends.push(send(2, 1, Trigger::AtStart));
        assert_eq!(s.total_sends(), 2);
        assert_eq!(s.total_send_bytes(), 200);
    }

    #[test]
    fn mask_capacity_is_enforced_at_65_ranks() {
        assert!(Payload::check_mask_capacity(64).is_ok());
        let err = Payload::check_mask_capacity(65).unwrap_err();
        assert!(err.to_string().contains("64"), "{err}");
        assert_eq!(Payload::all_ranks_mask(1).unwrap(), 1);
        assert_eq!(Payload::all_ranks_mask(3).unwrap(), 0b111);
        assert_eq!(Payload::all_ranks_mask(64).unwrap(), u64::MAX);
        assert!(Payload::all_ranks_mask(65).is_err());
    }

    #[test]
    fn onrecvall_validates_each_tag() {
        let mut s = CommSchedule::new(3, "fanin");
        s.ranks[1].sends.push(send(0, 1, Trigger::AtStart));
        s.ranks[2].sends.push(send(0, 2, Trigger::AtStart));
        s.ranks[0].sends.push(SendSpec {
            to: 1,
            tag: Tag(9),
            bytes: 1,
            payload: Payload::Control,
            trigger: Trigger::OnRecvAll(vec![Tag(1), Tag(2)]),
            protocol: Protocol::Eager,
        });
        assert!(s.validate().is_empty());
        // now reference a missing tag
        s.ranks[0].sends[0].trigger = Trigger::OnRecvAll(vec![Tag(1), Tag(3)]);
        assert!(!s.validate().is_empty());
    }
}
