//! The schedule executor: runs a [`CommSchedule`] on a [`Netsim`].
//!
//! Deterministic event loop. Data sends use either the eager protocol
//! (one message) or the rendezvous protocol (RTS → CTS → DATA, with the
//! handshake non-blocking at the sender). Completion of a rank is when
//! it has received every expected payload *and* injected its last send;
//! completion of the operation is the max over ranks — which is what the
//! paper's experiments time.

use std::collections::{HashMap, HashSet};

use crate::netsim::{EventQueue, Netsim, SimTime};

use super::schedule::{CommSchedule, Payload, Protocol, SendSpec, Tag, Trigger};
use super::Rank;

/// Control-message size for RTS/CTS (bytes). The models charge these at
/// `g(1)`; one byte keeps measurement and model aligned.
const CTRL_BYTES: u64 = 1;

/// What kind of message an executor event is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Kind {
    Data,
    Rts,
    Cts,
}

/// An executor event: a message delivery.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Deliver {
    kind: Kind,
    src: Rank,
    dst: Rank,
    tag: Tag,
    payload: Payload,
    bytes: u64,
}

/// Per-send bookkeeping state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SendState {
    /// Waiting for its trigger.
    Waiting,
    /// Rendezvous: RTS sent, waiting for CTS.
    AwaitingCts,
    /// Injected (eager data sent, or rendezvous data sent).
    Done,
}

/// Outcome of one schedule execution.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Operation completion time (max over ranks).
    pub completion: SimTime,
    /// Per-rank completion times.
    pub per_rank: Vec<SimTime>,
    /// Payloads received per rank (for verification).
    pub received: Vec<Vec<Payload>>,
    /// Messages injected into the network (incl. control traffic).
    pub messages: u64,
    /// Payload bytes moved (excl. control traffic).
    pub data_bytes: u64,
    /// Delayed-ACK stalls suffered.
    pub ack_stalls: u64,
    /// Name of the executed operation.
    pub name: String,
}

impl RunReport {
    /// Check that every rank received exactly its expected payload
    /// multiset (order-insensitive). Returns problems; empty = verified.
    pub fn verify(&self, schedule: &CommSchedule) -> Vec<String> {
        let mut problems = Vec::new();
        for (r, rs) in schedule.ranks.iter().enumerate() {
            let mut got = self.received[r].clone();
            let mut want = rs.expected.clone();
            got.sort();
            want.sort();
            if got != want {
                problems.push(format!(
                    "rank {r}: received {got:?}, expected {want:?}"
                ));
            }
        }
        problems
    }
}

/// A P-rank world bound to a network simulator.
pub struct World {
    sim: Netsim,
}

impl World {
    pub fn new(sim: Netsim) -> World {
        World { sim }
    }

    pub fn sim(&self) -> &Netsim {
        &self.sim
    }

    pub fn sim_mut(&mut self) -> &mut Netsim {
        &mut self.sim
    }

    /// Execute one schedule from a clean-clock state.
    pub fn run(&mut self, schedule: &CommSchedule) -> RunReport {
        self.sim.reset();
        self.run_no_reset(schedule)
    }

    /// Execute without resetting clocks (for back-to-back operations,
    /// e.g. the pLogP benchmark's message trains or composed collectives).
    pub fn run_no_reset(&mut self, schedule: &CommSchedule) -> RunReport {
        let p = schedule.p;
        assert_eq!(
            p,
            self.sim.num_nodes(),
            "schedule is for {p} ranks but the cluster has {}",
            self.sim.num_nodes()
        );
        debug_assert!(
            schedule.validate().is_empty(),
            "invalid schedule: {:?}",
            schedule.validate()
        );

        let mut queue: EventQueue<Deliver> = EventQueue::new();
        let mut send_state: Vec<Vec<SendState>> = schedule
            .ranks
            .iter()
            .map(|r| vec![SendState::Waiting; r.sends.len()])
            .collect();
        // tags received so far, per rank (set: O(1) membership — the
        // per-delivery trigger checks are the executor's hot path).
        // Only ranks with fan-in (OnRecvAll) triggers need the set at
        // all: an OnRecv(tag) candidate reached via the trigger index is
        // ready by construction (its tag just arrived).
        let needs_tagset: Vec<bool> = schedule
            .ranks
            .iter()
            .map(|rs| {
                rs.sends.iter().any(|s| matches!(s.trigger, Trigger::OnRecvAll(_)))
            })
            .collect();
        let mut got_tags: Vec<HashSet<Tag>> = vec![HashSet::new(); p];
        let mut received: Vec<Vec<Payload>> = vec![Vec::new(); p];
        // trigger index: per rank, (tag, send idx) sorted by tag, so a
        // delivery binary-searches its own candidates instead of
        // re-scanning the whole send list (quadratic for k-segment
        // chains before this index existed — see EXPERIMENTS.md §Perf).
        // A sorted Vec beats a HashMap here: one allocation per rank and
        // no hashing on the hot path.
        let waiting_on: Vec<Vec<(Tag, usize)>> = schedule
            .ranks
            .iter()
            .map(|rs| {
                let mut idx: Vec<(Tag, usize)> = Vec::new();
                for (i, spec) in rs.sends.iter().enumerate() {
                    match &spec.trigger {
                        Trigger::AtStart => {}
                        Trigger::OnRecv(tag) => idx.push((*tag, i)),
                        Trigger::OnRecvAll(tags) => {
                            idx.extend(tags.iter().map(|t| (*t, i)))
                        }
                    }
                }
                idx.sort_unstable();
                idx
            })
            .collect();
        // rendezvous bookkeeping: send idx by (sender, receiver, tag) —
        // one sender may have several outstanding RTSs with the same tag
        // (flat rendezvous trees), so the receiver disambiguates.
        let mut awaiting_cts: HashMap<(Rank, Rank, Tag), usize> = HashMap::new();
        let mut last_send_done: Vec<SimTime> = vec![SimTime::ZERO; p];
        let mut last_recv: Vec<SimTime> = vec![SimTime::ZERO; p];
        let mut data_bytes = 0u64;
        let mut messages = 0u64;

        let base_stalls = self.sim.stats().ack_stalls;
        let base_blackholed = self.sim.stats().blackholed;

        // Inject a data send (eager) or its RTS (rendezvous).
        #[allow(clippy::too_many_arguments)]
        fn inject(
            sim: &mut Netsim,
            queue: &mut EventQueue<Deliver>,
            awaiting_cts: &mut HashMap<(Rank, Rank, Tag), usize>,
            state: &mut SendState,
            idx: usize,
            rank: Rank,
            spec: &SendSpec,
            at: SimTime,
            last_send_done: &mut [SimTime],
            messages: &mut u64,
            data_bytes: &mut u64,
        ) {
            match spec.protocol {
                Protocol::Eager => {
                    let out = sim.send(at, rank, spec.to, spec.bytes);
                    *messages += 1;
                    *data_bytes += spec.bytes;
                    last_send_done[rank as usize] =
                        last_send_done[rank as usize].max(out.tx_done);
                    // a blackholed message (dead endpoint) never delivers
                    if !out.dropped {
                        queue.push(
                            out.delivered,
                            Deliver {
                                kind: Kind::Data,
                                src: rank,
                                dst: spec.to,
                                tag: spec.tag,
                                payload: spec.payload,
                                bytes: spec.bytes,
                            },
                        );
                    }
                    *state = SendState::Done;
                }
                Protocol::Rendezvous => {
                    let out = sim.send(at, rank, spec.to, CTRL_BYTES);
                    *messages += 1;
                    if !out.dropped {
                        queue.push(
                            out.delivered,
                            Deliver {
                                kind: Kind::Rts,
                                src: rank,
                                dst: spec.to,
                                tag: spec.tag,
                                payload: Payload::Control,
                                bytes: CTRL_BYTES,
                            },
                        );
                    }
                    awaiting_cts.insert((rank, spec.to, spec.tag), idx);
                    *state = SendState::AwaitingCts;
                }
            }
        }

        // Fire AtStart sends.
        for (r, rs) in schedule.ranks.iter().enumerate() {
            for (i, spec) in rs.sends.iter().enumerate() {
                if spec.trigger == Trigger::AtStart {
                    inject(
                        &mut self.sim,
                        &mut queue,
                        &mut awaiting_cts,
                        &mut send_state[r][i],
                        i,
                        r as Rank,
                        spec,
                        SimTime::ZERO,
                        &mut last_send_done,
                        &mut messages,
                        &mut data_bytes,
                    );
                }
            }
        }

        // Event loop.
        while let Some((t, ev)) = queue.pop() {
            match ev.kind {
                Kind::Data => {
                    let d = ev.dst as usize;
                    if needs_tagset[d] {
                        got_tags[d].insert(ev.tag);
                    }
                    received[d].push(ev.payload);
                    last_recv[d] = last_recv[d].max(t);
                    // fire only the sends indexed under this tag
                    let idx = &waiting_on[d];
                    let lo = idx.partition_point(|(tag, _)| *tag < ev.tag);
                    let hi = idx.partition_point(|(tag, _)| *tag <= ev.tag);
                    for &(_, i) in &idx[lo..hi] {
                        if send_state[d][i] != SendState::Waiting {
                            continue;
                        }
                        let spec = &schedule.ranks[d].sends[i];
                        let ready = match &spec.trigger {
                            Trigger::AtStart => false, // already fired
                            // found via the index for ev.tag => satisfied
                            Trigger::OnRecv(_) => true,
                            Trigger::OnRecvAll(tags) => {
                                tags.iter().all(|tg| got_tags[d].contains(tg))
                            }
                        };
                        if ready {
                            inject(
                                &mut self.sim,
                                &mut queue,
                                &mut awaiting_cts,
                                &mut send_state[d][i],
                                i,
                                ev.dst,
                                spec,
                                t,
                                &mut last_send_done,
                                &mut messages,
                                &mut data_bytes,
                            );
                        }
                    }
                }
                Kind::Rts => {
                    // Receiver is pre-posted: reply CTS immediately.
                    let out = self.sim.send(t, ev.dst, ev.src, CTRL_BYTES);
                    messages += 1;
                    if !out.dropped {
                        queue.push(
                            out.delivered,
                            Deliver {
                                kind: Kind::Cts,
                                src: ev.dst,
                                dst: ev.src,
                                tag: ev.tag,
                                payload: Payload::Control,
                                bytes: CTRL_BYTES,
                            },
                        );
                    }
                }
                Kind::Cts => {
                    // Sender may now push the data.
                    // CTS travels receiver->sender: ev.dst is the
                    // original data sender, ev.src the data receiver.
                    let key = (ev.dst, ev.src, ev.tag);
                    let idx = awaiting_cts
                        .remove(&key)
                        .expect("CTS for unknown rendezvous");
                    let spec = &schedule.ranks[ev.dst as usize].sends[idx];
                    let out = self.sim.send(t, ev.dst, spec.to, spec.bytes);
                    messages += 1;
                    data_bytes += spec.bytes;
                    last_send_done[ev.dst as usize] =
                        last_send_done[ev.dst as usize].max(out.tx_done);
                    send_state[ev.dst as usize][idx] = SendState::Done;
                    if !out.dropped {
                        queue.push(
                            out.delivered,
                            Deliver {
                                kind: Kind::Data,
                                src: ev.dst,
                                dst: spec.to,
                                tag: spec.tag,
                                payload: spec.payload,
                                bytes: spec.bytes,
                            },
                        );
                    }
                }
            }
        }

        // Deadlock / starvation check: every send must have fired.
        // Blackholed traffic (dead-node fault injection) legitimately
        // starves downstream sends, so the check only applies to runs
        // whose messages all traversed the network.
        if self.sim.stats().blackholed == base_blackholed {
            for (r, states) in send_state.iter().enumerate() {
                for (i, st) in states.iter().enumerate() {
                    assert!(
                        *st == SendState::Done,
                        "schedule '{}': rank {r} send {i} never fired ({st:?}) — \
                         deadlocked or mis-triggered",
                        schedule.name
                    );
                }
            }
        }

        let per_rank: Vec<SimTime> = (0..p)
            .map(|r| last_recv[r].max(last_send_done[r]))
            .collect();
        let completion = per_rank.iter().copied().max().unwrap_or(SimTime::ZERO);

        RunReport {
            completion,
            per_rank,
            received,
            messages,
            data_bytes,
            ack_stalls: self.sim.stats().ack_stalls - base_stalls,
            name: schedule.name.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetConfig;

    fn world(p: usize) -> World {
        World::new(Netsim::new(p, NetConfig::fast_ethernet_ideal()))
    }

    fn eager(to: Rank, tag: u64, bytes: u64, trigger: Trigger) -> SendSpec {
        SendSpec {
            to,
            tag: Tag(tag),
            bytes,
            payload: Payload::range(0, bytes),
            trigger,
            protocol: Protocol::Eager,
        }
    }

    #[test]
    fn single_send_completes_at_isolated_latency() {
        let mut w = world(2);
        let mut s = CommSchedule::new(2, "p2p");
        s.ranks[0].sends.push(eager(1, 0, 1024, Trigger::AtStart));
        s.ranks[1].expected.push(Payload::range(0, 1024));
        let rep = w.run(&s);
        let want = w.sim().isolated_latency(1024);
        assert!((rep.completion.as_secs() - want).abs() < 1e-9);
        assert!(rep.verify(&s).is_empty());
    }

    #[test]
    fn chained_sends_respect_dependency() {
        let mut w = world(3);
        let mut s = CommSchedule::new(3, "chain");
        s.ranks[0].sends.push(eager(1, 0, 1024, Trigger::AtStart));
        s.ranks[1].sends.push(eager(2, 1, 1024, Trigger::OnRecv(Tag(0))));
        s.ranks[1].expected.push(Payload::range(0, 1024));
        s.ranks[2].expected.push(Payload::range(0, 1024));
        let rep = w.run(&s);
        // two hops, each the isolated latency
        let want = 2.0 * w.sim().isolated_latency(1024);
        assert!((rep.completion.as_secs() - want).abs() < 1e-9,
            "got {} want {want}", rep.completion.as_secs());
    }

    #[test]
    fn rendezvous_adds_handshake_cost() {
        let mut we = world(2);
        let mut wr = world(2);
        let mut se = CommSchedule::new(2, "eager");
        se.ranks[0].sends.push(eager(1, 0, 1 << 16, Trigger::AtStart));
        se.ranks[1].expected.push(Payload::range(0, 1 << 16));
        let mut sr = se.clone();
        sr.name = "rdv".into();
        sr.ranks[0].sends[0].protocol = Protocol::Rendezvous;
        let re = we.run(&se);
        let rr = wr.run(&sr);
        // rendezvous pays roughly 2 control messages + an extra round trip
        assert!(rr.completion > re.completion);
        let extra = rr.completion.as_secs() - re.completion.as_secs();
        let rt = 2.0 * we.sim().isolated_latency(1);
        assert!((extra - rt).abs() < 30e-6, "extra={extra} rt~{rt}");
    }

    #[test]
    fn fan_in_waits_for_all() {
        let mut w = world(3);
        let mut s = CommSchedule::new(3, "fanin");
        s.ranks[1].sends.push(eager(0, 1, 512, Trigger::AtStart));
        s.ranks[2].sends.push(eager(0, 2, 512, Trigger::AtStart));
        s.ranks[0].sends.push(SendSpec {
            to: 1,
            tag: Tag(9),
            bytes: 1,
            payload: Payload::Control,
            trigger: Trigger::OnRecvAll(vec![Tag(1), Tag(2)]),
            protocol: Protocol::Eager,
        });
        s.ranks[0].expected.push(Payload::range(0, 512));
        s.ranks[0].expected.push(Payload::range(0, 512));
        s.ranks[1].expected.push(Payload::Control);
        let rep = w.run(&s);
        assert!(rep.verify(&s).is_empty(), "{:?}", rep.verify(&s));
        // token leaves rank 0 only after both arrivals
        assert!(rep.per_rank[1] > rep.per_rank[2]);
    }

    #[test]
    #[should_panic(expected = "never fired")]
    fn deadlocked_schedule_panics() {
        let mut w = world(2);
        let mut s = CommSchedule::new(2, "deadlock");
        // rank 0 waits for a tag that only it could send — never fires.
        // (validate() would flag this; bypass debug_assert via release
        // semantics by constructing the panic directly in the executor.)
        s.ranks[0].sends.push(eager(1, 0, 10, Trigger::OnRecv(Tag(7))));
        s.ranks[1].sends.push(eager(0, 7, 10, Trigger::OnRecv(Tag(0))));
        let _ = w.run(&s);
    }

    #[test]
    fn report_counts_control_traffic_separately() {
        let mut w = world(2);
        let mut s = CommSchedule::new(2, "rdv-count");
        s.ranks[0].sends.push(SendSpec {
            to: 1,
            tag: Tag(0),
            bytes: 1 << 20,
            payload: Payload::range(0, 1 << 20),
            trigger: Trigger::AtStart,
            protocol: Protocol::Rendezvous,
        });
        s.ranks[1].expected.push(Payload::range(0, 1 << 20));
        let rep = w.run(&s);
        assert_eq!(rep.messages, 3); // RTS + CTS + DATA
        assert_eq!(rep.data_bytes, 1 << 20);
        assert!(rep.verify(&s).is_empty());
    }

    #[test]
    fn run_resets_between_operations() {
        let mut w = world(2);
        let mut s = CommSchedule::new(2, "p2p");
        s.ranks[0].sends.push(eager(1, 0, 1024, Trigger::AtStart));
        s.ranks[1].expected.push(Payload::range(0, 1024));
        let a = w.run(&s);
        let b = w.run(&s);
        assert_eq!(a.completion, b.completion);
    }
}
