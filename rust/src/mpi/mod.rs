//! An MPI-like message-passing runtime over [`crate::netsim`].
//!
//! Collective algorithms are expressed as declarative *communication
//! schedules* ([`schedule::CommSchedule`]): per-rank ordered send lists
//! with receive-triggered dependencies, mirroring how LAM-MPI's collective
//! layer drives its point-to-point layer. The executor ([`world::World`])
//! runs a schedule on the simulated cluster with either the **eager** or
//! the **rendezvous** point-to-point protocol per message — the protocol
//! split is exactly what distinguishes the paper's "flavour" models
//! (`Flat` vs `Flat Rendezvous`, etc.).

pub mod schedule;
pub mod world;

pub use schedule::{
    CommSchedule, Payload, Protocol, RankSchedule, SendSpec, Tag, Trigger,
};
pub use world::{RunReport, World};

/// Rank index within a communicator (same as a netsim NodeId here).
pub type Rank = u32;
