//! Virtual time and the deterministic event queue.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in integer nanoseconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    /// Construct from seconds (rounds to nearest nanosecond).
    pub fn from_secs(s: f64) -> SimTime {
        assert!(s >= 0.0 && s.is_finite(), "bad time {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.6} ms", self.0 as f64 / 1e6)
    }
}

/// A deterministic time-ordered queue. Ties are broken by insertion
/// sequence number so identical timestamps pop in push order.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<(SimTime, u64, T)>>,
    seq: u64,
}

impl<T: Ord> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Ord> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, at: SimTime, ev: T) {
        self.heap.push(Reverse((at, self.seq, ev)));
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t, e))
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_roundtrip() {
        let t = SimTime::from_secs(1.5e-3);
        assert_eq!(t.0, 1_500_000);
        assert!((t.as_secs() - 1.5e-3).abs() < 1e-12);
    }

    #[test]
    fn time_add() {
        assert_eq!(SimTime(5) + SimTime(7), SimTime(12));
    }

    #[test]
    #[should_panic]
    fn negative_time_rejected() {
        SimTime::from_secs(-1.0);
    }

    #[test]
    fn queue_orders_by_time() {
        let mut q = EventQueue::new();
        q.push(SimTime(30), "c");
        q.push(SimTime(10), "a");
        q.push(SimTime(20), "b");
        assert_eq!(q.pop(), Some((SimTime(10), "a")));
        assert_eq!(q.pop(), Some((SimTime(20), "b")));
        assert_eq!(q.pop(), Some((SimTime(30), "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(SimTime(5), "first");
        q.push(SimTime(5), "second");
        q.push(SimTime(5), "third");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "second");
        assert_eq!(q.pop().unwrap().1, "third");
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(SimTime(9), 1u32);
        q.push(SimTime(3), 2u32);
        assert_eq!(q.peek_time(), Some(SimTime(3)));
        assert_eq!(q.pop().unwrap().0, SimTime(3));
    }
}
