//! Network configuration and presets.

/// TCP behaviour knobs (Linux 2.2-era semantics, per the paper's refs
/// [9] "Performance Issues with LAM/MPI on Linux 2.2.x" and [10]
/// Loncaric's TCP acknowledgement-policy patches).
#[derive(Debug, Clone, PartialEq)]
pub struct TcpConfig {
    /// Messages at or below this size are subject to the delayed-ACK
    /// stall (bytes). 0 disables the anomaly entirely.
    pub small_msg_threshold: u64,
    /// One in every `delayed_ack_every_n` small messages on a flow is
    /// stalled. The paper: "only one every n messages is delayed, with n
    /// varying from kernel to kernel implementation".
    pub delayed_ack_every_n: u64,
    /// The stall duration (seconds). Linux delayed-ACK timers were in the
    /// tens-of-milliseconds range on 2.2 kernels.
    pub delayed_ack_penalty: f64,
    /// After this many back-to-back (queued) sends, the socket buffer is
    /// streaming: per-message sender overhead is multiplied by
    /// `coalesce_factor` (the "bulk transmission" effect of §4.2). Also,
    /// a streaming flow stops suffering delayed-ACK stalls — the paper's
    /// observation that segment trains only pay the stall once.
    pub coalesce_after: u64,
    /// Multiplier (< 1.0) on sender overhead while streaming.
    pub coalesce_factor: f64,
    /// A send is only at risk of a delayed-ACK stall if its flow has been
    /// idle for longer than this window (seconds): back-to-back segment
    /// trains force the ACKs out, so only the *first* messages of a train
    /// can stall — the paper's §4.1 observation that the Segmented Chain
    /// delay "does not increase proportionally... but remains constant".
    pub ack_window: f64,
}

impl TcpConfig {
    /// The anomalies switched off: an ideal transport.
    pub fn ideal() -> TcpConfig {
        TcpConfig {
            small_msg_threshold: 0,
            delayed_ack_every_n: u64::MAX,
            delayed_ack_penalty: 0.0,
            coalesce_after: u64::MAX,
            coalesce_factor: 1.0,
            ack_window: 0.0,
        }
    }

    /// Linux 2.2-flavoured defaults used for the paper reproductions.
    ///
    /// Calibrated so the §4 anomalies are *visible but small*, like the
    /// paper's: "small variations in the predicted data for small
    /// messages, [which] were unable to compromise the final decision".
    pub fn linux22() -> TcpConfig {
        TcpConfig {
            small_msg_threshold: 64 * 1024,
            delayed_ack_every_n: 24,
            delayed_ack_penalty: 0.6e-3,
            coalesce_after: 6,
            coalesce_factor: 0.55,
            ack_window: 400e-6,
        }
    }
}

/// Physical/network parameters of a homogeneous switched cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Link bandwidth in bytes/second (full duplex, per direction).
    pub bandwidth_bps: f64,
    /// One-way propagation + switch transit delay (seconds).
    pub prop_delay: f64,
    /// Per-message sender-side overhead (syscall, MPI stack, NIC setup).
    pub send_overhead: f64,
    /// Per-message receiver-side overhead.
    pub recv_overhead: f64,
    /// Wire framing overhead per MSS-sized chunk (Ethernet + IP + TCP
    /// headers), bytes.
    pub header_bytes: u64,
    /// Maximum segment size for framing-overhead accounting (bytes).
    pub mss: u64,
    /// TCP behaviour model.
    pub tcp: TcpConfig,
}

impl NetConfig {
    /// The paper's testbed: switched Fast Ethernet (100 Mb/s), Pentium
    /// III 850 MHz nodes, LAM-MPI 6.5.9 on Linux 2.2/2.4.
    ///
    /// 100 Mb/s = 12.5 MB/s on the wire; per-message software overhead
    /// of ~25 us per side and ~55 us one-way latency are in the range the
    /// MagPIe/pLogP papers report for this class of hardware.
    pub fn fast_ethernet_icluster1() -> NetConfig {
        NetConfig {
            bandwidth_bps: 12.5e6,
            prop_delay: 30e-6,
            send_overhead: 25e-6,
            recv_overhead: 25e-6,
            header_bytes: 58,
            mss: 1460,
            tcp: TcpConfig::linux22(),
        }
    }

    /// Same cluster with the TCP anomalies disabled (model-faithful
    /// network, used to validate the models in isolation).
    pub fn fast_ethernet_ideal() -> NetConfig {
        NetConfig { tcp: TcpConfig::ideal(), ..Self::fast_ethernet_icluster1() }
    }

    /// Gigabit Ethernet variant (the paper's §5 future work mentions
    /// evaluating Ethernet 1Gb).
    pub fn gigabit_ethernet() -> NetConfig {
        NetConfig {
            bandwidth_bps: 125e6,
            prop_delay: 12e-6,
            send_overhead: 8e-6,
            recv_overhead: 8e-6,
            header_bytes: 58,
            mss: 1460,
            tcp: TcpConfig {
                small_msg_threshold: 16 * 1024,
                delayed_ack_every_n: 32,
                delayed_ack_penalty: 0.3e-3,
                coalesce_after: 4,
                coalesce_factor: 0.5,
                ack_window: 200e-6,
            },
        }
    }

    /// Myrinet-like low-latency interconnect (§5 future work): OS-bypass,
    /// no TCP anomalies, very low per-message overhead.
    pub fn myrinet_like() -> NetConfig {
        NetConfig {
            bandwidth_bps: 230e6,
            prop_delay: 7e-6,
            send_overhead: 2e-6,
            recv_overhead: 2e-6,
            header_bytes: 8,
            mss: 4096,
            tcp: TcpConfig::ideal(),
        }
    }

    /// Wide-area link used as the inter-cluster network in multi-level
    /// experiments (MagPIe-style grids).
    pub fn wan_link() -> NetConfig {
        NetConfig {
            bandwidth_bps: 4e6,
            prop_delay: 5e-3,
            send_overhead: 40e-6,
            recv_overhead: 40e-6,
            header_bytes: 58,
            mss: 1460,
            tcp: TcpConfig::ideal(),
        }
    }

    /// Wire serialization time for `m` payload bytes, including framing.
    pub fn wire_time(&self, m: u64) -> f64 {
        self.wire_time_at(m, self.bandwidth_bps)
    }

    /// Wire time at an explicit bandwidth (per-link overrides in multi-
    /// cluster topologies).
    pub fn wire_time_at(&self, m: u64, bandwidth_bps: f64) -> f64 {
        let chunks = m.div_ceil(self.mss).max(1);
        (m + chunks * self.header_bytes) as f64 / bandwidth_bps
    }

    /// The simulator's ground-truth sender gap for one message: overhead
    /// plus serialization. (The pLogP benchmark *measures* an estimate of
    /// this; models consume the measurement, not this function.)
    pub fn gap(&self, m: u64) -> f64 {
        self.send_overhead + self.wire_time(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_scales_with_size() {
        let c = NetConfig::fast_ethernet_ideal();
        assert!(c.wire_time(1 << 20) > c.wire_time(1 << 10));
        // 1 MB at 12.5 MB/s is ~84 ms plus framing
        let t = c.wire_time(1 << 20);
        assert!(t > 0.083 && t < 0.090, "t={t}");
    }

    #[test]
    fn wire_time_includes_headers_per_mss() {
        let c = NetConfig::fast_ethernet_ideal();
        // 2 MSS-sized chunks pay 2 headers
        let one = c.wire_time(1460);
        let two = c.wire_time(2920);
        assert!((two - 2.0 * one).abs() < 1e-12);
    }

    #[test]
    fn tiny_message_pays_one_header() {
        let c = NetConfig::fast_ethernet_ideal();
        let t = c.wire_time(1);
        assert!((t - 59.0 / 12.5e6).abs() < 1e-15);
    }

    #[test]
    fn gap_includes_overhead() {
        let c = NetConfig::fast_ethernet_ideal();
        assert!((c.gap(0) - c.send_overhead - c.wire_time(0)).abs() < 1e-15);
    }

    #[test]
    fn presets_are_distinct() {
        assert!(NetConfig::gigabit_ethernet().bandwidth_bps
            > NetConfig::fast_ethernet_icluster1().bandwidth_bps);
        assert!(NetConfig::myrinet_like().prop_delay
            < NetConfig::fast_ethernet_icluster1().prop_delay);
        assert!(NetConfig::wan_link().prop_delay > 1e-3);
    }

    #[test]
    fn ideal_tcp_has_no_anomalies() {
        let t = TcpConfig::ideal();
        assert_eq!(t.small_msg_threshold, 0);
        assert_eq!(t.delayed_ack_penalty, 0.0);
        assert_eq!(t.coalesce_factor, 1.0);
    }
}
