//! Discrete-event simulator of a switched full-duplex Ethernet cluster.
//!
//! This is the substitute for the paper's testbed (ID/HP icluster-1:
//! 50 Pentium III nodes on switched 100 Mb/s Ethernet, LAM-MPI 6.5.9 /
//! Linux 2.2). It models exactly the first-order effects the paper's
//! evaluation depends on:
//!
//! * **sender gap** — per-message overhead plus wire serialization, so a
//!   node injecting back-to-back messages is spaced by `g(m)`;
//! * **one-way latency** — propagation plus switch transit plus receiver
//!   overhead, the pLogP `L`;
//! * **switch output-port contention** — concurrent senders to one
//!   destination serialize at wire speed (full-duplex, so A→B and B→A do
//!   not contend);
//! * **Linux TCP delayed-ACK stalls** — every n-th small message on a
//!   flow is delayed (the paper's §4 small-message anomaly, refs [9,10]);
//! * **send-buffer coalescing** — back-to-back bulk sends amortize their
//!   per-message overhead (the paper's §4.2 "bulk transmission" effect
//!   that lets Flat Scatter beat its own model).
//!
//! Virtual time is integer nanoseconds ([`SimTime`]); runs are exactly
//! deterministic and reproducible. Degraded environments — slow nodes,
//! degraded links, dead nodes — are injected through explicit,
//! seed-free [`FaultPlan`]s (see [`fault`]), so faulted runs stay just
//! as reproducible as healthy ones.

pub mod config;
pub mod event;
pub mod fault;
pub mod sim;
pub mod trace;

pub use config::{NetConfig, TcpConfig};
pub use event::{EventQueue, SimTime};
pub use fault::{FaultPlan, LinkFault};
pub use sim::{MsgId, Netsim, NodeId, SendOutcome};
pub use trace::{PairTimings, Trace, TraceEvent, TraceKey, TraceMeta, TraceRecord, TraceSet};
