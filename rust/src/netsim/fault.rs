//! Deterministic fault plans for the simulator.
//!
//! A [`FaultPlan`] is an explicit, seed-free list of degradations —
//! slow nodes, degraded links, dead nodes — applied to a [`Netsim`]
//! before a run. There is no randomness anywhere: every fault is an
//! explicit per-node or per-link entry, entries are kept in a canonical
//! sorted order regardless of builder call order, and the same plan
//! applied to the same simulator produces bit-identical runs. That is
//! what lets faulted runs be captured in `trace v1` files and replayed
//! byte-stably (the plan itself is serialized into the trace metadata —
//! see [`super::trace::TraceMeta::fault_plan`]).
//!
//! Semantics:
//!
//! * **slow node** — multiplies the node's per-message send/recv
//!   overheads by a factor `> 1` (a straggler CPU), exactly
//!   [`Netsim::inject_node_slowdown`].
//! * **degraded link** — adds one-way delay and/or caps bandwidth on a
//!   directed `src→dst` link ([`Netsim::inject_link_delay`] /
//!   [`Netsim::set_link_bandwidth`]).
//! * **dead node** — the node's NIC is gone: every message to or from
//!   it is blackholed (never delivered, counted in
//!   [`super::sim::SimStats::blackholed`]). Schedules that depend on a
//!   dead node starve; the executor reports the run as incomplete
//!   instead of deadlocking.
//!
//! Plans are cluster-shaped, not run-shaped: entries naming nodes
//! outside a particular simulator's range are skipped on application
//! (the tuner builds one simulator per grid `p`, all sharing the
//! cluster's plan).

use super::sim::NodeId;

/// A degraded directed link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFault {
    pub src: NodeId,
    pub dst: NodeId,
    /// Extra one-way delay on the link, seconds (>= 0).
    pub extra_delay: f64,
    /// Bandwidth cap in bytes/s; `None` keeps the configured rate.
    pub bandwidth: Option<f64>,
}

/// An explicit, deterministic set of faults. See the module docs for
/// semantics; build with the chainable `slow_node` / `dead_node` /
/// `degrade_link` methods. Entries are canonically ordered and deduped
/// (last write per node/link wins), so two plans built from the same
/// facts in any order compare and serialize identically.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    slow_nodes: Vec<(NodeId, f64)>,
    dead_nodes: Vec<NodeId>,
    links: Vec<LinkFault>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Mark `node` as a straggler: per-message overheads are multiplied
    /// by `factor` (> 0; > 1 means slower).
    pub fn slow_node(mut self, node: NodeId, factor: f64) -> FaultPlan {
        assert!(factor > 0.0, "slowdown factor must be positive");
        match self.slow_nodes.binary_search_by_key(&node, |&(n, _)| n) {
            Ok(i) => self.slow_nodes[i].1 = factor,
            Err(i) => self.slow_nodes.insert(i, (node, factor)),
        }
        self
    }

    /// Mark `node` as dead: all its traffic is blackholed.
    pub fn dead_node(mut self, node: NodeId) -> FaultPlan {
        if let Err(i) = self.dead_nodes.binary_search(&node) {
            self.dead_nodes.insert(i, node);
        }
        self
    }

    /// Degrade the directed `src→dst` link: `extra_delay` seconds of
    /// added one-way delay (>= 0) and an optional bandwidth cap in
    /// bytes/s.
    pub fn degrade_link(
        mut self,
        src: NodeId,
        dst: NodeId,
        extra_delay: f64,
        bandwidth: Option<f64>,
    ) -> FaultPlan {
        assert!(extra_delay >= 0.0, "extra delay must be non-negative");
        if let Some(bps) = bandwidth {
            assert!(bps > 0.0, "bandwidth cap must be positive");
        }
        let fault = LinkFault { src, dst, extra_delay, bandwidth };
        match self.links.binary_search_by_key(&(src, dst), |l| (l.src, l.dst)) {
            Ok(i) => self.links[i] = fault,
            Err(i) => self.links.insert(i, fault),
        }
        self
    }

    pub fn is_empty(&self) -> bool {
        self.slow_nodes.is_empty() && self.dead_nodes.is_empty() && self.links.is_empty()
    }

    /// Slow-node entries, ascending by node id.
    pub fn slow_nodes(&self) -> &[(NodeId, f64)] {
        &self.slow_nodes
    }

    /// Dead nodes, ascending.
    pub fn dead_nodes(&self) -> &[NodeId] {
        &self.dead_nodes
    }

    /// Degraded links, ascending by `(src, dst)`.
    pub fn links(&self) -> &[LinkFault] {
        &self.links
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_orders_canonically_and_dedupes() {
        let a = FaultPlan::new()
            .slow_node(5, 2.0)
            .slow_node(1, 3.0)
            .dead_node(7)
            .dead_node(2)
            .dead_node(7)
            .degrade_link(3, 0, 1e-3, None)
            .degrade_link(0, 1, 2e-3, Some(1e6));
        let b = FaultPlan::new()
            .degrade_link(0, 1, 9.0, None) // superseded below
            .degrade_link(0, 1, 2e-3, Some(1e6))
            .degrade_link(3, 0, 1e-3, None)
            .dead_node(2)
            .dead_node(7)
            .slow_node(1, 3.0)
            .slow_node(5, 2.0);
        assert_eq!(a, b, "call order must not matter");
        assert_eq!(a.slow_nodes(), &[(1, 3.0), (5, 2.0)]);
        assert_eq!(a.dead_nodes(), &[2, 7]);
        assert_eq!(a.links()[0].dst, 1);
    }

    #[test]
    fn empty_plan_reports_empty() {
        assert!(FaultPlan::new().is_empty());
        assert!(!FaultPlan::new().dead_node(0).is_empty());
    }

    #[test]
    fn last_slowdown_per_node_wins() {
        let p = FaultPlan::new().slow_node(3, 2.0).slow_node(3, 8.0);
        assert_eq!(p.slow_nodes(), &[(3, 8.0)]);
    }
}
