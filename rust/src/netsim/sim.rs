//! The simulator core: resource-availability timing model.
//!
//! Every message's trajectory is computed at send time from three
//! monotone per-node resources — sender NIC (`tx_free`), switch output
//! port (`port_free`) and receiver CPU (`rx_free`) — which is exact for
//! this network class and keeps the hot path allocation-free.

use std::collections::HashMap;

use super::config::NetConfig;
use super::event::SimTime;
use super::fault::FaultPlan;
use super::trace::{Trace, TraceEvent};

/// Node index within the cluster.
pub type NodeId = u32;

/// Monotone per-simulation message id.
pub type MsgId = u64;

/// Everything the caller learns about one message transmission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SendOutcome {
    pub msg: MsgId,
    /// When the sender's NIC actually started on this message (after
    /// queueing behind earlier sends and any TCP stall).
    pub tx_start: SimTime,
    /// When the sender is free to inject the next message (pLogP gap).
    pub tx_done: SimTime,
    /// When the receiver has the full message (after `recv_overhead`).
    pub delivered: SimTime,
    /// Whether this message suffered a delayed-ACK stall.
    pub ack_stalled: bool,
    /// Whether this message rode a coalesced (streaming) buffer.
    pub coalesced: bool,
    /// Whether this message was blackholed (sender or receiver is a
    /// dead node — see [`Netsim::inject_node_dead`]). A dropped
    /// message is never delivered; `delivered` holds the injection
    /// time and must be ignored.
    pub dropped: bool,
}

/// Aggregate counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimStats {
    pub messages: u64,
    pub bytes: u64,
    pub local_copies: u64,
    pub ack_stalls: u64,
    pub coalesced_sends: u64,
    /// Messages blackholed because an endpoint was a dead node.
    pub blackholed: u64,
    pub last_delivery: SimTime,
}

/// The cluster simulator. See module docs for the timing model.
#[derive(Debug)]
pub struct Netsim {
    cfg: NetConfig,
    n: usize,
    tx_free: Vec<SimTime>,
    rx_free: Vec<SimTime>,
    port_free: Vec<SimTime>,
    /// Consecutive queued (back-to-back) sends per sender; drives the
    /// buffer-coalescing model.
    stream_run: Vec<u64>,
    /// Per-flow state: (idle-start small-message count, last tx_done);
    /// drives the delayed-ACK model.
    flow_small: HashMap<(NodeId, NodeId), (u64, SimTime)>,
    /// Failure injection: extra one-way delay per (src, dst) link.
    extra_link_delay: HashMap<(NodeId, NodeId), f64>,
    /// Per-link bandwidth overrides (bytes/s) — used for inter-cluster
    /// (WAN) links in multi-level topologies.
    link_bandwidth: HashMap<(NodeId, NodeId), f64>,
    /// Failure injection: multiplier on a node's send/recv overheads.
    node_slowdown: Vec<f64>,
    /// Failure injection: dead nodes blackhole all their traffic.
    dead: Vec<bool>,
    stats: SimStats,
    trace: Option<Trace>,
    next_msg: MsgId,
}

impl Netsim {
    pub fn new(n: usize, cfg: NetConfig) -> Netsim {
        assert!(n >= 1, "need at least one node");
        Netsim {
            cfg,
            n,
            tx_free: vec![SimTime::ZERO; n],
            rx_free: vec![SimTime::ZERO; n],
            port_free: vec![SimTime::ZERO; n],
            stream_run: vec![0; n],
            flow_small: HashMap::new(),
            extra_link_delay: HashMap::new(),
            link_bandwidth: HashMap::new(),
            node_slowdown: vec![1.0; n],
            dead: vec![false; n],
            stats: SimStats::default(),
            trace: None,
            next_msg: 0,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Enable event tracing with the given capacity (ring buffer).
    pub fn enable_trace(&mut self, capacity: usize) {
        self.trace = Some(Trace::new(capacity));
    }

    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_ref()
    }

    /// Failure injection: add `extra` seconds of one-way delay on the
    /// src→dst link.
    pub fn inject_link_delay(&mut self, src: NodeId, dst: NodeId, extra: f64) {
        assert!(extra >= 0.0);
        self.extra_link_delay.insert((src, dst), extra);
    }

    /// Failure injection: multiply a node's per-message overheads by
    /// `factor` (>1 = slower node, e.g. a straggler).
    pub fn inject_node_slowdown(&mut self, node: NodeId, factor: f64) {
        assert!(factor > 0.0);
        self.node_slowdown[node as usize] = factor;
    }

    /// Override the bandwidth (bytes/s) of the src→dst link — slower
    /// inter-cluster (WAN) links in multi-level topologies.
    pub fn set_link_bandwidth(&mut self, src: NodeId, dst: NodeId, bps: f64) {
        assert!(bps > 0.0);
        self.link_bandwidth.insert((src, dst), bps);
    }

    /// Failure injection: mark `node` dead. Every subsequent message to
    /// or from it is blackholed — never delivered, counted in
    /// [`SimStats::blackholed`], excluded from the trace.
    pub fn inject_node_dead(&mut self, node: NodeId) {
        self.dead[node as usize] = true;
    }

    /// Whether `node` is currently marked dead.
    pub fn is_dead(&self, node: NodeId) -> bool {
        self.dead[node as usize]
    }

    /// Apply every entry of a [`FaultPlan`] onto this simulator's
    /// injection state. Entries naming nodes outside this cluster's
    /// range are skipped — a plan describes the cluster, while the
    /// tuner builds simulators at every grid `p` (see the
    /// `netsim::fault` module docs).
    pub fn apply_faults(&mut self, plan: &FaultPlan) {
        let n = self.n as u32;
        for &(node, factor) in plan.slow_nodes() {
            if node < n {
                self.inject_node_slowdown(node, factor);
            }
        }
        for &node in plan.dead_nodes() {
            if node < n {
                self.inject_node_dead(node);
            }
        }
        for l in plan.links() {
            if l.src < n && l.dst < n {
                if l.extra_delay > 0.0 {
                    self.inject_link_delay(l.src, l.dst, l.extra_delay);
                }
                if let Some(bps) = l.bandwidth {
                    self.set_link_bandwidth(l.src, l.dst, bps);
                }
            }
        }
    }

    /// Reset all clocks and flow state, keeping configuration and
    /// injected failures. Use between repetitions.
    pub fn reset(&mut self) {
        self.tx_free.fill(SimTime::ZERO);
        self.rx_free.fill(SimTime::ZERO);
        self.port_free.fill(SimTime::ZERO);
        self.stream_run.fill(0);
        self.flow_small.clear();
        self.stats = SimStats::default();
        self.next_msg = 0;
        if let Some(t) = &mut self.trace {
            t.clear();
        }
    }

    /// Transmit `bytes` from `src` to `dst`, with the sender becoming
    /// ready at `at` (i.e. the protocol layer decided to send at `at`;
    /// the NIC may start later). Returns the full timing outcome.
    ///
    /// `src == dst` is a local copy: free and instantaneous (the root of
    /// a scatter keeps its own chunk without touching the network).
    pub fn send(&mut self, at: SimTime, src: NodeId, dst: NodeId, bytes: u64) -> SendOutcome {
        assert!((src as usize) < self.n, "src {src} out of range");
        assert!((dst as usize) < self.n, "dst {dst} out of range");
        let msg = self.next_msg;
        self.next_msg += 1;

        if self.dead[src as usize] || self.dead[dst as usize] {
            // Blackhole: the message is injected into the void. Clocks,
            // stats and the trace all stay untouched so a faulted run's
            // surviving traffic times exactly as if the dead node were
            // simply absent.
            self.stats.blackholed += 1;
            return SendOutcome {
                msg,
                tx_start: at,
                tx_done: at,
                delivered: at,
                ack_stalled: false,
                coalesced: false,
                dropped: true,
            };
        }

        if src == dst {
            self.stats.local_copies += 1;
            self.stats.last_delivery = self.stats.last_delivery.max(at);
            return SendOutcome {
                msg,
                tx_start: at,
                tx_done: at,
                delivered: at,
                ack_stalled: false,
                coalesced: false,
                dropped: false,
            };
        }

        let si = src as usize;
        let di = dst as usize;
        let slow_s = self.node_slowdown[si];
        let slow_r = self.node_slowdown[di];
        let tcp = &self.cfg.tcp;

        // --- sender NIC ---------------------------------------------------
        let queued = at < self.tx_free[si];
        if queued {
            self.stream_run[si] += 1;
        } else {
            self.stream_run[si] = 0;
        }
        let streaming = self.stream_run[si] >= tcp.coalesce_after;
        let mut tx_start = self.tx_free[si].max(at);

        // Delayed-ACK stall: one in every n small messages on a flow, but
        // only for *flow-idle* sends — a back-to-back segment train keeps
        // the ACK clock running and cannot stall past its first messages
        // (the paper's §4.1: the chain's extra delay "remains constant"
        // regardless of the number of segments). Streaming sockets are
        // likewise immune.
        let small = tcp.small_msg_threshold > 0 && bytes <= tcp.small_msg_threshold;
        let mut ack_stalled = false;
        if small && !streaming && tcp.delayed_ack_every_n != u64::MAX {
            let entry = self.flow_small.entry((src, dst)).or_insert((0, SimTime::ZERO));
            let idle = entry.1 == SimTime::ZERO
                || tx_start.saturating_sub(entry.1).as_secs() > tcp.ack_window;
            if idle {
                entry.0 += 1;
                if entry.0 % tcp.delayed_ack_every_n == 0 {
                    tx_start = tx_start + SimTime::from_secs(tcp.delayed_ack_penalty);
                    ack_stalled = true;
                    self.stats.ack_stalls += 1;
                }
            }
        }

        let overhead_factor = if streaming { tcp.coalesce_factor } else { 1.0 };
        if streaming {
            self.stats.coalesced_sends += 1;
        }
        let o_s = self.cfg.send_overhead * slow_s * overhead_factor;
        let wire = match self.link_bandwidth.get(&(src, dst)) {
            Some(&bps) => self.cfg.wire_time_at(bytes, bps),
            None => self.cfg.wire_time(bytes),
        };
        let tx_done = tx_start + SimTime::from_secs(o_s + wire);
        self.tx_free[si] = tx_done;
        // any traffic (small or large) keeps the flow's ACK clock warm
        self.flow_small.entry((src, dst)).or_insert((0, SimTime::ZERO)).1 = tx_done;

        // --- switch transit + output-port contention ----------------------
        let extra = self.extra_link_delay.get(&(src, dst)).copied().unwrap_or(0.0);
        let half_prop = SimTime::from_secs(self.cfg.prop_delay / 2.0 + extra);
        let arrival = tx_done + half_prop;
        // The port is a capacity constraint: uncontended traffic passes
        // through at `arrival`; contended messages space at wire speed.
        let port_done = arrival.max(self.port_free[di] + SimTime::from_secs(wire));
        self.port_free[di] = port_done;

        // --- receiver ------------------------------------------------------
        let o_r = SimTime::from_secs(self.cfg.recv_overhead * slow_r);
        let rx_start = (port_done + SimTime::from_secs(self.cfg.prop_delay / 2.0))
            .max(self.rx_free[di]);
        let delivered = rx_start + o_r;
        self.rx_free[di] = delivered;

        self.stats.messages += 1;
        self.stats.bytes += bytes;
        self.stats.last_delivery = self.stats.last_delivery.max(delivered);

        if let Some(t) = &mut self.trace {
            t.record(TraceEvent {
                msg,
                src,
                dst,
                bytes,
                tx_start,
                delivered,
                ack_stalled,
                coalesced: streaming,
            });
        }

        SendOutcome {
            msg,
            tx_start,
            tx_done,
            delivered,
            ack_stalled,
            coalesced: streaming,
            dropped: false,
        }
    }

    /// One-way latency of an isolated `bytes`-sized message on an idle
    /// network (does not mutate state). Useful as ground truth in tests.
    pub fn isolated_latency(&self, bytes: u64) -> f64 {
        self.cfg.send_overhead + self.cfg.wire_time(bytes) + self.cfg.prop_delay
            + self.cfg.recv_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::config::TcpConfig;

    fn ideal() -> Netsim {
        Netsim::new(8, NetConfig::fast_ethernet_ideal())
    }

    #[test]
    fn single_message_latency_decomposes() {
        let mut s = ideal();
        let out = s.send(SimTime::ZERO, 0, 1, 1024);
        let want = s.isolated_latency(1024);
        assert!((out.delivered.as_secs() - want).abs() < 1e-9,
            "got {} want {want}", out.delivered.as_secs());
    }

    #[test]
    fn back_to_back_sends_space_by_gap() {
        let mut s = ideal();
        let a = s.send(SimTime::ZERO, 0, 1, 4096);
        let b = s.send(SimTime::ZERO, 0, 2, 4096);
        let gap = s.config().gap(4096);
        assert_eq!(a.tx_done, b.tx_start);
        assert!((b.tx_done.as_secs() - a.tx_done.as_secs() - gap).abs() < 1e-9);
    }

    #[test]
    fn receiver_port_serializes_concurrent_senders() {
        let mut s = ideal();
        // 0→2 and 1→2 simultaneously: second delivery spaced by wire time.
        let a = s.send(SimTime::ZERO, 0, 2, 1 << 16);
        let b = s.send(SimTime::ZERO, 1, 2, 1 << 16);
        let wire = s.config().wire_time(1 << 16);
        let dt = b.delivered.as_secs() - a.delivered.as_secs();
        assert!(dt >= wire - 1e-9, "dt={dt} wire={wire}");
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        let mut s = ideal();
        let a = s.send(SimTime::ZERO, 0, 1, 1 << 16);
        let b = s.send(SimTime::ZERO, 1, 0, 1 << 16);
        // full duplex: both complete in isolated time
        let want = s.isolated_latency(1 << 16);
        assert!((a.delivered.as_secs() - want).abs() < 1e-9);
        assert!((b.delivered.as_secs() - want).abs() < 1e-9);
    }

    #[test]
    fn self_send_is_free() {
        let mut s = ideal();
        let out = s.send(SimTime::from_secs(1.0), 3, 3, 1 << 20);
        assert_eq!(out.delivered, SimTime::from_secs(1.0));
        assert_eq!(s.stats().messages, 0);
        assert_eq!(s.stats().local_copies, 1);
    }

    #[test]
    fn delayed_ack_stalls_every_nth_small_message() {
        let mut cfg = NetConfig::fast_ethernet_ideal();
        cfg.tcp = TcpConfig {
            small_msg_threshold: 1024,
            delayed_ack_every_n: 3,
            delayed_ack_penalty: 5e-3,
            coalesce_after: u64::MAX,
            coalesce_factor: 1.0,
            ack_window: 0.0,
        };
        let mut s = Netsim::new(4, cfg);
        let mut stalls = 0;
        for i in 0..9 {
            // idle gaps between sends so no queueing
            let at = SimTime::from_secs(i as f64);
            if s.send(at, 0, 1, 100).ack_stalled {
                stalls += 1;
            }
        }
        assert_eq!(stalls, 3);
        assert_eq!(s.stats().ack_stalls, 3);
    }

    #[test]
    fn large_messages_never_ack_stall() {
        let mut cfg = NetConfig::fast_ethernet_ideal();
        cfg.tcp = TcpConfig {
            small_msg_threshold: 1024,
            delayed_ack_every_n: 1,
            delayed_ack_penalty: 5e-3,
            coalesce_after: u64::MAX,
            coalesce_factor: 1.0,
            ack_window: 0.0,
        };
        let mut s = Netsim::new(4, cfg);
        for i in 0..5 {
            assert!(!s.send(SimTime::from_secs(i as f64), 0, 1, 4096).ack_stalled);
        }
    }

    #[test]
    fn streaming_coalesces_overhead() {
        let mut cfg = NetConfig::fast_ethernet_ideal();
        cfg.tcp = TcpConfig {
            small_msg_threshold: 0,
            delayed_ack_every_n: u64::MAX,
            delayed_ack_penalty: 0.0,
            coalesce_after: 2,
            coalesce_factor: 0.5,
            ack_window: 0.0,
        };
        let mut s = Netsim::new(4, cfg.clone());
        // queue 6 back-to-back sends; from the 2nd queued one on, coalesced
        let outs: Vec<_> = (0..6).map(|_| s.send(SimTime::ZERO, 0, 1, 1 << 14)).collect();
        assert!(!outs[0].coalesced);
        assert!(outs[5].coalesced);
        // coalesced spacing is smaller than non-coalesced spacing
        let d01 = outs[1].tx_done.saturating_sub(outs[0].tx_done);
        let d45 = outs[5].tx_done.saturating_sub(outs[4].tx_done);
        assert!(d45 < d01, "d01={d01:?} d45={d45:?}");
        assert!(s.stats().coalesced_sends > 0);
    }

    #[test]
    fn streaming_suppresses_ack_stalls() {
        let mut cfg = NetConfig::fast_ethernet_ideal();
        cfg.tcp = TcpConfig {
            small_msg_threshold: 1 << 20,
            delayed_ack_every_n: 2,
            delayed_ack_penalty: 5e-3,
            coalesce_after: 3,
            coalesce_factor: 1.0,
            ack_window: 0.0,
        };
        let mut s = Netsim::new(4, cfg);
        // A long back-to-back train: stalls can only hit the first few
        // messages, before streaming kicks in.
        let outs: Vec<_> = (0..20).map(|_| s.send(SimTime::ZERO, 0, 1, 512)).collect();
        let late_stalls = outs[5..].iter().filter(|o| o.ack_stalled).count();
        assert_eq!(late_stalls, 0);
    }

    #[test]
    fn link_delay_injection_slows_one_link_only() {
        let mut s = ideal();
        s.inject_link_delay(0, 1, 10e-3);
        let slow = s.send(SimTime::ZERO, 0, 1, 1024);
        let fast = s.send(SimTime::ZERO, 2, 3, 1024);
        assert!(slow.delivered.as_secs() > fast.delivered.as_secs() + 9e-3);
    }

    #[test]
    fn node_slowdown_scales_overheads() {
        let mut a = ideal();
        let mut b = ideal();
        b.inject_node_slowdown(0, 4.0);
        let fa = a.send(SimTime::ZERO, 0, 1, 1024);
        let fb = b.send(SimTime::ZERO, 0, 1, 1024);
        let extra = 3.0 * a.config().send_overhead;
        assert!(
            (fb.delivered.as_secs() - fa.delivered.as_secs() - extra).abs() < 1e-9
        );
    }

    #[test]
    fn reset_clears_clocks_but_keeps_injections() {
        let mut s = ideal();
        s.inject_link_delay(0, 1, 5e-3);
        s.send(SimTime::ZERO, 0, 1, 1024);
        assert!(s.stats().messages > 0);
        s.reset();
        assert_eq!(s.stats().messages, 0);
        let out = s.send(SimTime::ZERO, 0, 1, 1024);
        assert!(out.delivered.as_secs() > 5e-3); // injection survived
    }

    #[test]
    fn stats_count_messages_and_bytes() {
        let mut s = ideal();
        s.send(SimTime::ZERO, 0, 1, 100);
        s.send(SimTime::ZERO, 1, 2, 200);
        assert_eq!(s.stats().messages, 2);
        assert_eq!(s.stats().bytes, 300);
        assert!(s.stats().last_delivery > SimTime::ZERO);
    }

    #[test]
    fn trace_records_events() {
        let mut s = ideal();
        s.enable_trace(16);
        s.send(SimTime::ZERO, 0, 1, 100);
        s.send(SimTime::ZERO, 1, 2, 200);
        let t = s.trace().unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.events()[0].src, 0);
        assert_eq!(t.events()[1].bytes, 200);
    }

    #[test]
    #[should_panic]
    fn out_of_range_node_panics() {
        let mut s = ideal();
        s.send(SimTime::ZERO, 0, 99, 10);
    }

    #[test]
    fn dead_node_blackholes_both_directions() {
        let mut s = ideal();
        s.enable_trace(16);
        s.inject_node_dead(2);
        assert!(s.is_dead(2));
        let to = s.send(SimTime::ZERO, 0, 2, 1024);
        let from = s.send(SimTime::ZERO, 2, 1, 1024);
        assert!(to.dropped && from.dropped);
        let ok = s.send(SimTime::ZERO, 0, 1, 1024);
        assert!(!ok.dropped);
        // blackholed traffic leaves no mark: stats, clocks and trace
        // only see the surviving message
        assert_eq!(s.stats().blackholed, 2);
        assert_eq!(s.stats().messages, 1);
        assert_eq!(s.stats().last_delivery, ok.delivered);
        assert_eq!(s.trace().unwrap().len(), 1);
    }

    #[test]
    fn apply_faults_maps_every_entry() {
        let plan = crate::netsim::FaultPlan::new()
            .slow_node(0, 4.0)
            .dead_node(3)
            .degrade_link(1, 2, 10e-3, Some(1e6));
        let mut s = ideal();
        s.apply_faults(&plan);
        // slow node 0: same extra overhead as inject_node_slowdown
        let mut base = ideal();
        let fa = base.send(SimTime::ZERO, 0, 1, 1024);
        let fb = s.send(SimTime::ZERO, 0, 1, 1024);
        let extra = 3.0 * base.config().send_overhead;
        assert!((fb.delivered.as_secs() - fa.delivered.as_secs() - extra).abs() < 1e-9);
        // dead node 3
        assert!(s.send(SimTime::ZERO, 3, 1, 64).dropped);
        // degraded link 1→2: extra delay and the bandwidth cap both bite
        let slow = s.send(SimTime::ZERO, 1, 2, 1 << 16);
        let fast = base.send(SimTime::ZERO, 1, 2, 1 << 16);
        assert!(slow.delivered.as_secs() > fast.delivered.as_secs() + 9e-3);
    }

    #[test]
    fn apply_faults_skips_out_of_range_nodes() {
        let plan = crate::netsim::FaultPlan::new()
            .slow_node(50, 2.0)
            .dead_node(60)
            .degrade_link(0, 70, 1e-3, None);
        let mut s = ideal(); // 8 nodes
        s.apply_faults(&plan); // must not panic
        assert!(!s.send(SimTime::ZERO, 0, 1, 64).dropped);
    }

    #[test]
    fn dead_node_survives_reset() {
        let mut s = ideal();
        s.inject_node_dead(1);
        s.send(SimTime::ZERO, 0, 1, 64);
        s.reset();
        assert_eq!(s.stats().blackholed, 0);
        assert!(s.send(SimTime::ZERO, 0, 1, 64).dropped, "dead marker is an injection");
    }
}
