//! Per-message trace recording (bounded ring buffer).

use super::event::SimTime;
use super::sim::{MsgId, NodeId};

/// One recorded message transmission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub msg: MsgId,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    pub tx_start: SimTime,
    pub delivered: SimTime,
    pub ack_stalled: bool,
    pub coalesced: bool,
}

/// Bounded ring buffer of trace events. When full, the oldest events are
/// overwritten; `dropped()` reports how many were lost.
#[derive(Debug, Clone)]
pub struct Trace {
    buf: Vec<TraceEvent>,
    capacity: usize,
    start: usize,
    dropped: u64,
}

impl Trace {
    pub fn new(capacity: usize) -> Trace {
        assert!(capacity > 0);
        Trace { buf: Vec::with_capacity(capacity), capacity, start: 0, dropped: 0 }
    }

    pub fn record(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.start] = ev;
            self.start = (self.start + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    /// Events in chronological (recording) order.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.start..]);
        out.extend_from_slice(&self.buf[..self.start]);
        out
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.start = 0;
        self.dropped = 0;
    }

    /// Render as a tab-separated log for offline inspection.
    pub fn to_tsv(&self) -> String {
        let mut s = String::from("msg\tsrc\tdst\tbytes\ttx_start_ns\tdelivered_ns\tack\tcoal\n");
        for e in self.events() {
            s.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                e.msg, e.src, e.dst, e.bytes, e.tx_start.0, e.delivered.0,
                e.ack_stalled as u8, e.coalesced as u8
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(msg: MsgId) -> TraceEvent {
        TraceEvent {
            msg,
            src: 0,
            dst: 1,
            bytes: 10,
            tx_start: SimTime(msg * 100),
            delivered: SimTime(msg * 100 + 50),
            ack_stalled: false,
            coalesced: false,
        }
    }

    #[test]
    fn records_in_order() {
        let mut t = Trace::new(4);
        for i in 0..3 {
            t.record(ev(i));
        }
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].msg, 0);
        assert_eq!(evs[2].msg, 2);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut t = Trace::new(3);
        for i in 0..5 {
            t.record(ev(i));
        }
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].msg, 2);
        assert_eq!(evs[2].msg, 4);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn clear_resets() {
        let mut t = Trace::new(2);
        t.record(ev(0));
        t.record(ev(1));
        t.record(ev(2));
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn tsv_has_header_and_rows() {
        let mut t = Trace::new(4);
        t.record(ev(7));
        let tsv = t.to_tsv();
        assert!(tsv.starts_with("msg\t"));
        assert!(tsv.contains("\n7\t0\t1\t10\t"));
    }
}
