//! Per-message trace recording and the stable on-disk trace format.
//!
//! Two layers live here:
//!
//! * [`Trace`] — the in-simulator bounded ring buffer
//!   [`super::Netsim`] appends to while a run executes. **Drop
//!   semantics**: the buffer keeps the *newest* `capacity` events. While
//!   `len() < capacity` nothing is ever lost; once the buffer is full,
//!   each further [`Trace::record`] overwrites the oldest surviving
//!   event and increments [`Trace::dropped`] by exactly one — the
//!   counter is the number of events that were recorded but are no
//!   longer in the buffer, so `dropped() + len()` is the total ever
//!   recorded. There is no other coalescing: capacity exhaustion is the
//!   *only* way events disappear, and it is always counted. Because the
//!   oldest events are the ones lost, tail statistics (e.g. the final
//!   delivery time, which is the collective's completion) survive any
//!   amount of wraparound.
//! * [`TraceRecord`] / [`TraceSet`] — the persistent capture layer: one
//!   record per executed `(op, strategy, P, m, segment)` point, holding
//!   the drained events plus capture metadata (the pLogP signature the
//!   schedule was tuned under, the reported completion time, and the
//!   drop count), serialized as a versioned, diff-friendly TSV. A
//!   [`TraceSet`] is a directory of records keyed by [`TraceKey`]; the
//!   replay evaluator ([`crate::eval::ReplayEval`]) scores strategies
//!   from these files instead of re-running the simulator.
//!
//! ## File format (`trace v1`)
//!
//! ```text
//! # collective-tuner message trace v1
//! op      bcast
//! strategy        bcast/binomial
//! p       8
//! m       4096
//! segment -
//! completion_ns   1234567
//! dropped 0
//! plogp_l 6.05e-5
//! plogp_sizes     1,2,4,...
//! plogp_gaps      1.2e-5,...
//! event   msg     src     dst     bytes   tx_start_ns     delivered_ns    ack     coal
//! event   0       0       1       4096    0       123456  0       0
//! ```
//!
//! Metadata records are `key\tvalue` lines; the event block is rendered
//! through [`crate::util::table::Table::to_tsv`] with a leading `event`
//! column (the first `event` line, whose second field is `msg`, is the
//! column header). Floats use Rust's shortest-roundtrip formatting, so
//! `save → load → save` is byte-identical — the golden-trace regression
//! suite (`rust/tests/replay_golden.rs`) depends on that.
//!
//! Runs captured under a [`FaultPlan`] carry an *optional* fault block
//! between `plogp_gaps` and the event table — one record per fault
//! entry, in the plan's canonical order:
//!
//! ```text
//! fault_slow_node <node>  <factor>
//! fault_dead_node <node>
//! fault_link      <src>   <dst>   <extra_delay_s> <bandwidth_bps|->
//! ```
//!
//! The block is emitted only when a plan is present and non-empty, so
//! fault-free records serialize exactly as they did before the block
//! existed and pre-fault readers' files parse unchanged; faulted files
//! round-trip byte-identically like everything else.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::table::Table;

use super::event::SimTime;
use super::fault::FaultPlan;
use super::sim::{MsgId, NodeId};

/// One recorded message transmission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub msg: MsgId,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    pub tx_start: SimTime,
    pub delivered: SimTime,
    pub ack_stalled: bool,
    pub coalesced: bool,
}

/// Bounded ring buffer of trace events. When full, the oldest events are
/// overwritten; `dropped()` reports how many were lost (see the module
/// docs for the exact semantics).
#[derive(Debug, Clone)]
pub struct Trace {
    buf: Vec<TraceEvent>,
    capacity: usize,
    start: usize,
    dropped: u64,
}

impl Trace {
    pub fn new(capacity: usize) -> Trace {
        assert!(capacity > 0);
        Trace { buf: Vec::with_capacity(capacity), capacity, start: 0, dropped: 0 }
    }

    pub fn record(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.start] = ev;
            self.start = (self.start + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    /// Events in chronological (recording) order.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.start..]);
        out.extend_from_slice(&self.buf[..self.start]);
        out
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The ring's fixed capacity (events beyond it evict the oldest).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Events recorded but no longer in the buffer (overwritten after
    /// capacity exhaustion). `dropped() + len()` = total ever recorded.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.start = 0;
        self.dropped = 0;
    }

    /// Render as a tab-separated log for offline inspection.
    pub fn to_tsv(&self) -> String {
        event_table(&self.events(), false).to_tsv()
    }
}

/// The shared event columns, with or without the leading `event`
/// record-type column the file format uses.
fn event_table(events: &[TraceEvent], tagged: bool) -> Table {
    let mut header =
        vec!["msg", "src", "dst", "bytes", "tx_start_ns", "delivered_ns", "ack", "coal"];
    if tagged {
        header.insert(0, "event");
    }
    let mut t = Table::new(header);
    for e in events {
        let mut row = vec![
            e.msg.to_string(),
            e.src.to_string(),
            e.dst.to_string(),
            e.bytes.to_string(),
            e.tx_start.0.to_string(),
            e.delivered.0.to_string(),
            (e.ack_stalled as u8).to_string(),
            (e.coalesced as u8).to_string(),
        ];
        if tagged {
            row.insert(0, "event".to_string());
        }
        t.row(row);
    }
    t
}

const TRACE_HEADER: &str = "# collective-tuner message trace v1";

/// Capture metadata of one recorded run: the tuned point it executed
/// and the pLogP signature of the network it ran on (raw `L` + gap
/// samples, so this module stays independent of [`crate::plogp`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceMeta {
    /// Operation family name ([`crate::tuner::Op::name`]).
    pub op: String,
    /// Strategy name ([`crate::collectives::Strategy::name`]).
    pub strategy: String,
    /// Ranks the schedule ran with.
    pub p: usize,
    /// Message size in bytes.
    pub m: u64,
    /// Tuned segment size (None for unsegmented strategies).
    pub segment: Option<u64>,
    /// The executor-reported completion time of the run, in integer
    /// nanoseconds. Redundant with the event stream (it equals the last
    /// delivery; checked on load when nothing was dropped) — kept so a
    /// human can read a trace's score without replaying it.
    pub completion_ns: u64,
    /// Ring-buffer drops during capture (oldest events missing).
    pub dropped: u64,
    /// pLogP one-way latency `L` (seconds) of the captured network.
    pub plogp_l: f64,
    /// pLogP gap-table sample sizes (bytes).
    pub plogp_sizes: Vec<f64>,
    /// pLogP gap-table sample gaps (seconds).
    pub plogp_gaps: Vec<f64>,
    /// The fault plan the run executed under, if any. Serialized as an
    /// *optional* metadata block (`fault_slow_node` / `fault_dead_node`
    /// / `fault_link` records, emitted only when the plan is non-empty),
    /// so pre-fault `trace v1` files parse unchanged and fault-free
    /// records serialize exactly as before.
    pub fault_plan: Option<FaultPlan>,
}

impl TraceMeta {
    /// The set key this record files under.
    pub fn key(&self) -> TraceKey {
        TraceKey {
            op: self.op.clone(),
            strategy: self.strategy.clone(),
            p: self.p,
            m: self.m,
            segment: self.segment,
        }
    }
}

/// The identity of one captured grid point. Ordering is lexicographic
/// over `(op, strategy, p, m, segment)`, which is what lets
/// [`TraceSet`] range-scan a cell's segment variants or a strategy's
/// captured m column.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceKey {
    pub op: String,
    pub strategy: String,
    pub p: usize,
    pub m: u64,
    pub segment: Option<u64>,
}

impl TraceKey {
    /// Stable file name for this key (`/` in strategy names becomes
    /// `.`; an absent segment is `s0` — real segments are >= 1). Purely
    /// cosmetic: loading keys records from their metadata, not names.
    pub fn file_name(&self) -> String {
        format!(
            "{}.p{}.m{}.s{}.trace.tsv",
            self.strategy.replace('/', "."),
            self.p,
            self.m,
            self.segment.unwrap_or(0)
        )
    }
}

/// Per-(src, dst) timing extraction: `(tx_start, delivered)` pairs in
/// recording order for each directed node pair.
pub type PairTimings = BTreeMap<(NodeId, NodeId), Vec<(SimTime, SimTime)>>;

/// One captured run: metadata plus the drained event stream.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub meta: TraceMeta,
    pub events: Vec<TraceEvent>,
}

impl TraceRecord {
    /// The end of the run's critical path: the last recorded delivery.
    /// Every schedule terminates with a delivery (a send's `tx_done`
    /// precedes its own delivery, and local copies happen at an earlier
    /// event's time), so this equals the executor's reported completion
    /// — and it survives ring-buffer drops, which only lose the oldest
    /// events. Empty event streams fall back to the metadata value.
    pub fn critical_path(&self) -> SimTime {
        self.events
            .iter()
            .map(|e| e.delivered)
            .max()
            .unwrap_or(SimTime(self.meta.completion_ns))
    }

    /// Recorded timings grouped by directed `(src, dst)` pair — the raw
    /// material of per-link characterisation (observed delivery
    /// latencies, ACK-stall localisation).
    pub fn pair_timings(&self) -> PairTimings {
        let mut out = PairTimings::new();
        for e in &self.events {
            let pair = out.entry((e.src, e.dst)).or_default();
            pair.push((e.tx_start, e.delivered));
        }
        out
    }

    /// Serialize in the `trace v1` format (see module docs).
    pub fn to_tsv(&self) -> String {
        let m = &self.meta;
        let mut out = String::from(TRACE_HEADER);
        out.push('\n');
        out.push_str(&format!("op\t{}\n", m.op));
        out.push_str(&format!("strategy\t{}\n", m.strategy));
        out.push_str(&format!("p\t{}\n", m.p));
        out.push_str(&format!("m\t{}\n", m.m));
        out.push_str(&format!(
            "segment\t{}\n",
            m.segment.map(|s| s.to_string()).unwrap_or_else(|| "-".into())
        ));
        out.push_str(&format!("completion_ns\t{}\n", m.completion_ns));
        out.push_str(&format!("dropped\t{}\n", m.dropped));
        out.push_str(&format!("plogp_l\t{}\n", m.plogp_l));
        out.push_str(&format!("plogp_sizes\t{}\n", join_f64(&m.plogp_sizes)));
        out.push_str(&format!("plogp_gaps\t{}\n", join_f64(&m.plogp_gaps)));
        if let Some(fp) = m.fault_plan.as_ref().filter(|fp| !fp.is_empty()) {
            for &(node, factor) in fp.slow_nodes() {
                out.push_str(&format!("fault_slow_node\t{node}\t{factor}\n"));
            }
            for &node in fp.dead_nodes() {
                out.push_str(&format!("fault_dead_node\t{node}\n"));
            }
            for l in fp.links() {
                out.push_str(&format!(
                    "fault_link\t{}\t{}\t{}\t{}\n",
                    l.src,
                    l.dst,
                    l.extra_delay,
                    l.bandwidth.map(|b| b.to_string()).unwrap_or_else(|| "-".into())
                ));
            }
        }
        out.push_str(&event_table(&self.events, true).to_tsv());
        out
    }

    /// Parse the `trace v1` format, validating internal consistency
    /// (a complete capture's last delivery must equal the reported
    /// completion).
    pub fn from_tsv(text: &str) -> Result<TraceRecord> {
        let mut lines = text.lines();
        if lines.next() != Some(TRACE_HEADER) {
            bail!("not a trace file (missing '{TRACE_HEADER}')");
        }
        let mut op = None;
        let mut strategy = None;
        let mut p = None;
        let mut m = None;
        let mut segment = None;
        let mut completion_ns = None;
        let mut dropped = None;
        let mut plogp_l = None;
        let mut plogp_sizes = None;
        let mut plogp_gaps = None;
        let mut fault_plan: Option<FaultPlan> = None;
        let mut events: Vec<TraceEvent> = Vec::new();
        for (ln, line) in lines.enumerate() {
            let mut f = line.split('\t');
            let err = |what: &str| format!("line {}: {what}", ln + 2);
            match f.next() {
                Some("op") => op = Some(f.next().context("op value")?.to_string()),
                Some("strategy") => {
                    strategy = Some(f.next().context("strategy value")?.to_string())
                }
                Some("p") => p = Some(f.next().context("p value")?.parse()?),
                Some("m") => m = Some(f.next().context("m value")?.parse()?),
                Some("segment") => {
                    let tok = f.next().context("segment value")?;
                    segment = match tok {
                        "-" => Some(None),
                        s => Some(Some(s.parse::<u64>()?)),
                    };
                }
                Some("completion_ns") => {
                    completion_ns = Some(f.next().context("completion value")?.parse()?)
                }
                Some("dropped") => dropped = Some(f.next().context("dropped value")?.parse()?),
                Some("plogp_l") => plogp_l = Some(f.next().context("plogp_l value")?.parse()?),
                Some("plogp_sizes") => {
                    plogp_sizes = Some(split_f64(f.next().context("plogp_sizes value")?)?)
                }
                Some("plogp_gaps") => {
                    plogp_gaps = Some(split_f64(f.next().context("plogp_gaps value")?)?)
                }
                Some("fault_slow_node") => {
                    let node = f.next().context("fault_slow_node node")?.parse()?;
                    let factor = f.next().context("fault_slow_node factor")?.parse()?;
                    fault_plan =
                        Some(fault_plan.take().unwrap_or_default().slow_node(node, factor));
                }
                Some("fault_dead_node") => {
                    let node = f.next().context("fault_dead_node node")?.parse()?;
                    fault_plan = Some(fault_plan.take().unwrap_or_default().dead_node(node));
                }
                Some("fault_link") => {
                    let src = f.next().context("fault_link src")?.parse()?;
                    let dst = f.next().context("fault_link dst")?.parse()?;
                    let extra = f.next().context("fault_link extra_delay")?.parse()?;
                    let bandwidth = match f.next().context("fault_link bandwidth")? {
                        "-" => None,
                        b => Some(b.parse::<f64>()?),
                    };
                    fault_plan = Some(
                        fault_plan
                            .take()
                            .unwrap_or_default()
                            .degrade_link(src, dst, extra, bandwidth),
                    );
                }
                Some("event") => {
                    let fields: Vec<&str> = f.collect();
                    if fields.first() == Some(&"msg") {
                        continue; // the event block's column-header line
                    }
                    if fields.len() != 8 {
                        bail!(err(&format!("event row has {} fields, want 8", fields.len())));
                    }
                    events.push(TraceEvent {
                        msg: fields[0].parse()?,
                        src: fields[1].parse()?,
                        dst: fields[2].parse()?,
                        bytes: fields[3].parse()?,
                        tx_start: SimTime(fields[4].parse()?),
                        delivered: SimTime(fields[5].parse()?),
                        ack_stalled: parse_bool01(fields[6])?,
                        coalesced: parse_bool01(fields[7])?,
                    });
                }
                Some("") | None => {}
                Some(other) => bail!(err(&format!("unknown record '{other}'"))),
            }
        }
        let rec = TraceRecord {
            meta: TraceMeta {
                op: op.context("missing op record")?,
                strategy: strategy.context("missing strategy record")?,
                p: p.context("missing p record")?,
                m: m.context("missing m record")?,
                segment: segment.context("missing segment record")?,
                completion_ns: completion_ns.context("missing completion_ns record")?,
                dropped: dropped.context("missing dropped record")?,
                plogp_l: plogp_l.context("missing plogp_l record")?,
                plogp_sizes: plogp_sizes.context("missing plogp_sizes record")?,
                plogp_gaps: plogp_gaps.context("missing plogp_gaps record")?,
                fault_plan,
            },
            events,
        };
        if rec.meta.dropped == 0 && !rec.events.is_empty() {
            let last = rec.critical_path();
            if last.0 != rec.meta.completion_ns {
                bail!(
                    "corrupt trace: last delivery at {} ns but completion_ns says {} \
                     (and no events were dropped)",
                    last.0,
                    rec.meta.completion_ns
                );
            }
        }
        Ok(rec)
    }
}

fn join_f64(xs: &[f64]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

fn split_f64(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|t| t.trim().parse::<f64>().with_context(|| format!("bad float '{t}'")))
        .collect()
}

fn parse_bool01(s: &str) -> Result<bool> {
    match s {
        "0" => Ok(false),
        "1" => Ok(true),
        other => bail!("bad flag '{other}' (want 0 or 1)"),
    }
}

/// A keyed collection of captured traces — one capture sweep's output,
/// or a directory of committed golden fixtures. Insertion replaces an
/// existing record with the same key (re-capturing a cell supersedes
/// the old run); merging obeys the same rule, with the incoming set
/// winning conflicts.
#[derive(Debug, Clone, Default)]
pub struct TraceSet {
    records: BTreeMap<TraceKey, TraceRecord>,
}

impl TraceSet {
    pub fn new() -> TraceSet {
        TraceSet::default()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Total events across every record.
    pub fn total_events(&self) -> usize {
        self.records.values().map(|r| r.events.len()).sum()
    }

    /// File (= key-replacing) insert.
    pub fn insert(&mut self, rec: TraceRecord) {
        self.records.insert(rec.meta.key(), rec);
    }

    /// Fold `other` in; its records win key conflicts. Returns how many
    /// keys were new (not replacements).
    pub fn merge(&mut self, other: TraceSet) -> usize {
        let mut added = 0;
        for (k, r) in other.records {
            if self.records.insert(k, r).is_none() {
                added += 1;
            }
        }
        added
    }

    pub fn get(&self, key: &TraceKey) -> Option<&TraceRecord> {
        self.records.get(key)
    }

    /// The record captured at `(op, strategy, p, m)` regardless of its
    /// segment (each capture stores one record per cell — the tuned
    /// segment's run).
    pub fn at_cell(&self, op: &str, strategy: &str, p: usize, m: u64) -> Option<&TraceRecord> {
        let lo = TraceKey {
            op: op.to_string(),
            strategy: strategy.to_string(),
            p,
            m,
            segment: None,
        };
        let hi = TraceKey { segment: Some(u64::MAX), ..lo.clone() };
        self.records.range(lo..=hi).map(|(_, r)| r).next()
    }

    /// Every record for `(op, strategy, p)`, ascending in `m` — the
    /// column the replay evaluator interpolates over.
    pub fn cells_for(&self, op: &str, strategy: &str, p: usize) -> Vec<&TraceRecord> {
        let lo = TraceKey {
            op: op.to_string(),
            strategy: strategy.to_string(),
            p,
            m: 0,
            segment: None,
        };
        let hi = TraceKey { m: u64::MAX, segment: Some(u64::MAX), ..lo.clone() };
        self.records.range(lo..=hi).map(|(_, r)| r).collect()
    }

    pub fn records(&self) -> impl Iterator<Item = &TraceRecord> {
        self.records.values()
    }

    /// Distinct op names captured, sorted.
    pub fn ops(&self) -> Vec<String> {
        let mut v: Vec<String> = self.records.keys().map(|k| k.op.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Distinct captured process counts, ascending.
    pub fn p_values(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.records.keys().map(|k| k.p).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Distinct captured message sizes, ascending.
    pub fn m_values(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.records.keys().map(|k| k.m).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// The largest captured process count (a proxy for cluster size).
    pub fn max_p(&self) -> Option<usize> {
        self.records.keys().map(|k| k.p).max()
    }

    /// Write one `*.trace.tsv` per record under `dir` (created if
    /// needed). Returns the number of files written.
    pub fn save_dir(&self, dir: &Path) -> Result<usize> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        for (key, rec) in &self.records {
            let path = dir.join(key.file_name());
            std::fs::write(&path, rec.to_tsv())
                .with_context(|| format!("writing {}", path.display()))?;
        }
        Ok(self.records.len())
    }

    /// Load every `*.trace.tsv` under `dir` (sorted by file name, so
    /// load order — and any merge outcome — is deterministic).
    pub fn load_dir(dir: &Path) -> Result<TraceSet> {
        let mut paths: Vec<_> = std::fs::read_dir(dir)
            .with_context(|| format!("reading {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.ends_with(".trace.tsv"))
            })
            .collect();
        paths.sort();
        let mut set = TraceSet::new();
        for path in paths {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let rec = TraceRecord::from_tsv(&text)
                .with_context(|| format!("parsing {}", path.display()))?;
            set.insert(rec);
        }
        Ok(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(msg: MsgId) -> TraceEvent {
        TraceEvent {
            msg,
            src: 0,
            dst: 1,
            bytes: 10,
            tx_start: SimTime(msg * 100),
            delivered: SimTime(msg * 100 + 50),
            ack_stalled: false,
            coalesced: false,
        }
    }

    fn record(op: &str, strategy: &str, p: usize, m: u64, seg: Option<u64>) -> TraceRecord {
        let events: Vec<TraceEvent> = (0..4).map(ev).collect();
        TraceRecord {
            meta: TraceMeta {
                op: op.into(),
                strategy: strategy.into(),
                p,
                m,
                segment: seg,
                completion_ns: events.iter().map(|e| e.delivered.0).max().unwrap(),
                dropped: 0,
                plogp_l: 6.05e-5,
                plogp_sizes: vec![1.0, 1024.0, 65536.0],
                plogp_gaps: vec![1.1e-5, 1.3e-5, 6.4e-5],
                fault_plan: None,
            },
            events,
        }
    }

    #[test]
    fn records_in_order() {
        let mut t = Trace::new(4);
        for i in 0..3 {
            t.record(ev(i));
        }
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].msg, 0);
        assert_eq!(evs[2].msg, 2);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut t = Trace::new(3);
        for i in 0..5 {
            t.record(ev(i));
        }
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].msg, 2);
        assert_eq!(evs[2].msg, 4);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn capacity_exhaustion_counts_every_overwrite_exactly_once() {
        // filling to capacity drops nothing; each event past it drops
        // exactly one, so dropped() + len() is the total ever recorded
        let mut t = Trace::new(4);
        for i in 0..4 {
            t.record(ev(i));
            assert_eq!(t.dropped(), 0, "no drops before exhaustion");
        }
        assert_eq!(t.capacity(), 4);
        for i in 4..11 {
            t.record(ev(i));
            assert_eq!(t.dropped() + t.len() as u64, i + 1);
        }
        assert_eq!(t.dropped(), 7);
        // and the survivors are exactly the newest window
        assert_eq!(t.events().iter().map(|e| e.msg).collect::<Vec<_>>(), [7, 8, 9, 10]);
    }

    #[test]
    fn clear_resets() {
        let mut t = Trace::new(2);
        t.record(ev(0));
        t.record(ev(1));
        t.record(ev(2));
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn tsv_has_header_and_rows() {
        let mut t = Trace::new(4);
        t.record(ev(7));
        let tsv = t.to_tsv();
        assert!(tsv.starts_with("msg\t"));
        assert!(tsv.contains("\n7\t0\t1\t10\t"));
    }

    #[test]
    fn trace_record_roundtrips_bytes() {
        let rec = record("bcast", "bcast/seg_chain", 8, 4096, Some(512));
        let text = rec.to_tsv();
        let back = TraceRecord::from_tsv(&text).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.to_tsv(), text, "serialization must be byte-stable");
    }

    #[test]
    fn faulted_record_roundtrips_bytes() {
        let mut rec = record("bcast", "bcast/seg_chain", 8, 4096, Some(512));
        rec.meta.fault_plan = Some(
            FaultPlan::new()
                .slow_node(3, 2.5)
                .dead_node(7)
                .degrade_link(0, 1, 1.5e-3, Some(1e6))
                .degrade_link(4, 2, 2e-3, None),
        );
        let text = rec.to_tsv();
        assert!(text.contains("fault_slow_node\t3\t2.5\n"));
        assert!(text.contains("fault_dead_node\t7\n"));
        assert!(text.contains("fault_link\t0\t1\t0.0015\t1000000\n"));
        assert!(text.contains("fault_link\t4\t2\t0.002\t-\n"));
        let back = TraceRecord::from_tsv(&text).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.to_tsv(), text, "faulted serialization must be byte-stable");
    }

    #[test]
    fn fault_block_is_optional_and_absent_when_fault_free() {
        // pre-fault files (no fault_* records) parse to fault_plan: None
        let rec = record("bcast", "bcast/flat", 4, 64, None);
        let text = rec.to_tsv();
        assert!(!text.contains("fault_"), "fault-free records emit no fault block");
        assert_eq!(TraceRecord::from_tsv(&text).unwrap().meta.fault_plan, None);
        // an explicitly-empty plan serializes identically to no plan
        let mut with_empty = rec.clone();
        with_empty.meta.fault_plan = Some(FaultPlan::new());
        assert_eq!(with_empty.to_tsv(), text);
    }

    #[test]
    fn from_tsv_rejects_garbage_and_inconsistency() {
        assert!(TraceRecord::from_tsv("hello").is_err());
        assert!(TraceRecord::from_tsv(TRACE_HEADER).is_err()); // no metadata
        let rec = record("bcast", "bcast/flat", 4, 64, None);
        let text = rec.to_tsv();
        // a wrong completion with dropped=0 contradicts the events
        let bad = text.replace(
            &format!("completion_ns\t{}", rec.meta.completion_ns),
            "completion_ns\t1",
        );
        assert!(TraceRecord::from_tsv(&bad).is_err());
        // but with drops the tail-only check cannot apply
        let dropped = text.replace("dropped\t0", "dropped\t3");
        assert!(TraceRecord::from_tsv(&dropped).is_ok());
    }

    #[test]
    fn critical_path_is_last_delivery() {
        let rec = record("bcast", "bcast/binomial", 4, 64, None);
        assert_eq!(rec.critical_path(), SimTime(350));
        let empty = TraceRecord { meta: rec.meta.clone(), events: vec![] };
        assert_eq!(empty.critical_path(), SimTime(rec.meta.completion_ns));
    }

    #[test]
    fn pair_timings_group_by_directed_pair() {
        let mut rec = record("bcast", "bcast/flat", 4, 64, None);
        rec.events.push(TraceEvent { src: 1, dst: 0, ..ev(9) });
        let pt = rec.pair_timings();
        assert_eq!(pt.len(), 2);
        assert_eq!(pt[&(0, 1)].len(), 4);
        assert_eq!(pt[&(1, 0)], vec![(SimTime(900), SimTime(950))]);
    }

    #[test]
    fn set_keys_cells_and_columns() {
        let mut set = TraceSet::new();
        for m in [64u64, 4096, 65536] {
            set.insert(record("bcast", "bcast/seg_chain", 8, m, Some(m / 2)));
        }
        set.insert(record("bcast", "bcast/seg_chain", 4, 64, Some(32)));
        set.insert(record("scatter", "scatter/flat", 8, 64, None));
        assert_eq!(set.len(), 5);
        assert!(set.at_cell("bcast", "bcast/seg_chain", 8, 4096).is_some());
        assert!(set.at_cell("bcast", "bcast/seg_chain", 16, 4096).is_none());
        let col = set.cells_for("bcast", "bcast/seg_chain", 8);
        assert_eq!(col.iter().map(|r| r.meta.m).collect::<Vec<_>>(), [64, 4096, 65536]);
        assert_eq!(set.ops(), ["bcast", "scatter"]);
        assert_eq!(set.p_values(), [4, 8]);
        assert_eq!(set.m_values(), [64, 4096, 65536]);
        assert_eq!(set.max_p(), Some(8));
    }

    #[test]
    fn insert_and_merge_replace_by_key() {
        let mut a = TraceSet::new();
        a.insert(record("bcast", "bcast/flat", 4, 64, None));
        let mut newer = record("bcast", "bcast/flat", 4, 64, None);
        newer.events.truncate(2);
        newer.meta.completion_ns = newer.events.last().unwrap().delivered.0;
        let mut b = TraceSet::new();
        b.insert(newer.clone());
        b.insert(record("scatter", "scatter/flat", 4, 64, None));
        assert_eq!(a.merge(b), 1, "one new key, one replacement");
        assert_eq!(a.len(), 2);
        assert_eq!(a.at_cell("bcast", "bcast/flat", 4, 64).unwrap().events.len(), 2);
    }

    #[test]
    fn dir_roundtrip_is_byte_identical() {
        let dir = std::env::temp_dir().join("ct-trace-dir-test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut set = TraceSet::new();
        set.insert(record("bcast", "bcast/seg_chain", 8, 4096, Some(512)));
        set.insert(record("allreduce", "allreduce/rec_doubling", 8, 4096, None));
        let mut faulted = record("scatter", "scatter/flat", 8, 4096, None);
        faulted.meta.fault_plan =
            Some(FaultPlan::new().slow_node(1, 3.0).degrade_link(0, 1, 1e-3, None));
        set.insert(faulted);
        assert_eq!(set.save_dir(&dir).unwrap(), 3);
        let back = TraceSet::load_dir(&dir).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in set.records().zip(back.records()) {
            assert_eq!(a, b);
            assert_eq!(a.to_tsv(), b.to_tsv());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_names_are_stable_and_fs_safe() {
        let k = record("allgather", "allgather/gather+bcast", 8, 64, None).meta.key();
        assert_eq!(k.file_name(), "allgather.gather+bcast.p8.m64.s0.trace.tsv");
        let k = record("bcast", "bcast/seg_chain", 8, 4096, Some(512)).meta.key();
        assert_eq!(k.file_name(), "bcast.seg_chain.p8.m4096.s512.trace.tsv");
    }
}
