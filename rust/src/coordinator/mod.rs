//! L3 tuning coordinator — a concurrent, cached decision-table service.
//!
//! The paper's end state is a runtime that tunes **once per network**
//! and then serves strategy decisions statically (§5); its companion
//! papers (cs/0408033 on logical-cluster identification, cs/0206038 on
//! multi-level collectives) assume a per-cluster coordination layer that
//! owns those decisions. This module is that layer:
//!
//! * [`signature`] — [`ClusterSignature`] fingerprints a network by its
//!   quantized pLogP parameters, node count, and op set, so equivalent
//!   clusters share one decision table.
//! * [`snapshot`] — [`SnapshotCache`], epoch-published immutable
//!   snapshots behind a hand-rolled atomic `Arc` swap
//!   ([`crate::util::arcswap`]): warm reads are one atomic snapshot pin
//!   plus a [`DenseTable`] index — no lock, ever — while writers build
//!   the next snapshot aside and publish it atomically, with
//!   generation-counter LRU eviction.
//! * [`service`] — [`Coordinator`], the long-running service: registry
//!   of discovered clusters, `(op, cluster, P, m) → Decision` queries,
//!   and a request-coalescing miss path (concurrent cold misses on one
//!   signature block on a single in-flight tuner run).
//! * [`refresh`] — [`RefreshPolicy`], periodic pLogP re-probing with
//!   drift detection and atomic table swap.
//! * [`net`] — the coordinator over the wire: the `ct/1` TSV-over-TCP
//!   protocol (`docs/PROTOCOL.md`), the `coordd` server
//!   ([`net::CoordServer`]), the remote client ([`net::NetClient`]),
//!   and a loopback in-process transport; drift re-publishes reach
//!   subscribed clients as `INVALIDATE`/`TABLEUPDATE` pushes via
//!   [`Coordinator::watch_publishes`].
//!
//! Typical service lifecycle (what `collective-tuner serve` runs):
//!
//! ```no_run
//! use collective_tuner::coordinator::Coordinator;
//! use collective_tuner::netsim::NetConfig;
//! use collective_tuner::topology::{ClusterSpec, GridSpec};
//! use collective_tuner::tuner::Op;
//!
//! let grid = GridSpec::new(
//!     vec![ClusterSpec::icluster1()],
//!     NetConfig::wan_link(),
//! );
//! let coord = Coordinator::with_defaults();
//! coord.register_islands(&grid).unwrap();              // discovery feeds the registry
//! let d = coord.decision(Op::Bcast, "icluster-1", 48, 1 << 20).unwrap();
//! println!("use {} (segment {:?})", d.strategy.name(), d.segment);
//! ```

pub mod net;
pub mod refresh;
pub mod service;
pub mod signature;
pub mod snapshot;

pub use refresh::{RefreshOutcome, RefreshPolicy};
pub use service::{
    Coordinator, CoordinatorConfig, CoordinatorStats, DecisionSource, PublishEvent, PublishKind,
    RegisteredCluster, TableSet,
};
pub use signature::ClusterSignature;
pub use snapshot::{CacheStats, DenseTable, SnapshotCache};
