//! Sharded concurrent decision-table cache.
//!
//! The coordinator's hot path is a lookup by [`ClusterSignature`]; the
//! cold path is a tuner run that can take milliseconds. A single lock
//! would serialize every client behind every miss, so the cache is
//! sharded: signatures hash to one of `N` independent
//! `RwLock<HashMap<..>>` shards, readers on the hot path take one shard's
//! read lock only, and writers (table publication, refresh swaps) block
//! just their shard. Each shard evicts least-recently-used entries when
//! it reaches capacity; hit/miss/eviction counters are lock-free.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::signature::ClusterSignature;

/// Lock-free counter snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries resident across all shards at snapshot time.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction over all lookups (0 when no lookups yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry<V> {
    value: V,
    /// Logical timestamp of the last touch; bumped on every `get` hit
    /// without upgrading the shard's read lock.
    last_used: AtomicU64,
}

/// A sharded LRU map from [`ClusterSignature`] to a shared value
/// (the coordinator stores `Arc<TableSet>`).
pub struct ShardedCache<V> {
    shards: Vec<RwLock<HashMap<ClusterSignature, Entry<V>>>>,
    capacity_per_shard: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl<V: Clone> ShardedCache<V> {
    pub fn new(num_shards: usize, capacity_per_shard: usize) -> ShardedCache<V> {
        assert!(num_shards >= 1, "need at least one shard");
        assert!(capacity_per_shard >= 1, "need capacity for at least one entry");
        ShardedCache {
            shards: (0..num_shards).map(|_| RwLock::new(HashMap::new())).collect(),
            capacity_per_shard,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: &ClusterSignature) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Hot-path lookup: one shard read lock, counters and recency are
    /// atomic bumps.
    pub fn get(&self, key: &ClusterSignature) -> Option<V> {
        let shard = self.shards[self.shard_of(key)].read().unwrap();
        match shard.get(key) {
            Some(e) => {
                e.last_used.store(self.next_tick(), Ordering::Relaxed);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Counter-neutral lookup: same read path as [`ShardedCache::get`]
    /// (including the recency bump) but without touching the hit/miss
    /// counters. The coordinator's miss path re-checks the cache under
    /// its in-flight lock, and that re-check must not double-count the
    /// logical miss the first `get` already recorded.
    pub fn peek(&self, key: &ClusterSignature) -> Option<V> {
        let shard = self.shards[self.shard_of(key)].read().unwrap();
        shard.get(key).map(|e| {
            e.last_used.store(self.next_tick(), Ordering::Relaxed);
            e.value.clone()
        })
    }

    /// Publish (or atomically replace) the value for `key`, evicting the
    /// shard's least-recently-used entry if the shard is full.
    pub fn insert(&self, key: ClusterSignature, value: V) {
        let t = self.next_tick();
        let mut shard = self.shards[self.shard_of(&key)].write().unwrap();
        if !shard.contains_key(&key) && shard.len() >= self.capacity_per_shard {
            let victim = shard
                .iter()
                .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                .map(|(k, _)| *k);
            if let Some(victim) = victim {
                shard.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.insert(key, Entry { value, last_used: AtomicU64::new(t) });
    }

    /// Drop one entry (refresh uses this to retire a drifted signature).
    pub fn remove(&self, key: &ClusterSignature) -> bool {
        self.shards[self.shard_of(key)]
            .write()
            .unwrap()
            .remove(key)
            .is_some()
    }

    pub fn contains(&self, key: &ClusterSignature) -> bool {
        self.shards[self.shard_of(key)].read().unwrap().contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total capacity across shards.
    pub fn capacity(&self) -> usize {
        self.shards.len() * self.capacity_per_shard
    }

    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
    }

    /// Counter + occupancy snapshot (counters are monotonic; the
    /// snapshot is not atomic across shards).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }

    /// Copy out every resident `(signature, value)` pair (persistence).
    pub fn snapshot(&self) -> Vec<(ClusterSignature, V)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let shard = s.read().unwrap();
            out.extend(shard.iter().map(|(k, e)| (*k, e.value.clone())));
        }
        out.sort_by_key(|(k, _)| *k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(nodes: usize) -> ClusterSignature {
        ClusterSignature {
            nodes,
            ops: super::super::signature::OPS_ALL,
            l_bucket: -170,
            gap_buckets: [-203, -190, -120, -80, -52],
        }
    }

    #[test]
    fn get_miss_then_insert_then_hit() {
        let c: ShardedCache<u32> = ShardedCache::new(4, 8);
        assert_eq!(c.get(&sig(2)), None);
        c.insert(sig(2), 42);
        assert_eq!(c.get(&sig(2)), Some(42));
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn insert_replaces_in_place() {
        let c: ShardedCache<u32> = ShardedCache::new(2, 2);
        c.insert(sig(3), 1);
        c.insert(sig(3), 2);
        assert_eq!(c.get(&sig(3)), Some(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn lru_eviction_order_single_shard() {
        // one shard so all keys contend for the same capacity
        let c: ShardedCache<u32> = ShardedCache::new(1, 3);
        c.insert(sig(10), 10);
        c.insert(sig(11), 11);
        c.insert(sig(12), 12);
        // touch 10 so 11 becomes the LRU
        assert_eq!(c.get(&sig(10)), Some(10));
        c.insert(sig(13), 13);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.contains(&sig(10)), "recently-used entry survived");
        assert!(!c.contains(&sig(11)), "LRU entry evicted");
        assert!(c.contains(&sig(12)));
        assert!(c.contains(&sig(13)));
    }

    #[test]
    fn peek_reads_without_touching_counters() {
        let c: ShardedCache<u32> = ShardedCache::new(2, 4);
        c.insert(sig(2), 7);
        assert_eq!(c.peek(&sig(2)), Some(7));
        assert_eq!(c.peek(&sig(3)), None);
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (0, 0));
        // but peek still refreshes recency: 2 must survive over 4
        let c1: ShardedCache<u32> = ShardedCache::new(1, 2);
        c1.insert(sig(2), 2);
        c1.insert(sig(4), 4);
        assert_eq!(c1.peek(&sig(2)), Some(2)); // 4 becomes LRU
        c1.insert(sig(5), 5);
        assert!(c1.contains(&sig(2)));
        assert!(!c1.contains(&sig(4)));
    }

    #[test]
    fn remove_and_clear() {
        let c: ShardedCache<u32> = ShardedCache::new(2, 4);
        c.insert(sig(5), 5);
        assert!(c.remove(&sig(5)));
        assert!(!c.remove(&sig(5)));
        c.insert(sig(6), 6);
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let c: ShardedCache<u32> = ShardedCache::new(4, 8);
        for n in [9usize, 3, 7, 5] {
            c.insert(sig(n), n as u32);
        }
        let snap = c.snapshot();
        assert_eq!(snap.len(), 4);
        let nodes: Vec<usize> = snap.iter().map(|(k, _)| k.nodes).collect();
        assert_eq!(nodes, vec![3, 5, 7, 9]);
    }

    #[test]
    fn concurrent_readers_and_writers_do_not_lose_counts() {
        use std::sync::atomic::AtomicU64;
        let c: ShardedCache<u64> = ShardedCache::new(8, 16);
        for n in 2..10usize {
            c.insert(sig(n), n as u64);
        }
        let found = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..8 {
                let c = &c;
                let found = &found;
                scope.spawn(move || {
                    for i in 0..1000usize {
                        let n = 2 + (i + t) % 8;
                        if c.get(&sig(n)) == Some(n as u64) {
                            found.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert_eq!(found.load(Ordering::Relaxed), 8 * 1000);
        assert_eq!(c.stats().hits, 8 * 1000);
    }
}
