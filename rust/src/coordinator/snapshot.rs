//! Epoch-published decision snapshots: the coordinator's lock-free
//! read path.
//!
//! The predecessor of this module (`ShardedCache`) striped the table
//! map across `RwLock`ed shards — readers still took a lock, so a
//! drift refresh serialized against every concurrent `decision()`. Here
//! the entire hot-path state is one immutable [`CoordSnapshot`] behind
//! a [`crate::util::arcswap::ArcSwap`]:
//!
//! * **Readers never lock.** A warm decision is one snapshot pin (two
//!   atomic loads + one increment, see the arcswap module docs), one
//!   hash lookup by cluster name, and one [`DenseTable`] index — no
//!   mutex, no `RwLock`, no allocation. The stress and property tests
//!   in `tests/coordinator.rs` / `tests/properties.rs` enforce this
//!   path's torn-read-freedom and LRU parity.
//! * **Writers publish.** Every mutation (cold-miss tune completion,
//!   drift refresh, warm start, invalidation, re-registration) clones
//!   the current map of `Arc`ed entries off to the side, edits the
//!   clone, and publishes the new snapshot atomically under a single
//!   writer mutex. Readers observe the old or the new snapshot in its
//!   entirety, never a mix.
//! * **LRU without read-side mutation.** Each entry carries a
//!   generation stamp (`last_used: AtomicU64`) **shared across
//!   snapshot generations** by `Arc`: a reader bumping recency on an
//!   older snapshot still informs the next eviction, and the
//!   tick/eviction order is exactly the old read-side-LRU order (the
//!   property test replays access sequences against a reference
//!   model).
//!
//! Publish-side instrumentation (`coordinator.snapshot_publishes`,
//! `coordinator.publish_ns`, and the read path's
//! `coordinator.snapshot_read_retries`) follows the obs overhead
//! contract: one relaxed load when disabled.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::obs::{self, Span};
use crate::tuner::{Decision, Op};
use crate::util::arcswap::ArcSwap;

use super::service::TableSet;
use super::signature::ClusterSignature;

/// Lock-free counter snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries resident at snapshot time.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction over all lookups (0 when no lookups yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A [`TableSet`] flattened for index-arithmetic lookups: per op, a
/// dense `p → row` map and precomputed `m` bucket boundaries over one
/// contiguous cell array, built once at publish time. `decide` is two
/// slice indexes plus one binary search over a handful of cut points —
/// no float math, no nearest-neighbour scan.
///
/// The flattening is exact: `decide(op, p, m)` equals
/// [`TableSet::decision`] for **every** query, because the `p` map is
/// built by evaluating [`crate::tuner::DecisionTable::nearest_p_index`]
/// per integer and the `m` cuts are found by binary-searching the
/// reference [`crate::tuner::DecisionTable::nearest_m_index`] predicate
/// between adjacent grid points (the property suite replays random
/// queries against both).
#[derive(Debug)]
pub struct DenseTable {
    ops: Vec<DenseOp>,
    /// All ops' cells, concatenated row-major.
    cells: Box<[Decision]>,
}

#[derive(Debug)]
struct DenseOp {
    /// Offset of this op's first cell in `cells`.
    base: usize,
    m_len: usize,
    /// `p → p-grid row`, for `p` in `0..=p_max` (larger `p` clamps).
    p_map: Box<[u32]>,
    /// `m_cuts[i]` is the smallest `m` that snaps past row `i`; the
    /// bucket of `m` is the number of cuts `<= m`.
    m_cuts: Box<[u64]>,
}

impl DenseTable {
    pub fn new(set: &TableSet) -> DenseTable {
        let mut cells = Vec::new();
        let mut ops = Vec::with_capacity(Op::COUNT);
        for t in set.tables() {
            let base = cells.len();
            cells.extend_from_slice(&t.entries);
            let p_max = *t.p_grid.last().expect("p grid is non-empty");
            let p_map: Box<[u32]> =
                (0..=p_max).map(|p| t.nearest_p_index(p) as u32).collect();
            let m_len = t.m_grid.len();
            let mut m_cuts = Vec::with_capacity(m_len.saturating_sub(1));
            // saturate: an empty m grid is degenerate but constructible,
            // and `0..m_len - 1` would underflow to a near-infinite loop
            for i in 0..m_len.saturating_sub(1) {
                // invariant: nearest(lo) <= i < nearest(hi); shrink to
                // the exact crossover by probing the reference predicate
                let (mut lo, mut hi) = (t.m_grid[i], t.m_grid[i + 1]);
                while lo + 1 < hi {
                    let mid = lo + (hi - lo) / 2;
                    if t.nearest_m_index(mid) > i {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                }
                debug_assert!(t.nearest_m_index(hi) > i);
                debug_assert!(t.nearest_m_index(hi - 1) <= i);
                m_cuts.push(hi);
            }
            ops.push(DenseOp { base, m_len, p_map, m_cuts: m_cuts.into_boxed_slice() });
        }
        DenseTable { ops, cells: cells.into_boxed_slice() }
    }

    /// Snap-to-nearest decision by pure index arithmetic.
    pub fn decide(&self, op: Op, p: usize, m: u64) -> Decision {
        let t = &self.ops[op.index()];
        let pi = t.p_map[p.min(t.p_map.len() - 1)] as usize;
        let mi = t.m_cuts.partition_point(|&c| c <= m);
        self.cells[t.base + pi * t.m_len + mi]
    }
}

/// One resident table set. Shared by `Arc` across snapshot generations,
/// so the recency stamp a reader bumps on generation N is the same
/// atomic the generation-N+1 eviction pass inspects.
struct TableEntry {
    set: Arc<TableSet>,
    dense: DenseTable,
    last_used: AtomicU64,
}

/// A cluster-name index entry: the signature the name resolves to and,
/// when resident, its tables — so a warm decision needs neither the
/// registry `RwLock` nor a signature hash.
struct NameEntry {
    signature: ClusterSignature,
    entry: Option<Arc<TableEntry>>,
}

/// The immutable hot-path state one publish produces.
#[derive(Default)]
struct CoordSnapshot {
    bysig: HashMap<ClusterSignature, Arc<TableEntry>>,
    byname: HashMap<String, NameEntry>,
    /// Monotonic publish counter, stamped under the publish lock. Every
    /// answer read from this snapshot can carry the epoch it was
    /// computed from — the net protocol's invalidation-ordering
    /// guarantee (docs/PROTOCOL.md) is stated in these epochs.
    epoch: u64,
}

/// The coordinator's table cache: epoch-published snapshots with
/// generation-counter LRU eviction. Same observable semantics as the
/// sharded predecessor (hit/miss/eviction accounting, `peek`
/// counter-neutrality, tick-ordered eviction), but reads are lock-free.
pub struct SnapshotCache {
    swap: ArcSwap<CoordSnapshot>,
    /// Serializes read-modify-publish cycles (writers only).
    publish_lock: Mutex<()>,
    capacity: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl SnapshotCache {
    pub fn new(capacity: usize) -> SnapshotCache {
        assert!(capacity >= 1, "need capacity for at least one entry");
        SnapshotCache {
            swap: ArcSwap::new(Arc::new(CoordSnapshot::default()))
                .with_retry_metric("coordinator.snapshot_read_retries"),
            publish_lock: Mutex::new(()),
            capacity,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// The whole warm decision in one snapshot pin: resolve the cluster
    /// name through the published index and answer from the dense
    /// table. `None` when the name is unknown to the snapshot or its
    /// tables are not resident (the caller falls back to the registry +
    /// coalesced tune path). Counts a hit and bumps recency on success;
    /// counter-neutral on `None` (the slow path's `get` does the
    /// accounting there).
    /// The returned epoch is the publish epoch of the snapshot the
    /// decision was read from — decision and epoch come from the *same*
    /// pin, so the pairing is exact even while writers publish
    /// concurrently.
    pub fn warm_decide(
        &self,
        name: &str,
        op: Op,
        p: usize,
        m: u64,
    ) -> Option<(Decision, ClusterSignature, u64)> {
        let snap = self.swap.load();
        let ne = snap.byname.get(name)?;
        let entry = ne.entry.as_ref()?;
        entry.last_used.store(self.next_tick(), Ordering::Relaxed);
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some((entry.dense.decide(op, p, m), ne.signature, snap.epoch))
    }

    /// The currently-published snapshot's epoch (0 before any publish).
    /// Monotonic: each publish stamps `epoch + 1` under the publish
    /// lock.
    pub fn epoch(&self) -> u64 {
        self.swap.load().epoch
    }

    /// Hot-path lookup by signature: one snapshot pin; counters and
    /// recency are atomic bumps.
    pub fn get(&self, key: &ClusterSignature) -> Option<Arc<TableSet>> {
        let snap = self.swap.load();
        match snap.bysig.get(key) {
            Some(e) => {
                e.last_used.store(self.next_tick(), Ordering::Relaxed);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.set))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Counter-neutral lookup: same read path as [`SnapshotCache::get`]
    /// (including the recency bump) but without touching the hit/miss
    /// counters. The coordinator's miss path re-checks the cache under
    /// its in-flight lock, and that re-check must not double-count the
    /// logical miss the first `get` already recorded.
    pub fn peek(&self, key: &ClusterSignature) -> Option<Arc<TableSet>> {
        let snap = self.swap.load();
        snap.bysig.get(key).map(|e| {
            e.last_used.store(self.next_tick(), Ordering::Relaxed);
            Arc::clone(&e.set)
        })
    }

    /// Publish (or replace) the tables for `key`, evicting the
    /// least-recently-used entry if at capacity. `names` is the current
    /// cluster-name → signature mapping to index the new snapshot by.
    pub fn insert(
        &self,
        key: ClusterSignature,
        set: Arc<TableSet>,
        names: &[(String, ClusterSignature)],
    ) {
        let t = self.next_tick();
        self.publish(names, |bysig| {
            if !bysig.contains_key(&key) && bysig.len() >= self.capacity {
                let victim = bysig
                    .iter()
                    .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                    .map(|(k, _)| *k);
                if let Some(victim) = victim {
                    bysig.remove(&victim);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
            let entry = TableEntry {
                dense: DenseTable::new(&set),
                set,
                last_used: AtomicU64::new(t),
            };
            bysig.insert(key, Arc::new(entry));
        });
    }

    /// Drop one entry (refresh retires a drifted signature this way).
    pub fn remove(&self, key: &ClusterSignature, names: &[(String, ClusterSignature)]) -> bool {
        let mut removed = false;
        self.publish(names, |bysig| {
            removed = bysig.remove(key).is_some();
        });
        removed
    }

    /// Republish with a fresh name index and unchanged tables — the
    /// coordinator calls this after every (re-)registration so warm
    /// reads never resolve a name through a stale signature.
    pub fn sync_names(&self, names: &[(String, ClusterSignature)]) {
        self.publish(names, |_| {});
    }

    /// Build-aside-and-publish: clone the resident map, let `edit`
    /// mutate the clone, rebuild the name index, swap atomically.
    /// Readers pinning the previous snapshot are undisturbed.
    fn publish<F>(&self, names: &[(String, ClusterSignature)], edit: F)
    where
        F: FnOnce(&mut HashMap<ClusterSignature, Arc<TableEntry>>),
    {
        let _w = self.publish_lock.lock().unwrap();
        let _span = Span::start("coordinator.publish_ns");
        let cur = self.swap.load_full();
        let mut bysig = cur.bysig.clone();
        edit(&mut bysig);
        let byname = names
            .iter()
            .map(|(name, sig)| {
                let ne = NameEntry { signature: *sig, entry: bysig.get(sig).cloned() };
                (name.clone(), ne)
            })
            .collect();
        self.swap.store(Arc::new(CoordSnapshot { bysig, byname, epoch: cur.epoch + 1 }));
        if obs::enabled() {
            obs::registry().counter("coordinator.snapshot_publishes").inc();
        }
    }

    pub fn contains(&self, key: &ClusterSignature) -> bool {
        self.swap.load().bysig.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.swap.load().bysig.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Counter + occupancy snapshot (counters are monotonic).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }

    /// Copy out every resident `(signature, tables)` pair, sorted by
    /// signature (persistence).
    pub fn snapshot(&self) -> Vec<(ClusterSignature, Arc<TableSet>)> {
        let snap = self.swap.load();
        let mut out: Vec<(ClusterSignature, Arc<TableSet>)> = snap
            .bysig
            .iter()
            .map(|(k, e)| (*k, Arc::clone(&e.set)))
            .collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::DecisionTable;

    fn sig(nodes: usize) -> ClusterSignature {
        ClusterSignature {
            nodes,
            ops: super::super::signature::OPS_ALL,
            l_bucket: -170,
            gap_buckets: [-203, -190, -120, -80, -52],
        }
    }

    /// A minimal valid table set whose every decision carries `marker`
    /// as the predicted time — enough to tell entries apart.
    fn tiny(marker: u32) -> Arc<TableSet> {
        let tables = Op::ALL
            .iter()
            .map(|&op| {
                let d = Decision {
                    strategy: op.family()[0],
                    segment: None,
                    predicted: f64::from(marker),
                };
                DecisionTable::new(op, vec![2], vec![1], vec![d])
            })
            .collect();
        Arc::new(TableSet::new(tables))
    }

    fn marker(set: &TableSet) -> u32 {
        set.decision(Op::Bcast, 2, 1).predicted as u32
    }

    /// A table set with real multi-row grids and a distinct predicted
    /// value per cell, so a one-cell snap disagreement is visible.
    fn gridded() -> Arc<TableSet> {
        let p_grid = vec![2usize, 8, 32];
        let m_grid = vec![1u64, 1024, 1 << 20];
        let tables = Op::ALL
            .iter()
            .map(|&op| {
                let entries = (0..p_grid.len() * m_grid.len())
                    .map(|i| Decision {
                        strategy: op.family()[0],
                        segment: None,
                        predicted: (op.index() * 100 + i) as f64,
                    })
                    .collect();
                DecisionTable::new(op, p_grid.clone(), m_grid.clone(), entries)
            })
            .collect();
        Arc::new(TableSet::new(tables))
    }

    #[test]
    fn dense_decide_agrees_with_table_lookup_at_exact_ties() {
        // the flattening contract says dense == slow for EVERY query;
        // these sit exactly on the tie/boundary points where the two
        // code paths (partition_point over precomputed cuts vs
        // first-on-ties nearest scan) could plausibly diverge
        let set = gridded();
        let dense = DenseTable::new(&set);
        let queries = [
            // m = 32 is the exact log-space midpoint of 1 and 1024
            // (sqrt(1024)); m = 1<<15 the midpoint of 1024 and 1<<20
            (2usize, 32u64),
            (2, 1 << 15),
            // p = 5 is equidistant from grid points 2 and 8
            (5, 1 << 15),
            (5, 4096),
            // m = 0 and m = 1 edges (log snap clamps m to >= 1)
            (8, 0),
            (8, 1),
            // one past / one short of a boundary
            (20, 1023),
            (20, 1025),
            (20, (1 << 15) + 1),
            // beyond both grids: clamps to the last row/column
            (100, 1 << 24),
            (0, 1 << 15),
        ];
        for (p, m) in queries {
            for op in Op::ALL {
                assert_eq!(
                    dense.decide(op, p, m),
                    set.decision(op, p, m),
                    "{op:?} P={p} m={m}"
                );
            }
        }
    }

    #[test]
    fn dense_table_survives_an_empty_m_grid() {
        // degenerate but constructible; building the dense form used to
        // underflow `0..m_len - 1` and spin through usize::MAX indexes
        let tables = Op::ALL
            .iter()
            .map(|&op| DecisionTable::new(op, vec![2], vec![], vec![]))
            .collect();
        let set = TableSet::new(tables);
        let _dense = DenseTable::new(&set);
    }

    #[test]
    fn get_miss_then_insert_then_hit() {
        let c = SnapshotCache::new(8);
        assert!(c.get(&sig(2)).is_none());
        c.insert(sig(2), tiny(42), &[]);
        assert_eq!(c.get(&sig(2)).map(|t| marker(&t)), Some(42));
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn insert_replaces_in_place() {
        let c = SnapshotCache::new(2);
        c.insert(sig(3), tiny(1), &[]);
        c.insert(sig(3), tiny(2), &[]);
        assert_eq!(c.get(&sig(3)).map(|t| marker(&t)), Some(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn lru_eviction_order() {
        let c = SnapshotCache::new(3);
        c.insert(sig(10), tiny(10), &[]);
        c.insert(sig(11), tiny(11), &[]);
        c.insert(sig(12), tiny(12), &[]);
        // touch 10 so 11 becomes the LRU
        assert!(c.get(&sig(10)).is_some());
        c.insert(sig(13), tiny(13), &[]);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.contains(&sig(10)), "recently-used entry survived");
        assert!(!c.contains(&sig(11)), "LRU entry evicted");
        assert!(c.contains(&sig(12)));
        assert!(c.contains(&sig(13)));
    }

    #[test]
    fn peek_reads_without_touching_counters() {
        let c = SnapshotCache::new(4);
        c.insert(sig(2), tiny(7), &[]);
        assert_eq!(c.peek(&sig(2)).map(|t| marker(&t)), Some(7));
        assert!(c.peek(&sig(3)).is_none());
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (0, 0));
        // but peek still refreshes recency: 2 must survive over 4
        let c1 = SnapshotCache::new(2);
        c1.insert(sig(2), tiny(2), &[]);
        c1.insert(sig(4), tiny(4), &[]);
        assert!(c1.peek(&sig(2)).is_some()); // 4 becomes LRU
        c1.insert(sig(5), tiny(5), &[]);
        assert!(c1.contains(&sig(2)));
        assert!(!c1.contains(&sig(4)));
    }

    #[test]
    fn remove_retires_an_entry() {
        let c = SnapshotCache::new(4);
        c.insert(sig(5), tiny(5), &[]);
        assert!(c.remove(&sig(5), &[]));
        assert!(!c.remove(&sig(5), &[]));
        assert!(c.is_empty());
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let c = SnapshotCache::new(8);
        for n in [9usize, 3, 7, 5] {
            c.insert(sig(n), tiny(n as u32), &[]);
        }
        let snap = c.snapshot();
        assert_eq!(snap.len(), 4);
        let nodes: Vec<usize> = snap.iter().map(|(k, _)| k.nodes).collect();
        assert_eq!(nodes, vec![3, 5, 7, 9]);
    }

    #[test]
    fn warm_decide_resolves_names_through_the_published_index() {
        let c = SnapshotCache::new(4);
        let names = vec![("a".to_string(), sig(2)), ("b".to_string(), sig(3))];
        // registered but not resident: the index knows the name but
        // warm reads must fall through to the slow path
        c.sync_names(&names);
        assert!(c.warm_decide("a", Op::Bcast, 2, 1).is_none());
        assert_eq!(c.stats().hits, 0, "a warm fall-through is counter-neutral");

        c.insert(sig(2), tiny(42), &names);
        let (d, s, _) = c.warm_decide("a", Op::Bcast, 8, 1 << 20).unwrap();
        assert_eq!(d.predicted as u32, 42);
        assert_eq!(s, sig(2));
        assert_eq!(c.stats().hits, 1);
        assert!(c.warm_decide("b", Op::Bcast, 2, 1).is_none(), "b not resident");
        assert!(c.warm_decide("ghost", Op::Bcast, 2, 1).is_none());
    }

    #[test]
    fn epochs_advance_once_per_publish_and_tag_warm_reads() {
        let c = SnapshotCache::new(4);
        assert_eq!(c.epoch(), 0, "no publish yet");
        let names = vec![("a".to_string(), sig(2))];
        c.sync_names(&names); // publish 1
        c.insert(sig(2), tiny(7), &names); // publish 2
        assert_eq!(c.epoch(), 2);
        let (_, _, e) = c.warm_decide("a", Op::Bcast, 2, 1).unwrap();
        assert_eq!(e, 2, "warm read carries the epoch of the snapshot it pinned");
        c.remove(&sig(2), &names); // publish 3
        assert_eq!(c.epoch(), 3);
    }

    #[test]
    fn recency_survives_republication() {
        // a bump recorded on one snapshot generation must steer the
        // eviction decided on a later generation (shared atomics)
        let c = SnapshotCache::new(2);
        c.insert(sig(2), tiny(2), &[]);
        c.insert(sig(4), tiny(4), &[]);
        c.sync_names(&[]); // republish: new snapshot, same entries
        assert!(c.get(&sig(2)).is_some()); // bump on the new generation
        c.insert(sig(5), tiny(5), &[]);
        assert!(c.contains(&sig(2)));
        assert!(!c.contains(&sig(4)), "LRU by shared generation stamp");
    }

    #[test]
    fn concurrent_readers_and_writers_do_not_lose_counts() {
        let c = SnapshotCache::new(16);
        for n in 2..10usize {
            c.insert(sig(n), tiny(n as u32), &[]);
        }
        let found = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..8 {
                let c = &c;
                let found = &found;
                scope.spawn(move || {
                    for i in 0..1000usize {
                        let n = 2 + (i + t) % 8;
                        if c.get(&sig(n)).map(|v| marker(&v)) == Some(n as u32) {
                            found.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert_eq!(found.load(Ordering::Relaxed), 8 * 1000);
        assert_eq!(c.stats().hits, 8 * 1000);
    }
}
