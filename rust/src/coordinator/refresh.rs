//! Refresh policy: detect pLogP drift and atomically re-tune.
//!
//! The paper's operating mode is "tune once, serve statically" (§5) —
//! valid exactly as long as the measured parameters still describe the
//! network. Hardware swaps, kernel upgrades (the §4 TCP behaviours are
//! kernel-version-specific), or load changes move `L` and `g(m)`; a
//! deployed coordinator therefore periodically re-probes and compares
//! against the parameters a cluster was registered with. Below the
//! drift threshold nothing happens (lookups stay on the cached table);
//! above it the cluster is re-registered under its new signature, a
//! fresh table is tuned (on the coordinator's parallel tuning engine —
//! see [`crate::tuner::Tuner::jobs`]), and a fresh cache snapshot is
//! published atomically (see [`super::snapshot`]) — concurrent readers
//! keep answering lock-free from whichever snapshot they pinned, old
//! or new, never a partial one.

use anyhow::{Context, Result};

use crate::netsim::Netsim;
use crate::obs::{self, Span};
use crate::plogp::bench::{self, BenchOptions};

use super::service::Coordinator;
use super::signature::{self, ClusterSignature};

/// When and how to re-probe.
#[derive(Debug, Clone)]
pub struct RefreshPolicy {
    /// Re-tune when [`signature::drift`] exceeds this. The default (10 %)
    /// sits above measurement noise (~couple %) and below the margins
    /// at which strategy crossover points actually move.
    pub drift_tolerance: f64,
    /// Measurement options for the re-probe.
    pub bench: BenchOptions,
}

impl Default for RefreshPolicy {
    fn default() -> Self {
        RefreshPolicy { drift_tolerance: 0.10, bench: BenchOptions::default() }
    }
}

/// What one refresh pass decided.
#[derive(Debug, Clone)]
pub enum RefreshOutcome {
    /// Drift under tolerance; the cached table stands.
    Unchanged { drift: f64 },
    /// Drift over tolerance; table re-tuned and swapped in.
    Refreshed {
        drift: f64,
        old: ClusterSignature,
        new: ClusterSignature,
    },
}

impl RefreshOutcome {
    pub fn drift(&self) -> f64 {
        match self {
            RefreshOutcome::Unchanged { drift } | RefreshOutcome::Refreshed { drift, .. } => {
                *drift
            }
        }
    }

    pub fn refreshed(&self) -> bool {
        matches!(self, RefreshOutcome::Refreshed { .. })
    }
}

impl Coordinator {
    /// Re-probe `cluster`'s network on `sim` — between the same
    /// representative pair it was registered from — and re-tune if the
    /// parameters drifted beyond the policy's tolerance.
    pub fn refresh(
        &self,
        cluster: &str,
        sim: &mut Netsim,
        policy: &RefreshPolicy,
    ) -> Result<RefreshOutcome> {
        let _pass = Span::start("coordinator.refresh_ns");
        if obs::enabled() {
            obs::registry().counter("coordinator.refresh.checks").inc();
        }
        let rc = self
            .cluster(cluster)
            .with_context(|| format!("cluster '{cluster}' is not registered"))?;
        let fresh = bench::measure_pair_with(sim, rc.probe.0, rc.probe.1, &policy.bench);
        let drift = signature::drift(&rc.net, &fresh);
        if drift <= policy.drift_tolerance {
            return Ok(RefreshOutcome::Unchanged { drift });
        }
        let new = self
            .register_with_probe(cluster, rc.nodes, fresh.clone(), rc.probe)
            .with_context(|| format!("re-registering '{cluster}' after a drift probe"))?;
        self.force_retune(new, &fresh);
        if obs::enabled() {
            obs::registry().counter("coordinator.refresh.swaps").inc();
        }
        if new != rc.signature {
            // Retire the drifted table unless another registered cluster
            // still resolves to that signature.
            let still_used = self
                .clusters()
                .iter()
                .any(|c| c.name != cluster && c.signature == rc.signature);
            if !still_used {
                self.evict_signature(&rc.signature);
            }
        }
        Ok(RefreshOutcome::Refreshed { drift, old: rc.signature, new })
    }

    /// Refresh every registered cluster against simulators produced by
    /// `make_sim` (name → probe simulator). Returns per-cluster outcomes
    /// sorted by name.
    pub fn refresh_all<F: FnMut(&str) -> Netsim>(
        &self,
        mut make_sim: F,
        policy: &RefreshPolicy,
    ) -> Result<Vec<(String, RefreshOutcome)>> {
        let mut out = Vec::new();
        for rc in self.clusters() {
            let mut sim = make_sim(&rc.name);
            let outcome = self.refresh(&rc.name, &mut sim, policy)?;
            out.push((rc.name, outcome));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetConfig;
    use crate::plogp;
    use crate::tuner::{grids, Op};

    use super::super::service::CoordinatorConfig;

    fn small() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            shards: 2,
            capacity_per_shard: 4,
            p_grid: vec![2, 8, 24],
            m_grid: grids::log_grid(1, 1 << 20, 6),
            ..CoordinatorConfig::default()
        })
    }

    fn measured(cfg: NetConfig) -> crate::plogp::PLogP {
        let mut sim = Netsim::new(2, cfg);
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn stable_network_is_unchanged() {
        let c = small();
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        let _ = c.tables("a").unwrap();
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        let outcome = c.refresh("a", &mut sim, &RefreshPolicy::default()).unwrap();
        assert!(!outcome.refreshed(), "{outcome:?}");
        assert!(outcome.drift() < 0.01, "{outcome:?}");
        assert_eq!(c.tune_count(), 1, "no re-tune on a stable network");
    }

    #[test]
    fn drifted_network_is_retuned_and_swapped() {
        let c = small();
        // register as Fast Ethernet, then "the network got upgraded"
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        let before = c.tables("a").unwrap();
        let mut upgraded = Netsim::new(2, NetConfig::gigabit_ethernet());
        let outcome = c.refresh("a", &mut upgraded, &RefreshPolicy::default()).unwrap();
        assert!(outcome.refreshed(), "{outcome:?}");
        assert!(outcome.drift() > 0.10, "{outcome:?}");
        assert_eq!(c.tune_count(), 2);
        // registry now answers from the new table
        let after = c.tables("a").unwrap();
        assert!(!std::sync::Arc::ptr_eq(&before, &after));
        // and the decision reflects the faster network
        let d = c.decision(Op::Bcast, "a", 24, 1 << 20).unwrap();
        assert!(d.predicted > 0.0 && d.predicted.is_finite());
        match outcome {
            RefreshOutcome::Refreshed { old, new, .. } => assert_ne!(old, new),
            _ => unreachable!(),
        }
    }

    #[test]
    fn refresh_probes_the_registered_pair_not_rank_zero() {
        let c = small();
        // "b" is an island living on nodes 4..8 of a larger simulator;
        // it was measured between (4, 5) and must be re-probed there
        let mut sim = Netsim::new(8, NetConfig::fast_ethernet_ideal());
        let net_b = plogp::bench::measure_pair(&mut sim, 4, 5);
        c.register_with_probe("b", 4, net_b, (4, 5)).unwrap();
        let _ = c.tables("b").unwrap();
        // degrade only the (0, 1) links; island "b" is untouched
        sim.inject_link_delay(0, 1, 500e-6);
        sim.inject_link_delay(1, 0, 500e-6);
        let outcome = c.refresh("b", &mut sim, &RefreshPolicy::default()).unwrap();
        assert!(
            !outcome.refreshed(),
            "refresh must re-probe (4, 5), not (0, 1): {outcome:?}"
        );
        assert!(outcome.drift() < 0.01, "{outcome:?}");
    }

    #[test]
    fn refresh_unknown_cluster_errors() {
        let c = small();
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        assert!(c.refresh("ghost", &mut sim, &RefreshPolicy::default()).is_err());
    }

    #[test]
    fn refresh_all_visits_every_cluster() {
        let c = small();
        c.register("a", 8, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.register("b", 8, measured(NetConfig::gigabit_ethernet())).unwrap();
        // every re-probe sees Fast Ethernet: "a" is unchanged, while
        // "b" (registered as gigabit) has drifted
        let outcomes = c
            .refresh_all(
                |_name| Netsim::new(2, NetConfig::fast_ethernet_ideal()),
                &RefreshPolicy::default(),
            )
            .unwrap();
        assert_eq!(outcomes.len(), 2);
        assert_eq!(outcomes[0].0, "a");
        assert!(!outcomes[0].1.refreshed());
        assert!(outcomes[1].1.refreshed(), "b drifted from gigabit to fast ethernet");
    }
}
