//! The tuning coordinator: a long-running, thread-safe decision service.
//!
//! One [`Coordinator`] owns the decision tables for every logical
//! cluster it has been told about (registered explicitly, from a
//! [`GridSpec`], or recovered by `topology::discover`) and answers
//! `(op, cluster, P, m) → Decision` queries from any number of threads:
//!
//! * **hot path** — one lock-free pin of the epoch-published
//!   [`super::snapshot::SnapshotCache`] snapshot: the cluster name
//!   resolves through the published index straight to a flattened
//!   [`super::snapshot::DenseTable`], so a warm `decision()` touches no
//!   mutex, no `RwLock`, and allocates nothing; equivalent networks
//!   share one table.
//! * **cold path** — a tuner run (artifact backend when available,
//!   native models otherwise). Concurrent misses on the same signature
//!   *coalesce*: exactly one thread tunes, the rest block on the
//!   in-flight run and reuse its result.
//! * **persistence** — [`Coordinator::persist_to`] /
//!   [`Coordinator::warm_start_from`] save and restore the registry and
//!   every cached table, the paper's tune-once-then-static operating
//!   mode across process restarts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::eval::{EvalCounts, ReplayEval};
use crate::models::CorrectionTable;
use crate::netsim::{Netsim, NodeId};
use crate::obs::{self, DecisionEvent, DecisionOutcome, Span};
use crate::plogp::{bench, GapTable, PLogP};
use crate::topology::GridSpec;
use crate::tuner::{grids, persist, Decision, DecisionTable, Op, Tuner};
use crate::util::json::Json;

use super::signature::ClusterSignature;
use super::snapshot::{CacheStats, SnapshotCache};

/// The per-operation decision tables tuned for one signature: one
/// [`DecisionTable`] per [`Op::ALL`] entry (broadcast, scatter, and the
/// extended collectives), all produced by a single coalesced tuner run.
#[derive(Debug, Clone)]
pub struct TableSet {
    tables: Vec<DecisionTable>,
}

impl TableSet {
    /// Build from one table per op, in [`Op::ALL`] order.
    pub fn new(tables: Vec<DecisionTable>) -> TableSet {
        assert_eq!(tables.len(), Op::COUNT, "one table per Op::ALL entry");
        for (i, t) in tables.iter().enumerate() {
            assert_eq!(t.op.index(), i, "tables must be in Op::ALL order");
        }
        TableSet { tables }
    }

    pub fn table(&self, op: Op) -> &DecisionTable {
        &self.tables[op.index()]
    }

    /// All tables, in [`Op::ALL`] order.
    pub fn tables(&self) -> &[DecisionTable] {
        &self.tables
    }

    /// Snap-to-nearest decision lookup.
    pub fn decision(&self, op: Op, p: usize, m: u64) -> Decision {
        *self.table(op).lookup(p, m)
    }
}

/// Coordinator construction parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Historical lock-striping width. Reads no longer shard (the cache
    /// is one epoch-published snapshot); the field survives so existing
    /// configs keep meaning: total LRU capacity is
    /// `shards * capacity_per_shard`.
    pub shards: usize,
    /// LRU capacity per (historical) shard.
    pub capacity_per_shard: usize,
    /// Signature quantization tolerance (see [`super::signature`]).
    pub tolerance: f64,
    /// Process-count grid every table is tuned over.
    pub p_grid: Vec<usize>,
    /// Message-size grid every table is tuned over.
    pub m_grid: Vec<u64>,
    /// When set, try the AOT artifact backend from this directory
    /// (falling back to native models if it cannot be loaded).
    pub artifact_dir: Option<PathBuf>,
    /// When set, load a trace-fitted correction table (the `calibrate`
    /// subcommand's `corrections.tsv`; a directory or the file itself)
    /// and tune on the corrected native models. Mutually exclusive with
    /// `artifact_dir`: corrections apply to the native model backend.
    pub corrections: Option<PathBuf>,
    /// Worker threads for the tuner's parallel grid sweep (0 = one per
    /// core). Coalesced misses and drift re-tunes both run on it.
    pub jobs: usize,
    /// How old retired tables may be and still be served when a tune
    /// fails (the stale shelf's bound). Past it, a failed tune falls
    /// back to a local model evaluation instead.
    pub max_staleness: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            shards: 8,
            capacity_per_shard: 32,
            tolerance: super::signature::DEFAULT_TOLERANCE,
            p_grid: grids::default_p_grid(),
            m_grid: grids::default_m_grid(),
            artifact_dir: None,
            corrections: None,
            jobs: 0,
            max_staleness: Duration::from_secs(300),
        }
    }
}

/// Where a decision's answer came from, on the ladder the coordinator
/// walks when tuning is impossible: fresh tables, then the stale shelf
/// (retired tables within [`CoordinatorConfig::max_staleness`]), then a
/// last-resort local model evaluation. Mirrored into the flight
/// recorder as [`DecisionOutcome`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionSource {
    /// Up-to-date published tables (warm hit or successful tune).
    Fresh,
    /// Retired tables served within the staleness bound.
    Stale,
    /// A local [`crate::eval::ModelEval`] tune because nothing better
    /// existed.
    Fallback,
}

impl DecisionSource {
    pub fn name(&self) -> &'static str {
        match self {
            DecisionSource::Fresh => "fresh",
            DecisionSource::Stale => "stale",
            DecisionSource::Fallback => "fallback",
        }
    }
}

/// One cluster known to the coordinator.
#[derive(Debug, Clone)]
pub struct RegisteredCluster {
    pub name: String,
    pub nodes: usize,
    pub net: PLogP,
    pub signature: ClusterSignature,
    /// The representative node pair the pLogP parameters were measured
    /// between — the refresh policy re-probes the *same* pair, which
    /// matters when a cluster is an island inside a larger simulator
    /// (its link is not the `(0, 1)` link).
    pub probe: (NodeId, NodeId),
}

/// An in-flight tuner run that concurrent misses block on. The leader
/// deposits whatever it ended up serving — fresh tables, or the
/// degraded substitute when its tune failed — plus how it resolved, so
/// followers report honestly.
#[derive(Default)]
struct Inflight {
    result: Mutex<Option<(Arc<TableSet>, DecisionOutcome)>>,
    ready: Condvar,
}

/// What a table-publication event did to the signature it names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PublishKind {
    /// Fresh tables for the signature were published (cold-miss tune,
    /// drift re-tune, warm start). Subscribers should re-read.
    Updated,
    /// The signature's resident tables were dropped (invalidation, or a
    /// refresh retiring a drifted signature). Cached decisions derived
    /// from them are stale.
    Invalidated,
}

/// One table-publication event, as delivered to
/// [`Coordinator::watch_publishes`] receivers. `epoch` is the cache's
/// publish epoch *after* the event took effect: any decision carrying a
/// smaller epoch may predate this event.
#[derive(Debug, Clone)]
pub struct PublishEvent {
    pub kind: PublishKind,
    pub signature: ClusterSignature,
    pub epoch: u64,
}

/// Aggregate service counters.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorStats {
    pub cache: CacheStats,
    /// Actual tuner executions (coalesced misses count once).
    pub tunes: u64,
    /// Failed tuner runs (injected or real).
    pub tune_failures: u64,
    /// Decisions served from the stale shelf after a failed tune.
    pub stale_serves: u64,
    /// Decisions served from the last-resort model fallback.
    pub fallback_serves: u64,
    /// Clusters in the registry.
    pub registered: usize,
    /// The tuner's cumulative sweep counters across those runs (model
    /// invocations, pruned searches, warm-start hits — see
    /// [`EvalCounts`]).
    pub eval: EvalCounts,
}

/// The L3 tuning coordinator. Cheap to share: every method takes
/// `&self`; wrap in an [`Arc`] or borrow across `std::thread::scope`.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    tuner: Tuner,
    /// The loaded correction table when [`CoordinatorConfig::corrections`]
    /// is set — kept so the degradation ladder's local fallback tuner
    /// answers consistently with the primary one.
    corrections: Option<CorrectionTable>,
    cache: SnapshotCache,
    inflight: Mutex<HashMap<ClusterSignature, Arc<Inflight>>>,
    registry: RwLock<HashMap<String, RegisteredCluster>>,
    tunes: AtomicU64,
    /// Retired tables kept for degraded serving: eviction moves tables
    /// here (with their retirement instant) instead of discarding them,
    /// so a later *failed* tune can answer from them while they are
    /// younger than [`CoordinatorConfig::max_staleness`]. Never read on
    /// the healthy path.
    stale_shelf: Mutex<HashMap<ClusterSignature, (Arc<TableSet>, Instant)>>,
    /// Deterministic fault injection: the next N tuner runs fail. The
    /// chaos suite and the bench's degraded phase drive this; 0 in
    /// production.
    fail_next_tunes: AtomicU64,
    tune_failures: AtomicU64,
    stale_serves: AtomicU64,
    fallback_serves: AtomicU64,
    /// Table-publication subscribers (`watch_publishes`). Disconnected
    /// receivers are pruned on the next notification.
    watchers: Mutex<Vec<mpsc::Sender<PublishEvent>>>,
}

const MANIFEST_HEADER: &str = "# collective-tuner coordinator manifest v1";

impl Coordinator {
    /// Panicking convenience over [`Coordinator::try_new`], for configs
    /// known good (tests, defaults). Configs carrying operator-supplied
    /// paths should use `try_new` and surface the error.
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator::try_new(cfg).unwrap_or_else(|e| panic!("{e:#}"))
    }

    /// Build a coordinator, loading the corrections table when one is
    /// configured. Fails on an unreadable/invalid corrections path or
    /// on a config naming both an artifact and corrections (corrections
    /// apply to the native model backend only).
    pub fn try_new(cfg: CoordinatorConfig) -> Result<Coordinator> {
        if cfg.artifact_dir.is_some() && cfg.corrections.is_some() {
            bail!(
                "corrections apply to the native model backend; \
                 configure either an artifact dir or a corrections table, not both"
            );
        }
        let corrections = match &cfg.corrections {
            Some(path) => Some(
                CorrectionTable::load(path)
                    .with_context(|| format!("loading corrections from {}", path.display()))?,
            ),
            None => None,
        };
        let tuner = match (&cfg.artifact_dir, &corrections) {
            (Some(dir), _) => Tuner::auto(dir),
            (None, Some(table)) => Tuner::corrected(table.clone()),
            (None, None) => Tuner::native(),
        }
        .jobs(cfg.jobs);
        let cache = SnapshotCache::new(cfg.shards.max(1) * cfg.capacity_per_shard.max(1));
        Ok(Coordinator {
            cfg,
            tuner,
            corrections,
            cache,
            inflight: Mutex::new(HashMap::new()),
            registry: RwLock::new(HashMap::new()),
            tunes: AtomicU64::new(0),
            stale_shelf: Mutex::new(HashMap::new()),
            fail_next_tunes: AtomicU64::new(0),
            tune_failures: AtomicU64::new(0),
            stale_serves: AtomicU64::new(0),
            fallback_serves: AtomicU64::new(0),
            watchers: Mutex::new(Vec::new()),
        })
    }

    /// Paper-sized grids, native backend, 8×32 cache.
    pub fn with_defaults() -> Coordinator {
        Coordinator::new(CoordinatorConfig::default())
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn backend_name(&self) -> &'static str {
        self.tuner.backend_name()
    }

    // ---- registry -----------------------------------------------------

    /// Register (or re-register) a cluster under `name`, measured
    /// between ranks `(0, 1)` of its own simulator. Returns its
    /// signature; tables are tuned lazily on first query. Fails with a
    /// structured error (not a panic) when the probed parameters are
    /// degenerate — a fault-degraded probe can legitimately report a
    /// zero or infinite latency/gap, and the registry must refuse it.
    pub fn register(&self, name: &str, nodes: usize, net: PLogP) -> Result<ClusterSignature> {
        self.register_with_probe(name, nodes, net, (0, 1))
    }

    /// Register a cluster whose parameters were measured between an
    /// explicit representative pair (e.g. two members of a discovered
    /// island inside a grid simulator); refresh re-probes that pair.
    /// Same degenerate-parameter contract as [`Coordinator::register`].
    pub fn register_with_probe(
        &self,
        name: &str,
        nodes: usize,
        net: PLogP,
        probe: (NodeId, NodeId),
    ) -> Result<ClusterSignature> {
        let signature = ClusterSignature::try_with_tolerance(&net, nodes, self.cfg.tolerance)
            .with_context(|| format!("registering cluster '{name}'"))?;
        let rc = RegisteredCluster { name: name.to_string(), nodes, net, signature, probe };
        self.registry.write().unwrap().insert(rc.name.clone(), rc);
        // republish so the snapshot's name index never resolves this
        // name through a stale signature (re-registration moves it)
        self.cache.sync_names(&self.name_map());
        Ok(signature)
    }

    /// The current name → signature mapping, for snapshot publication.
    fn name_map(&self) -> Vec<(String, ClusterSignature)> {
        self.registry
            .read()
            .unwrap()
            .iter()
            .map(|(name, rc)| (name.clone(), rc.signature))
            .collect()
    }

    /// Register every cluster of a [`GridSpec`]: probe each island's own
    /// network parameters on a 2-node simulator of its `NetConfig` (the
    /// LogP benchmark procedure measures between two representative
    /// nodes; homogeneity makes that sufficient, §1).
    pub fn register_islands(&self, grid: &GridSpec) -> Result<Vec<ClusterSignature>> {
        grid.clusters
            .iter()
            .map(|c| {
                let mut sim = Netsim::new(2, c.net.clone());
                let net = bench::measure(&mut sim);
                self.register(&c.name, c.nodes, net)
            })
            .collect()
    }

    /// Blind wiring of the two companion papers' pipeline: recover the
    /// islands from latency probes (`topology::discover`), measure pLogP
    /// between the first two members of each island, and register them
    /// as `island-<i>`. Single-node islands have nothing to tune and are
    /// skipped.
    pub fn register_discovered(
        &self,
        sim: &mut Netsim,
        threshold_factor: f64,
    ) -> Vec<RegisteredCluster> {
        let d = crate::topology::discover::discover(sim, threshold_factor);
        let mut out = Vec::new();
        for c in 0..d.num_clusters {
            let members = d.members(c);
            if members.len() < 2 {
                log::warn!("island {c} has a single node; skipping (nothing to tune)");
                continue;
            }
            let net = bench::measure_pair(sim, members[0], members[1]);
            let name = format!("island-{c}");
            // a fault-degraded island probes degenerate parameters;
            // skip it (like the single-node case) instead of failing
            // the whole discovery pass
            match self.register_with_probe(&name, members.len(), net, (members[0], members[1])) {
                Ok(_) => out.push(self.cluster(&name).unwrap()),
                Err(e) => log::warn!("island {c} probed degenerate parameters ({e:#}); skipping"),
            }
        }
        out
    }

    /// Look up one registered cluster.
    pub fn cluster(&self, name: &str) -> Option<RegisteredCluster> {
        self.registry.read().unwrap().get(name).cloned()
    }

    /// All registered clusters, sorted by name.
    pub fn clusters(&self) -> Vec<RegisteredCluster> {
        let mut v: Vec<RegisteredCluster> =
            self.registry.read().unwrap().values().cloned().collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    // ---- the decision path --------------------------------------------

    /// Tables for a registered cluster (tuning on first use).
    pub fn tables(&self, cluster: &str) -> Result<Arc<TableSet>> {
        let rc = self
            .cluster(cluster)
            .with_context(|| format!("cluster '{cluster}' is not registered"))?;
        Ok(self.tables_for(rc.signature, &rc.net))
    }

    /// The full query API: strategy + segment + predicted time for one
    /// `(op, cluster, P, m)` point. When observability is enabled the
    /// end-to-end latency lands in `coordinator.decision_ns` and the
    /// decision itself in the flight recorder.
    ///
    /// The warm path is lock-free: one atomic pin of the published
    /// snapshot resolves the cluster name straight to its flattened
    /// [`super::snapshot::DenseTable`] — no registry `RwLock`, no
    /// cluster clone, no allocation. Only a cold or unindexed query
    /// falls back to the registry + coalesced tune path below.
    pub fn decision(&self, op: Op, cluster: &str, p: usize, m: u64) -> Result<Decision> {
        self.decision_versioned(op, cluster, p, m).map(|(d, _)| d)
    }

    /// [`Coordinator::decision`] plus the publish epoch the answer was
    /// computed from. The net layer serves this pair so remote clients
    /// can order decisions against `Invalidate` pushes (the protocol's
    /// ordering guarantee is stated in epochs, not frame arrival order —
    /// see docs/PROTOCOL.md).
    pub fn decision_versioned(
        &self,
        op: Op,
        cluster: &str,
        p: usize,
        m: u64,
    ) -> Result<(Decision, u64)> {
        self.decision_full(op, cluster, p, m).map(|(d, e, _)| (d, e))
    }

    /// [`Coordinator::decision_versioned`] plus where on the
    /// degradation ladder the answer came from. A source other than
    /// [`DecisionSource::Fresh`] means tuning failed and the
    /// coordinator degraded instead of erroring; the same fact lands in
    /// the flight recorder and the `coordinator.{stale,fallback}_serves`
    /// counters.
    pub fn decision_full(
        &self,
        op: Op,
        cluster: &str,
        p: usize,
        m: u64,
    ) -> Result<(Decision, u64, DecisionSource)> {
        let t0 = obs::timer_start();
        let warm = {
            let _read = Span::start("coordinator.decision.cache_read_ns");
            self.cache.warm_decide(cluster, op, p, m)
        };
        if let Some((d, signature, epoch)) = warm {
            if let Some(t0) = t0 {
                obs::registry().counter("coordinator.cache_hits").inc();
                self.trace_decision(t0, signature, op, DecisionOutcome::Hit, &d);
            }
            return Ok((d, epoch, DecisionSource::Fresh));
        }
        let rc = self
            .cluster(cluster)
            .with_context(|| format!("cluster '{cluster}' is not registered"))?;
        let (tables, outcome) = self.tables_for_traced(rc.signature, &rc.net);
        let d = tables.decision(op, p, m);
        // The cold path has no single snapshot pin to read an epoch
        // from; the cache's current epoch is a safe (conservative,
        // never-newer-than-the-tables) stamp because the leader
        // published the tables before we got here.
        let epoch = self.cache.epoch();
        if let Some(t0) = t0 {
            self.trace_decision(t0, rc.signature, op, outcome, &d);
        }
        let source = match outcome {
            DecisionOutcome::Stale => DecisionSource::Stale,
            DecisionOutcome::Fallback => DecisionSource::Fallback,
            _ => DecisionSource::Fresh,
        };
        Ok((d, epoch, source))
    }

    /// Warm-path-only read: answer from the published snapshot or
    /// return `None` — never tune, never block on an in-flight run.
    /// This is what the net layer's push notifier uses to recompute a
    /// subscriber's decisions after a publish: a notifier must not be
    /// drafted into tuner work.
    pub fn warm_decision(
        &self,
        cluster: &str,
        op: Op,
        p: usize,
        m: u64,
    ) -> Option<(Decision, u64)> {
        self.cache.warm_decide(cluster, op, p, m).map(|(d, _, epoch)| (d, epoch))
    }

    /// The cache's current publish epoch (0 before any publish;
    /// monotonic under the publish lock).
    pub fn epoch(&self) -> u64 {
        self.cache.epoch()
    }

    // ---- publish watchers ----------------------------------------------

    /// Subscribe to table-publication events: every tune completion,
    /// drift re-tune, warm start, and invalidation sends one
    /// [`PublishEvent`] after its snapshot is published. Events are
    /// delivered on an unbounded channel in publish order per writer;
    /// use the carried `epoch` (not arrival order) to order them
    /// globally. Dropping the receiver unsubscribes.
    pub fn watch_publishes(&self) -> mpsc::Receiver<PublishEvent> {
        let (tx, rx) = mpsc::channel();
        self.watchers.lock().unwrap().push(tx);
        rx
    }

    /// Fan one publication event out to every live watcher, pruning
    /// disconnected ones. Called *after* the cache publish, so a watcher
    /// that re-reads on receipt observes the new snapshot (or a newer
    /// one — epochs disambiguate).
    fn notify_publish(&self, kind: PublishKind, signature: ClusterSignature) {
        let mut watchers = self.watchers.lock().unwrap();
        if watchers.is_empty() {
            return;
        }
        let ev = PublishEvent { kind, signature, epoch: self.cache.epoch() };
        watchers.retain(|tx| tx.send(ev.clone()).is_ok());
    }

    /// Record one resolved decision into the latency histogram, the
    /// decisions counter, and the flight recorder (obs already known to
    /// be enabled: the caller holds a live `timer_start`).
    fn trace_decision(
        &self,
        t0: Instant,
        signature: ClusterSignature,
        op: Op,
        outcome: DecisionOutcome,
        d: &Decision,
    ) {
        let latency_ns = u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
        let reg = obs::registry();
        reg.histogram("coordinator.decision_ns").record(latency_ns);
        reg.counter("coordinator.decisions").inc();
        let fr = obs::flight();
        fr.record(DecisionEvent {
            ts_ns: fr.now_ns(),
            signature: signature.key(),
            op: op.name(),
            outcome,
            strategy: d.strategy.name(),
            segment: d.segment,
            latency_ns,
        });
    }

    /// Tables for an explicit signature/parameter pair. Cache hit → one
    /// lock-free snapshot read. Cache miss → coalesced tuner run: the
    /// first thread in tunes, every concurrent caller of the same
    /// signature blocks on that run instead of starting its own.
    pub fn tables_for(&self, signature: ClusterSignature, net: &PLogP) -> Arc<TableSet> {
        self.tables_for_traced(signature, net).0
    }

    /// [`Coordinator::tables_for`] plus how the lookup resolved, with
    /// each phase timed into its own histogram when observability is on
    /// (`coordinator.decision.{cache_read,coalesce_wait,tune}_ns`).
    fn tables_for_traced(
        &self,
        signature: ClusterSignature,
        net: &PLogP,
    ) -> (Arc<TableSet>, DecisionOutcome) {
        let cached = {
            let _read = Span::start("coordinator.decision.cache_read_ns");
            self.cache.get(&signature)
        };
        if let Some(t) = cached {
            if obs::enabled() {
                obs::registry().counter("coordinator.cache_hits").inc();
            }
            return (t, DecisionOutcome::Hit);
        }
        let (flight, leader) = {
            let mut map = self.inflight.lock().unwrap();
            // Re-check under the lock: a finishing leader publishes to
            // the cache *before* retiring its in-flight entry, so if the
            // entry is gone the table is already visible here. `peek`
            // keeps the hit/miss counters honest — the logical miss was
            // already counted by the `get` above.
            if let Some(t) = self.cache.peek(&signature) {
                if obs::enabled() {
                    obs::registry().counter("coordinator.cache_hits").inc();
                }
                return (t, DecisionOutcome::Hit);
            }
            match map.get(&signature) {
                Some(f) => (Arc::clone(f), false),
                None => {
                    let f = Arc::new(Inflight::default());
                    map.insert(signature, Arc::clone(&f));
                    (f, true)
                }
            }
        };
        if leader {
            if obs::enabled() {
                obs::registry().counter("coordinator.cache_misses").inc();
            }
            let _tune = Span::start("coordinator.decision.tune_ns");
            let (tables, outcome) = match self.tune_now(net) {
                Ok(t) => {
                    let tables = Arc::new(t);
                    self.cache.insert(signature, Arc::clone(&tables), &self.name_map());
                    self.notify_publish(PublishKind::Updated, signature);
                    if obs::enabled() {
                        obs::registry().gauge("coordinator.degraded_mode").set(0);
                    }
                    (tables, DecisionOutcome::Miss)
                }
                // Degraded answers are deliberately NOT published to
                // the cache: the next cold query retries the tune
                // instead of laundering stale tables into fresh ones.
                Err(e) => self.degraded_tables(signature, net, &e),
            };
            *flight.result.lock().unwrap() = Some((Arc::clone(&tables), outcome));
            flight.ready.notify_all();
            self.inflight.lock().unwrap().remove(&signature);
            (tables, outcome)
        } else {
            if obs::enabled() {
                obs::registry().counter("coordinator.coalesced_waits").inc();
            }
            let _wait = Span::start("coordinator.decision.coalesce_wait_ns");
            let mut guard = flight.result.lock().unwrap();
            while guard.is_none() {
                guard = flight.ready.wait(guard).unwrap();
            }
            let (tables, leader_outcome) = guard.as_ref().unwrap();
            // A follower of a degraded leader got degraded tables too;
            // report that, not a comforting "coalesced".
            let outcome = if leader_outcome.is_degraded() {
                *leader_outcome
            } else {
                DecisionOutcome::Coalesced
            };
            (Arc::clone(tables), outcome)
        }
    }

    /// The degradation ladder, walked when a tune fails: the stale
    /// shelf (retired tables younger than the staleness bound), then a
    /// last-resort [`crate::eval::ModelEval`] tune via
    /// [`Tuner::native`], which cannot fail. Counts into
    /// `coordinator.{stale,fallback}_serves` and raises the
    /// `coordinator.degraded_mode` gauge.
    fn degraded_tables(
        &self,
        signature: ClusterSignature,
        net: &PLogP,
        err: &anyhow::Error,
    ) -> (Arc<TableSet>, DecisionOutcome) {
        if let Some(tables) = self.shelved(&signature) {
            self.stale_serves.fetch_add(1, Ordering::Relaxed);
            log::warn!(
                "tune for {} failed ({err:#}); serving retired tables from the stale shelf",
                signature.key()
            );
            if obs::enabled() {
                let reg = obs::registry();
                reg.counter("coordinator.stale_serves").inc();
                reg.gauge("coordinator.degraded_mode").set(1);
            }
            return (tables, DecisionOutcome::Stale);
        }
        self.fallback_serves.fetch_add(1, Ordering::Relaxed);
        log::warn!(
            "tune for {} failed ({err:#}) with no stale tables on the shelf; \
             serving a local model fallback",
            signature.key()
        );
        if obs::enabled() {
            let reg = obs::registry();
            reg.counter("coordinator.fallback_serves").inc();
            reg.gauge("coordinator.degraded_mode").set(1);
        }
        let fallback = self.local_tuner();
        let tables = fallback
            .tune_all(net, &self.cfg.p_grid, &self.cfg.m_grid)
            .expect("native tuner is infallible");
        self.tuner.merge_stats(&fallback.stats());
        (Arc::new(TableSet::new(tables)), DecisionOutcome::Fallback)
    }

    /// The infallible local model tuner the degradation ladder and the
    /// artifact-failure path substitute in. Carries the configured
    /// correction table so degraded answers agree with fresh ones.
    fn local_tuner(&self) -> Tuner {
        match &self.corrections {
            Some(table) => Tuner::corrected(table.clone()),
            None => Tuner::native(),
        }
        .jobs(self.cfg.jobs)
    }

    /// Stale-shelf lookup, pruning entries past the staleness bound on
    /// the way (the shelf stays bounded by live signatures).
    fn shelved(&self, signature: &ClusterSignature) -> Option<Arc<TableSet>> {
        let mut shelf = self.stale_shelf.lock().unwrap();
        shelf.retain(|_, (_, retired)| retired.elapsed() <= self.cfg.max_staleness);
        shelf.get(signature).map(|(t, _)| Arc::clone(t))
    }

    /// Make the next `n` tuner runs fail. Deterministic — a countdown,
    /// not a probability — so chaos tests and the bench's degraded
    /// phase replay exactly. Production never calls this.
    pub fn inject_tune_failures(&self, n: u64) {
        self.fail_next_tunes.fetch_add(n, Ordering::Relaxed);
    }

    /// Run the tuner for every op family (counted; this is what
    /// miss-coalescing avoids). One run produces the whole [`TableSet`],
    /// so a single cold miss covers broadcast, scatter, and all the
    /// extended collectives. Fails only when a failure was injected
    /// (the artifact backend already falls back to native internally);
    /// the caller walks the degradation ladder.
    fn tune_now(&self, net: &PLogP) -> Result<TableSet> {
        let mut pending = self.fail_next_tunes.load(Ordering::Relaxed);
        while pending > 0 {
            match self.fail_next_tunes.compare_exchange(
                pending,
                pending - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.tune_failures.fetch_add(1, Ordering::Relaxed);
                    if obs::enabled() {
                        obs::registry().counter("coordinator.tune_failures").inc();
                    }
                    bail!("injected tune failure ({} more pending)", pending - 1);
                }
                Err(now) => pending = now,
            }
        }
        self.tunes.fetch_add(1, Ordering::Relaxed);
        let tables = match self.tuner.tune_all(net, &self.cfg.p_grid, &self.cfg.m_grid) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("artifact tuner failed ({e:#}); re-tuning with native models");
                let fallback = self.local_tuner();
                let tables = fallback
                    .tune_all(net, &self.cfg.p_grid, &self.cfg.m_grid)
                    .expect("native tuner is infallible");
                // keep the service's cumulative eval counters honest:
                // this run's sweep work happened on the fallback tuner
                self.tuner.merge_stats(&fallback.stats());
                tables
            }
        };
        Ok(TableSet::new(tables))
    }

    /// Re-tune a signature right now and atomically publish the result
    /// (the refresh policy's swap; readers only ever see the old or the
    /// new snapshot, never a partial table). A failed re-tune degrades
    /// (stale shelf, then model fallback) without publishing.
    pub(super) fn force_retune(&self, signature: ClusterSignature, net: &PLogP) -> Arc<TableSet> {
        match self.tune_now(net) {
            Ok(t) => {
                let tables = Arc::new(t);
                self.cache.insert(signature, Arc::clone(&tables), &self.name_map());
                self.notify_publish(PublishKind::Updated, signature);
                if obs::enabled() {
                    obs::registry().gauge("coordinator.degraded_mode").set(0);
                }
                tables
            }
            Err(e) => self.degraded_tables(signature, net, &e).0,
        }
    }

    /// Drop a cached signature (refresh retires drifted tables). The
    /// retired tables move to the stale shelf first, so a later failed
    /// tune can still answer from them within the staleness bound.
    pub(super) fn evict_signature(&self, signature: &ClusterSignature) -> bool {
        if let Some(tables) = self.cache.peek(signature) {
            self.stale_shelf
                .lock()
                .unwrap()
                .insert(*signature, (tables, Instant::now()));
        }
        let removed = self.cache.remove(signature, &self.name_map());
        if removed {
            self.notify_publish(PublishKind::Invalidated, *signature);
        }
        removed
    }

    /// Drop `cluster`'s cached tables, if resident: the next query for
    /// its signature re-tunes. Returns whether anything was evicted.
    /// Like every cache write this publishes a fresh snapshot —
    /// concurrent readers keep answering from the one they pinned and
    /// are never blocked.
    pub fn invalidate(&self, cluster: &str) -> bool {
        match self.cluster(cluster) {
            Some(rc) => self.evict_signature(&rc.signature),
            None => false,
        }
    }

    // ---- observability -------------------------------------------------

    pub fn stats(&self) -> CoordinatorStats {
        CoordinatorStats {
            cache: self.cache.stats(),
            tunes: self.tunes.load(Ordering::Relaxed),
            tune_failures: self.tune_failures.load(Ordering::Relaxed),
            stale_serves: self.stale_serves.load(Ordering::Relaxed),
            fallback_serves: self.fallback_serves.load(Ordering::Relaxed),
            registered: self.registry.read().unwrap().len(),
            eval: self.tuner.stats(),
        }
    }

    /// Actual tuner executions so far.
    pub fn tune_count(&self) -> u64 {
        self.tunes.load(Ordering::Relaxed)
    }

    /// Every service counter as one [`Json`] value — the cache
    /// hit/miss path *and* the per-tune sweep counters.
    pub fn stats_to_json(&self) -> Json {
        let st = self.stats();
        Json::obj(vec![
            ("backend", Json::str(self.backend_name())),
            ("registered", Json::from(st.registered)),
            ("tunes", Json::from(st.tunes)),
            (
                "cache",
                Json::obj(vec![
                    ("entries", Json::from(st.cache.entries)),
                    ("hits", Json::from(st.cache.hits)),
                    ("misses", Json::from(st.cache.misses)),
                    ("evictions", Json::from(st.cache.evictions)),
                ]),
            ),
            (
                "degraded",
                Json::obj(vec![
                    ("tune_failures", Json::from(st.tune_failures)),
                    ("stale_serves", Json::from(st.stale_serves)),
                    ("fallback_serves", Json::from(st.fallback_serves)),
                ]),
            ),
            ("eval", st.eval.to_json_value()),
        ])
    }

    /// Every service counter in one JSON blob — rendered through the
    /// shared [`crate::util::json`] writer (no hand-rolled formatting),
    /// so a running `serve` instance (or `query --stats`) reports its
    /// whole cost picture in one machine-readable line. Keys are
    /// unchanged from the hand-formatted original (objects serialize
    /// with sorted keys).
    pub fn stats_json(&self) -> String {
        self.stats_to_json().to_string()
    }

    // ---- persistence ---------------------------------------------------

    /// Save the registry and every cached table set under `dir`.
    /// Returns the number of table sets written. Values use Rust's
    /// shortest-roundtrip float formatting, so a warm start recomputes
    /// bit-identical signatures.
    pub fn persist_to(&self, dir: &Path) -> Result<usize> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let mut manifest = String::from(MANIFEST_HEADER);
        manifest.push('\n');
        for rc in self.clusters() {
            let sizes: Vec<String> =
                rc.net.table.sizes().iter().map(|x| x.to_string()).collect();
            let gaps: Vec<String> =
                rc.net.table.gaps().iter().map(|x| x.to_string()).collect();
            manifest.push_str(&format!(
                "cluster\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                rc.name,
                rc.nodes,
                rc.probe.0,
                rc.probe.1,
                rc.net.l,
                sizes.join(","),
                gaps.join(",")
            ));
        }
        std::fs::write(dir.join("manifest.tsv"), manifest)
            .with_context(|| format!("writing {}", dir.join("manifest.tsv").display()))?;
        let mut saved = 0usize;
        for (sig, tables) in self.cache.snapshot() {
            for table in tables.tables() {
                let name = format!("{}.{}.tsv", sig.key(), table.op.name());
                persist::save(table, &dir.join(name))?;
            }
            saved += 1;
        }
        Ok(saved)
    }

    /// Warm-start from a directory of captured traces (the `record`
    /// CLI subcommand's output): replay-tune one [`TableSet`] over the
    /// captured grids, register the captured network as `cluster`, and
    /// pre-warm the cache with the result — tuned tables grounded in a
    /// *recorded* workload rather than a live backend. Requires full op
    /// coverage (`record --op all`) and full strategy coverage of the
    /// captured grid: any cell whose every candidate went unobserved
    /// would tune to `+inf`, and serving that is refused loudly.
    pub fn warm_start_from_traces(&self, dir: &Path, cluster: &str) -> Result<ClusterSignature> {
        let replay = ReplayEval::load(dir)?;
        let captured_ops = replay.set().ops();
        for op in Op::ALL {
            if !captured_ops.iter().any(|o| o == op.name()) {
                bail!(
                    "{}: no '{}' traces captured; a coordinator warm start needs every \
                     op family (re-record with --op all)",
                    dir.display(),
                    op.name()
                );
            }
        }
        let p_grid = replay.set().p_values();
        let m_grid = replay.set().m_values();
        let nodes = replay.set().max_p().expect("non-empty set");
        let net = replay.net().clone();
        let tuner = Tuner::with_evaluator(Box::new(replay)).jobs(self.cfg.jobs);
        let tables = tuner.tune_all(&net, &p_grid, &m_grid)?;
        for table in &tables {
            for (i, d) in table.entries.iter().enumerate() {
                if !d.predicted.is_finite() {
                    bail!(
                        "{}: captured traces cover no '{}' strategy at grid cell \
                         (P={}, m={}) — refusing to warm-start from an unobserved cell",
                        dir.display(),
                        table.op.name(),
                        table.p_grid[i / table.m_grid.len()],
                        table.m_grid[i % table.m_grid.len()]
                    );
                }
            }
        }
        let sig = self.register(cluster, nodes, net)?;
        self.cache.insert(sig, Arc::new(TableSet::new(tables)), &self.name_map());
        self.notify_publish(PublishKind::Updated, sig);
        Ok(sig)
    }

    /// Load a directory written by [`Coordinator::persist_to`]:
    /// re-register every cluster and pre-warm the cache with every table
    /// set found on disk. Returns the number of table sets loaded.
    pub fn warm_start_from(&self, dir: &Path) -> Result<usize> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut lines = text.lines();
        if lines.next() != Some(MANIFEST_HEADER) {
            bail!("{} is not a coordinator manifest", path.display());
        }
        let mut loaded = 0usize;
        for (ln, line) in lines.enumerate() {
            let mut f = line.split('\t');
            match f.next() {
                Some("cluster") => {
                    let name = f.next().context("cluster name")?;
                    let nodes: usize = f.next().context("node count")?.parse()?;
                    let probe_a: NodeId = f.next().context("probe src")?.parse()?;
                    let probe_b: NodeId = f.next().context("probe dst")?.parse()?;
                    let l: f64 = f.next().context("latency")?.parse()?;
                    let sizes = parse_f64_csv(f.next().context("gap sizes")?)?;
                    let gaps = parse_f64_csv(f.next().context("gap values")?)?;
                    let net = PLogP::new(l, GapTable::new(sizes, gaps));
                    let sig = self.register_with_probe(name, nodes, net, (probe_a, probe_b))?;
                    let paths: Vec<PathBuf> = Op::ALL
                        .iter()
                        .map(|op| dir.join(format!("{}.{}.tsv", sig.key(), op.name())))
                        .collect();
                    // warm only complete sets: a partial directory (e.g.
                    // written before the extended ops existed) re-tunes
                    // lazily instead of serving half-initialized state
                    if paths.iter().all(|p| p.exists()) && !self.cache.contains(&sig) {
                        let tables = paths
                            .iter()
                            .map(|p| persist::load(p))
                            .collect::<Result<Vec<_>>>()?;
                        // a structured error (not the TableSet invariant
                        // panic) when a file's op record contradicts its
                        // filename — hand-edited or miscopied tables
                        for (op, t) in Op::ALL.iter().zip(&tables) {
                            if t.op != *op {
                                bail!(
                                    "{}: table declares op '{}' but the filename says '{}'",
                                    paths[op.index()].display(),
                                    t.op.name(),
                                    op.name()
                                );
                            }
                        }
                        self.cache.insert(sig, Arc::new(TableSet::new(tables)), &self.name_map());
                        self.notify_publish(PublishKind::Updated, sig);
                        loaded += 1;
                    }
                }
                Some("") | None => {}
                Some(other) => bail!("line {}: unknown record '{other}'", ln + 2),
            }
        }
        Ok(loaded)
    }
}

fn parse_f64_csv(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|t| t.trim().parse::<f64>().with_context(|| format!("bad float '{t}'")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetConfig;
    use crate::topology::ClusterSpec;

    fn small_config() -> CoordinatorConfig {
        CoordinatorConfig {
            shards: 2,
            capacity_per_shard: 4,
            p_grid: vec![2, 8, 24],
            m_grid: grids::log_grid(1, 1 << 20, 6),
            ..CoordinatorConfig::default()
        }
    }

    fn measured(cfg: NetConfig) -> PLogP {
        let mut sim = Netsim::new(2, cfg);
        bench::measure(&mut sim)
    }

    #[test]
    fn registering_a_fault_degraded_probe_errors_instead_of_panicking() {
        let c = Coordinator::new(small_config());
        // what a probe over a FaultPlan-degraded pair aggregates: an
        // infinite latency (dead/unreachable endpoint) alongside
        // otherwise healthy gap samples
        let net = PLogP {
            l: f64::INFINITY,
            table: GapTable::new(vec![1.0, 1024.0], vec![5e-6, 6e-6]),
        };
        let err = c.register("faulted", 8, net).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("degenerate probed latency"), "{chain}");
        assert!(chain.contains("'faulted'"), "{chain}");
        assert_eq!(c.stats().registered, 0, "a refused registration leaves no state");
        assert!(c.cluster("faulted").is_none());
    }

    #[test]
    fn unknown_cluster_is_an_error() {
        let c = Coordinator::new(small_config());
        let err = c.decision(Op::Bcast, "nowhere", 8, 1024).unwrap_err();
        assert!(err.to_string().contains("not registered"), "{err}");
    }

    #[test]
    fn decision_matches_direct_tuner_output() {
        let cfg = small_config();
        let c = Coordinator::new(cfg.clone());
        let net = measured(NetConfig::fast_ethernet_ideal());
        c.register("a", 24, net.clone()).unwrap();
        let want = {
            let (b, _) = Tuner::native().tune(&net, &cfg.p_grid, &cfg.m_grid).unwrap();
            *b.lookup(24, 65536)
        };
        let got = c.decision(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(got.strategy, want.strategy);
        assert_eq!(got.segment, want.segment);
        assert_eq!(c.tune_count(), 1);
    }

    #[test]
    fn equivalent_clusters_share_one_table() {
        let c = Coordinator::new(small_config());
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.register("b", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        let ta = c.tables("a").unwrap();
        let tb = c.tables("b").unwrap();
        assert!(Arc::ptr_eq(&ta, &tb), "same signature must share one Arc");
        assert_eq!(c.tune_count(), 1);
        assert_eq!(c.stats().registered, 2);
    }

    #[test]
    fn ext_decisions_match_direct_tuner_output_from_one_tune() {
        let cfg = small_config();
        let c = Coordinator::new(cfg.clone());
        let net = measured(NetConfig::fast_ethernet_ideal());
        c.register("a", 24, net.clone()).unwrap();
        let want = {
            let t = Tuner::native()
                .tune_op(Op::AllGather, &net, &cfg.p_grid, &cfg.m_grid)
                .unwrap();
            *t.lookup(24, 65536)
        };
        let got = c.decision(Op::AllGather, "a", 24, 65536).unwrap();
        assert_eq!(got.strategy, want.strategy);
        assert_eq!(got.predicted, want.predicted);
        // the one coalesced tuner run covers every op family
        for op in Op::ALL {
            let d = c.decision(op, "a", 16, 4096).unwrap();
            assert!(op.family().contains(&d.strategy), "{:?}", d);
        }
        assert_eq!(c.tune_count(), 1);
    }

    #[test]
    fn distinct_networks_tune_separately() {
        let c = Coordinator::new(small_config());
        c.register("fe", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.register("ge", 24, measured(NetConfig::gigabit_ethernet())).unwrap();
        let _ = c.tables("fe").unwrap();
        let _ = c.tables("ge").unwrap();
        assert_eq!(c.tune_count(), 2);
    }

    #[test]
    fn stats_json_reports_cache_and_eval_counters_together() {
        let c = Coordinator::new(small_config());
        c.register("a", 8, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.decision(Op::Bcast, "a", 8, 4096).unwrap();
        c.decision(Op::Bcast, "a", 8, 4096).unwrap();
        let json = c.stats_json();
        assert!(json.contains("\"backend\":\"native\""), "{json}");
        assert!(json.contains("\"tunes\":1"), "{json}");
        assert!(json.contains("\"hits\":"), "{json}");
        assert!(json.contains("\"model_invocations\":"), "{json}");
        // emitted through the shared util::json writer: the blob parses
        // back, and the original hand-formatted shape is intact
        let doc = crate::util::json::parse(&json).expect("stats_json is valid JSON");
        let crate::util::json::Json::Obj(top) = &doc else { panic!("not an object") };
        for key in ["backend", "registered", "tunes", "cache", "eval"] {
            assert!(top.contains_key(key), "missing '{key}' in {json}");
        }
        let crate::util::json::Json::Obj(cache) = &top["cache"] else { panic!() };
        for key in ["entries", "hits", "misses", "evictions"] {
            assert!(cache.contains_key(key), "missing cache '{key}' in {json}");
        }
        assert_eq!(top["tunes"], crate::util::json::Json::Num(1.0));
        // the native sweep actually ran: the eval counters are live
        let st = c.stats();
        assert!(st.eval.cells > 0, "{:?}", st.eval);
        assert!(st.eval.model_invocations > 0);
    }

    #[test]
    fn repeated_queries_hit_the_cache() {
        let c = Coordinator::new(small_config());
        c.register("a", 8, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        for _ in 0..10 {
            c.decision(Op::Scatter, "a", 8, 4096).unwrap();
        }
        assert_eq!(c.tune_count(), 1);
        let st = c.stats();
        assert!(st.cache.hits >= 9, "{st:?}");
    }

    #[test]
    fn warm_decisions_equal_slow_path_decisions() {
        // the dense-table fast path and a fresh tuner run must agree on
        // every probed query — the flattening is exact, not approximate
        let cfg = small_config();
        let c = Coordinator::new(cfg.clone());
        let net = measured(NetConfig::fast_ethernet_ideal());
        c.register("a", 24, net.clone()).unwrap();
        let tables = c.tables("a").unwrap(); // cold tune; warms the index
        for op in Op::ALL {
            for p in [1usize, 2, 7, 8, 24, 100] {
                for m in [1u64, 37, 4096, 65536, 1 << 20, 1 << 24] {
                    let warm = c.decision(op, "a", p, m).unwrap();
                    assert_eq!(warm, tables.decision(op, p, m), "{op:?} P={p} m={m}");
                }
            }
        }
        assert_eq!(c.tune_count(), 1, "every query above was a warm hit");
    }

    #[test]
    fn invalidate_drops_cached_tables_and_forces_a_retune() {
        let c = Coordinator::new(small_config());
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.decision(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(c.tune_count(), 1);
        assert!(c.invalidate("a"));
        assert!(!c.invalidate("a"), "second invalidation finds nothing resident");
        c.decision(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(c.tune_count(), 2, "invalidation forces a re-tune");
        assert!(!c.invalidate("ghost"), "unknown clusters are a no-op");
    }

    #[test]
    fn warm_start_from_traces_builds_served_tables_without_a_tuner_run() {
        use crate::harness::experiments::record_traces;

        let dir = std::env::temp_dir().join("ct-coord-trace-warm-test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = NetConfig::fast_ethernet_ideal();
        let p_grid = [2usize, 4, 8];
        let m_grid = [64u64, 4096];
        let (set, _net) = record_traces(&cfg, &Op::ALL, &p_grid, &m_grid, &[1024, 8192], 1 << 14);
        set.save_dir(&dir).unwrap();

        let c = Coordinator::new(small_config());
        let sig = c.warm_start_from_traces(&dir, "captured").unwrap();
        // served straight from the replay-tuned cache: no tuner run
        for op in Op::ALL {
            let d = c.decision(op, "captured", 4, 4096).unwrap();
            assert!(op.family().contains(&d.strategy), "{d:?}");
            assert!(d.predicted.is_finite() && d.predicted > 0.0);
        }
        assert_eq!(c.tune_count(), 0);
        assert_eq!(c.cluster("captured").unwrap().nodes, 8);
        assert!(c.cluster("captured").unwrap().signature == sig);

        // a partial capture (one op family missing) is refused loudly
        let partial = std::env::temp_dir().join("ct-coord-trace-warm-partial");
        let _ = std::fs::remove_dir_all(&partial);
        let (set, _) = record_traces(&cfg, &[Op::Bcast], &p_grid, &m_grid, &[1024, 8192], 1 << 14);
        set.save_dir(&partial).unwrap();
        let err = c.warm_start_from_traces(&partial, "partial").unwrap_err();
        assert!(err.to_string().contains("--op all"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&partial).ok();
    }

    #[test]
    fn watch_publishes_sees_tunes_and_invalidations_in_epoch_order() {
        let c = Coordinator::new(small_config());
        let sig = c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        let rx = c.watch_publishes();
        c.decision(Op::Bcast, "a", 24, 65536).unwrap(); // cold tune → Updated
        let ev = rx.try_recv().expect("tune completion notifies watchers");
        assert_eq!(ev.kind, PublishKind::Updated);
        assert_eq!(ev.signature, sig);
        assert!(ev.epoch >= 1);
        assert!(c.invalidate("a")); // → Invalidated
        let ev2 = rx.try_recv().expect("invalidation notifies watchers");
        assert_eq!(ev2.kind, PublishKind::Invalidated);
        assert_eq!(ev2.signature, sig);
        assert!(ev2.epoch > ev.epoch, "epochs are monotonic across publishes");
        assert!(rx.try_recv().is_err(), "no spurious events");
        // dropping the receiver unsubscribes without disturbing service
        drop(rx);
        c.decision(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(c.tune_count(), 2);
    }

    #[test]
    fn warm_decision_never_tunes() {
        let c = Coordinator::new(small_config());
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        assert!(c.warm_decision("a", Op::Bcast, 24, 65536).is_none(), "not resident");
        assert_eq!(c.tune_count(), 0, "warm_decision must not tune");
        let (want, epoch) = c.decision_versioned(Op::Bcast, "a", 24, 65536).unwrap();
        let (got, warm_epoch) = c.warm_decision("a", Op::Bcast, 24, 65536).unwrap();
        assert_eq!(got, want);
        assert!(warm_epoch >= epoch);
        assert_eq!(c.tune_count(), 1);
    }

    #[test]
    fn failed_tune_with_no_shelf_serves_a_model_fallback() {
        let c = Coordinator::new(small_config());
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.inject_tune_failures(1);
        let (d, _epoch, source) = c.decision_full(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(source, DecisionSource::Fallback, "no shelf entry exists yet");
        assert!(d.predicted.is_finite() && d.predicted > 0.0);
        assert_eq!(c.tune_count(), 0, "the failed run is not a tune");
        let st = c.stats();
        assert_eq!(st.tune_failures, 1);
        assert_eq!(st.fallback_serves, 1);
        assert_eq!(st.stale_serves, 0);
        // degraded answers are not cached: the next query tunes fresh
        let (d2, _, source2) = c.decision_full(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(source2, DecisionSource::Fresh);
        assert_eq!(c.tune_count(), 1);
        // the fallback is the native model tuner, so the answers agree
        assert_eq!(d, d2, "ModelEval fallback equals the native tune");
    }

    #[test]
    fn failed_tune_after_eviction_serves_stale_within_bound() {
        let c = Coordinator::new(small_config());
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        let fresh = c.decision(Op::Bcast, "a", 24, 65536).unwrap();
        assert!(c.invalidate("a"), "eviction moves tables to the stale shelf");
        c.inject_tune_failures(1);
        let (d, _epoch, source) = c.decision_full(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(source, DecisionSource::Stale);
        assert_eq!(d, fresh, "stale serve answers from the retired tables");
        let st = c.stats();
        assert_eq!(st.stale_serves, 1);
        assert_eq!(st.fallback_serves, 0);
        assert_eq!(c.tune_count(), 1, "only the original tune ran");
        // recovery: the injection is spent, so the service re-tunes
        let (_, _, source2) = c.decision_full(Op::Scatter, "a", 8, 1024).unwrap();
        assert_eq!(source2, DecisionSource::Fresh);
        assert_eq!(c.tune_count(), 2);
    }

    #[test]
    fn stale_shelf_respects_the_staleness_bound() {
        let cfg = CoordinatorConfig {
            max_staleness: Duration::from_millis(0), // everything is too old
            ..small_config()
        };
        let c = Coordinator::new(cfg);
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.decision(Op::Bcast, "a", 24, 65536).unwrap();
        assert!(c.invalidate("a"));
        std::thread::sleep(Duration::from_millis(5));
        c.inject_tune_failures(1);
        let (_, _, source) = c.decision_full(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(
            source,
            DecisionSource::Fallback,
            "shelved tables past the bound must not be served"
        );
        assert_eq!(c.stats().stale_serves, 0);
    }

    #[test]
    fn coalesced_followers_of_a_degraded_leader_report_degraded() {
        // Serial sanity for the Inflight contract (the concurrent
        // version lives in the stress suite): the leader's degraded
        // outcome must flow through decision_full's source mapping.
        let c = Coordinator::new(small_config());
        c.register("a", 24, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.inject_tune_failures(2);
        let (_, _, s1) = c.decision_full(Op::Bcast, "a", 24, 65536).unwrap();
        let (_, _, s2) = c.decision_full(Op::Bcast, "a", 24, 65536).unwrap();
        assert_eq!(s1, DecisionSource::Fallback);
        assert_eq!(s2, DecisionSource::Fallback);
        assert_eq!(c.stats().fallback_serves, 2);
        assert_eq!(c.stats().tune_failures, 2);
    }

    #[test]
    fn stats_json_carries_the_degraded_block() {
        let c = Coordinator::new(small_config());
        c.register("a", 8, measured(NetConfig::fast_ethernet_ideal())).unwrap();
        c.inject_tune_failures(1);
        c.decision(Op::Bcast, "a", 8, 4096).unwrap();
        let json = c.stats_json();
        let doc = crate::util::json::parse(&json).expect("valid JSON");
        let crate::util::json::Json::Obj(top) = &doc else { panic!("not an object") };
        let crate::util::json::Json::Obj(deg) = &top["degraded"] else {
            panic!("missing degraded block in {json}")
        };
        assert_eq!(deg["tune_failures"], crate::util::json::Json::Num(1.0));
        assert_eq!(deg["fallback_serves"], crate::util::json::Json::Num(1.0));
        assert_eq!(deg["stale_serves"], crate::util::json::Json::Num(0.0));
    }

    #[test]
    fn register_islands_covers_a_grid() {
        let grid = GridSpec::new(
            vec![
                ClusterSpec::new("alpha", 5, NetConfig::fast_ethernet_ideal()),
                ClusterSpec::new("beta", 3, NetConfig::gigabit_ethernet()),
            ],
            NetConfig::wan_link(),
        );
        let c = Coordinator::new(small_config());
        let sigs = c.register_islands(&grid).unwrap();
        assert_eq!(sigs.len(), 2);
        assert_ne!(sigs[0], sigs[1]);
        assert!(c.cluster("alpha").is_some());
        assert!(c.cluster("beta").is_some());
    }

    #[test]
    fn register_discovered_finds_and_measures_islands() {
        let grid = GridSpec::new(
            vec![
                ClusterSpec::new("a", 4, NetConfig::fast_ethernet_ideal()),
                ClusterSpec::new("b", 4, NetConfig::fast_ethernet_ideal()),
            ],
            NetConfig::wan_link(),
        );
        let mut sim = grid.build_sim();
        let c = Coordinator::new(small_config());
        let found = c.register_discovered(&mut sim, 3.0);
        assert_eq!(found.len(), 2);
        assert_eq!(found[0].nodes, 4);
        // both islands are the same hardware: one signature, one tune
        let _ = c.tables("island-0").unwrap();
        let _ = c.tables("island-1").unwrap();
        assert_eq!(c.tune_count(), 1);
    }
}
