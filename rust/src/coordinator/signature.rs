//! Cluster signatures: a quantized fingerprint of one network.
//!
//! The paper tunes once per network and serves decisions statically
//! (§5). Two clusters whose pLogP parameters agree to within measurement
//! noise should therefore *share* one decision table rather than tune
//! twice — homogeneous islands of the same hardware generation are the
//! common case in the grids both companion papers target. A
//! [`ClusterSignature`] quantizes the parameters that actually enter the
//! cost models (`L` and `g(m)` at a fixed set of probe sizes) into
//! multiplicative buckets, together with the node count and the op set,
//! so equivalence is a plain `Eq`/`Hash` and the coordinator's cache can
//! key on it.

use crate::plogp::PLogP;

/// Default quantization tolerance: parameters within ±5 % land in the
/// same bucket (the pLogP benchmark's run-to-run noise is below this on
/// the simulated testbed; see `plogp::bench` tests).
pub const DEFAULT_TOLERANCE: f64 = 0.05;

/// Gap-table probe sizes entering the fingerprint (bytes, as f64 for
/// [`PLogP::gap`]): 1 B, 1 KiB, 64 KiB, 1 MiB, 4 MiB — the span the
/// tuner's m-grid and s-grid actually exercise.
pub const PROBE_SIZES: [f64; 5] = [1.0, 1024.0, 65536.0, 1048576.0, 4194304.0];

/// Op-set bit: the signature covers broadcast tables.
pub const OPS_BCAST: u8 = 1 << 0;
/// Op-set bit: the signature covers scatter tables.
pub const OPS_SCATTER: u8 = 1 << 1;
/// Op-set bit: gather tables.
pub const OPS_GATHER: u8 = 1 << 2;
/// Op-set bit: reduce tables.
pub const OPS_REDUCE: u8 = 1 << 3;
/// Op-set bit: barrier tables.
pub const OPS_BARRIER: u8 = 1 << 4;
/// Op-set bit: allgather tables.
pub const OPS_ALLGATHER: u8 = 1 << 5;
/// Op-set bit: allreduce tables.
pub const OPS_ALLREDUCE: u8 = 1 << 6;
/// Every collective family (what [`super::service::TableSet`] holds —
/// one bit per [`crate::tuner::Op::ALL`] entry).
pub const OPS_ALL: u8 = OPS_BCAST
    | OPS_SCATTER
    | OPS_GATHER
    | OPS_REDUCE
    | OPS_BARRIER
    | OPS_ALLGATHER
    | OPS_ALLREDUCE;

/// Why a cluster signature could not be computed: a probed pLogP
/// parameter was degenerate. Reachable in production — a
/// [`crate::netsim::FaultPlan`]'s dead nodes or degraded links can
/// drive a probe's measured latency or gap to zero or infinity, and the
/// coordinator must refuse such a registration instead of panicking.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SignatureError {
    /// The probed one-way latency `L` was non-positive or non-finite.
    DegenerateLatency { value: f64 },
    /// The probed gap at `probe` bytes was non-positive or non-finite.
    DegenerateGap { probe: f64, value: f64 },
}

impl std::fmt::Display for SignatureError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SignatureError::DegenerateLatency { value } => write!(
                f,
                "degenerate probed latency L = {value}: cannot fingerprint this network \
                 (dead or unreachable probe endpoints?)"
            ),
            SignatureError::DegenerateGap { probe, value } => write!(
                f,
                "degenerate probed gap g({probe}) = {value}: cannot fingerprint this \
                 network (faulted or saturated link?)"
            ),
        }
    }
}

impl std::error::Error for SignatureError {}

/// Quantize `x > 0` into a multiplicative bucket: values within a factor
/// of `(1 + tol)` of each other map to the same or adjacent buckets, and
/// values differing by less than ~`tol/2` around a bucket center map to
/// the same bucket. Panics on a degenerate `x`; probe-derived values go
/// through [`try_bucket`].
pub fn bucket(x: f64, tol: f64) -> i64 {
    try_bucket(x, tol)
        .unwrap_or_else(|| panic!("bucket() needs a positive finite value, got {x}"))
}

/// Fallible form of [`bucket`]: `None` when `x` is non-positive or
/// non-finite — a faulted probe can legitimately report a dead link as
/// a zero, negative, or infinite parameter, and the signature path must
/// surface that as an error rather than a panic.
pub fn try_bucket(x: f64, tol: f64) -> Option<i64> {
    assert!(tol > 0.0, "tolerance must be positive");
    (x > 0.0 && x.is_finite()).then(|| (x.ln() / (1.0 + tol).ln()).round() as i64)
}

/// The quantized fingerprint of one cluster's network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClusterSignature {
    /// Number of nodes in the cluster.
    pub nodes: usize,
    /// Which operation families the tables cover ([`OPS_ALL`] today).
    pub ops: u8,
    /// Quantized one-way latency `L`.
    pub l_bucket: i64,
    /// Quantized `g(m)` at each of [`PROBE_SIZES`].
    pub gap_buckets: [i64; 5],
}

impl ClusterSignature {
    /// Fingerprint with the default tolerance. Panics on degenerate
    /// parameters — probe-derived networks go through [`Self::try_of`].
    pub fn of(net: &PLogP, nodes: usize) -> ClusterSignature {
        ClusterSignature::with_tolerance(net, nodes, DEFAULT_TOLERANCE)
    }

    /// Fingerprint with an explicit quantization tolerance (panicking
    /// convenience over [`Self::try_with_tolerance`]).
    pub fn with_tolerance(net: &PLogP, nodes: usize, tol: f64) -> ClusterSignature {
        ClusterSignature::try_with_tolerance(net, nodes, tol).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible fingerprint with the default tolerance.
    pub fn try_of(net: &PLogP, nodes: usize) -> Result<ClusterSignature, SignatureError> {
        ClusterSignature::try_with_tolerance(net, nodes, DEFAULT_TOLERANCE)
    }

    /// Fallible fingerprint: a structured [`SignatureError`] instead of
    /// a panic when a probed parameter is degenerate (the coordinator's
    /// registration path, where fault-degraded probes are expected).
    pub fn try_with_tolerance(
        net: &PLogP,
        nodes: usize,
        tol: f64,
    ) -> Result<ClusterSignature, SignatureError> {
        assert!(nodes >= 1);
        let l_bucket =
            try_bucket(net.l, tol).ok_or(SignatureError::DegenerateLatency { value: net.l })?;
        let mut gap_buckets = [0i64; 5];
        for (i, &m) in PROBE_SIZES.iter().enumerate() {
            let g = net.gap(m);
            gap_buckets[i] =
                try_bucket(g, tol).ok_or(SignatureError::DegenerateGap { probe: m, value: g })?;
        }
        Ok(ClusterSignature { nodes, ops: OPS_ALL, l_bucket, gap_buckets })
    }

    /// Stable, filesystem-safe key for persistence
    /// (`sig-p<nodes>-o<ops>-l<bucket>-g<b0>_<b1>_...`).
    pub fn key(&self) -> String {
        let gaps: Vec<String> = self.gap_buckets.iter().map(|b| b.to_string()).collect();
        format!(
            "sig-p{}-o{}-l{}-g{}",
            self.nodes,
            self.ops,
            self.l_bucket,
            gaps.join("_")
        )
    }
}

/// Maximum relative difference between two parameter sets, over `L` and
/// `g(m)` at the probe sizes — the scalar the refresh policy thresholds
/// on to decide whether a network has drifted enough to re-tune.
pub fn drift(baseline: &PLogP, fresh: &PLogP) -> f64 {
    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-12);
    let mut d = rel(baseline.l, fresh.l);
    for m in PROBE_SIZES {
        d = d.max(rel(baseline.gap(m), fresh.gap(m)));
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp::{bench, GapTable};

    fn measured(cfg: NetConfig) -> PLogP {
        let mut sim = Netsim::new(2, cfg);
        bench::measure(&mut sim)
    }

    #[test]
    fn bucket_groups_within_tolerance_and_splits_beyond() {
        // ln(1.02)/ln(1.05) ≈ 0.41 -> rounds to 0, same bucket as 1.0
        assert_eq!(bucket(1.0, 0.05), bucket(1.02, 0.05));
        // a factor of 2 is ~14 buckets away at 5 %
        assert_ne!(bucket(1.0, 0.05), bucket(2.0, 0.05));
        assert!(bucket(2.0, 0.05) > bucket(1.0, 0.05) + 10);
    }

    #[test]
    fn try_bucket_rejects_degenerate_values_without_panicking() {
        for bad in [0.0, -1.0, f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            assert_eq!(try_bucket(bad, 0.05), None, "{bad}");
        }
        assert_eq!(try_bucket(1.02, 0.05), Some(bucket(1.02, 0.05)));
    }

    /// A probe over a faulted network (dead node / fully degraded link)
    /// reports degenerate parameters; signature construction must
    /// return a structured error instead of panicking. `PLogP`'s
    /// constructor rejects such values, so this builds the struct
    /// literally — exactly what a probe aggregating raw measurements
    /// can produce.
    #[test]
    fn degenerate_probes_yield_structured_errors() {
        let table = GapTable::new(vec![1.0, 1024.0], vec![5e-6, 6e-6]);
        for bad_l in [0.0, -1e-6, f64::INFINITY, f64::NAN] {
            let net = PLogP { l: bad_l, table: table.clone() };
            match ClusterSignature::try_of(&net, 8) {
                Err(SignatureError::DegenerateLatency { value }) => {
                    assert!(!(value > 0.0 && value.is_finite()));
                }
                other => panic!("expected DegenerateLatency, got {other:?}"),
            }
            let err = ClusterSignature::try_with_tolerance(&net, 8, 0.05).unwrap_err();
            assert!(err.to_string().contains("degenerate probed latency"), "{err}");
        }
        // a healthy network still fingerprints
        let net = PLogP { l: 6e-5, table };
        assert!(ClusterSignature::try_of(&net, 8).is_ok());
    }

    #[test]
    fn try_of_agrees_with_the_panicking_path_on_healthy_networks() {
        let net = measured(NetConfig::fast_ethernet_ideal());
        assert_eq!(ClusterSignature::try_of(&net, 8).unwrap(), ClusterSignature::of(&net, 8));
    }

    #[test]
    fn identical_measurements_identical_signature() {
        let a = measured(NetConfig::fast_ethernet_ideal());
        let b = measured(NetConfig::fast_ethernet_ideal());
        assert_eq!(ClusterSignature::of(&a, 8), ClusterSignature::of(&b, 8));
    }

    #[test]
    fn node_count_separates_signatures() {
        let net = measured(NetConfig::fast_ethernet_ideal());
        assert_ne!(ClusterSignature::of(&net, 8), ClusterSignature::of(&net, 16));
    }

    #[test]
    fn different_network_class_separates_signatures() {
        let fe = measured(NetConfig::fast_ethernet_ideal());
        let ge = measured(NetConfig::gigabit_ethernet());
        assert_ne!(ClusterSignature::of(&fe, 8), ClusterSignature::of(&ge, 8));
    }

    #[test]
    fn ops_bitset_covers_every_op() {
        assert_eq!(OPS_ALL.count_ones() as usize, crate::tuner::Op::COUNT);
    }

    #[test]
    fn key_is_stable_and_filesystem_safe() {
        let net = measured(NetConfig::fast_ethernet_ideal());
        let sig = ClusterSignature::of(&net, 24);
        let k = sig.key();
        assert_eq!(k, sig.key());
        assert!(k.starts_with("sig-p24-"));
        assert!(k.chars().all(|c| c.is_ascii_alphanumeric() || "-_".contains(c)), "{k}");
    }

    #[test]
    fn drift_zero_for_identical_and_positive_for_scaled() {
        let net = measured(NetConfig::fast_ethernet_ideal());
        assert!(drift(&net, &net) < 1e-12);
        let slower = PLogP::new(
            net.l * 1.5,
            GapTable::new(net.table.sizes().to_vec(), net.table.gaps().to_vec()),
        );
        let d = drift(&net, &slower);
        assert!((d - 0.5).abs() < 1e-9, "drift {d}");
    }
}
