//! Cluster signatures: a quantized fingerprint of one network.
//!
//! The paper tunes once per network and serves decisions statically
//! (§5). Two clusters whose pLogP parameters agree to within measurement
//! noise should therefore *share* one decision table rather than tune
//! twice — homogeneous islands of the same hardware generation are the
//! common case in the grids both companion papers target. A
//! [`ClusterSignature`] quantizes the parameters that actually enter the
//! cost models (`L` and `g(m)` at a fixed set of probe sizes) into
//! multiplicative buckets, together with the node count and the op set,
//! so equivalence is a plain `Eq`/`Hash` and the coordinator's cache can
//! key on it.

use crate::plogp::PLogP;

/// Default quantization tolerance: parameters within ±5 % land in the
/// same bucket (the pLogP benchmark's run-to-run noise is below this on
/// the simulated testbed; see `plogp::bench` tests).
pub const DEFAULT_TOLERANCE: f64 = 0.05;

/// Gap-table probe sizes entering the fingerprint (bytes, as f64 for
/// [`PLogP::gap`]): 1 B, 1 KiB, 64 KiB, 1 MiB, 4 MiB — the span the
/// tuner's m-grid and s-grid actually exercise.
pub const PROBE_SIZES: [f64; 5] = [1.0, 1024.0, 65536.0, 1048576.0, 4194304.0];

/// Op-set bit: the signature covers broadcast tables.
pub const OPS_BCAST: u8 = 1 << 0;
/// Op-set bit: the signature covers scatter tables.
pub const OPS_SCATTER: u8 = 1 << 1;
/// Op-set bit: gather tables.
pub const OPS_GATHER: u8 = 1 << 2;
/// Op-set bit: reduce tables.
pub const OPS_REDUCE: u8 = 1 << 3;
/// Op-set bit: barrier tables.
pub const OPS_BARRIER: u8 = 1 << 4;
/// Op-set bit: allgather tables.
pub const OPS_ALLGATHER: u8 = 1 << 5;
/// Op-set bit: allreduce tables.
pub const OPS_ALLREDUCE: u8 = 1 << 6;
/// Every collective family (what [`super::service::TableSet`] holds —
/// one bit per [`crate::tuner::Op::ALL`] entry).
pub const OPS_ALL: u8 = OPS_BCAST
    | OPS_SCATTER
    | OPS_GATHER
    | OPS_REDUCE
    | OPS_BARRIER
    | OPS_ALLGATHER
    | OPS_ALLREDUCE;

/// Quantize `x > 0` into a multiplicative bucket: values within a factor
/// of `(1 + tol)` of each other map to the same or adjacent buckets, and
/// values differing by less than ~`tol/2` around a bucket center map to
/// the same bucket.
pub fn bucket(x: f64, tol: f64) -> i64 {
    assert!(x > 0.0 && x.is_finite(), "bucket() needs a positive finite value, got {x}");
    assert!(tol > 0.0, "tolerance must be positive");
    (x.ln() / (1.0 + tol).ln()).round() as i64
}

/// The quantized fingerprint of one cluster's network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClusterSignature {
    /// Number of nodes in the cluster.
    pub nodes: usize,
    /// Which operation families the tables cover ([`OPS_ALL`] today).
    pub ops: u8,
    /// Quantized one-way latency `L`.
    pub l_bucket: i64,
    /// Quantized `g(m)` at each of [`PROBE_SIZES`].
    pub gap_buckets: [i64; 5],
}

impl ClusterSignature {
    /// Fingerprint with the default tolerance.
    pub fn of(net: &PLogP, nodes: usize) -> ClusterSignature {
        ClusterSignature::with_tolerance(net, nodes, DEFAULT_TOLERANCE)
    }

    /// Fingerprint with an explicit quantization tolerance.
    pub fn with_tolerance(net: &PLogP, nodes: usize, tol: f64) -> ClusterSignature {
        assert!(nodes >= 1);
        ClusterSignature {
            nodes,
            ops: OPS_ALL,
            l_bucket: bucket(net.l, tol),
            gap_buckets: PROBE_SIZES.map(|m| bucket(net.gap(m), tol)),
        }
    }

    /// Stable, filesystem-safe key for persistence
    /// (`sig-p<nodes>-o<ops>-l<bucket>-g<b0>_<b1>_...`).
    pub fn key(&self) -> String {
        let gaps: Vec<String> = self.gap_buckets.iter().map(|b| b.to_string()).collect();
        format!(
            "sig-p{}-o{}-l{}-g{}",
            self.nodes,
            self.ops,
            self.l_bucket,
            gaps.join("_")
        )
    }
}

/// Maximum relative difference between two parameter sets, over `L` and
/// `g(m)` at the probe sizes — the scalar the refresh policy thresholds
/// on to decide whether a network has drifted enough to re-tune.
pub fn drift(baseline: &PLogP, fresh: &PLogP) -> f64 {
    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-12);
    let mut d = rel(baseline.l, fresh.l);
    for m in PROBE_SIZES {
        d = d.max(rel(baseline.gap(m), fresh.gap(m)));
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp::{bench, GapTable};

    fn measured(cfg: NetConfig) -> PLogP {
        let mut sim = Netsim::new(2, cfg);
        bench::measure(&mut sim)
    }

    #[test]
    fn bucket_groups_within_tolerance_and_splits_beyond() {
        // ln(1.02)/ln(1.05) ≈ 0.41 -> rounds to 0, same bucket as 1.0
        assert_eq!(bucket(1.0, 0.05), bucket(1.02, 0.05));
        // a factor of 2 is ~14 buckets away at 5 %
        assert_ne!(bucket(1.0, 0.05), bucket(2.0, 0.05));
        assert!(bucket(2.0, 0.05) > bucket(1.0, 0.05) + 10);
    }

    #[test]
    fn identical_measurements_identical_signature() {
        let a = measured(NetConfig::fast_ethernet_ideal());
        let b = measured(NetConfig::fast_ethernet_ideal());
        assert_eq!(ClusterSignature::of(&a, 8), ClusterSignature::of(&b, 8));
    }

    #[test]
    fn node_count_separates_signatures() {
        let net = measured(NetConfig::fast_ethernet_ideal());
        assert_ne!(ClusterSignature::of(&net, 8), ClusterSignature::of(&net, 16));
    }

    #[test]
    fn different_network_class_separates_signatures() {
        let fe = measured(NetConfig::fast_ethernet_ideal());
        let ge = measured(NetConfig::gigabit_ethernet());
        assert_ne!(ClusterSignature::of(&fe, 8), ClusterSignature::of(&ge, 8));
    }

    #[test]
    fn ops_bitset_covers_every_op() {
        assert_eq!(OPS_ALL.count_ones() as usize, crate::tuner::Op::COUNT);
    }

    #[test]
    fn key_is_stable_and_filesystem_safe() {
        let net = measured(NetConfig::fast_ethernet_ideal());
        let sig = ClusterSignature::of(&net, 24);
        let k = sig.key();
        assert_eq!(k, sig.key());
        assert!(k.starts_with("sig-p24-"));
        assert!(k.chars().all(|c| c.is_ascii_alphanumeric() || "-_".contains(c)), "{k}");
    }

    #[test]
    fn drift_zero_for_identical_and_positive_for_scaled() {
        let net = measured(NetConfig::fast_ethernet_ideal());
        assert!(drift(&net, &net) < 1e-12);
        let slower = PLogP::new(
            net.l * 1.5,
            GapTable::new(net.table.sizes().to_vec(), net.table.gaps().to_vec()),
        );
        let d = drift(&net, &slower);
        assert!((d - 0.5).abs() < 1e-9, "drift {d}");
    }
}
