//! The coordinator's network front-end: a thread-per-connection
//! `ct/1` server over `std::net`, the subscription hub, and the push
//! notifier that turns [`Coordinator::watch_publishes`] events into
//! `INVALIDATE` / `TABLEUPDATE` frames.
//!
//! [`serve_connection`] is transport-agnostic (it takes any `BufRead`
//! plus a [`ConnShared`] writer), so the TCP server and the loopback
//! test harness ([`super::loopback`]) run byte-for-byte the same
//! request loop.
//!
//! ## Concurrency contract
//!
//! * **One reader thread per connection.** Only the connection's own
//!   thread reads its stream; framing state never needs a lock.
//! * **Writes are serialized per connection.** Both the request loop
//!   (responses) and the notifier (pushes) write through
//!   [`ConnShared::send`], which holds the connection's writer mutex
//!   for exactly one whole frame — frames interleave, bytes never do.
//! * **The notifier never tunes.** It recomputes subscriber decisions
//!   through [`Coordinator::warm_decision`] (lock-free snapshot reads
//!   only), so a slow tuner run can never stall push delivery; a
//!   subscription whose tables went non-resident gets an `INVALIDATE`
//!   instead.
//! * **Push ordering is by epoch, not arrival.** Every push carries the
//!   publish epoch it was derived from; the protocol's ordering
//!   guarantee (docs/PROTOCOL.md §6) is stated in those epochs, which
//!   is what makes the per-connection writer mutex sufficient — no
//!   global ordering across connections is needed.
//!
//! ## Failure posture
//!
//! The server degrades instead of dying (docs/PROTOCOL.md §8):
//!
//! * **Socket deadlines everywhere.** The accepted socket gets
//!   [`ServerOptions::read_timeout`] / [`ServerOptions::write_timeout`]
//!   once; `TcpStream` clones share them, so both the request loop's
//!   responses and the notifier's pushes are deadline-bounded. A read
//!   deadline that expires *between* frames is an idle poll tick (the
//!   connection stays up); one that expires *inside* a frame is a
//!   stalled peer and closes the connection.
//! * **Idle reaper.** With [`ServerOptions::idle_timeout`] set, a
//!   connection that sends nothing for that long is closed.
//! * **Accept gate.** Past [`ServerOptions::max_connections`] live
//!   connections, new ones are shed before the handshake with
//!   `NACK 0 busy` — structured and retryable, never a silent drop.
//! * **Panic isolation.** A panic inside one connection's request loop
//!   is caught; the connection dies, the server keeps serving.
//! * **Bounded drain.** Shutdown joins connection threads for at most
//!   [`ServerOptions::drain_timeout`], then detaches stragglers (their
//!   sockets are already shut down, so they exit on their own).

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs::{self, Span};

use super::super::service::{Coordinator, PublishEvent, PublishKind};
use super::super::signature::ClusterSignature;
use super::frame::{codes, Frame, Point, QueryReply, MAX_BATCH_ITEMS, PROTOCOL_VERSION};

/// Server-side tunables shared by the TCP and loopback front-ends.
/// The deadline fields only bite on real sockets; the loopback pipes
/// never time out (they are process-local and cannot stall).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Free-text server identification echoed in `WELCOME`.
    pub banner: String,
    /// Honor the `SHUTDOWN` frame (off by default: a remote kill switch
    /// is opt-in, e.g. for the CI socket smoke).
    pub allow_remote_shutdown: bool,
    /// Per-read socket deadline. Doubles as the idle poll tick: an
    /// expiry with no bytes buffered re-checks stop/idle and keeps
    /// waiting; an expiry mid-frame closes the connection (stalled
    /// peer).
    pub read_timeout: Option<Duration>,
    /// Per-write socket deadline, shared by responses and pushes.
    pub write_timeout: Option<Duration>,
    /// Close connections that send nothing for this long (`None` =
    /// never reap). Enforced at read-deadline granularity.
    pub idle_timeout: Option<Duration>,
    /// Shed new connections (with `NACK 0 busy`) past this many live
    /// ones.
    pub max_connections: usize,
    /// How long shutdown waits for connection threads before detaching
    /// the stragglers.
    pub drain_timeout: Duration,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            banner: "collective-tuner coordd".to_string(),
            allow_remote_shutdown: false,
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(10)),
            idle_timeout: None,
            max_connections: 1024,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

/// The write half of one connection, shared between its reader thread
/// (responses) and the notifier (pushes). See the module docs for the
/// locking contract.
pub(crate) struct ConnShared {
    writer: Mutex<Box<dyn Write + Send>>,
    /// Per-connection push sequence number.
    seq: AtomicU64,
    /// Cleared when the reader thread exits or a write fails; the hub
    /// prunes dead connections on the next notification.
    alive: AtomicBool,
    peer: String,
}

impl ConnShared {
    pub(crate) fn new(writer: Box<dyn Write + Send>, peer: String) -> ConnShared {
        ConnShared {
            writer: Mutex::new(writer),
            seq: AtomicU64::new(0),
            alive: AtomicBool::new(true),
            peer,
        }
    }

    fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Write one whole frame under the writer mutex and flush. On
    /// failure (including a write-deadline expiry) the connection is
    /// marked dead (the reader thread and the hub both observe that).
    fn send(&self, frame: &Frame) -> std::io::Result<()> {
        let bytes = frame.encode();
        let mut w = self.writer.lock().unwrap();
        let r = w.write_all(bytes.as_bytes()).and_then(|()| w.flush());
        drop(w);
        if r.is_err() {
            self.alive.store(false, Ordering::Relaxed);
        } else if obs::enabled() {
            obs::registry().counter("net.frames_tx").inc();
        }
        r
    }
}

/// One live subscription: which cluster, which grid points, and where
/// to push. `last_sig` tracks the signature the subscriber last got
/// tables for, so a refresh that retires the old signature right after
/// publishing the new one does not produce a spurious `INVALIDATE`.
struct SubEntry {
    cluster: String,
    points: Vec<Point>,
    last_sig: ClusterSignature,
    conn: Arc<ConnShared>,
}

/// All subscriptions of one server instance. Locked briefly by the
/// request loop (add/remove) and the notifier (iterate); never held
/// across a tuner run, and held across `send` only on the notifier
/// thread — the request loop cannot deadlock against it.
#[derive(Default)]
pub(crate) struct SubscriptionHub {
    subs: Mutex<Vec<SubEntry>>,
}

impl SubscriptionHub {
    fn add(&self, entry: SubEntry) {
        self.subs.lock().unwrap().push(entry);
    }

    pub(crate) fn drop_conn(&self, conn: &Arc<ConnShared>) {
        self.subs.lock().unwrap().retain(|e| !Arc::ptr_eq(&e.conn, conn));
    }

    /// Fan one publish event out to the affected subscribers.
    pub(crate) fn notify(&self, coord: &Coordinator, ev: &PublishEvent) {
        let mut subs = self.subs.lock().unwrap();
        subs.retain(|e| e.conn.alive.load(Ordering::Relaxed));
        for e in subs.iter_mut() {
            let current = coord.cluster(&e.cluster).map(|rc| rc.signature);
            let frame = match ev.kind {
                PublishKind::Updated if current == Some(ev.signature) => {
                    // Fresh tables for this subscriber's cluster:
                    // recompute its points from the published snapshot.
                    let mut rows = Vec::with_capacity(e.points.len());
                    let mut epoch = u64::MAX;
                    for pt in &e.points {
                        match coord.warm_decision(&e.cluster, pt.op, pt.p, pt.m) {
                            Some((d, ep)) => {
                                epoch = epoch.min(ep);
                                rows.push((*pt, d));
                            }
                            // Raced with another retirement: the next
                            // event for that publish handles it.
                            None => break,
                        }
                    }
                    if rows.len() != e.points.len() {
                        continue;
                    }
                    e.last_sig = ev.signature;
                    Frame::TableUpdate {
                        seq: e.conn.next_seq(),
                        epoch,
                        cluster: e.cluster.clone(),
                        rows,
                    }
                }
                PublishKind::Invalidated
                    if e.last_sig == ev.signature || current == Some(ev.signature) =>
                {
                    Frame::Invalidate {
                        seq: e.conn.next_seq(),
                        epoch: ev.epoch,
                        cluster: e.cluster.clone(),
                    }
                }
                _ => continue,
            };
            if e.conn.send(&frame).is_ok() && obs::enabled() {
                obs::registry().counter("net.pushes").inc();
            }
        }
    }
}

/// What [`serve_connection`] needs besides its streams.
pub(crate) struct ConnContext {
    pub coord: Arc<Coordinator>,
    pub hub: Arc<SubscriptionHub>,
    pub opts: ServerOptions,
    /// The owning server's stop flag; connection loops poll it on
    /// every idle tick so a draining server never waits a full read
    /// deadline for them.
    pub stop: Arc<AtomicBool>,
    /// Set when an authorized `SHUTDOWN` frame arrives; the owning
    /// server polls it.
    pub shutdown_requested: Arc<AtomicBool>,
}

/// The `ct/1` request loop, shared by the TCP server and the loopback
/// transport: handshake, then serve frames until the peer says `BYE`,
/// hangs up, idles out, or breaks protocol. Always leaves the
/// connection marked dead and its subscriptions dropped; never panics
/// on peer input.
pub(crate) fn serve_connection(ctx: &ConnContext, mut reader: impl BufRead, conn: Arc<ConnShared>) {
    if let Err(e) = run_connection(ctx, &mut reader, &conn) {
        log::debug!("net: connection {} closed: {e:#}", conn.peer);
    }
    conn.alive.store(false, Ordering::Relaxed);
    ctx.hub.drop_conn(&conn);
}

fn run_connection(
    ctx: &ConnContext,
    reader: &mut impl BufRead,
    conn: &Arc<ConnShared>,
) -> Result<()> {
    // ---- handshake: exactly one HELLO, version must match ------------
    match next_frame(ctx, reader, conn)? {
        Some(Frame::Hello { version }) if version == PROTOCOL_VERSION => {
            conn.send(&Frame::Welcome {
                version: PROTOCOL_VERSION,
                banner: ctx.opts.banner.clone(),
            })?;
        }
        Some(Frame::Hello { version }) => {
            let _ = conn.send(&Frame::Error {
                code: codes::VERSION.to_string(),
                message: format!("server speaks ct/{PROTOCOL_VERSION}, client sent ct/{version}"),
            });
            anyhow::bail!("version mismatch (peer ct/{version})");
        }
        Some(other) => {
            let _ = conn.send(&Frame::Error {
                code: codes::MALFORMED.to_string(),
                message: "first frame must be HELLO".to_string(),
            });
            anyhow::bail!("handshake violation: {other:?}");
        }
        None => return Ok(()), // connected and left without a word
    }

    // ---- request loop -------------------------------------------------
    while let Some(frame) = next_frame(ctx, reader, conn)? {
        match frame {
            Frame::Ping { id } => {
                conn.send(&Frame::Pong { id, epoch: ctx.coord.epoch() })?;
            }
            Frame::Batch { id, queries } => {
                let _span = Span::start("net.request_ns");
                let mut epoch = u64::MAX;
                let mut errors = 0u64;
                let replies: Vec<QueryReply> = queries
                    .iter()
                    .map(|q| match ctx.coord.decision_versioned(q.op, &q.cluster, q.p, q.m) {
                        Ok((d, ep)) => {
                            epoch = epoch.min(ep);
                            QueryReply::Decision(d)
                        }
                        Err(e) => {
                            errors += 1;
                            QueryReply::Error {
                                code: codes::UNREGISTERED.to_string(),
                                message: format!("{e:#}"),
                            }
                        }
                    })
                    .collect();
                if obs::enabled() {
                    let reg = obs::registry();
                    reg.counter("net.queries").add(replies.len() as u64);
                    reg.counter("net.query_errors").add(errors);
                }
                let epoch = if epoch == u64::MAX { 0 } else { epoch };
                conn.send(&Frame::Decisions { id, epoch, replies })?;
            }
            Frame::Subscribe { id, cluster, points } => {
                if points.len() > MAX_BATCH_ITEMS {
                    conn.send(&Frame::Nack {
                        id,
                        code: codes::TOO_LARGE.to_string(),
                        message: format!("at most {MAX_BATCH_ITEMS} points per subscription"),
                    })?;
                    continue;
                }
                let Some(rc) = ctx.coord.cluster(&cluster) else {
                    conn.send(&Frame::Nack {
                        id,
                        code: codes::UNREGISTERED.to_string(),
                        message: format!("cluster '{cluster}' is not registered"),
                    })?;
                    continue;
                };
                // Materialize the initial answers (this may tune — a
                // subscription is a query-equivalent, unlike the
                // notifier's warm-only recomputation later).
                let mut rows = Vec::with_capacity(points.len());
                let mut epoch = u64::MAX;
                for pt in &points {
                    let (d, ep) = ctx
                        .coord
                        .decision_versioned(pt.op, &cluster, pt.p, pt.m)
                        .with_context(|| format!("subscribing to '{cluster}'"))?;
                    epoch = epoch.min(ep);
                    rows.push((*pt, d));
                }
                let epoch = if epoch == u64::MAX { ctx.coord.epoch() } else { epoch };
                ctx.hub.add(SubEntry {
                    cluster: cluster.clone(),
                    points: points.clone(),
                    last_sig: rc.signature,
                    conn: Arc::clone(conn),
                });
                if obs::enabled() {
                    obs::registry().counter("net.subscriptions").inc();
                }
                conn.send(&Frame::Subscribed {
                    id,
                    cluster: cluster.clone(),
                    signature: rc.signature.key(),
                    epoch,
                })?;
                // Initial state push so subscribers need no separate
                // BATCH to seed their cache.
                conn.send(&Frame::TableUpdate {
                    seq: conn.next_seq(),
                    epoch,
                    cluster,
                    rows,
                })?;
            }
            Frame::Shutdown => {
                if ctx.opts.allow_remote_shutdown {
                    let _ = conn.send(&Frame::Bye);
                    ctx.shutdown_requested.store(true, Ordering::SeqCst);
                    return Ok(());
                }
                conn.send(&Frame::Error {
                    code: codes::UNSUPPORTED.to_string(),
                    message: "remote shutdown is not enabled on this server".to_string(),
                })?;
            }
            Frame::Bye => return Ok(()),
            other => {
                // Server-only frames arriving at the server are a
                // protocol violation; fatal per docs/PROTOCOL.md §7.
                let _ = conn.send(&Frame::Error {
                    code: codes::MALFORMED.to_string(),
                    message: "unexpected server-originated frame".to_string(),
                });
                anyhow::bail!("client sent server-only frame {other:?}");
            }
        }
    }
    Ok(())
}

/// Wait for the next frame, distinguishing the read deadline's two
/// meanings. A deadline expiry with *no bytes buffered* is an idle poll
/// tick: check the stop flag and the idle budget, then keep waiting. An
/// expiry once a frame has started (inside [`read_frame`]) propagates
/// as an error — a peer that stalls mid-frame is broken, not idle.
/// Transports without deadlines (the loopback pipes) never tick.
fn next_frame(
    ctx: &ConnContext,
    reader: &mut impl BufRead,
    conn: &ConnShared,
) -> Result<Option<Frame>> {
    let waiting_since = Instant::now();
    loop {
        match reader.fill_buf() {
            Ok([]) => return Ok(None), // EOF
            Ok(_) => return read_frame(reader, conn),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if ctx.stop.load(Ordering::SeqCst) {
                    return Ok(None); // server draining: hang up now
                }
                if let Some(limit) = ctx.opts.idle_timeout {
                    if waiting_since.elapsed() >= limit {
                        if obs::enabled() {
                            obs::registry().counter("net.idle_reaped").inc();
                        }
                        log::debug!("net: reaping idle connection {}", conn.peer);
                        return Ok(None);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(anyhow::Error::from(e).context("reading from peer")),
        }
    }
}

/// Read one frame, translating a decode failure into an `ERROR` frame
/// for the peer before propagating it (fatal to the connection).
fn read_frame(reader: &mut impl BufRead, conn: &ConnShared) -> Result<Option<Frame>> {
    match Frame::read_from(reader) {
        Ok(f) => {
            if f.is_some() && obs::enabled() {
                obs::registry().counter("net.frames_rx").inc();
            }
            Ok(f)
        }
        Err(e) => {
            let _ = conn.send(&Frame::Error {
                code: e.code.to_string(),
                message: e.message.clone(),
            });
            Err(e.into())
        }
    }
}

/// One live TCP connection, kept so shutdown can unblock and join it.
struct LiveConn {
    stream: TcpStream,
    shared: Arc<ConnShared>,
    thread: JoinHandle<()>,
}

/// The `coordd` TCP server: nonblocking accept loop, one thread per
/// connection, plus the notifier thread that drives pushes off
/// [`Coordinator::watch_publishes`]. See the module docs for the full
/// concurrency contract and failure posture.
pub struct CoordServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    drain_timeout: Duration,
    accept: Option<JoinHandle<()>>,
    notifier: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<LiveConn>>>,
}

impl CoordServer {
    /// Bind `addr` (e.g. `127.0.0.1:7177`, or port `0` for ephemeral)
    /// and start serving. Returns once the listener is live;
    /// [`CoordServer::local_addr`] has the actual port.
    pub fn start(coord: Arc<Coordinator>, addr: &str, opts: ServerOptions) -> Result<CoordServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let local = listener.local_addr().context("local_addr")?;

        let stop = Arc::new(AtomicBool::new(false));
        let shutdown_requested = Arc::new(AtomicBool::new(false));
        let hub = Arc::new(SubscriptionHub::default());
        let conns: Arc<Mutex<Vec<LiveConn>>> = Arc::new(Mutex::new(Vec::new()));
        let drain_timeout = opts.drain_timeout;

        // Subscribe to publish events *before* serving any client, so
        // no event between first-query and notifier-start is lost.
        let events = coord.watch_publishes();
        let notifier = {
            let coord = Arc::clone(&coord);
            let hub = Arc::clone(&hub);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || notifier_loop(&coord, &hub, &events, &stop))
        };

        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let ctx = Arc::new(ConnContext {
                coord,
                hub,
                opts,
                stop: Arc::clone(&stop),
                shutdown_requested: Arc::clone(&shutdown_requested),
            });
            std::thread::spawn(move || accept_loop(&listener, &ctx, &conns, &stop))
        };

        Ok(CoordServer {
            addr: local,
            stop,
            shutdown_requested,
            drain_timeout,
            accept: Some(accept),
            notifier: Some(notifier),
            conns,
        })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether an authorized remote `SHUTDOWN` frame has arrived.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: stop accepting, unblock every connection
    /// reader by shutting its socket down, then join threads for at
    /// most the drain deadline — a wedged connection is detached, not
    /// waited on forever. Idempotent via `Drop` (shutdown then drop is
    /// fine).
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let mut pending = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in &pending {
            let _ = c.stream.shutdown(Shutdown::Both);
            c.shared.alive.store(false, Ordering::Relaxed);
        }
        let deadline = Instant::now() + self.drain_timeout;
        while !pending.is_empty() && Instant::now() < deadline {
            let mut still_running = Vec::with_capacity(pending.len());
            for c in pending {
                if c.thread.is_finished() {
                    let _ = c.thread.join();
                } else {
                    still_running.push(c);
                }
            }
            pending = still_running;
            if !pending.is_empty() {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        for c in pending {
            // Socket already shut down: the thread exits as soon as its
            // current operation (e.g. an in-flight tune) completes.
            log::warn!(
                "net: detaching connection thread {} still running at drain deadline",
                c.shared.peer
            );
        }
        if let Some(h) = self.notifier.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CoordServer {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Drain [`Coordinator::watch_publishes`] events into hub
/// notifications until `stop` is raised (checked on a 100 ms timeout)
/// or the coordinator goes away. Shared with the loopback transport.
pub(crate) fn notifier_loop(
    coord: &Coordinator,
    hub: &SubscriptionHub,
    events: &mpsc::Receiver<PublishEvent>,
    stop: &AtomicBool,
) {
    loop {
        match events.recv_timeout(Duration::from_millis(100)) {
            Ok(ev) => hub.notify(coord, &ev),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Refuse one just-accepted connection with `NACK 0 busy` (id 0: there
/// is no request yet — the refusal is about the connection itself) and
/// close it. Best-effort: a peer that is already gone just loses the
/// courtesy frame.
fn shed_connection(mut stream: TcpStream, peer: SocketAddr, limit: usize) {
    if obs::enabled() {
        obs::registry().counter("net.sheds").inc();
    }
    log::warn!("net: shedding connection from {peer}: at the {limit}-connection limit");
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let frame = Frame::Nack {
        id: 0,
        code: codes::BUSY.to_string(),
        message: format!("server is at its {limit}-connection limit; retry after backoff"),
    };
    let _ = stream.write_all(frame.encode().as_bytes());
    let _ = stream.shutdown(Shutdown::Both);
}

fn accept_loop(
    listener: &TcpListener,
    ctx: &Arc<ConnContext>,
    conns: &Arc<Mutex<Vec<LiveConn>>>,
    stop: &AtomicBool,
) {
    let open = Arc::new(AtomicU64::new(0));
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                if open.load(Ordering::Relaxed) >= ctx.opts.max_connections as u64 {
                    shed_connection(stream, peer, ctx.opts.max_connections);
                    continue;
                }
                let _ = stream.set_nodelay(true);
                // The accepted socket may inherit the listener's
                // nonblocking flag on some platforms; serve_connection
                // wants blocking-with-deadline semantics.
                let _ = stream.set_nonblocking(false);
                // Deadlines are per-socket, so setting them here covers
                // every clone: the reader thread's reads, the request
                // loop's responses, and the notifier's pushes.
                let _ = stream.set_read_timeout(ctx.opts.read_timeout);
                let _ = stream.set_write_timeout(ctx.opts.write_timeout);
                let (reader, writer) = match (stream.try_clone(), stream.try_clone()) {
                    (Ok(r), Ok(w)) => (r, w),
                    (Err(e), _) | (_, Err(e)) => {
                        log::warn!("net: cannot clone accepted stream from {peer}: {e}");
                        continue;
                    }
                };
                let shared = Arc::new(ConnShared::new(Box::new(writer), peer.to_string()));
                if obs::enabled() {
                    obs::registry().counter("net.connections").inc();
                }
                let thread = {
                    let ctx = Arc::clone(ctx);
                    let shared = Arc::clone(&shared);
                    let open = Arc::clone(&open);
                    let sock = stream.try_clone().ok();
                    open.fetch_add(1, Ordering::Relaxed);
                    std::thread::spawn(move || {
                        // Panic isolation: a bug tripped by one peer's
                        // input kills that connection, not the server.
                        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            serve_connection(&ctx, BufReader::new(reader), Arc::clone(&shared));
                        }));
                        // The accept loop's `LiveConn` entry keeps the
                        // fd open until it is reaped; close the peer's
                        // half now so an idle-reaped or errored-out
                        // client observes EOF immediately instead of a
                        // silently dead socket.
                        if let Some(s) = sock {
                            let _ = s.shutdown(Shutdown::Both);
                        }
                        if caught.is_err() {
                            shared.alive.store(false, Ordering::Relaxed);
                            ctx.hub.drop_conn(&shared);
                            if obs::enabled() {
                                obs::registry().counter("net.conn_panics").inc();
                            }
                            log::error!("net: connection {} panicked; isolated", shared.peer);
                        }
                        let now = open.fetch_sub(1, Ordering::Relaxed) - 1;
                        if obs::enabled() {
                            obs::registry().gauge("net.open_connections").set(now);
                        }
                    })
                };
                if obs::enabled() {
                    obs::registry().gauge("net.open_connections").set(open.load(Ordering::Relaxed));
                }
                let mut guard = conns.lock().unwrap();
                // Reap finished connections so a long-lived server does
                // not accumulate dead handles.
                let mut live = Vec::with_capacity(guard.len() + 1);
                for c in guard.drain(..) {
                    if c.shared.alive.load(Ordering::Relaxed) {
                        live.push(c);
                    } else {
                        let _ = c.thread.join();
                    }
                }
                live.push(LiveConn { stream, shared, thread });
                *guard = live;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => {
                log::warn!("net: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_are_deadlined_but_not_idle_reaping() {
        let o = ServerOptions::default();
        assert!(o.read_timeout.is_some());
        assert!(o.write_timeout.is_some());
        assert!(o.idle_timeout.is_none(), "idle reaping is opt-in");
        assert!(o.max_connections >= 64);
        assert!(!o.drain_timeout.is_zero());
        assert!(!o.allow_remote_shutdown);
    }
}
