//! [`NetClient`]: the remote twin of the coordinator's warm-read
//! surface. `decision()` / `query_batch()` mirror
//! [`Coordinator::decision`](super::super::service::Coordinator::decision)
//! over the `ct/1` wire, `subscribe()` registers for push updates, and
//! the client enforces the protocol's invalidation-ordering guarantee
//! (docs/PROTOCOL.md §6): it never returns a decision computed from a
//! snapshot older than an `INVALIDATE` it had already observed when
//! the query was sent.
//!
//! ## Resilience contract
//!
//! Every failure is classified before anything else happens:
//!
//! * **transport** ([`TransportError`]) — I/O error, socket deadline,
//!   EOF mid-stream, or undecodable bytes from the peer. The link is
//!   torn down; with a redial handle and a multi-attempt
//!   [`RetryPolicy`] the next attempt reconnects transparently
//!   (re-`HELLO`, re-`SUBSCRIBE` every recorded subscription) and
//!   re-sends the request. All `ct/1` client requests are idempotent
//!   reads, so a resend after an ambiguous failure is safe.
//! * **remote** ([`RemoteError`]) — the server answered with a
//!   structured refusal. Only `busy` (the accept-gate shed) is
//!   retryable; everything else is surfaced immediately.
//! * **protocol** — the peer spoke, decodably, out of turn. Never
//!   retried in place.
//!
//! Reconnection preserves the per-cluster invalidation floors: an
//! `INVALIDATE` observed on the old connection still fences decisions
//! served on the new one (§6 survives the socket). Backoff between
//! attempts is bounded exponential with *deterministic* decorrelated
//! jitter — the jitter stream is a hash of the attempt counter, not an
//! OS random draw, so a replayed failure schedule backs off on a
//! byte-identical schedule.
//!
//! ## Concurrency contract
//!
//! * The whole connection state (link, id counter, buffered pushes,
//!   per-cluster invalidation floors, retry bookkeeping) lives behind
//!   **one mutex**; every method takes `&self`, so a [`NetClient`] can
//!   be shared across threads like the in-process coordinator —
//!   requests from different threads serialize per connection (open one
//!   client per thread for parallelism; the bench does exactly that).
//!   Backoff sleeps hold the mutex: a retrying request keeps the
//!   connection to itself, exactly as a slow round-trip would.
//! * The transport is any `Read`/`Write` pair: a `TcpStream` clone
//!   pair ([`NetClient::connect`]) or a loopback pipe pair
//!   ([`super::loopback::LoopbackServer::connect`]). The client is the
//!   only reader of its stream.
//! * Pushes (`INVALIDATE` / `TABLEUPDATE`) arrive interleaved with
//!   responses and are buffered internally by whichever request is
//!   currently draining the stream; [`NetClient::take_pushes`] hands
//!   them out, and [`NetClient::wait_pushes`] polls for them with
//!   `PING` round-trips (which works on any blocking transport — no
//!   read timeouts needed).

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::io::{BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::obs;
use crate::tuner::{Decision, Op};

use super::frame::{codes, Frame, Point, Query, QueryReply, PROTOCOL_VERSION};

/// A structured error the server returned for one query or request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemoteError {
    pub code: String,
    pub message: String,
}

impl RemoteError {
    /// Whether retrying the same request (after backoff, possibly on a
    /// fresh connection) can plausibly succeed. Matches the
    /// classification table in docs/PROTOCOL.md §8.
    pub fn is_retryable(&self) -> bool {
        self.code == codes::BUSY
    }
}

impl fmt::Display for RemoteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

impl std::error::Error for RemoteError {}

/// A transport-level failure: I/O error, socket deadline expiry, EOF
/// mid-stream, or bytes the frame codec could not decode. Always
/// retryable on a fresh connection; the old one is torn down.
#[derive(Debug, Clone)]
pub struct TransportError(pub String);

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "transport: {}", self.0)
    }
}

impl std::error::Error for TransportError {}

/// How many times a request is attempted and how the client backs off
/// in between. The two presets name the two sensible postures; the
/// fields are public for anything in between.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per request (≥ 1; 1 = no retries).
    pub max_attempts: u32,
    /// First backoff delay (and the floor of every later one).
    pub base_delay: Duration,
    /// Hard cap on any single backoff delay.
    pub max_delay: Duration,
}

impl RetryPolicy {
    /// One attempt, no backoff: every failure surfaces immediately.
    /// The right posture for tests and for callers with their own
    /// retry loop.
    pub fn fail_fast() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            base_delay: Duration::from_millis(0),
            max_delay: Duration::from_millis(0),
        }
    }

    /// Six attempts, 25 ms base, 1 s cap: rides out a server restart
    /// without hammering it.
    pub fn resilient() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 6,
            base_delay: Duration::from_millis(25),
            max_delay: Duration::from_secs(1),
        }
    }

    /// The delay before retry number `seq` (a client-lifetime attempt
    /// counter), given the previous delay: decorrelated jitter
    /// (`delay ∈ [base, 3·prev]`, capped) with the random draw
    /// replaced by a fixed multiplicative hash of `seq`, so the
    /// schedule is reproducible run to run.
    pub fn backoff_delay(&self, seq: u64, prev: Duration) -> Duration {
        let lo = self.base_delay.as_nanos() as f64;
        let hi = ((prev.as_nanos() as f64) * 3.0).max(lo);
        let raw = lo + jitter_frac(seq) * (hi - lo);
        Duration::from_nanos(raw.min(self.max_delay.as_nanos() as f64) as u64)
    }
}

/// SplitMix64 finalizer → uniform fraction in `[0, 1)`. Deterministic:
/// the jitter stream is a pure function of the attempt counter.
fn jitter_frac(seq: u64) -> f64 {
    let mut z = seq.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// Connection-shaping knobs: socket deadlines plus the retry posture.
/// The default is byte-for-byte the pre-resilience client — no
/// deadlines, fail-fast — so existing callers change nothing.
#[derive(Debug, Clone, Copy)]
pub struct ClientOptions {
    /// TCP connect deadline (`None` = OS default).
    pub connect_timeout: Option<Duration>,
    /// Socket read deadline; a read that exceeds it is a
    /// [`TransportError`], never an indefinite hang.
    pub read_timeout: Option<Duration>,
    /// Socket write deadline.
    pub write_timeout: Option<Duration>,
    /// Attempt count and backoff shape.
    pub retry: RetryPolicy,
}

impl Default for ClientOptions {
    fn default() -> Self {
        ClientOptions {
            connect_timeout: None,
            read_timeout: None,
            write_timeout: None,
            retry: RetryPolicy::fail_fast(),
        }
    }
}

impl ClientOptions {
    /// Deadlines on every socket operation plus the resilient retry
    /// posture: the configuration the chaos suite runs under.
    pub fn resilient() -> ClientOptions {
        ClientOptions {
            connect_timeout: Some(Duration::from_secs(2)),
            read_timeout: Some(Duration::from_secs(5)),
            write_timeout: Some(Duration::from_secs(5)),
            retry: RetryPolicy::resilient(),
        }
    }
}

/// A dialing function: produces a fresh, unhandshaken transport pair.
/// Stored so the client can reconnect transparently.
type Redial = dyn Fn() -> Result<(Box<dyn Read + Send>, Box<dyn Write + Send>)> + Send + Sync;

/// One live, handshaken transport.
struct Link {
    reader: BufReader<Box<dyn Read + Send>>,
    writer: Box<dyn Write + Send>,
    banner: String,
}

struct Inner {
    /// `None` = no usable connection; the next request redials (or
    /// fails with [`TransportError`] if there is nothing to dial).
    link: Option<Link>,
    redial: Option<Box<Redial>>,
    opts: ClientOptions,
    next_id: u64,
    /// Client-lifetime backoff draw counter (the jitter stream index).
    jitter_seq: u64,
    /// Whether a handshake has ever succeeded (distinguishes the
    /// constructor's first dial from a true reconnection).
    ever_connected: bool,
    /// Successful transparent reconnections.
    reconnects: u64,
    pushes: VecDeque<Push>,
    /// Per-cluster invalidation floor: the highest `INVALIDATE` epoch
    /// observed. Decisions at or above the floor recorded *before* a
    /// query was sent are guaranteed by the server; a response below
    /// that floor is a protocol violation surfaced as `stale`. The map
    /// deliberately survives reconnection: the guarantee is about what
    /// this client has *observed*, not about any one socket.
    invalidated: HashMap<String, u64>,
    /// Subscriptions to re-establish after a reconnect, newest per
    /// cluster.
    subs: Vec<(String, Vec<Point>)>,
}

/// A server-initiated push, as surfaced to client code.
#[derive(Debug, Clone, PartialEq)]
pub enum Push {
    /// Decisions for `cluster` carrying an epoch `< epoch` are stale.
    Invalidate { epoch: u64, cluster: String },
    /// Fresh decisions for every subscribed point of `cluster`.
    TableUpdate { epoch: u64, cluster: String, rows: Vec<(Point, Decision)> },
}

/// A `ct/1` client connection. See the module docs for the sharing,
/// push-delivery, and resilience contracts.
pub struct NetClient {
    inner: Mutex<Inner>,
}

impl NetClient {
    /// Connect over TCP and handshake, with the default (fail-fast,
    /// deadline-free) options.
    pub fn connect(addr: &str) -> Result<NetClient> {
        NetClient::connect_with(addr, ClientOptions::default())
    }

    /// Connect over TCP with explicit deadlines and retry posture. The
    /// dial itself runs under the retry policy, and the client keeps
    /// the address as a redial handle for transparent reconnection.
    pub fn connect_with(addr: &str, opts: ClientOptions) -> Result<NetClient> {
        let addr_owned = addr.to_string();
        let redial: Box<Redial> = Box::new(move || dial_tcp(&addr_owned, &opts));
        let mut inner = Inner {
            link: None,
            redial: Some(redial),
            opts,
            next_id: 1,
            jitter_seq: 0,
            ever_connected: false,
            reconnects: 0,
            pushes: VecDeque::new(),
            invalidated: HashMap::new(),
            subs: Vec::new(),
        };
        retrying(&mut inner, |inner| ensure_link(inner))?;
        Ok(NetClient { inner: Mutex::new(inner) })
    }

    /// Handshake over an arbitrary transport (the loopback pipes, or a
    /// pre-connected socket pair), with default options and no redial
    /// handle: a transport failure here is terminal for the client.
    pub fn from_transport(
        reader: Box<dyn Read + Send>,
        writer: Box<dyn Write + Send>,
    ) -> Result<NetClient> {
        NetClient::from_transport_with(reader, writer, ClientOptions::default())
    }

    /// [`NetClient::from_transport`] with explicit options. Socket
    /// deadlines do not apply (the transport is opaque), but the retry
    /// policy governs `busy` refusals and — once a redial handle is
    /// installed with [`NetClient::set_redial`] — reconnection.
    pub fn from_transport_with(
        reader: Box<dyn Read + Send>,
        writer: Box<dyn Write + Send>,
        opts: ClientOptions,
    ) -> Result<NetClient> {
        let mut link = Link { reader: BufReader::new(reader), writer, banner: String::new() };
        handshake(&mut link)?;
        Ok(NetClient {
            inner: Mutex::new(Inner {
                link: Some(link),
                redial: None,
                opts,
                next_id: 1,
                jitter_seq: 0,
                ever_connected: true,
                reconnects: 0,
                pushes: VecDeque::new(),
                invalidated: HashMap::new(),
                subs: Vec::new(),
            }),
        })
    }

    /// Install (or replace) the redial handle: how the client obtains a
    /// fresh transport after the current one fails. `connect*` installs
    /// one automatically; transport-constructed clients (loopback) use
    /// this to opt into reconnection.
    pub fn set_redial<F>(&self, f: F)
    where
        F: Fn() -> Result<(Box<dyn Read + Send>, Box<dyn Write + Send>)> + Send + Sync + 'static,
    {
        self.inner.lock().unwrap().redial = Some(Box::new(f));
    }

    /// The server's `WELCOME` banner (from the most recent handshake).
    pub fn banner(&self) -> String {
        let inner = self.inner.lock().unwrap();
        inner.link.as_ref().map(|l| l.banner.clone()).unwrap_or_default()
    }

    /// Successful transparent reconnections so far.
    pub fn reconnects(&self) -> u64 {
        self.inner.lock().unwrap().reconnects
    }

    /// The highest `INVALIDATE` epoch observed for `cluster` (0 if
    /// none). Survives reconnection — see the module docs.
    pub fn invalidation_floor(&self, cluster: &str) -> u64 {
        self.inner.lock().unwrap().invalidated.get(cluster).copied().unwrap_or(0)
    }

    /// The warm-read surface, one point at a time: exactly the
    /// in-process `Coordinator::decision` signature, answered remotely.
    pub fn decision(&self, op: Op, cluster: &str, p: usize, m: u64) -> Result<Decision> {
        let mut replies = self.query_batch(&[Query {
            op,
            cluster: cluster.to_string(),
            p,
            m,
        }])?;
        match replies.pop().context("server answered an empty batch")? {
            Ok(d) => Ok(d),
            Err(e) => Err(e.into()),
        }
    }

    /// One batched round-trip: every query answered in order, each
    /// individually a decision or a structured error (a batch can
    /// partially succeed). Runs under the retry policy.
    pub fn query_batch(&self, queries: &[Query]) -> Result<Vec<Result<Decision, RemoteError>>> {
        let mut inner = self.inner.lock().unwrap();
        retrying(&mut inner, |inner| try_query_batch(inner, queries))
    }

    /// Subscribe to `(op, P, m)` points of one cluster. Returns the
    /// cluster's signature key and the subscription epoch; the initial
    /// `TABLEUPDATE` lands in the push buffer immediately after. The
    /// subscription is recorded and re-established automatically after
    /// a reconnect.
    pub fn subscribe(&self, cluster: &str, points: &[Point]) -> Result<(String, u64)> {
        let mut inner = self.inner.lock().unwrap();
        let out = retrying(&mut inner, |inner| try_subscribe(inner, cluster, points))?;
        inner.subs.retain(|(c, _)| c != cluster);
        inner.subs.push((cluster.to_string(), points.to_vec()));
        Ok(out)
    }

    /// One `PING` round-trip; returns the server's current publish
    /// epoch. Also drains any queued pushes into the buffer.
    pub fn ping(&self) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        retrying(&mut inner, try_ping)
    }

    /// Drain every buffered push (non-blocking; pushes are buffered as
    /// a side effect of any request round-trip).
    pub fn take_pushes(&self) -> Vec<Push> {
        self.inner.lock().unwrap().pushes.drain(..).collect()
    }

    /// Poll (via `PING` round-trips) until at least `min` pushes are
    /// buffered or `timeout` elapses; returns whatever arrived. Works
    /// on any blocking transport — no socket read timeouts involved.
    pub fn wait_pushes(&self, min: usize, timeout: Duration) -> Result<Vec<Push>> {
        let deadline = Instant::now() + timeout;
        loop {
            {
                let mut inner = self.inner.lock().unwrap();
                if inner.pushes.len() >= min {
                    return Ok(inner.pushes.drain(..).collect());
                }
            }
            if Instant::now() >= deadline {
                return Ok(self.take_pushes());
            }
            self.ping()?; // drains anything the server queued before the PONG
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Ask the server to shut down (requires `--allow-remote-shutdown`
    /// on the server side). Returns once the server acknowledges with
    /// `BYE`. Never retried: shutdown is not an idempotent read.
    pub fn shutdown_server(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        ensure_link(&mut inner)?;
        let Inner { link, pushes, invalidated, .. } = &mut *inner;
        let link = link.as_mut().expect("ensure_link");
        send(link, &Frame::Shutdown)?;
        match recv_response(link, pushes, invalidated)? {
            Frame::Bye => Ok(()),
            Frame::Error { code, message } => bail!(RemoteError { code, message }),
            other => bail!("expected BYE, got {other:?}"),
        }
    }

    /// Polite hangup (best-effort `BYE`).
    pub fn close(self) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(link) = inner.link.as_mut() {
            let _ = send(link, &Frame::Bye);
        }
    }
}

/// Dial `addr` with the options' connect/read/write deadlines applied.
fn dial_tcp(
    addr: &str,
    opts: &ClientOptions,
) -> Result<(Box<dyn Read + Send>, Box<dyn Write + Send>)> {
    let stream = match opts.connect_timeout {
        Some(t) => {
            let addrs = addr
                .to_socket_addrs()
                .map_err(|e| TransportError(format!("resolving {addr}: {e}")))?;
            let mut last: Option<std::io::Error> = None;
            let mut stream = None;
            for a in addrs {
                match TcpStream::connect_timeout(&a, t) {
                    Ok(s) => {
                        stream = Some(s);
                        break;
                    }
                    Err(e) => last = Some(e),
                }
            }
            match stream {
                Some(s) => s,
                None => bail!(TransportError(format!(
                    "connecting {addr}: {}",
                    last.map_or_else(|| "no addresses".to_string(), |e| e.to_string())
                ))),
            }
        }
        None => TcpStream::connect(addr)
            .map_err(|e| TransportError(format!("connecting {addr}: {e}")))?,
    };
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(opts.read_timeout)
        .map_err(|e| TransportError(format!("setting read deadline: {e}")))?;
    stream
        .set_write_timeout(opts.write_timeout)
        .map_err(|e| TransportError(format!("setting write deadline: {e}")))?;
    let reader = stream
        .try_clone()
        .map_err(|e| TransportError(format!("cloning stream: {e}")))?;
    Ok((Box::new(reader), Box::new(stream)))
}

/// Run `attempt` under `inner`'s retry policy: transport failures tear
/// the link down and (with a redial handle) reconnect on the next
/// attempt; `busy` refusals back off and retry on the same link;
/// everything else surfaces immediately.
fn retrying<T>(inner: &mut Inner, mut attempt: impl FnMut(&mut Inner) -> Result<T>) -> Result<T> {
    let policy = inner.opts.retry;
    let max_attempts = policy.max_attempts.max(1);
    let mut prev = policy.base_delay;
    let mut tries = 0u32;
    loop {
        tries += 1;
        let err = match attempt(inner) {
            Ok(v) => return Ok(v),
            Err(e) => e,
        };
        let transport = err.downcast_ref::<TransportError>().is_some();
        if transport {
            // the stream state is unknowable (a frame may be half
            // written or half read): never reuse the link
            inner.link = None;
        }
        let busy = err
            .downcast_ref::<RemoteError>()
            .map(RemoteError::is_retryable)
            .unwrap_or(false);
        if !(transport || busy) || tries >= max_attempts {
            return Err(err);
        }
        if transport && inner.redial.is_none() {
            return Err(err); // nothing to reconnect with
        }
        let delay = policy.backoff_delay(inner.jitter_seq, prev);
        inner.jitter_seq += 1;
        prev = delay;
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
    }
}

/// Make sure `inner.link` is a live, handshaken connection, redialing
/// if necessary. A successful redial re-establishes every recorded
/// subscription and counts into `net.reconnects`.
fn ensure_link(inner: &mut Inner) -> Result<()> {
    if inner.link.is_some() {
        return Ok(());
    }
    let redial = match inner.redial.as_ref() {
        Some(r) => r,
        None => bail!(TransportError(
            "connection failed and this client has no redial handle".to_string()
        )),
    };
    let (reader, writer) = match redial() {
        Ok(pair) => pair,
        Err(e) => match e.downcast::<TransportError>() {
            Ok(te) => bail!(te),
            Err(e) => bail!(TransportError(format!("redial failed: {e:#}"))),
        },
    };
    let mut link = Link { reader: BufReader::new(reader), writer, banner: String::new() };
    handshake(&mut link)?;
    inner.link = Some(link);
    if inner.ever_connected {
        inner.reconnects += 1;
        if obs::enabled() {
            obs::registry().counter("net.reconnects").inc();
        }
    }
    inner.ever_connected = true;
    resubscribe(inner)
}

/// `HELLO` → `WELCOME` (or a structured refusal). A `NACK` here is the
/// server's accept gate shedding load before the handshake; its code
/// (`busy`) is retryable and classified by the caller.
fn handshake(link: &mut Link) -> Result<()> {
    send(link, &Frame::Hello { version: PROTOCOL_VERSION })?;
    match recv_frame(link)? {
        Frame::Welcome { version, banner } if version == PROTOCOL_VERSION => {
            link.banner = banner;
            Ok(())
        }
        Frame::Welcome { version, .. } => {
            bail!("server answered ct/{version}, this client speaks ct/{PROTOCOL_VERSION}")
        }
        Frame::Nack { code, message, .. } => bail!(RemoteError { code, message }),
        Frame::Error { code, message } => bail!("handshake refused: {code}: {message}"),
        other => bail!("handshake violation: expected WELCOME, got {other:?}"),
    }
}

/// Re-issue every recorded subscription on the fresh link. A
/// subscription the server now refuses (e.g. the cluster was
/// unregistered while we were away) is dropped with a warning rather
/// than failing the reconnect.
fn resubscribe(inner: &mut Inner) -> Result<()> {
    let subs = std::mem::take(&mut inner.subs);
    for (cluster, points) in subs {
        match try_subscribe(inner, &cluster, &points) {
            Ok(_) => inner.subs.push((cluster, points)),
            Err(e) => {
                if e.downcast_ref::<TransportError>().is_some() {
                    return Err(e); // the fresh link already died
                }
                log::warn!("dropping subscription to '{cluster}' after reconnect: {e:#}");
            }
        }
    }
    Ok(())
}

fn try_query_batch(
    inner: &mut Inner,
    queries: &[Query],
) -> Result<Vec<Result<Decision, RemoteError>>> {
    ensure_link(inner)?;
    let id = inner.next_id;
    inner.next_id += 1;
    // Snapshot the invalidation floors *before* sending: pushes
    // that arrive while we wait may postdate the server's answer
    // and must not count against it.
    let floor: u64 = queries
        .iter()
        .filter_map(|q| inner.invalidated.get(&q.cluster).copied())
        .max()
        .unwrap_or(0);
    let Inner { link, pushes, invalidated, .. } = &mut *inner;
    let link = link.as_mut().expect("ensure_link");
    send(link, &Frame::Batch { id, queries: queries.to_vec() })?;
    let (epoch, replies) = loop {
        match recv_response(link, pushes, invalidated)? {
            Frame::Decisions { id: rid, epoch, replies } if rid == id => break (epoch, replies),
            Frame::Nack { id: rid, code, message } if rid == id => {
                bail!(RemoteError { code, message })
            }
            other => bail!("expected DECISIONS for id {id}, got {other:?}"),
        }
    };
    if replies.len() != queries.len() {
        bail!("server answered {} replies to {} queries", replies.len(), queries.len());
    }
    let any_ok = replies.iter().any(|r| matches!(r, QueryReply::Decision(_)));
    if any_ok && epoch < floor {
        // The ordering guarantee says this cannot happen with a
        // conforming server; surface it instead of serving a
        // decision older than an acknowledged invalidation.
        bail!(RemoteError {
            code: codes::STALE.to_string(),
            message: format!(
                "decisions at epoch {epoch} predate acknowledged invalidate at {floor}"
            ),
        });
    }
    Ok(replies
        .into_iter()
        .map(|r| match r {
            QueryReply::Decision(d) => Ok(d),
            QueryReply::Error { code, message } => Err(RemoteError { code, message }),
        })
        .collect())
}

fn try_subscribe(inner: &mut Inner, cluster: &str, points: &[Point]) -> Result<(String, u64)> {
    ensure_link(inner)?;
    let id = inner.next_id;
    inner.next_id += 1;
    let Inner { link, pushes, invalidated, .. } = &mut *inner;
    let link = link.as_mut().expect("ensure_link");
    send(
        link,
        &Frame::Subscribe { id, cluster: cluster.to_string(), points: points.to_vec() },
    )?;
    loop {
        match recv_response(link, pushes, invalidated)? {
            Frame::Subscribed { id: rid, signature, epoch, .. } if rid == id => {
                return Ok((signature, epoch))
            }
            Frame::Nack { id: rid, code, message } if rid == id => {
                bail!(RemoteError { code, message })
            }
            other => bail!("expected SUBSCRIBED for id {id}, got {other:?}"),
        }
    }
}

fn try_ping(inner: &mut Inner) -> Result<u64> {
    ensure_link(inner)?;
    let id = inner.next_id;
    inner.next_id += 1;
    let Inner { link, pushes, invalidated, .. } = &mut *inner;
    let link = link.as_mut().expect("ensure_link");
    send(link, &Frame::Ping { id })?;
    loop {
        match recv_response(link, pushes, invalidated)? {
            Frame::Pong { id: rid, epoch } if rid == id => return Ok(epoch),
            other => bail!("expected PONG for id {id}, got {other:?}"),
        }
    }
}

fn send(link: &mut Link, frame: &Frame) -> Result<()> {
    let bytes = frame.encode();
    link.writer
        .write_all(bytes.as_bytes())
        .map_err(|e| TransportError(format!("writing frame: {e}")))?;
    link.writer
        .flush()
        .map_err(|e| TransportError(format!("flushing frame: {e}")))?;
    Ok(())
}

/// Read exactly one frame; every failure mode (I/O error, deadline,
/// EOF, undecodable bytes) is a [`TransportError`].
fn recv_frame(link: &mut Link) -> Result<Frame> {
    match Frame::read_from(&mut link.reader) {
        Ok(Some(f)) => Ok(f),
        Ok(None) => bail!(TransportError("server closed the connection".to_string())),
        Err(e) => bail!(TransportError(format!("reading frame: {e}"))),
    }
}

/// Read frames until a non-push arrives, buffering pushes (and folding
/// `INVALIDATE` epochs into the per-cluster floor) on the way.
fn recv_response(
    link: &mut Link,
    pushes: &mut VecDeque<Push>,
    invalidated: &mut HashMap<String, u64>,
) -> Result<Frame> {
    loop {
        match recv_frame(link)? {
            Frame::Invalidate { epoch, cluster, .. } => {
                let floor = invalidated.entry(cluster.clone()).or_insert(0);
                *floor = (*floor).max(epoch);
                pushes.push_back(Push::Invalidate { epoch, cluster });
            }
            Frame::TableUpdate { epoch, cluster, rows, .. } => {
                pushes.push_back(Push::TableUpdate { epoch, cluster, rows });
            }
            other => return Ok(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_is_deterministic_and_bounded() {
        let p = RetryPolicy::resilient();
        let mut prev = p.base_delay;
        let mut schedule = Vec::new();
        for seq in 0..32u64 {
            let d = p.backoff_delay(seq, prev);
            assert!(d >= p.base_delay, "delay {d:?} under base at seq {seq}");
            assert!(d <= p.max_delay, "delay {d:?} over cap at seq {seq}");
            schedule.push(d);
            prev = d;
        }
        // byte-stable: the same seeds reproduce the same schedule
        let mut prev2 = p.base_delay;
        for (seq, want) in schedule.iter().enumerate() {
            let d = p.backoff_delay(seq as u64, prev2);
            assert_eq!(d, *want);
            prev2 = d;
        }
        // and it actually grows toward the cap (decorrelated jitter
        // expands the window as prev grows)
        assert!(schedule.iter().any(|d| *d > p.base_delay * 4));
    }

    #[test]
    fn jitter_fraction_is_uniformish_and_pure() {
        let mut sum = 0.0;
        for seq in 0..1000u64 {
            let f = jitter_frac(seq);
            assert!((0.0..1.0).contains(&f));
            assert_eq!(f, jitter_frac(seq), "pure function of seq");
            sum += f;
        }
        let mean = sum / 1000.0;
        assert!((0.4..0.6).contains(&mean), "mean {mean} far from 0.5");
    }

    #[test]
    fn fail_fast_policy_has_one_attempt_and_busy_is_the_only_retryable_code() {
        assert_eq!(RetryPolicy::fail_fast().max_attempts, 1);
        assert!(RemoteError { code: codes::BUSY.into(), message: String::new() }.is_retryable());
        for code in [codes::VERSION, codes::MALFORMED, codes::TOO_LARGE, codes::UNREGISTERED,
                     codes::UNSUPPORTED, codes::STALE]
        {
            let e = RemoteError { code: code.into(), message: String::new() };
            assert!(!e.is_retryable(), "{code} must be fatal");
        }
    }
}
