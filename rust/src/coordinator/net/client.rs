//! [`NetClient`]: the remote twin of the coordinator's warm-read
//! surface. `decision()` / `query_batch()` mirror
//! [`Coordinator::decision`](super::super::service::Coordinator::decision)
//! over the `ct/1` wire, `subscribe()` registers for push updates, and
//! the client enforces the protocol's invalidation-ordering guarantee
//! (docs/PROTOCOL.md §6): it never returns a decision computed from a
//! snapshot older than an `INVALIDATE` it had already observed when
//! the query was sent.
//!
//! ## Concurrency contract
//!
//! * The whole connection state (reader, writer, id counter, buffered
//!   pushes, per-cluster invalidation floors) lives behind **one
//!   mutex**; every method takes `&self`, so a [`NetClient`] can be
//!   shared across threads like the in-process coordinator — requests
//!   from different threads serialize per connection (open one client
//!   per thread for parallelism; the bench does exactly that).
//! * The transport is any `Read`/`Write` pair: a `TcpStream` clone
//!   pair ([`NetClient::connect`]) or a loopback pipe pair
//!   ([`super::loopback::LoopbackServer::connect`]). The client is the
//!   only reader of its stream.
//! * Pushes (`INVALIDATE` / `TABLEUPDATE`) arrive interleaved with
//!   responses and are buffered internally by whichever request is
//!   currently draining the stream; [`NetClient::take_pushes`] hands
//!   them out, and [`NetClient::wait_pushes`] polls for them with
//!   `PING` round-trips (which works on any blocking transport — no
//!   read timeouts needed).

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::tuner::{Decision, Op};

use super::frame::{codes, Frame, Point, Query, QueryReply, PROTOCOL_VERSION};

/// A structured error the server returned for one query or request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemoteError {
    pub code: String,
    pub message: String,
}

impl fmt::Display for RemoteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

impl std::error::Error for RemoteError {}

/// A server-initiated push, as surfaced to client code.
#[derive(Debug, Clone, PartialEq)]
pub enum Push {
    /// Decisions for `cluster` carrying an epoch `< epoch` are stale.
    Invalidate { epoch: u64, cluster: String },
    /// Fresh decisions for every subscribed point of `cluster`.
    TableUpdate { epoch: u64, cluster: String, rows: Vec<(Point, Decision)> },
}

struct Inner {
    reader: BufReader<Box<dyn Read + Send>>,
    writer: Box<dyn Write + Send>,
    next_id: u64,
    pushes: VecDeque<Push>,
    /// Per-cluster invalidation floor: the highest `INVALIDATE` epoch
    /// observed. Decisions at or above the floor recorded *before* a
    /// query was sent are guaranteed by the server; a response below
    /// that floor is a protocol violation surfaced as `stale`.
    invalidated: HashMap<String, u64>,
    banner: String,
}

/// A `ct/1` client connection. See the module docs for the sharing and
/// push-delivery contract.
pub struct NetClient {
    inner: Mutex<Inner>,
}

impl NetClient {
    /// Connect over TCP and handshake.
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = stream.try_clone().context("cloning stream")?;
        NetClient::from_transport(Box::new(reader), Box::new(stream))
    }

    /// Handshake over an arbitrary transport (the loopback pipes, or a
    /// pre-connected socket pair).
    pub fn from_transport(
        reader: Box<dyn Read + Send>,
        writer: Box<dyn Write + Send>,
    ) -> Result<NetClient> {
        let mut inner = Inner {
            reader: BufReader::new(reader),
            writer,
            next_id: 1,
            pushes: VecDeque::new(),
            invalidated: HashMap::new(),
            banner: String::new(),
        };
        send(&mut inner, &Frame::Hello { version: PROTOCOL_VERSION })?;
        match recv_response(&mut inner)? {
            Frame::Welcome { version, banner } if version == PROTOCOL_VERSION => {
                inner.banner = banner;
            }
            Frame::Welcome { version, .. } => {
                bail!("server answered ct/{version}, this client speaks ct/{PROTOCOL_VERSION}")
            }
            Frame::Error { code, message } => bail!("handshake refused: {code}: {message}"),
            other => bail!("handshake violation: expected WELCOME, got {other:?}"),
        }
        Ok(NetClient { inner: Mutex::new(inner) })
    }

    /// The server's `WELCOME` banner.
    pub fn banner(&self) -> String {
        self.inner.lock().unwrap().banner.clone()
    }

    /// The warm-read surface, one point at a time: exactly the
    /// in-process `Coordinator::decision` signature, answered remotely.
    pub fn decision(&self, op: Op, cluster: &str, p: usize, m: u64) -> Result<Decision> {
        let mut replies = self.query_batch(&[Query {
            op,
            cluster: cluster.to_string(),
            p,
            m,
        }])?;
        match replies.pop().context("server answered an empty batch")? {
            Ok(d) => Ok(d),
            Err(e) => Err(e.into()),
        }
    }

    /// One batched round-trip: every query answered in order, each
    /// individually a decision or a structured error (a batch can
    /// partially succeed).
    pub fn query_batch(&self, queries: &[Query]) -> Result<Vec<Result<Decision, RemoteError>>> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        // Snapshot the invalidation floors *before* sending: pushes
        // that arrive while we wait may postdate the server's answer
        // and must not count against it.
        let floor: u64 = queries
            .iter()
            .filter_map(|q| inner.invalidated.get(&q.cluster).copied())
            .max()
            .unwrap_or(0);
        send(&mut inner, &Frame::Batch { id, queries: queries.to_vec() })?;
        let (epoch, replies) = loop {
            match recv_response(&mut inner)? {
                Frame::Decisions { id: rid, epoch, replies } if rid == id => {
                    break (epoch, replies)
                }
                Frame::Nack { id: rid, code, message } if rid == id => {
                    bail!(RemoteError { code, message })
                }
                other => bail!("expected DECISIONS for id {id}, got {other:?}"),
            }
        };
        if replies.len() != queries.len() {
            bail!("server answered {} replies to {} queries", replies.len(), queries.len());
        }
        let any_ok = replies.iter().any(|r| matches!(r, QueryReply::Decision(_)));
        if any_ok && epoch < floor {
            // The ordering guarantee says this cannot happen with a
            // conforming server; surface it instead of serving a
            // decision older than an acknowledged invalidation.
            bail!(RemoteError {
                code: codes::STALE.to_string(),
                message: format!(
                    "decisions at epoch {epoch} predate acknowledged invalidate at {floor}"
                ),
            });
        }
        Ok(replies
            .into_iter()
            .map(|r| match r {
                QueryReply::Decision(d) => Ok(d),
                QueryReply::Error { code, message } => Err(RemoteError { code, message }),
            })
            .collect())
    }

    /// Subscribe to `(op, P, m)` points of one cluster. Returns the
    /// cluster's signature key and the subscription epoch; the initial
    /// `TABLEUPDATE` lands in the push buffer immediately after.
    pub fn subscribe(&self, cluster: &str, points: &[Point]) -> Result<(String, u64)> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        send(
            &mut inner,
            &Frame::Subscribe { id, cluster: cluster.to_string(), points: points.to_vec() },
        )?;
        loop {
            match recv_response(&mut inner)? {
                Frame::Subscribed { id: rid, signature, epoch, .. } if rid == id => {
                    return Ok((signature, epoch))
                }
                Frame::Nack { id: rid, code, message } if rid == id => {
                    bail!(RemoteError { code, message })
                }
                other => bail!("expected SUBSCRIBED for id {id}, got {other:?}"),
            }
        }
    }

    /// One `PING` round-trip; returns the server's current publish
    /// epoch. Also drains any queued pushes into the buffer.
    pub fn ping(&self) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        send(&mut inner, &Frame::Ping { id })?;
        loop {
            match recv_response(&mut inner)? {
                Frame::Pong { id: rid, epoch } if rid == id => return Ok(epoch),
                other => bail!("expected PONG for id {id}, got {other:?}"),
            }
        }
    }

    /// Drain every buffered push (non-blocking; pushes are buffered as
    /// a side effect of any request round-trip).
    pub fn take_pushes(&self) -> Vec<Push> {
        self.inner.lock().unwrap().pushes.drain(..).collect()
    }

    /// Poll (via `PING` round-trips) until at least `min` pushes are
    /// buffered or `timeout` elapses; returns whatever arrived. Works
    /// on any blocking transport — no socket read timeouts involved.
    pub fn wait_pushes(&self, min: usize, timeout: Duration) -> Result<Vec<Push>> {
        let deadline = Instant::now() + timeout;
        loop {
            {
                let mut inner = self.inner.lock().unwrap();
                if inner.pushes.len() >= min {
                    return Ok(inner.pushes.drain(..).collect());
                }
            }
            if Instant::now() >= deadline {
                return Ok(self.take_pushes());
            }
            self.ping()?; // drains anything the server queued before the PONG
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Ask the server to shut down (requires `--allow-remote-shutdown`
    /// on the server side). Returns once the server acknowledges with
    /// `BYE`.
    pub fn shutdown_server(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        send(&mut inner, &Frame::Shutdown)?;
        match recv_response(&mut inner)? {
            Frame::Bye => Ok(()),
            Frame::Error { code, message } => bail!(RemoteError { code, message }),
            other => bail!("expected BYE, got {other:?}"),
        }
    }

    /// Polite hangup (best-effort `BYE`).
    pub fn close(self) {
        let mut inner = self.inner.lock().unwrap();
        let _ = send(&mut inner, &Frame::Bye);
    }
}

fn send(inner: &mut Inner, frame: &Frame) -> Result<()> {
    let bytes = frame.encode();
    inner.writer.write_all(bytes.as_bytes()).context("writing frame")?;
    inner.writer.flush().context("flushing frame")?;
    Ok(())
}

/// Read frames until a non-push arrives, buffering pushes (and folding
/// `INVALIDATE` epochs into the per-cluster floor) on the way. A
/// connection-level `ERROR` or EOF is fatal.
fn recv_response(inner: &mut Inner) -> Result<Frame> {
    loop {
        let frame = Frame::read_from(&mut inner.reader)
            .map_err(anyhow::Error::from)?
            .context("server closed the connection")?;
        match frame {
            Frame::Invalidate { epoch, cluster, .. } => {
                let floor = inner.invalidated.entry(cluster.clone()).or_insert(0);
                *floor = (*floor).max(epoch);
                inner.pushes.push_back(Push::Invalidate { epoch, cluster });
            }
            Frame::TableUpdate { epoch, cluster, rows, .. } => {
                inner.pushes.push_back(Push::TableUpdate { epoch, cluster, rows });
            }
            other => return Ok(other),
        }
    }
}
