//! Wire frames for the coordinator's TCP protocol (`ct/1`): a
//! line-delimited, TAB-separated, versioned format — the network twin
//! of the `tuner::persist` TSV idiom, hand-rolled because the crate
//! vendors no serialization dependency. The normative grammar lives in
//! `docs/PROTOCOL.md`; this module is its only implementation, shared
//! verbatim by the server, the client, and the loopback transport so
//! the three cannot drift apart.
//!
//! Every frame is one header line plus, for the batched frames
//! (`BATCH`, `DECISIONS`, `SUBSCRIBE`, `TABLEUPDATE`), exactly the
//! item-line count the header declares. [`Frame::encode`] produces the
//! canonical byte form; [`Frame::read_from`] parses exactly one frame
//! off a [`BufRead`] and is total: malformed, truncated, or oversized
//! input returns a structured [`FrameError`] — never a panic, never an
//! unbounded allocation (lines are capped at [`MAX_LINE_BYTES`], item
//! counts at [`MAX_BATCH_ITEMS`]; the property suite fuzzes both).
//!
//! ## Concurrency contract
//!
//! This module is pure data: no statics, no interior mutability, no
//! locks. Encoding and decoding are plain value transformations, safe
//! from any thread. Framing state (partial reads) lives entirely in
//! the caller's `BufRead`, so one reader must own one stream — the
//! server gives each connection a dedicated reader thread, and
//! [`super::client::NetClient`] serializes its reader behind a mutex.

use std::fmt;
use std::io::BufRead;

use crate::collectives::Strategy;
use crate::tuner::{Decision, Op};

/// The one protocol revision this build speaks. `HELLO`/`WELCOME`
/// negotiate on exact equality; see `docs/PROTOCOL.md` §2.
pub const PROTOCOL_VERSION: u32 = 1;

/// Hard cap on one frame line, including the terminating newline.
/// A line that exceeds this is rejected before it is buffered whole.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Hard cap on the item count a batched frame may declare.
pub const MAX_BATCH_ITEMS: usize = 4096;

/// Stable machine-readable error codes carried by `ERROR`, `NACK`,
/// and per-query `E` items (`docs/PROTOCOL.md` §7).
pub mod codes {
    /// Version negotiation failed.
    pub const VERSION: &str = "version";
    /// Syntactically invalid frame; the connection is closed.
    pub const MALFORMED: &str = "malformed";
    /// A line or item count exceeded its hard cap.
    pub const TOO_LARGE: &str = "too-large";
    /// The named cluster is not in the coordinator's registry.
    pub const UNREGISTERED: &str = "unregistered";
    /// The frame is valid but this server refuses it (e.g. remote
    /// shutdown not enabled).
    pub const UNSUPPORTED: &str = "unsupported";
    /// A decision was computed from a snapshot older than an
    /// acknowledged `INVALIDATE` (client-side detection; servers never
    /// emit this).
    pub const STALE: &str = "stale";
    /// The server is at its connection limit and shed this connection
    /// before the handshake (`NACK` with id 0). Always retryable:
    /// back off and redial.
    pub const BUSY: &str = "busy";
}

/// One `(op, cluster, P, m)` question inside a `BATCH`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Query {
    pub op: Op,
    pub cluster: String,
    pub p: usize,
    pub m: u64,
}

/// One `(op, P, m)` grid point of a subscription (the cluster is named
/// once in the `SUBSCRIBE` header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Point {
    pub op: Op,
    pub p: usize,
    pub m: u64,
}

/// One per-query outcome inside a `DECISIONS` frame: a decision (`D`
/// item) or a structured error (`E` item) — a batch can partially
/// succeed without failing the connection.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryReply {
    Decision(Decision),
    Error { code: String, message: String },
}

/// Every `ct/1` frame. Client-originated: `Hello`, `Ping`, `Batch`,
/// `Subscribe`, `Shutdown`, `Bye`. Server-originated: `Welcome`,
/// `Pong`, `Decisions`, `Subscribed`, `Nack`, `Error`, `Bye`, and the
/// pushes `Invalidate` / `TableUpdate`. The codec itself is
/// direction-agnostic; direction rules are enforced by the endpoints.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Hello { version: u32 },
    Welcome { version: u32, banner: String },
    Ping { id: u64 },
    Pong { id: u64, epoch: u64 },
    Batch { id: u64, queries: Vec<Query> },
    Decisions { id: u64, epoch: u64, replies: Vec<QueryReply> },
    Subscribe { id: u64, cluster: String, points: Vec<Point> },
    Subscribed { id: u64, cluster: String, signature: String, epoch: u64 },
    /// Request-level refusal, keyed by the request's `id`.
    Nack { id: u64, code: String, message: String },
    /// Push: the cluster's resident tables were dropped; decisions
    /// carrying an epoch `< epoch` are stale (`docs/PROTOCOL.md` §6).
    Invalidate { seq: u64, epoch: u64, cluster: String },
    /// Push: fresh decisions for every subscribed point.
    TableUpdate { seq: u64, epoch: u64, cluster: String, rows: Vec<(Point, Decision)> },
    /// Connection-level fatal error; the sender closes after this.
    Error { code: String, message: String },
    Shutdown,
    Bye,
}

/// A structured decode failure: a stable [`codes`] value plus a
/// human-readable message. Servers echo it back as an `ERROR` frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameError {
    pub code: &'static str,
    pub message: String,
}

impl FrameError {
    fn malformed(message: impl Into<String>) -> FrameError {
        FrameError { code: codes::MALFORMED, message: message.into() }
    }

    fn too_large(message: impl Into<String>) -> FrameError {
        FrameError { code: codes::TOO_LARGE, message: message.into() }
    }
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

impl std::error::Error for FrameError {}

/// TAB and newline are field/frame delimiters; strings carried in
/// frames must not contain them. Encoding replaces offenders with a
/// space rather than producing an unparseable wire (the strict decoder
/// would reject it and kill the connection over a log message).
fn sanitize(s: &str) -> String {
    if s.bytes().any(|b| b == b'\t' || b == b'\n' || b == b'\r') {
        s.replace(['\t', '\n', '\r'], " ")
    } else {
        s.to_string()
    }
}

fn push_decision(out: &mut String, d: &Decision) {
    out.push_str(d.strategy.name());
    out.push('\t');
    match d.segment {
        Some(s) => out.push_str(&s.to_string()),
        None => out.push('-'),
    }
    out.push('\t');
    // Shortest-roundtrip float formatting: re-encoding a decoded frame
    // reproduces the bytes exactly (the round-trip property test).
    out.push_str(&format!("{}", d.predicted));
}

impl Frame {
    /// Canonical wire bytes: header line plus declared item lines,
    /// every line newline-terminated.
    pub fn encode(&self) -> String {
        let mut s = String::new();
        match self {
            Frame::Hello { version } => {
                s.push_str(&format!("HELLO\tct\t{version}\n"));
            }
            Frame::Welcome { version, banner } => {
                s.push_str(&format!("WELCOME\tct\t{version}\t{}\n", sanitize(banner)));
            }
            Frame::Ping { id } => s.push_str(&format!("PING\t{id}\n")),
            Frame::Pong { id, epoch } => s.push_str(&format!("PONG\t{id}\t{epoch}\n")),
            Frame::Batch { id, queries } => {
                s.push_str(&format!("BATCH\t{id}\t{}\n", queries.len()));
                for q in queries {
                    s.push_str(&format!(
                        "Q\t{}\t{}\t{}\t{}\n",
                        q.op.name(),
                        sanitize(&q.cluster),
                        q.p,
                        q.m
                    ));
                }
            }
            Frame::Decisions { id, epoch, replies } => {
                s.push_str(&format!("DECISIONS\t{id}\t{epoch}\t{}\n", replies.len()));
                for r in replies {
                    match r {
                        QueryReply::Decision(d) => {
                            s.push_str("D\t");
                            push_decision(&mut s, d);
                            s.push('\n');
                        }
                        QueryReply::Error { code, message } => {
                            s.push_str(&format!(
                                "E\t{}\t{}\n",
                                sanitize(code),
                                sanitize(message)
                            ));
                        }
                    }
                }
            }
            Frame::Subscribe { id, cluster, points } => {
                s.push_str(&format!(
                    "SUBSCRIBE\t{id}\t{}\t{}\n",
                    sanitize(cluster),
                    points.len()
                ));
                for p in points {
                    s.push_str(&format!("P\t{}\t{}\t{}\n", p.op.name(), p.p, p.m));
                }
            }
            Frame::Subscribed { id, cluster, signature, epoch } => {
                s.push_str(&format!(
                    "SUBSCRIBED\t{id}\t{}\t{}\t{epoch}\n",
                    sanitize(cluster),
                    sanitize(signature)
                ));
            }
            Frame::Nack { id, code, message } => {
                s.push_str(&format!(
                    "NACK\t{id}\t{}\t{}\n",
                    sanitize(code),
                    sanitize(message)
                ));
            }
            Frame::Invalidate { seq, epoch, cluster } => {
                s.push_str(&format!("INVALIDATE\t{seq}\t{epoch}\t{}\n", sanitize(cluster)));
            }
            Frame::TableUpdate { seq, epoch, cluster, rows } => {
                s.push_str(&format!(
                    "TABLEUPDATE\t{seq}\t{epoch}\t{}\t{}\n",
                    sanitize(cluster),
                    rows.len()
                ));
                for (p, d) in rows {
                    s.push_str(&format!("U\t{}\t{}\t{}\t", p.op.name(), p.p, p.m));
                    push_decision(&mut s, d);
                    s.push('\n');
                }
            }
            Frame::Error { code, message } => {
                s.push_str(&format!("ERROR\t{}\t{}\n", sanitize(code), sanitize(message)));
            }
            Frame::Shutdown => s.push_str("SHUTDOWN\n"),
            Frame::Bye => s.push_str("BYE\n"),
        }
        s
    }

    /// Read exactly one frame. `Ok(None)` is a clean EOF *between*
    /// frames; EOF inside a frame (missing newline, missing item lines)
    /// is a [`FrameError`]. Never panics on any input byte sequence.
    pub fn read_from(r: &mut impl BufRead) -> Result<Option<Frame>, FrameError> {
        let header = match read_line(r)? {
            Some(h) => h,
            None => return Ok(None),
        };
        let f: Vec<&str> = header.split('\t').collect();
        let frame = match f[0] {
            "HELLO" => {
                expect_fields(&f, 3)?;
                expect_proto(f[1])?;
                Frame::Hello { version: parse_u32(f[2], "version")? }
            }
            "WELCOME" => {
                expect_fields(&f, 4)?;
                expect_proto(f[1])?;
                Frame::Welcome {
                    version: parse_u32(f[2], "version")?,
                    banner: f[3].to_string(),
                }
            }
            "PING" => {
                expect_fields(&f, 2)?;
                Frame::Ping { id: parse_u64(f[1], "id")? }
            }
            "PONG" => {
                expect_fields(&f, 3)?;
                Frame::Pong { id: parse_u64(f[1], "id")?, epoch: parse_u64(f[2], "epoch")? }
            }
            "BATCH" => {
                expect_fields(&f, 3)?;
                let id = parse_u64(f[1], "id")?;
                let n = parse_count(f[2])?;
                let mut queries = Vec::with_capacity(n);
                for _ in 0..n {
                    let item = read_item(r, "Q")?;
                    let g: Vec<&str> = item.split('\t').collect();
                    expect_fields(&g, 5)?;
                    queries.push(Query {
                        op: parse_op(g[1])?,
                        cluster: parse_cluster(g[2])?,
                        p: parse_usize(g[3], "p")?,
                        m: parse_u64(g[4], "m")?,
                    });
                }
                Frame::Batch { id, queries }
            }
            "DECISIONS" => {
                expect_fields(&f, 4)?;
                let id = parse_u64(f[1], "id")?;
                let epoch = parse_u64(f[2], "epoch")?;
                let n = parse_count(f[3])?;
                let mut replies = Vec::with_capacity(n);
                for _ in 0..n {
                    let item = match read_line(r)? {
                        Some(l) => l,
                        None => return Err(FrameError::malformed("truncated frame: missing item")),
                    };
                    let g: Vec<&str> = item.split('\t').collect();
                    match g[0] {
                        "D" => {
                            expect_fields(&g, 4)?;
                            replies.push(QueryReply::Decision(parse_decision(g[1], g[2], g[3])?));
                        }
                        "E" => {
                            expect_fields(&g, 3)?;
                            replies.push(QueryReply::Error {
                                code: g[1].to_string(),
                                message: g[2].to_string(),
                            });
                        }
                        other => {
                            return Err(FrameError::malformed(format!(
                                "expected 'D' or 'E' item line, got '{other}'"
                            )))
                        }
                    }
                }
                Frame::Decisions { id, epoch, replies }
            }
            "SUBSCRIBE" => {
                expect_fields(&f, 4)?;
                let id = parse_u64(f[1], "id")?;
                let cluster = parse_cluster(f[2])?;
                let n = parse_count(f[3])?;
                let mut points = Vec::with_capacity(n);
                for _ in 0..n {
                    let item = read_item(r, "P")?;
                    let g: Vec<&str> = item.split('\t').collect();
                    expect_fields(&g, 4)?;
                    points.push(Point {
                        op: parse_op(g[1])?,
                        p: parse_usize(g[2], "p")?,
                        m: parse_u64(g[3], "m")?,
                    });
                }
                Frame::Subscribe { id, cluster, points }
            }
            "SUBSCRIBED" => {
                expect_fields(&f, 5)?;
                Frame::Subscribed {
                    id: parse_u64(f[1], "id")?,
                    cluster: parse_cluster(f[2])?,
                    signature: f[3].to_string(),
                    epoch: parse_u64(f[4], "epoch")?,
                }
            }
            "NACK" => {
                expect_fields(&f, 4)?;
                Frame::Nack {
                    id: parse_u64(f[1], "id")?,
                    code: f[2].to_string(),
                    message: f[3].to_string(),
                }
            }
            "INVALIDATE" => {
                expect_fields(&f, 4)?;
                Frame::Invalidate {
                    seq: parse_u64(f[1], "seq")?,
                    epoch: parse_u64(f[2], "epoch")?,
                    cluster: parse_cluster(f[3])?,
                }
            }
            "TABLEUPDATE" => {
                expect_fields(&f, 5)?;
                let seq = parse_u64(f[1], "seq")?;
                let epoch = parse_u64(f[2], "epoch")?;
                let cluster = parse_cluster(f[3])?;
                let n = parse_count(f[4])?;
                let mut rows = Vec::with_capacity(n);
                for _ in 0..n {
                    let item = read_item(r, "U")?;
                    let g: Vec<&str> = item.split('\t').collect();
                    expect_fields(&g, 7)?;
                    let point = Point {
                        op: parse_op(g[1])?,
                        p: parse_usize(g[2], "p")?,
                        m: parse_u64(g[3], "m")?,
                    };
                    rows.push((point, parse_decision(g[4], g[5], g[6])?));
                }
                Frame::TableUpdate { seq, epoch, cluster, rows }
            }
            "ERROR" => {
                expect_fields(&f, 3)?;
                Frame::Error { code: f[1].to_string(), message: f[2].to_string() }
            }
            "SHUTDOWN" => {
                expect_fields(&f, 1)?;
                Frame::Shutdown
            }
            "BYE" => {
                expect_fields(&f, 1)?;
                Frame::Bye
            }
            other => {
                return Err(FrameError::malformed(format!("unknown frame '{other}'")));
            }
        };
        Ok(Some(frame))
    }
}

impl Frame {
    /// Decode a string that must contain exactly one frame (tests and
    /// tooling; endpoints use [`Frame::read_from`] on the live stream).
    pub fn decode(text: &str) -> Result<Frame, FrameError> {
        let mut cur = std::io::Cursor::new(text.as_bytes());
        let frame = Frame::read_from(&mut cur)?
            .ok_or_else(|| FrameError::malformed("empty input"))?;
        if (cur.position() as usize) < text.len() {
            return Err(FrameError::malformed("trailing bytes after frame"));
        }
        Ok(frame)
    }
}

/// One capped line, without its newline. `Ok(None)` on immediate EOF;
/// EOF before the newline, a line over [`MAX_LINE_BYTES`], or invalid
/// UTF-8 are errors.
fn read_line(r: &mut impl BufRead) -> Result<Option<String>, FrameError> {
    let mut buf = Vec::new();
    let n = r
        .take((MAX_LINE_BYTES + 1) as u64)
        .read_until(b'\n', &mut buf)
        .map_err(|e| FrameError::malformed(format!("read failed: {e}")))?;
    if n == 0 {
        return Ok(None);
    }
    if n > MAX_LINE_BYTES {
        return Err(FrameError::too_large(format!("line exceeds {MAX_LINE_BYTES} bytes")));
    }
    if buf.last() != Some(&b'\n') {
        return Err(FrameError::malformed("truncated frame: missing newline"));
    }
    buf.pop();
    String::from_utf8(buf).map(Some).map_err(|_| FrameError::malformed("invalid UTF-8"))
}

/// One item line that must carry the given tag.
fn read_item(r: &mut impl BufRead, tag: &str) -> Result<String, FrameError> {
    match read_line(r)? {
        Some(l) if l.split('\t').next() == Some(tag) => Ok(l),
        Some(l) => Err(FrameError::malformed(format!(
            "expected '{tag}' item line, got '{}'",
            l.split('\t').next().unwrap_or("")
        ))),
        None => Err(FrameError::malformed("truncated frame: missing item line")),
    }
}

fn expect_fields(f: &[&str], want: usize) -> Result<(), FrameError> {
    if f.len() != want {
        return Err(FrameError::malformed(format!(
            "'{}': expected {want} fields, got {}",
            f[0],
            f.len()
        )));
    }
    Ok(())
}

fn expect_proto(name: &str) -> Result<(), FrameError> {
    if name != "ct" {
        return Err(FrameError::malformed(format!("unknown protocol '{name}'")));
    }
    Ok(())
}

fn parse_u64(s: &str, what: &str) -> Result<u64, FrameError> {
    s.parse().map_err(|_| FrameError::malformed(format!("bad {what} '{s}'")))
}

fn parse_u32(s: &str, what: &str) -> Result<u32, FrameError> {
    s.parse().map_err(|_| FrameError::malformed(format!("bad {what} '{s}'")))
}

fn parse_usize(s: &str, what: &str) -> Result<usize, FrameError> {
    s.parse().map_err(|_| FrameError::malformed(format!("bad {what} '{s}'")))
}

fn parse_count(s: &str) -> Result<usize, FrameError> {
    let n = parse_usize(s, "item count")?;
    if n > MAX_BATCH_ITEMS {
        return Err(FrameError::too_large(format!(
            "item count {n} exceeds the {MAX_BATCH_ITEMS} cap"
        )));
    }
    Ok(n)
}

fn parse_op(s: &str) -> Result<Op, FrameError> {
    Op::from_name(s).ok_or_else(|| FrameError::malformed(format!("unknown op '{s}'")))
}

fn parse_cluster(s: &str) -> Result<String, FrameError> {
    if s.is_empty() {
        return Err(FrameError::malformed("empty cluster name"));
    }
    Ok(s.to_string())
}

fn parse_decision(strategy: &str, segment: &str, predicted: &str) -> Result<Decision, FrameError> {
    let strategy = Strategy::from_name(strategy)
        .ok_or_else(|| FrameError::malformed(format!("unknown strategy '{strategy}'")))?;
    let segment = match segment {
        "-" => None,
        s => Some(parse_u64(s, "segment")?),
    };
    let predicted: f64 = predicted
        .parse()
        .map_err(|_| FrameError::malformed(format!("bad predicted time '{predicted}'")))?;
    Ok(Decision { strategy, segment, predicted })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_line_frames_roundtrip() {
        for f in [
            Frame::Hello { version: 1 },
            Frame::Welcome { version: 1, banner: "coordd test".into() },
            Frame::Ping { id: 7 },
            Frame::Pong { id: 7, epoch: 42 },
            Frame::Subscribed {
                id: 3,
                cluster: "fe-0".into(),
                signature: "sig-p12-o127-l-170-g-203".into(),
                epoch: 9,
            },
            Frame::Nack { id: 4, code: "unregistered".into(), message: "no such cluster".into() },
            Frame::Invalidate { seq: 1, epoch: 12, cluster: "ge-1".into() },
            Frame::Error { code: "malformed".into(), message: "what".into() },
            Frame::Shutdown,
            Frame::Bye,
        ] {
            let enc = f.encode();
            assert_eq!(Frame::decode(&enc).unwrap(), f, "{enc:?}");
            assert_eq!(Frame::decode(&enc).unwrap().encode(), enc, "byte-identical");
        }
    }

    #[test]
    fn batched_frames_roundtrip() {
        let d = Decision {
            strategy: Strategy::BcastSegChain,
            segment: Some(4096),
            predicted: 1.5e-3,
        };
        let d2 = Decision { strategy: Strategy::ScatterFlat, segment: None, predicted: 0.25 };
        let p = Point { op: Op::Bcast, p: 12, m: 65536 };
        for f in [
            Frame::Batch {
                id: 10,
                queries: vec![
                    Query { op: Op::Bcast, cluster: "fe-0".into(), p: 12, m: 1 << 20 },
                    Query { op: Op::AllReduce, cluster: "ge-0".into(), p: 8, m: 1 },
                ],
            },
            Frame::Batch { id: 11, queries: vec![] },
            Frame::Decisions {
                id: 10,
                epoch: 5,
                replies: vec![
                    QueryReply::Decision(d),
                    QueryReply::Error { code: "unregistered".into(), message: "nope".into() },
                ],
            },
            Frame::Subscribe { id: 2, cluster: "fe-0".into(), points: vec![p] },
            Frame::TableUpdate {
                seq: 3,
                epoch: 8,
                cluster: "fe-0".into(),
                rows: vec![(p, d), (Point { op: Op::Gather, p: 4, m: 64 }, d2)],
            },
        ] {
            let enc = f.encode();
            assert_eq!(Frame::decode(&enc).unwrap(), f, "{enc:?}");
            assert_eq!(Frame::decode(&enc).unwrap().encode(), enc, "byte-identical");
        }
    }

    #[test]
    fn rejects_garbage_without_panicking() {
        for bad in [
            "",
            "NOPE\n",
            "HELLO\tct\n",
            "HELLO\tmq\t1\n",
            "HELLO\tct\tx\n",
            "PING\t1", // no newline
            "BATCH\t1\t2\nQ\tbcast\ta\t2\t4\n", // declares 2 items, has 1
            "BATCH\t1\t1\nP\tbcast\t2\t4\n",    // wrong item tag
            "BATCH\t1\t1\nQ\twarp\ta\t2\t4\n",  // unknown op
            "BATCH\t1\t1\nQ\tbcast\t\t2\t4\n",  // empty cluster
            "BATCH\t1\t99999\n",                // count over cap
            "DECISIONS\t1\t0\t1\nD\tbcast/flat\t-\tnope\n",
            "DECISIONS\t1\t0\t1\nD\twarp/flat\t-\t1.0\n",
            "HELLO\tct\t1\nBYE\n", // trailing bytes after frame (decode)
        ] {
            assert!(Frame::decode(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn oversized_line_is_rejected_not_buffered() {
        let huge = format!("ERROR\tx\t{}\n", "y".repeat(MAX_LINE_BYTES));
        let err = Frame::decode(&huge).unwrap_err();
        assert_eq!(err.code, codes::TOO_LARGE);
    }

    #[test]
    fn every_strict_prefix_of_a_frame_is_rejected() {
        let f = Frame::TableUpdate {
            seq: 3,
            epoch: 8,
            cluster: "fe-0".into(),
            rows: vec![(
                Point { op: Op::Bcast, p: 12, m: 65536 },
                Decision { strategy: Strategy::BcastChain, segment: None, predicted: 2.5e-4 },
            )],
        };
        let enc = f.encode();
        for k in 1..enc.len() {
            assert!(Frame::decode(&enc[..k]).is_err(), "prefix {k} of {enc:?}");
        }
    }

    #[test]
    fn sanitizer_keeps_delimiters_out_of_encoded_frames() {
        let f = Frame::Error { code: "malformed".into(), message: "tab\there\nand newline".into() };
        let enc = f.encode();
        let reparsed = Frame::decode(&enc).unwrap();
        match reparsed {
            Frame::Error { message, .. } => assert_eq!(message, "tab here and newline"),
            other => panic!("{other:?}"),
        }
    }
}
