//! The coordinator over the wire: a zero-dependency, line-delimited
//! TSV-over-TCP protocol (`ct/1`) that puts a network front-end on the
//! L3 decision service — the paper's "tune once, serve many" premise
//! at the scale where clients are other processes and other hosts, not
//! threads.
//!
//! Three pieces share one protocol implementation:
//!
//! * [`frame`] — the versioned frame codec (`HELLO`, batched
//!   `BATCH`/`DECISIONS`, `SUBSCRIBE`, and the server-initiated
//!   `INVALIDATE`/`TABLEUPDATE` pushes). The normative spec is
//!   `docs/PROTOCOL.md`; the codec is total (malformed, truncated, or
//!   oversized input is a structured error, never a panic).
//! * [`server`] — [`CoordServer`], the `coordd` TCP server:
//!   thread-per-connection over `std::net`, a notifier thread that
//!   turns [`Coordinator::watch_publishes`] events into pushes, and
//!   graceful shutdown. The drift refresher re-publishing a snapshot
//!   is what subscribed clients observe as `TABLEUPDATE`.
//! * [`client`] — [`NetClient`], the remote warm-read surface
//!   (`decision`, `query_batch`, `subscribe`), enforcing the
//!   epoch-based invalidation-ordering guarantee client-side.
//! * [`loopback`] — the same request loop over in-memory pipes: the
//!   protocol's test harness and an embedded, socket-free transport.
//!
//! Per-file module docs state each piece's concurrency contract (the
//! same way `util/arcswap.rs` documents its guarantees and hazards).
//!
//! [`Coordinator::watch_publishes`]: super::service::Coordinator::watch_publishes

pub mod client;
pub mod frame;
pub mod loopback;
pub mod server;

pub use client::{ClientOptions, NetClient, Push, RemoteError, RetryPolicy, TransportError};
pub use frame::{
    Frame, FrameError, Point, Query, QueryReply, MAX_BATCH_ITEMS, MAX_LINE_BYTES, PROTOCOL_VERSION,
};
pub use loopback::LoopbackServer;
pub use server::{CoordServer, ServerOptions};
