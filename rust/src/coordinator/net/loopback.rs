//! In-process loopback transport: the same `ct/1` request loop as the
//! TCP server, but over a pair of in-memory byte pipes — no socket, no
//! port, no OS nondeterminism. This is the protocol's test harness
//! (the end-to-end storm test and the in-process half of the
//! unregistered-cluster regression both run on it) and a zero-syscall
//! way to embed the server in another process.
//!
//! ## Concurrency contract
//!
//! * [`pipe`] is a single-producer, single-consumer byte stream: one
//!   [`PipeWriter`], one [`PipeReader`], backed by a mutex + condvar
//!   ring. `Write` never blocks (the buffer is unbounded); `Read`
//!   blocks until bytes arrive or every writer is dropped (then EOF).
//!   Dropping the reader makes subsequent writes fail with
//!   `BrokenPipe`, which is how a server connection thread learns its
//!   client went away.
//! * [`LoopbackServer::connect`] spawns one server-side thread per
//!   client, running [`super::server::serve_connection`] verbatim —
//!   the loopback and TCP transports cannot diverge in behavior
//!   because they share every line of the request loop.
//! * Connection threads are detached; they exit when their client is
//!   dropped (pipe EOF). [`LoopbackServer::shutdown`] (or `Drop`)
//!   stops and joins only the notifier thread, so drop clients first
//!   if you need every byte flushed.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::obs;

use super::super::service::Coordinator;
use super::client::{ClientOptions, NetClient};
use super::server::{serve_connection, ConnContext, ConnShared, ServerOptions, SubscriptionHub};

struct PipeState {
    buf: VecDeque<u8>,
    /// Writer dropped → reader sees EOF after draining.
    write_closed: bool,
    /// Reader dropped → writes fail with `BrokenPipe`.
    read_closed: bool,
}

struct PipeInner {
    state: Mutex<PipeState>,
    ready: Condvar,
}

/// Write half of an in-memory pipe. Cheap unbounded writes; see the
/// module docs for the close semantics.
pub struct PipeWriter(Arc<PipeInner>);

/// Read half of an in-memory pipe. Blocking reads, EOF when the write
/// half is gone.
pub struct PipeReader(Arc<PipeInner>);

/// A fresh SPSC byte pipe.
pub fn pipe() -> (PipeWriter, PipeReader) {
    let inner = Arc::new(PipeInner {
        state: Mutex::new(PipeState {
            buf: VecDeque::new(),
            write_closed: false,
            read_closed: false,
        }),
        ready: Condvar::new(),
    });
    (PipeWriter(Arc::clone(&inner)), PipeReader(inner))
}

impl Write for PipeWriter {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        let mut st = self.0.state.lock().unwrap();
        if st.read_closed {
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "pipe reader dropped",
            ));
        }
        st.buf.extend(data);
        self.0.ready.notify_all();
        Ok(data.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        self.0.state.lock().unwrap().write_closed = true;
        self.0.ready.notify_all();
    }
}

impl Read for PipeReader {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        let mut st = self.0.state.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                let n = out.len().min(st.buf.len());
                for slot in out.iter_mut().take(n) {
                    *slot = st.buf.pop_front().expect("len checked");
                }
                return Ok(n);
            }
            if st.write_closed {
                return Ok(0); // EOF
            }
            st = self.0.ready.wait(st).unwrap();
        }
    }
}

impl Drop for PipeReader {
    fn drop(&mut self) {
        self.0.state.lock().unwrap().read_closed = true;
        self.0.ready.notify_all();
    }
}

/// An in-process `ct/1` server: same coordinator, same hub, same
/// request loop as [`super::server::CoordServer`], minus the TCP
/// accept loop.
pub struct LoopbackServer {
    ctx: Arc<ConnContext>,
    stop: Arc<AtomicBool>,
    notifier: Option<JoinHandle<()>>,
    next_conn: std::sync::atomic::AtomicU64,
}

impl LoopbackServer {
    /// Start a loopback server over `coord` with default options.
    pub fn start(coord: Arc<Coordinator>) -> LoopbackServer {
        let opts = ServerOptions {
            banner: "collective-tuner loopback".to_string(),
            ..ServerOptions::default()
        };
        LoopbackServer::start_with(coord, opts)
    }

    pub fn start_with(coord: Arc<Coordinator>, opts: ServerOptions) -> LoopbackServer {
        let hub = Arc::new(SubscriptionHub::default());
        let stop = Arc::new(AtomicBool::new(false));
        let events = coord.watch_publishes();
        let notifier = {
            let coord = Arc::clone(&coord);
            let hub = Arc::clone(&hub);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                super::server::notifier_loop(&coord, &hub, &events, &stop)
            })
        };
        let ctx = Arc::new(ConnContext {
            coord,
            hub,
            opts,
            stop: Arc::clone(&stop),
            shutdown_requested: Arc::new(AtomicBool::new(false)),
        });
        LoopbackServer {
            ctx,
            stop,
            notifier: Some(notifier),
            next_conn: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Open one in-process connection: spawns the server-side thread
    /// and returns a fully handshaken client.
    pub fn connect(&self) -> Result<NetClient> {
        self.connect_with(ClientOptions::default())
    }

    /// [`LoopbackServer::connect`] with explicit client options (the
    /// chaos suite connects with a retrying policy).
    pub fn connect_with(&self, opts: ClientOptions) -> Result<NetClient> {
        let (reader, writer) = self.transport_pair();
        NetClient::from_transport_with(reader, writer, opts)
    }

    /// A fresh, unhandshaken client-side transport pair with its
    /// server-side thread already running — the building block
    /// [`NetClient::set_redial`] needs for reconnection over loopback.
    pub fn transport_pair(
        &self,
    ) -> (Box<dyn Read + Send>, Box<dyn Write + Send>) {
        let (c2s_w, c2s_r) = pipe();
        let (s2c_w, s2c_r) = pipe();
        let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        let shared = Arc::new(ConnShared::new(Box::new(s2c_w), format!("loopback-{id}")));
        if obs::enabled() {
            obs::registry().counter("net.connections").inc();
        }
        let ctx = Arc::clone(&self.ctx);
        std::thread::spawn(move || {
            serve_connection(&ctx, std::io::BufReader::new(c2s_r), shared);
        });
        (Box::new(s2c_r), Box::new(c2s_w))
    }

    /// Stop and join the notifier. Connection threads exit on their
    /// own when their clients are dropped.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.notifier.take() {
            let _ = h.join();
        }
    }
}

impl Drop for LoopbackServer {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipe_delivers_bytes_in_order_and_eofs_on_writer_drop() {
        let (mut w, mut r) = pipe();
        w.write_all(b"hello ").unwrap();
        w.write_all(b"world").unwrap();
        drop(w);
        let mut out = String::new();
        r.read_to_string(&mut out).unwrap();
        assert_eq!(out, "hello world");
    }

    #[test]
    fn pipe_write_fails_after_reader_drop() {
        let (mut w, r) = pipe();
        drop(r);
        let err = w.write_all(b"x").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn pipe_read_blocks_until_data_arrives() {
        let (mut w, mut r) = pipe();
        let t = std::thread::spawn(move || {
            let mut buf = [0u8; 4];
            r.read_exact(&mut buf).unwrap();
            buf
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        w.write_all(b"ping").unwrap();
        assert_eq!(&t.join().unwrap(), b"ping");
    }
}
