//! pLogP parameter measurement — the MPI LogP Benchmark procedure
//! (Kielmann et al. [5]) run against the simulated cluster.
//!
//! * `g(m)` — measured from the sender-side occupation of an individual
//!   message (`tx_done - tx_start`), repeated and medianed. This mirrors
//!   the real tool's per-message measurement; in particular it does *not*
//!   capture the streaming/bulk behaviour of long trains — exactly the
//!   mismatch the paper observes in §4.2 ("the pLogP parameters measured
//!   by the pLogP benchmark tool are not adapted to such situations, as
//!   it considers only individual transmissions").
//! * `L` — from the round-trip time of 1-byte messages:
//!   `L = RTT(1)/2 - g(1)`.
//!
//! Measurement runs on ranks 0 and 1 of the cluster, like the original
//! tool; homogeneity makes that representative (§1).

use crate::netsim::{Netsim, NodeId, SimTime};

use super::{default_size_grid, GapTable, PLogP};

/// Measurement options.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Repetitions per sample (median taken).
    pub reps: usize,
    /// Message sizes to sample.
    pub size_grid: Vec<u64>,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { reps: 7, size_grid: default_size_grid(32) }
    }
}

fn assert_probe_pair(sim: &Netsim, src: NodeId, dst: NodeId) {
    assert!(src != dst, "probe endpoints must differ");
    assert!(
        (src as usize) < sim.num_nodes() && (dst as usize) < sim.num_nodes(),
        "probe pair ({src}, {dst}) out of range for {} nodes",
        sim.num_nodes()
    );
}

/// Measure the sender gap for one message size (median of `reps`
/// individually-spaced messages) between ranks 0 and 1.
pub fn measure_gap(sim: &mut Netsim, bytes: u64, reps: usize) -> f64 {
    measure_gap_between(sim, 0, 1, bytes, reps)
}

/// Measure the sender gap between an explicit node pair — the
/// coordinator probes *inside* a discovered island of a larger grid, so
/// the representative pair is not always ranks 0 and 1.
pub fn measure_gap_between(
    sim: &mut Netsim,
    src: NodeId,
    dst: NodeId,
    bytes: u64,
    reps: usize,
) -> f64 {
    assert_probe_pair(sim, src, dst);
    sim.reset();
    let mut samples: Vec<f64> = Vec::with_capacity(reps);
    // space the probes far apart so each is an individual transmission
    let spacing = 1.0;
    for i in 0..reps {
        let at = SimTime::from_secs(i as f64 * spacing);
        let out = sim.send(at, src, dst, bytes);
        samples.push(out.tx_done.saturating_sub(out.tx_start).as_secs());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Measure one-way latency via 1-byte round trips between ranks 0 and 1:
/// `L = RTT/2 - g(1)`.
pub fn measure_latency(sim: &mut Netsim, reps: usize) -> f64 {
    measure_latency_between(sim, 0, 1, reps)
}

/// Measure one-way latency between an explicit node pair.
pub fn measure_latency_between(
    sim: &mut Netsim,
    src: NodeId,
    dst: NodeId,
    reps: usize,
) -> f64 {
    assert_probe_pair(sim, src, dst);
    let g1 = measure_gap_between(sim, src, dst, 1, reps);
    sim.reset();
    let mut rtts: Vec<f64> = Vec::with_capacity(reps);
    for i in 0..reps {
        let at = SimTime::from_secs(i as f64);
        let fwd = sim.send(at, src, dst, 1);
        let back = sim.send(fwd.delivered, dst, src, 1);
        rtts.push(back.delivered.saturating_sub(at).as_secs());
    }
    rtts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rtt = rtts[rtts.len() / 2];
    (rtt / 2.0 - g1).max(1e-9)
}

/// Full pLogP measurement with default options (ranks 0 and 1).
pub fn measure(sim: &mut Netsim) -> PLogP {
    measure_with(sim, &BenchOptions::default())
}

/// Full pLogP measurement (ranks 0 and 1).
pub fn measure_with(sim: &mut Netsim, opts: &BenchOptions) -> PLogP {
    measure_pair_with(sim, 0, 1, opts)
}

/// Full pLogP measurement between an explicit representative pair, with
/// default options.
pub fn measure_pair(sim: &mut Netsim, src: NodeId, dst: NodeId) -> PLogP {
    measure_pair_with(sim, src, dst, &BenchOptions::default())
}

/// Full pLogP measurement between an explicit representative pair.
pub fn measure_pair_with(
    sim: &mut Netsim,
    src: NodeId,
    dst: NodeId,
    opts: &BenchOptions,
) -> PLogP {
    let l = measure_latency_between(sim, src, dst, opts.reps);
    let sizes: Vec<f64> = opts.size_grid.iter().map(|&m| m as f64).collect();
    let gaps: Vec<f64> = opts
        .size_grid
        .iter()
        .map(|&m| measure_gap_between(sim, src, dst, m, opts.reps))
        .collect();
    sim.reset();
    PLogP::new(l, GapTable::new(sizes, gaps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetConfig;

    #[test]
    fn measured_gap_matches_ground_truth_ideal() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let mut sim = Netsim::new(2, cfg.clone());
        for m in [1u64, 1024, 65536, 1 << 20] {
            let got = measure_gap(&mut sim, m, 5);
            let want = cfg.gap(m);
            assert!(
                (got - want).abs() / want < 1e-6,
                "m={m}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn measured_latency_matches_ground_truth_ideal() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let mut sim = Netsim::new(2, cfg.clone());
        let got = measure_latency(&mut sim, 5);
        let want = cfg.prop_delay + cfg.recv_overhead;
        assert!(
            (got - want).abs() / want < 1e-6,
            "got {got} want {want}"
        );
    }

    #[test]
    fn measurement_robust_to_tcp_anomalies() {
        // with Linux-2.2 TCP on, the median filters the occasional stall
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        let ideal = NetConfig::fast_ethernet_ideal();
        let got = measure_gap(&mut sim, 1024, 7);
        let want = ideal.gap(1024);
        assert!((got - want).abs() / want < 0.05, "got {got} want {want}");
    }

    #[test]
    fn full_measurement_produces_monotone_plausible_table() {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        let p = measure(&mut sim);
        assert!(p.l > 0.0);
        assert_eq!(p.table.len(), 32);
        // gap grows with size overall
        assert!(p.table.gap(4.0 * 1024.0 * 1024.0) > p.table.gap(1.0));
        // and the big-message gap is wire-dominated: ~0.08 us/byte
        let g1m = p.table.gap(1048576.0);
        assert!(g1m > 0.07 && g1m < 0.12, "g(1MB)={g1m}");
    }

    #[test]
    fn gigabit_measures_faster_than_fast_ethernet() {
        let mut fe = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        let mut ge = Netsim::new(2, NetConfig::gigabit_ethernet());
        let pfe = measure(&mut fe);
        let pge = measure(&mut ge);
        assert!(pge.l < pfe.l);
        assert!(pge.table.gap((1 << 20) as f64) < pfe.table.gap((1 << 20) as f64));
    }

    #[test]
    fn pair_measurement_matches_rank01_inside_an_island() {
        use crate::topology::{ClusterSpec, GridSpec};
        // islands 0..4 and 4..8; an intra-island pair of the second
        // island must measure the same parameters as ranks (0, 1)
        let grid = GridSpec::new(
            vec![
                ClusterSpec::new("a", 4, NetConfig::fast_ethernet_ideal()),
                ClusterSpec::new("b", 4, NetConfig::fast_ethernet_ideal()),
            ],
            NetConfig::wan_link(),
        );
        let mut sim = grid.build_sim();
        let base = measure_pair(&mut sim, 0, 1);
        let island_b = measure_pair(&mut sim, 4, 5);
        assert!((base.l - island_b.l).abs() / base.l < 1e-9);
        for m in [1.0f64, 65536.0] {
            assert!(
                (base.gap(m) - island_b.gap(m)).abs() / base.gap(m) < 1e-9,
                "g({m}) differs between islands of identical hardware"
            );
        }
        // a cross-island (WAN) pair must NOT match
        let wan = measure_latency_between(&mut sim, 1, 5, 3);
        assert!(wan > 2.0 * base.l, "wan {wan} vs lan {}", base.l);
    }

    #[test]
    fn measurement_leaves_sim_clean() {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        let _ = measure(&mut sim);
        assert_eq!(sim.stats().messages, 0); // reset at the end
    }
}
