//! pLogP parameter measurement — the MPI LogP Benchmark procedure
//! (Kielmann et al. [5]) run against the simulated cluster.
//!
//! * `g(m)` — measured from the sender-side occupation of an individual
//!   message (`tx_done - tx_start`), repeated and medianed. This mirrors
//!   the real tool's per-message measurement; in particular it does *not*
//!   capture the streaming/bulk behaviour of long trains — exactly the
//!   mismatch the paper observes in §4.2 ("the pLogP parameters measured
//!   by the pLogP benchmark tool are not adapted to such situations, as
//!   it considers only individual transmissions").
//! * `L` — from the round-trip time of 1-byte messages:
//!   `L = RTT(1)/2 - g(1)`.
//!
//! Measurement runs on ranks 0 and 1 of the cluster, like the original
//! tool; homogeneity makes that representative (§1).

use crate::netsim::{Netsim, SimTime};

use super::{default_size_grid, GapTable, PLogP};

/// Measurement options.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Repetitions per sample (median taken).
    pub reps: usize,
    /// Message sizes to sample.
    pub size_grid: Vec<u64>,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { reps: 7, size_grid: default_size_grid(32) }
    }
}

/// Measure the sender gap for one message size (median of `reps`
/// individually-spaced messages).
pub fn measure_gap(sim: &mut Netsim, bytes: u64, reps: usize) -> f64 {
    assert!(sim.num_nodes() >= 2, "need two nodes to measure");
    sim.reset();
    let mut samples: Vec<f64> = Vec::with_capacity(reps);
    // space the probes far apart so each is an individual transmission
    let spacing = 1.0;
    for i in 0..reps {
        let at = SimTime::from_secs(i as f64 * spacing);
        let out = sim.send(at, 0, 1, bytes);
        samples.push(out.tx_done.saturating_sub(out.tx_start).as_secs());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Measure one-way latency via 1-byte round trips:
/// `L = RTT/2 - g(1)`.
pub fn measure_latency(sim: &mut Netsim, reps: usize) -> f64 {
    assert!(sim.num_nodes() >= 2);
    let g1 = measure_gap(sim, 1, reps);
    sim.reset();
    let mut rtts: Vec<f64> = Vec::with_capacity(reps);
    for i in 0..reps {
        let at = SimTime::from_secs(i as f64);
        let fwd = sim.send(at, 0, 1, 1);
        let back = sim.send(fwd.delivered, 1, 0, 1);
        rtts.push(back.delivered.saturating_sub(at).as_secs());
    }
    rtts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rtt = rtts[rtts.len() / 2];
    (rtt / 2.0 - g1).max(1e-9)
}

/// Full pLogP measurement with default options.
pub fn measure(sim: &mut Netsim) -> PLogP {
    measure_with(sim, &BenchOptions::default())
}

/// Full pLogP measurement.
pub fn measure_with(sim: &mut Netsim, opts: &BenchOptions) -> PLogP {
    let l = measure_latency(sim, opts.reps);
    let sizes: Vec<f64> = opts.size_grid.iter().map(|&m| m as f64).collect();
    let gaps: Vec<f64> = opts
        .size_grid
        .iter()
        .map(|&m| measure_gap(sim, m, opts.reps))
        .collect();
    sim.reset();
    PLogP::new(l, GapTable::new(sizes, gaps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetConfig;

    #[test]
    fn measured_gap_matches_ground_truth_ideal() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let mut sim = Netsim::new(2, cfg.clone());
        for m in [1u64, 1024, 65536, 1 << 20] {
            let got = measure_gap(&mut sim, m, 5);
            let want = cfg.gap(m);
            assert!(
                (got - want).abs() / want < 1e-6,
                "m={m}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn measured_latency_matches_ground_truth_ideal() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let mut sim = Netsim::new(2, cfg.clone());
        let got = measure_latency(&mut sim, 5);
        let want = cfg.prop_delay + cfg.recv_overhead;
        assert!(
            (got - want).abs() / want < 1e-6,
            "got {got} want {want}"
        );
    }

    #[test]
    fn measurement_robust_to_tcp_anomalies() {
        // with Linux-2.2 TCP on, the median filters the occasional stall
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        let ideal = NetConfig::fast_ethernet_ideal();
        let got = measure_gap(&mut sim, 1024, 7);
        let want = ideal.gap(1024);
        assert!((got - want).abs() / want < 0.05, "got {got} want {want}");
    }

    #[test]
    fn full_measurement_produces_monotone_plausible_table() {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        let p = measure(&mut sim);
        assert!(p.l > 0.0);
        assert_eq!(p.table.len(), 32);
        // gap grows with size overall
        assert!(p.table.gap(4.0 * 1024.0 * 1024.0) > p.table.gap(1.0));
        // and the big-message gap is wire-dominated: ~0.08 us/byte
        let g1m = p.table.gap(1048576.0);
        assert!(g1m > 0.07 && g1m < 0.12, "g(1MB)={g1m}");
    }

    #[test]
    fn gigabit_measures_faster_than_fast_ethernet() {
        let mut fe = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        let mut ge = Netsim::new(2, NetConfig::gigabit_ethernet());
        let pfe = measure(&mut fe);
        let pge = measure(&mut ge);
        assert!(pge.l < pfe.l);
        assert!(pge.table.gap((1 << 20) as f64) < pfe.table.gap((1 << 20) as f64));
    }

    #[test]
    fn measurement_leaves_sim_clean() {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        let _ = measure(&mut sim);
        assert_eq!(sim.stats().messages, 0); // reset at the end
    }
}
