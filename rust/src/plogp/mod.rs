//! The parameterised LogP (pLogP) network model and its measurement.
//!
//! pLogP (Kielmann et al. [5,6]) describes a network by:
//! * `L` — end-to-end latency of a message,
//! * `g(m)` — the *gap* of an `m`-byte message: the minimum interval
//!   between consecutive message injections at a node (the reciprocal of
//!   achievable message rate), captured as a table of samples,
//! * `P` — the number of processes.
//!
//! [`GapTable`] holds the sampled gap function with piecewise-linear
//! interpolation (clamped below the table, linearly extrapolated above
//! it — identical semantics to `ref.gap_interp` on the Python side).
//! [`bench`] measures `L` and `g(m)` against the simulated cluster with
//! the same procedure the MPI LogP Benchmark uses on real hardware.

pub mod bench;
pub mod cache;

pub use cache::{CachedRow, GapCache};

/// Extremum statistics of the gap function over one size interval —
/// the raw material of the tuner's m-aware sweep lower bounds
/// ([`crate::models::LOWER_BOUNDS`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GapRange {
    /// `min g(s)` over the interval.
    pub gap_min: f64,
    /// `max g(s)` over the interval.
    pub gap_max: f64,
    /// `min g(s)/s` over the interval — the best per-byte gap rate; by
    /// subadditivity, streaming `m` bytes in segments can never beat
    /// `m · rate_min`.
    pub rate_min: f64,
}

/// Sampled gap function `g(m)` with piecewise-linear interpolation.
#[derive(Debug, Clone, PartialEq)]
pub struct GapTable {
    sizes: Vec<f64>,
    gaps: Vec<f64>,
}

impl GapTable {
    /// Build from (size, gap) samples. Sizes must be strictly increasing
    /// and there must be at least two samples.
    pub fn new(sizes: Vec<f64>, gaps: Vec<f64>) -> GapTable {
        assert_eq!(sizes.len(), gaps.len());
        assert!(sizes.len() >= 2, "need at least two gap samples");
        assert!(
            sizes.windows(2).all(|w| w[0] < w[1]),
            "gap-table sizes must be strictly increasing"
        );
        assert!(gaps.iter().all(|g| g.is_finite() && *g > 0.0));
        GapTable { sizes, gaps }
    }

    /// The synthetic table implied by a [`crate::netsim::NetConfig`]'s
    /// ground truth (for tests: what a perfect benchmark would measure).
    pub fn from_config(cfg: &crate::netsim::NetConfig, points: &[u64]) -> GapTable {
        let sizes: Vec<f64> = points.iter().map(|&m| m as f64).collect();
        let gaps: Vec<f64> = points.iter().map(|&m| cfg.gap(m)).collect();
        GapTable::new(sizes, gaps)
    }

    pub fn len(&self) -> usize {
        self.sizes.len()
    }

    pub fn is_empty(&self) -> bool {
        false // constructor guarantees >= 2 samples
    }

    pub fn sizes(&self) -> &[f64] {
        &self.sizes
    }

    pub fn gaps(&self) -> &[f64] {
        &self.gaps
    }

    /// g(m): piecewise-linear; clamped below the first sample,
    /// extrapolated beyond the last with the final segment's slope —
    /// but never below the last sample (a noisy table must not
    /// extrapolate the gap negative). Identical semantics to
    /// `ref.gap_interp` / the Pallas kernel on the Python side.
    pub fn gap(&self, m: f64) -> f64 {
        let n = self.sizes.len();
        // segment index: count of sizes <= m, minus one, clamped
        let mut idx = match self
            .sizes
            .binary_search_by(|s| s.partial_cmp(&m).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        idx = idx.min(n - 2);
        let (s0, s1) = (self.sizes[idx], self.sizes[idx + 1]);
        let (g0, g1) = (self.gaps[idx], self.gaps[idx + 1]);
        let t = ((m - s0) / (s1 - s0)).max(0.0);
        let g = g0 + t * (g1 - g0);
        if t > 1.0 {
            g.max(g1)
        } else {
            g
        }
    }

    /// g(1): the small-message gap used by the rendezvous models.
    pub fn gap1(&self) -> f64 {
        self.gap(1.0)
    }

    /// The smallest sampled gap. Every interpolated or extrapolated
    /// value stays at or above it (interior points lie between their
    /// bracketing samples, values below the table clamp to the first
    /// sample, and extrapolation floors at the last sample), so this is
    /// a global lower bound on `g` at *any* size.
    pub fn min_gap(&self) -> f64 {
        self.gaps.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Extremum statistics of `g` (and of the per-byte rate `g(s)/s`)
    /// over `[lo, hi]`. On every piece of the interpolant — clamped
    /// below the table, linear between samples, slope-extrapolated with
    /// a floor above it — both `g` and `g(s)/s` are monotone, so the
    /// interval extrema are attained at the interval endpoints or at
    /// interior sample points; the scan evaluates exactly those.
    pub fn range_stats(&self, lo: f64, hi: f64) -> GapRange {
        assert!(lo >= 1.0 && hi >= lo, "need 1 <= lo <= hi");
        let mut r = GapRange {
            gap_min: f64::INFINITY,
            gap_max: f64::NEG_INFINITY,
            rate_min: f64::INFINITY,
        };
        let mut visit = |s: f64| {
            let g = self.gap(s);
            r.gap_min = r.gap_min.min(g);
            r.gap_max = r.gap_max.max(g);
            r.rate_min = r.rate_min.min(g / s);
        };
        visit(lo);
        if hi > lo {
            visit(hi);
        }
        for &s in &self.sizes {
            if s > lo && s < hi {
                visit(s);
            }
        }
        r
    }
}

/// A full pLogP parameter set for one network.
#[derive(Debug, Clone, PartialEq)]
pub struct PLogP {
    /// One-way latency `L` (seconds).
    pub l: f64,
    /// The gap function.
    pub table: GapTable,
}

impl PLogP {
    pub fn new(l: f64, table: GapTable) -> PLogP {
        assert!(l > 0.0 && l.is_finite());
        PLogP { l, table }
    }

    pub fn gap(&self, m: f64) -> f64 {
        self.table.gap(m)
    }

    /// Render as a short report.
    pub fn summary(&self) -> String {
        format!(
            "pLogP: L = {:.1} us, g(1) = {:.1} us, g(64k) = {:.1} us, {} samples",
            self.l * 1e6,
            self.table.gap1() * 1e6,
            self.table.gap(65536.0) * 1e6,
            self.table.len()
        )
    }
}

/// A random pLogP parameter set over an adversarial (non-monotone) gap
/// table: up to `max_samples` cumulative-uniform sizes (step up to
/// `size_step` bytes) with independently log-uniform gaps — the regime
/// where the sweep's pruning bounds are weakest. Shared by the
/// model-layer property tests and the sweep-exactness integration
/// tests so both fuzz the same distribution.
pub fn adversarial_net(
    rng: &mut crate::util::prng::Prng,
    max_samples: usize,
    size_step: f64,
) -> PLogP {
    let n = rng.range_usize(2, max_samples.max(3));
    let mut sizes = Vec::with_capacity(n);
    let mut acc = 0.0;
    for _ in 0..n {
        acc += rng.uniform(1.0, size_step);
        sizes.push(acc);
    }
    let gaps: Vec<f64> = (0..n).map(|_| rng.log_uniform(1e-6, 1e-2)).collect();
    PLogP::new(rng.log_uniform(1e-6, 1e-3), GapTable::new(sizes, gaps))
}

/// The default measurement grid: log-spaced from 1 byte to 4 MB,
/// padded/truncated to exactly `n` points (the AOT artifact has a fixed
/// table length).
pub fn default_size_grid(n: usize) -> Vec<u64> {
    assert!(n >= 2);
    let lo = 1f64;
    let hi = (4u64 << 20) as f64;
    let mut out: Vec<u64> = (0..n)
        .map(|i| {
            let t = i as f64 / (n - 1) as f64;
            (lo * (hi / lo).powf(t)).round() as u64
        })
        .collect();
    out.dedup();
    // de-duplication at the small end can shrink the list; re-spread the
    // tail to keep exactly n strictly-increasing entries
    let mut next = out.last().copied().unwrap_or(1) + 1;
    while out.len() < n {
        out.push(next);
        next += 1;
    }
    out.sort_unstable();
    out.dedup();
    while out.len() < n {
        let last = *out.last().unwrap();
        out.push(last * 2);
    }
    out.truncate(n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetConfig;

    #[test]
    fn interp_exact_at_samples() {
        let t = GapTable::new(vec![1.0, 10.0, 100.0], vec![5e-6, 6e-6, 9e-6]);
        assert!((t.gap(1.0) - 5e-6).abs() < 1e-18);
        assert!((t.gap(10.0) - 6e-6).abs() < 1e-18);
        assert!((t.gap(100.0) - 9e-6).abs() < 1e-18);
    }

    #[test]
    fn interp_midpoint() {
        let t = GapTable::new(vec![0.0, 10.0], vec![1.0, 2.0]);
        assert!((t.gap(5.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn clamp_below_extrapolate_above() {
        let t = GapTable::new(vec![10.0, 20.0], vec![7.0, 9.0]);
        assert_eq!(t.gap(1.0), 7.0);
        assert!((t.gap(30.0) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn matches_python_ref_semantics() {
        // identical cases to python/tests TestGapInterp
        let t = GapTable::new(vec![1.0, 10.0, 100.0, 1000.0], vec![5.0, 6.0, 9.0, 20.0]);
        for (m, want) in [(1.0, 5.0), (10.0, 6.0), (100.0, 9.0), (1000.0, 20.0)] {
            assert!((t.gap(m) - want).abs() < 1e-9, "g({m})");
        }
    }

    #[test]
    #[should_panic]
    fn non_monotone_sizes_rejected() {
        GapTable::new(vec![10.0, 5.0], vec![1.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn single_sample_rejected() {
        GapTable::new(vec![10.0], vec![1.0]);
    }

    #[test]
    fn from_config_matches_ground_truth() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let t = GapTable::from_config(&cfg, &[1, 1024, 65536]);
        assert!((t.gap(1024.0) - cfg.gap(1024)).abs() < 1e-12);
    }

    #[test]
    fn default_grid_properties() {
        for n in [8usize, 16, 32, 48] {
            let g = default_size_grid(n);
            assert_eq!(g.len(), n);
            assert!(g.windows(2).all(|w| w[0] < w[1]), "{g:?}");
            assert_eq!(g[0], 1);
            assert!(*g.last().unwrap() >= 4 << 20);
        }
    }

    #[test]
    fn range_stats_find_interval_extrema() {
        // non-monotone gaps: dip at 10, spike at 100
        let t = GapTable::new(vec![1.0, 10.0, 100.0, 1000.0], vec![5.0, 2.0, 9.0, 4.0]);
        assert_eq!(t.min_gap(), 2.0);
        let r = t.range_stats(1.0, 1000.0);
        assert_eq!(r.gap_min, 2.0);
        assert_eq!(r.gap_max, 9.0);
        // restricting the interval excludes the dip
        let r = t.range_stats(100.0, 1000.0);
        assert_eq!(r.gap_min, 4.0);
        assert_eq!(r.gap_max, 9.0);
        // degenerate interval: everything collapses to g(lo)
        let r = t.range_stats(10.0, 10.0);
        assert_eq!(r.gap_min, 2.0);
        assert_eq!(r.gap_max, 2.0);
        assert_eq!(r.rate_min, 0.2);
    }

    #[test]
    fn range_stats_bound_a_dense_scan() {
        // brute-force check on an adversarial table: candidate-point
        // extrema really do bound a dense sampling of the interval
        let t = GapTable::new(vec![2.0, 7.0, 30.0, 900.0], vec![8.0, 3.0, 11.0, 2.5]);
        for (lo, hi) in [(1.0, 4.0), (1.0, 100.0), (5.0, 2000.0), (1.0, 1e6)] {
            let r = t.range_stats(lo, hi);
            let mut s = lo;
            while s <= hi {
                let g = t.gap(s);
                assert!(r.gap_min <= g + 1e-12, "min at s={s}");
                assert!(r.gap_max >= g - 1e-12, "max at s={s}");
                assert!(r.rate_min <= g / s + 1e-12, "rate at s={s}");
                s *= 1.037;
            }
        }
    }

    #[test]
    fn plogp_summary_mentions_l() {
        let p = PLogP::new(
            60e-6,
            GapTable::new(vec![1.0, 100.0], vec![5e-5, 6e-5]),
        );
        assert!(p.summary().contains("L = 60.0 us"));
    }
}
