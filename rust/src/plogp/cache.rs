//! [`GapCache`] — per-tune precomputation of the gap interpolations the
//! sweep hot path would otherwise redo for every cell.
//!
//! One tuning sweep evaluates the same message-size grid and the same
//! segment-size grid thousands of times (cells × strategies × segment
//! candidates), and every cost-model evaluation starts with one or two
//! binary-search interpolations into the [`super::GapTable`]. The cache
//! computes each distinct interpolation exactly once — `g(m)` per
//! message-grid row, `g(s)` per segment-grid point, `g(1)` and the
//! rendezvous constant — so the innermost loop becomes array indexing.
//! It also precomputes the per-row [`super::GapRange`] statistics that
//! feed the m-aware pruning bounds ([`crate::models::LOWER_BOUNDS`]).
//!
//! Exactness: every cached value is produced by the same
//! [`super::GapTable::gap`] call the uncached path would make, so a
//! cost model fed from the cache returns bit-identical `f64`s — the
//! tuner's tables cannot drift from the exhaustive argmin (asserted in
//! `rust/tests/evaluator.rs`).

use super::{GapRange, PLogP};

/// Cached per-message-size quantities: the interpolated gap and the
/// `[1, m]` range statistics behind the m-aware lower bounds.
#[derive(Debug, Clone, Copy)]
pub struct CachedRow {
    /// The message size this row caches.
    pub m: u64,
    /// `g(m)`.
    pub g_m: f64,
    /// Extrema of `g` and `g(s)/s` over candidate segments `[1, m]`.
    pub range: GapRange,
}

/// Precomputed gap interpolations for one `(net, m_grid, s_grid)`
/// tuning sweep. Built once per tuned operation by the engine and
/// threaded to the evaluator through [`crate::eval::CellCtx`].
#[derive(Debug, Clone)]
pub struct GapCache {
    l: f64,
    g1: f64,
    rdv: f64,
    gap_floor: f64,
    m_grid: Vec<u64>,
    /// Whether `m_grid` is strictly ascending (the normal case); rows
    /// are binary-searched when it is and linear-scanned when not, so a
    /// caller-supplied unsorted grid degrades gracefully instead of
    /// silently missing every lookup.
    m_sorted: bool,
    rows: Vec<CachedRow>,
    s_grid: Vec<u64>,
    gap_at_s: Vec<f64>,
}

impl GapCache {
    /// Interpolate every grid point of one sweep up front.
    pub fn new(net: &PLogP, m_grid: &[u64], s_grid: &[u64]) -> GapCache {
        let rows = m_grid
            .iter()
            .map(|&m| CachedRow {
                m,
                g_m: net.gap(m as f64),
                range: net.table.range_stats(1.0, m.max(1) as f64),
            })
            .collect();
        GapCache {
            l: net.l,
            g1: net.gap(1.0),
            // identical expression to `CostInputs::new` — bit-exact
            rdv: 2.0 * net.gap(1.0) + 3.0 * net.l,
            gap_floor: net.table.min_gap(),
            m_sorted: m_grid.windows(2).all(|w| w[0] < w[1]),
            m_grid: m_grid.to_vec(),
            rows,
            s_grid: s_grid.to_vec(),
            gap_at_s: s_grid.iter().map(|&s| net.gap(s as f64)).collect(),
        }
    }

    /// The cached row for message size `m`, if `m` is on this cache's
    /// message grid (point queries off the grid fall back to direct
    /// interpolation).
    pub fn row(&self, m: u64) -> Option<&CachedRow> {
        let i = if self.m_sorted {
            self.m_grid.binary_search(&m).ok()?
        } else {
            self.m_grid.iter().position(|&x| x == m)?
        };
        Some(&self.rows[i])
    }

    /// Was this cache built for exactly this segment grid?
    pub fn covers(&self, s_grid: &[u64]) -> bool {
        self.s_grid == s_grid
    }

    /// `g(s_grid[i])` (unclamped; callers substitute `g(m)` for
    /// candidates that clamp onto the message size).
    pub fn gap_at_segment(&self, i: usize) -> f64 {
        self.gap_at_s[i]
    }

    /// Network latency `L`.
    pub fn l(&self) -> f64 {
        self.l
    }

    /// `g(1)`.
    pub fn g1(&self) -> f64 {
        self.g1
    }

    /// The rendezvous handshake constant `2 g(1) + 3 L`.
    pub fn rdv(&self) -> f64 {
        self.rdv
    }

    /// The table-wide minimum sampled gap (sound at any size).
    pub fn gap_floor(&self) -> f64 {
        self.gap_floor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plogp::GapTable;

    fn toy() -> PLogP {
        let sizes: Vec<f64> = vec![1., 2., 4., 8., 16., 32., 64., 128.];
        let gaps: Vec<f64> = sizes.iter().map(|s| 1.0 + s).collect();
        PLogP::new(10.0, GapTable::new(sizes, gaps))
    }

    #[test]
    fn cached_gaps_are_bit_identical_to_direct_interpolation() {
        let net = toy();
        let m_grid = [1u64, 3, 8, 200];
        let s_grid = [2u64, 5, 64, 4096];
        let c = GapCache::new(&net, &m_grid, &s_grid);
        for (i, &s) in s_grid.iter().enumerate() {
            assert_eq!(c.gap_at_segment(i), net.gap(s as f64));
        }
        for &m in &m_grid {
            let row = c.row(m).unwrap();
            assert_eq!(row.g_m, net.gap(m as f64));
            assert_eq!(row.range, net.table.range_stats(1.0, m as f64));
        }
        assert_eq!(c.g1(), net.gap(1.0));
        assert_eq!(c.rdv(), 2.0 * net.gap(1.0) + 3.0 * net.l);
        assert_eq!(c.gap_floor(), net.table.min_gap());
        assert_eq!(c.l(), net.l);
    }

    #[test]
    fn off_grid_sizes_have_no_row() {
        let net = toy();
        let c = GapCache::new(&net, &[4, 16], &[8]);
        assert!(c.row(4).is_some());
        assert!(c.row(5).is_none());
    }

    #[test]
    fn unsorted_message_grids_still_resolve_rows() {
        let net = toy();
        let c = GapCache::new(&net, &[8192, 64, 16], &[8]);
        for m in [16u64, 64, 8192] {
            let row = c.row(m).expect("row present despite unsorted grid");
            assert_eq!(row.m, m);
            assert_eq!(row.g_m, net.gap(m as f64));
        }
        assert!(c.row(7).is_none());
    }

    #[test]
    fn covers_matches_exact_segment_grid_only() {
        let net = toy();
        let c = GapCache::new(&net, &[4], &[8, 64]);
        assert!(c.covers(&[8, 64]));
        assert!(!c.covers(&[8]));
        assert!(!c.covers(&[8, 65]));
    }
}
