//! `collective-tuner` — the L3 coordinator binary.
//!
//! Subcommands: `bench-plogp`, `tune`, `calibrate`, `run`,
//! `experiment`, `discover`, `serve`, `coordd`, `query`, `obs`,
//! `info`. See `cli::USAGE` or run with `help`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use collective_tuner::collectives::{multilevel, Strategy};
use collective_tuner::coordinator::{Coordinator, CoordinatorConfig, RefreshPolicy};
use collective_tuner::eval;
use collective_tuner::harness::experiments;
use collective_tuner::mpi::World;
use collective_tuner::netsim::{NetConfig, Netsim};
use collective_tuner::obs;
use collective_tuner::plogp;
use collective_tuner::runtime::TunerArtifact;
use collective_tuner::topology::{discover, ClusterSpec, GridSpec};
use collective_tuner::tuner::{grids, persist, DecisionTable, Op, Tuner};
use collective_tuner::util::prng::Prng;
use collective_tuner::util::table::{fmt_bytes, fmt_time, Table};

use collective_tuner::cli::{self, Args};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match Args::parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::USAGE);
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&parsed) {
        eprintln!("error: {e:#}");
        std::process::exit(classify_failure(&e));
    }
}

/// Map a failure to an exit code and print a one-line retryable/fatal
/// classification when the error chain carries a typed network error.
/// Exit codes (documented in `cli::USAGE`): 1 generic, 3 transport
/// failure (retryable), 4 unregistered cluster (fatal).
fn classify_failure(err: &anyhow::Error) -> i32 {
    use collective_tuner::coordinator::net::frame::codes;
    use collective_tuner::coordinator::net::{RemoteError, TransportError};
    for cause in err.chain() {
        if cause.downcast_ref::<TransportError>().is_some() {
            eprintln!("classification: retryable (transport failure; back off and redial)");
            return 3;
        }
        if let Some(re) = cause.downcast_ref::<RemoteError>() {
            if re.code == codes::UNREGISTERED {
                eprintln!("classification: fatal (cluster is not registered on the server)");
                return 4;
            }
            if re.is_retryable() {
                eprintln!("classification: retryable ({}; back off and redial)", re.code);
                return 3;
            }
            eprintln!("classification: fatal ({})", re.code);
            return 1;
        }
    }
    1
}

fn dispatch(args: &Args) -> Result<()> {
    if let Some(level) = args.log_level()? {
        obs::init_logging(level);
    }
    // Observability is opt-in (see the obs module's overhead contract):
    // turn it on exactly when a surface that reads it was requested.
    // `coordd` always counts: its final OBS_SNAPSHOT_JSON line is the
    // CI socket smoke's artifact.
    if args.flag("stats")
        || args.get("metrics-interval").is_some()
        || args.command == "obs"
        || args.command == "coordd"
    {
        obs::set_enabled(true);
    }
    match args.command.as_str() {
        "bench-plogp" => cmd_bench_plogp(args),
        "tune" => cmd_tune(args),
        "record" => cmd_record(args),
        "replay" => cmd_replay(args),
        "calibrate" => cmd_calibrate(args),
        "validate" => cmd_validate(args),
        "run" => cmd_run(args),
        "experiment" => cmd_experiment(args),
        "discover" => cmd_discover(args),
        "serve" => cmd_serve(args),
        "coordd" => cmd_coordd(args),
        "query" => cmd_query(args),
        "obs" => cmd_obs(args),
        "info" => cmd_info(args),
        "help" | "--help" | "-h" => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{}", cli::USAGE),
    }
}

fn cmd_bench_plogp(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let mut sim = Netsim::new(2, cfg);
    let net = plogp::bench::measure(&mut sim);
    println!("{}", net.summary());
    let mut t = Table::new(vec!["size", "g(m)"]);
    for (s, g) in net.table.sizes().iter().zip(net.table.gaps()) {
        t.row(vec![fmt_bytes(*s), fmt_time(*g)]);
    }
    println!("{}", t.to_ascii());
    println!("L = {}", fmt_time(net.l));
    Ok(())
}

fn backend_tuner(args: &Args) -> Result<Tuner> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(TunerArtifact::default_dir);
    let corrections = args.get("corrections").map(PathBuf::from);
    let tuner = match (args.get_or("backend", "auto").as_str(), &corrections) {
        // trace-fitted corrections attach to the native models; their
        // presence pins the backend (an artifact would silently ignore
        // the fitted factors)
        ("auto" | "native", Some(path)) => Tuner::with_corrections(path)?,
        ("artifact", Some(_)) => {
            bail!("--corrections applies to the native model backend, not --backend artifact")
        }
        ("auto", None) => Tuner::auto(&dir),
        ("native", None) => Tuner::native(),
        ("artifact", None) => Tuner::with_artifact(&dir)?,
        (other, _) => bail!("unknown --backend '{other}' (auto, native, artifact)"),
    };
    Ok(tuner.jobs(args.usize_or("jobs", 0)?))
}

/// Parse `--op` into a list of operation families: a comma-separated
/// list of op names, `all` for every family, or the default (bcast +
/// scatter, the paper's core pair).
fn op_list(args: &Args) -> Result<Vec<Op>> {
    match args.get("op") {
        None => Ok(vec![Op::Bcast, Op::Scatter]),
        Some("all") => Ok(Op::ALL.to_vec()),
        Some(spec) => spec
            .split(',')
            .map(|tok| {
                let tok = tok.trim();
                Op::from_name(tok).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown --op '{tok}' (all, bcast, scatter, gather, reduce, \
                         barrier, allgather, allreduce)"
                    )
                })
            })
            .collect(),
    }
}

/// Persist tables when `--save` was given, then print them.
fn save_and_print_tables(args: &Args, tables: &[DecisionTable]) -> Result<()> {
    if let Some(dir) = args.get("save") {
        let dir = PathBuf::from(dir);
        for table in tables {
            persist::save(table, &dir.join(format!("{}.table.tsv", table.op.name())))?;
        }
        println!("saved decision tables to {}", dir.display());
    }
    for table in tables {
        println!("== {} decision table ==", table.op.name());
        let mut t = Table::new(vec!["P", "m", "strategy", "segment", "predicted"]);
        for (qi, &p) in table.p_grid.iter().enumerate() {
            for (mi, &m) in table.m_grid.iter().enumerate() {
                // compact: only print every 4th m column of wide grids
                if table.m_grid.len() > 12 && mi % 4 != 0 {
                    continue;
                }
                let d = table.at(qi, mi);
                t.row(vec![
                    p.to_string(),
                    fmt_bytes(m as f64),
                    d.strategy.name().to_string(),
                    d.segment.map(|x| fmt_bytes(x as f64)).unwrap_or_else(|| "-".into()),
                    fmt_time(d.predicted),
                ]);
            }
        }
        println!("{}", t.to_ascii());
        let mut share = Table::new(vec!["strategy", "share"]);
        for (st, frac) in table.share() {
            share.row(vec![st.name().to_string(), format!("{:.0}%", frac * 100.0)]);
        }
        println!("{}", share.to_ascii());
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    // tuning against captured traces is the replay path, whichever
    // spelling the caller used
    if args.get("trace-dir").is_some() || args.get_or("backend", "auto") == "replay" {
        return cmd_replay(args);
    }
    let cfg = args.net_config()?;
    let mut sim = Netsim::new(2, cfg);
    let net = plogp::bench::measure(&mut sim);
    println!("measured {}", net.summary());

    let tuner = backend_tuner(args)?;
    println!("backend: {} ({} sweep worker(s))", tuner.backend_name(), tuner.jobs);
    if let Some(c) = args.get("corrections") {
        println!("corrections: {c}");
    }
    let ops = op_list(args)?;
    let p_grid = args
        .usize_list("procs")?
        .unwrap_or_else(grids::default_p_grid);
    let m_grid = grids::default_m_grid();
    let t0 = std::time::Instant::now();
    let tables = ops
        .iter()
        .map(|&op| tuner.tune_op(op, &net, &p_grid, &m_grid))
        .collect::<Result<Vec<_>>>()?;
    let dt = t0.elapsed();
    println!(
        "tuned {} grid points in {:.2} ms\n",
        ops.len() * p_grid.len() * m_grid.len(),
        dt.as_secs_f64() * 1e3
    );
    if args.flag("stats") {
        let counts = tuner.stats();
        if counts.cells == 0 {
            // the batched artifact path never sweeps per-cell models,
            // so there are no counters to report (and no pruning claim
            // to make)
            println!("sweep stats: n/a (batched {} backend)\n", tuner.backend_name());
        } else {
            let cells = (p_grid.len() * m_grid.len()) as u64;
            let families: Vec<&[Strategy]> = ops.iter().map(|op| op.family()).collect();
            let exhaustive = eval::exhaustive_invocations(&families, cells, tuner.s_grid.len());
            println!("sweep stats: {}", counts.to_json());
            println!(
                "model invocations: {} vs {} exhaustive ({:.1}x fewer)\n",
                counts.model_invocations,
                exhaustive,
                counts.reduction_vs(exhaustive)
            );
        }
        println!("obs: {}\n", obs::registry().snapshot_json());
    }

    save_and_print_tables(args, &tables)
}

/// Capture message traces: the replay backend's input, one file per
/// `(op, strategy, P, m)` cell.
fn cmd_record(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let dir = args
        .get("trace-dir")
        .ok_or_else(|| anyhow::anyhow!("record needs --trace-dir <dir>"))?;
    let ops = op_list(args)?;
    let p_grid = args.usize_list("procs")?.unwrap_or_else(|| vec![2, 4, 8, 16, 32]);
    let mpoints = args.usize_or("mpoints", 9)?.max(2);
    let m_grid = grids::log_grid(1, 1 << 20, mpoints);
    let capacity = args.usize_or("capacity", eval::DEFAULT_TRACE_CAPACITY)?.max(1);
    let t0 = std::time::Instant::now();
    let (set, net) = experiments::record_traces(
        &cfg,
        &ops,
        &p_grid,
        &m_grid,
        &grids::default_s_grid(),
        capacity,
    );
    println!("measured {}", net.summary());
    let n = set.save_dir(Path::new(dir))?;
    println!(
        "captured {n} trace(s) ({} events across {} op families) in {:.2} s",
        set.total_events(),
        set.ops().len(),
        t0.elapsed().as_secs_f64()
    );
    println!("wrote {dir}");
    Ok(())
}

/// Tune from captured traces — the deterministic regression backend.
fn cmd_replay(args: &Args) -> Result<()> {
    let dir = args
        .get("trace-dir")
        .ok_or_else(|| anyhow::anyhow!("the replay backend needs --trace-dir <dir>"))?;
    let replay = eval::ReplayEval::load(Path::new(dir))?;
    let net = replay.net().clone();
    println!(
        "replaying {} trace(s) ({} events) from {dir}",
        replay.set().len(),
        replay.set().total_events()
    );
    println!("captured {}", net.summary());
    // default to every captured op family, and to the captured grids —
    // off-grid cells would just miss to +inf
    let ops: Vec<Op> = match args.get("op") {
        None => {
            let captured = replay.set().ops();
            captured.iter().filter_map(|n| Op::from_name(n)).collect()
        }
        Some(_) => op_list(args)?,
    };
    let p_grid = args.usize_list("procs")?.unwrap_or_else(|| replay.set().p_values());
    let m_grid = replay.set().m_values();
    let handle = replay.clone();
    let tuner = Tuner::with_evaluator(Box::new(replay)).jobs(args.usize_or("jobs", 0)?);
    let t0 = std::time::Instant::now();
    let tables = ops
        .iter()
        .map(|&op| tuner.tune_op(op, &net, &p_grid, &m_grid))
        .collect::<Result<Vec<_>>>()?;
    println!(
        "replay-tuned {} grid points in {:.2} ms\n",
        ops.len() * p_grid.len() * m_grid.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    if args.flag("stats") {
        println!("replay stats: {}\n", handle.stats().to_json());
    }
    save_and_print_tables(args, &tables)
}

/// Fit trace-derived correction factors — one multiplier per
/// `(strategy, size-octave)` — that close the gap between the analytic
/// models and a captured workload, and write the versioned corrections
/// TSV that `tune`/`serve`/`coordd` accept via `--corrections`.
fn cmd_calibrate(args: &Args) -> Result<()> {
    use collective_tuner::models::{correct, CorrectionTable};
    use collective_tuner::netsim::TraceSet;

    let dir = args
        .get("trace-dir")
        .ok_or_else(|| anyhow::anyhow!("calibrate needs --trace-dir <dir>"))?;
    let set = TraceSet::load_dir(Path::new(dir))?;
    let net = correct::net_of(&set)
        .ok_or_else(|| anyhow::anyhow!("no trace records in {dir}"))?;
    println!("calibrating against {} trace(s) from {dir}", set.len());
    println!("captured {}", net.summary());
    let (table, report) = CorrectionTable::fit(&set, &net);
    print!("{}", report.to_text());
    if let Some(out) = args.get("save") {
        let path = table.save(Path::new(out))?;
        println!("wrote {} ({} factor(s))", path.display(), table.len());
    } else {
        println!("(re-run with --save <dir> to write the corrections table)");
    }
    Ok(())
}

/// Cross-check two evaluation backends over a grid.
fn cmd_validate(args: &Args) -> Result<()> {
    use collective_tuner::eval::{Evaluator, ModelEval, ReplayEval, SimEval};
    use collective_tuner::tuner::validate::{cross_validate, ValidateOptions};

    let cfg = args.net_config()?;
    let trace_dir = args.get("trace-dir");
    let mut replay_handle: Option<ReplayEval> = None;
    let mut build = |name: &str, role: &str| -> Result<Box<dyn Evaluator>> {
        match name {
            "native" => Ok(Box::new(ModelEval::new())),
            "sim" => Ok(Box::new(SimEval::new(cfg.clone()))),
            "replay" => {
                let dir = trace_dir.ok_or_else(|| {
                    anyhow::anyhow!("--{role} replay needs --trace-dir <dir>")
                })?;
                let r = ReplayEval::load(Path::new(dir))?;
                replay_handle = Some(r.clone());
                Ok(Box::new(r))
            }
            other => bail!("unknown --{role} '{other}' (native, sim, replay)"),
        }
    };
    let reference = build(&args.get_or("reference", "sim"), "reference")?;
    let candidate = build(&args.get_or("candidate", "native"), "candidate")?;
    let net = match &replay_handle {
        Some(r) => r.net().clone(),
        None => {
            let mut sim = Netsim::new(2, cfg.clone());
            plogp::bench::measure(&mut sim)
        }
    };
    // judge over the captured grids when replay is involved (anything
    // else scores +inf misses), over the paper's spread otherwise
    let (p_list, m_list) = match &replay_handle {
        Some(r) => (r.set().p_values(), r.set().m_values()),
        None => (vec![4usize, 8, 16, 24, 32, 48], vec![256u64, 4096, 65536, 1 << 18, 1 << 20]),
    };
    let p_list = match args.usize_list("procs")? {
        None => p_list,
        Some(requested) => {
            // an uncaptured P makes every replay score +inf and the
            // report meaningless — reject it instead of judging noise
            if let Some(r) = &replay_handle {
                for &p in &requested {
                    if !r.set().p_values().contains(&p) {
                        bail!(
                            "--procs {p} is not in the captured trace grid \
                             (captured: {:?})",
                            r.set().p_values()
                        );
                    }
                }
            }
            requested
        }
    };
    let ops = op_list(args)?;
    let opts = ValidateOptions::default();
    println!(
        "validate: candidate {} judged by reference {} over {}x{} cells",
        candidate.name(),
        reference.name(),
        p_list.len(),
        m_list.len()
    );
    // `--corrections` switches to the calibration report: the same
    // reference judges the uncorrected and the corrected native models.
    if let Some(cpath) = args.get("corrections") {
        use collective_tuner::models::CorrectionTable;
        use collective_tuner::tuner::validate::validate_calibration;
        if args.get_or("candidate", "native") != "native" {
            bail!("--corrections judges the corrected native model; drop --candidate");
        }
        let table = CorrectionTable::load(Path::new(cpath))?;
        let mut t = Table::new(vec![
            "op", "points", "err_before", "err_after", "acc_before", "acc_after",
        ]);
        for &op in &ops {
            let rep = validate_calibration(
                reference.as_ref(),
                &table,
                &net,
                op.family(),
                &p_list,
                &m_list,
                &opts,
            );
            t.row(vec![
                op.name().to_string(),
                rep.uncorrected.points.to_string(),
                format!("{:.4}", rep.uncorrected.mean_rel_err),
                format!("{:.4}", rep.corrected.mean_rel_err),
                format!("{:.0}%", rep.uncorrected.accuracy() * 100.0),
                format!("{:.0}%", rep.corrected.accuracy() * 100.0),
            ]);
            println!(
                "{}: mean rel err {:.4} -> {:.4} ({}), accuracy delta {:+.0}%",
                op.name(),
                rep.uncorrected.mean_rel_err,
                rep.corrected.mean_rel_err,
                if rep.error_reduced() { "improved" } else { "REGRESSED" },
                rep.accuracy_delta() * 100.0
            );
        }
        println!("{}", t.to_ascii());
        if let Some(r) = &replay_handle {
            println!("replay stats: {}", r.stats().to_json());
        }
        return Ok(());
    }
    let mut table = Table::new(vec![
        "op", "points", "correct", "meaningful", "correct_meaningful", "mean_rel_err",
        "max_regret",
    ]);
    for &op in &ops {
        let rep = cross_validate(
            reference.as_ref(),
            candidate.as_ref(),
            &net,
            op.family(),
            &p_list,
            &m_list,
            &opts,
        );
        table.row(vec![
            op.name().to_string(),
            rep.points.to_string(),
            rep.correct.to_string(),
            rep.meaningful.to_string(),
            rep.correct_meaningful.to_string(),
            format!("{:.3}", rep.mean_rel_err),
            format!("{:.3}", rep.max_regret),
        ]);
        println!(
            "{}: {:.0}% overall, {:.0}% where it matters (>10% margin), worst regret {:.1}%",
            op.name(),
            rep.accuracy() * 100.0,
            rep.meaningful_accuracy() * 100.0,
            rep.max_regret * 100.0
        );
    }
    println!("{}", table.to_ascii());
    if let Some(r) = &replay_handle {
        println!("replay stats: {}", r.stats().to_json());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let p = args.usize_or("procs", 24)?;
    let m = args.u64_or("bytes", 64 * 1024)?;
    let op = args.get_or("op", "bcast");
    let seg = args.get("segment").map(cli::parse_size).transpose()?;

    let sched = match op.as_str() {
        "bcast" | "scatter" => {
            let strategy_name = args.get_or("strategy", "auto");
            if strategy_name == "auto" {
                // measure + tune + look up
                let mut sim = Netsim::new(2, cfg.clone());
                let net = plogp::bench::measure(&mut sim);
                let tuner = backend_tuner(args)?;
                let (b, s) =
                    tuner.tune(&net, &grids::default_p_grid(), &grids::default_m_grid())?;
                let table = if op == "bcast" { b } else { s };
                let d = *table.lookup(p, m);
                println!(
                    "tuned choice: {} (segment {:?}, predicted {})",
                    d.strategy.name(),
                    d.segment,
                    fmt_time(d.predicted)
                );
                return run_strategy(&cfg, d.strategy, p, m, d.segment);
            }
            let full = if strategy_name.contains('/') {
                strategy_name.clone()
            } else {
                format!("{op}/{strategy_name}")
            };
            let strategy = Strategy::from_name(&full)
                .ok_or_else(|| anyhow::anyhow!("unknown strategy '{full}'"))?;
            return run_strategy(&cfg, strategy, p, m, seg);
        }
        "gather" | "reduce" | "barrier" | "allgather" | "allreduce" => {
            let family = Op::from_name(&op).expect("matched op names parse");
            // barriers carry no payload: accept --bytes 0 (the schedule
            // builders ignore the size entirely)
            let m = if family == Op::Barrier { m.max(1) } else { m };
            let strategy_name = args.get_or("strategy", "auto");
            if strategy_name == "auto" {
                // measure + tune the one family + look up, exactly like
                // the core ops: same engine, same evaluator backends
                let mut sim = Netsim::new(2, cfg.clone());
                let net = plogp::bench::measure(&mut sim);
                let tuner = backend_tuner(args)?;
                let table = tuner.tune_op(
                    family,
                    &net,
                    &grids::default_p_grid(),
                    &grids::default_m_grid(),
                )?;
                let d = *table.lookup(p, m);
                println!(
                    "tuned choice: {} (predicted {})",
                    d.strategy.name(),
                    fmt_time(d.predicted)
                );
                d.strategy.try_build(p, 0, m, None)?
            } else {
                let full = if strategy_name.contains('/') {
                    strategy_name.clone()
                } else {
                    format!("{op}/{strategy_name}")
                };
                let strategy = Strategy::from_name(&full)
                    .filter(|s| family.family().contains(s))
                    .ok_or_else(|| anyhow::anyhow!("unknown {op} strategy '{full}'"))?;
                strategy.try_build(p, 0, m, None)?
            }
        }
        other => bail!("unknown --op '{other}'"),
    };
    run_schedule(&cfg, &sched, p)
}

fn run_strategy(
    cfg: &collective_tuner::netsim::NetConfig,
    strategy: Strategy,
    p: usize,
    m: u64,
    seg: Option<u64>,
) -> Result<()> {
    let sched = strategy.build(p, 0, m, seg);
    run_schedule(cfg, &sched, p)
}

fn run_schedule(
    cfg: &collective_tuner::netsim::NetConfig,
    sched: &collective_tuner::mpi::CommSchedule,
    p: usize,
) -> Result<()> {
    let mut world = World::new(Netsim::new(p, cfg.clone()));
    let rep = world.run(sched);
    let problems = rep.verify(sched);
    println!("operation : {}", sched.name);
    println!("ranks     : {p}");
    println!("messages  : {} ({} data bytes)", rep.messages, rep.data_bytes);
    println!("ack stalls: {}", rep.ack_stalls);
    println!("completion: {}", fmt_time(rep.completion.as_secs()));
    println!("verified  : {}", if problems.is_empty() { "ok" } else { "FAILED" });
    for pr in &problems {
        println!("  ! {pr}");
    }
    if !problems.is_empty() {
        bail!("payload verification failed");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let id = args.get_or("id", "all");
    let out_dir = args.get("out").map(PathBuf::from);
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL_IDS.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let result = experiments::run(id, &cfg)
            .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}'"))?;
        println!("{}", result.render());
        if let Some(dir) = &out_dir {
            let path = result.write_csv(dir)?;
            println!("wrote {}\n", path.display());
        }
    }
    Ok(())
}

fn cmd_discover(args: &Args) -> Result<()> {
    use collective_tuner::topology::{ClusterSpec, GridSpec};
    // Demo topology: N nodes split across --clusters islands over a WAN;
    // the discovery procedure must recover the layout blind.
    let total = args.usize_or("nodes", 12)?;
    let k = args.usize_or("clusters", 2)?.max(1).min(total);
    let base = total / k;
    let mut sizes = vec![base; k];
    sizes[0] += total - base * k;
    let grid = GridSpec::new(
        sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| ClusterSpec::new(format!("c{i}"), n, args.net_config().unwrap()))
            .collect(),
        collective_tuner::netsim::NetConfig::wan_link(),
    );
    let mut sim = grid.build_sim();
    let d = discover::discover(&mut sim, 3.0);
    println!("probed {total} nodes: found {} islands", d.num_clusters);
    for c in 0..d.num_clusters {
        println!("  island {c}: nodes {:?} (root {})", d.members(c), d.roots()[c]);
    }
    let ok = d.num_clusters == k;
    println!("planted layout {:?} -> {}", sizes, if ok { "RECOVERED" } else { "MISSED" });
    if !ok {
        bail!("discovery failed");
    }
    Ok(())
}

fn coordinator_from_args(args: &Args) -> Result<Coordinator> {
    let defaults = CoordinatorConfig::default();
    let corrections = args.get("corrections").map(PathBuf::from);
    let artifact_dir = match args.get_or("backend", "auto").as_str() {
        "native" => None,
        "artifact" if corrections.is_some() => {
            bail!("--corrections applies to the native model backend, not --backend artifact")
        }
        // corrections pin the native backend: an artifact would
        // silently ignore the fitted factors
        "auto" if corrections.is_some() => None,
        "auto" | "artifact" => {
            let dir = args
                .get("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(TunerArtifact::default_dir);
            // an explicit artifact request must fail loudly, not fall
            // back to native like `auto` does
            if args.get_or("backend", "auto") == "artifact" {
                Tuner::with_artifact(&dir)?;
            }
            Some(dir)
        }
        other => bail!("unknown --backend '{other}' (auto, native, artifact)"),
    };
    let cfg = CoordinatorConfig {
        shards: args.usize_or("shards", defaults.shards)?.max(1),
        capacity_per_shard: args.usize_or("capacity", defaults.capacity_per_shard)?.max(1),
        jobs: args.usize_or("jobs", 0)?,
        artifact_dir,
        corrections,
        max_staleness: std::time::Duration::from_secs(
            args.u64_or("max-staleness", defaults.max_staleness.as_secs())?,
        ),
        ..defaults
    };
    Coordinator::try_new(cfg)
}

fn cmd_query(args: &Args) -> Result<()> {
    if args.get("connect").is_some() {
        return cmd_query_net(args);
    }
    let cfg = args.net_config()?;
    let coord = coordinator_from_args(args)?;
    if let Some(dir) = args.get("warm") {
        let n = coord.warm_start_from(Path::new(dir))?;
        println!("warm start: loaded {n} table set(s) from {dir}");
    }
    let name = args.get_or("cluster", "default");
    let nodes = args.usize_or("nodes", 50)?;
    if let Some(dir) = args.get("traces") {
        let sig = coord.warm_start_from_traces(Path::new(dir), &name)?;
        println!(
            "trace warm start: replay-tuned tables for '{name}' from {dir} \
             (signature {})",
            sig.key()
        );
    }
    if coord.cluster(&name).is_none() {
        // An explicit warm start that does not cover the requested
        // cluster is a caller mistake: measuring and tuning a fresh
        // default cluster here would silently mask it.
        if args.get("warm").is_some() {
            let known: Vec<String> =
                coord.clusters().iter().map(|c| c.name.clone()).collect();
            bail!(
                "cluster '{name}' is not in the warm-started set \
                 (loaded: {known:?}); drop --warm to measure and register it fresh"
            );
        }
        let mut sim = Netsim::new(2, cfg);
        let net = plogp::bench::measure(&mut sim);
        println!("measured {}", net.summary());
        coord.register(&name, nodes, net)?;
    }
    let op_name = args.get_or("op", "bcast");
    let op = Op::from_name(&op_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown --op '{op_name}' (bcast, scatter, gather, reduce, barrier, \
             allgather, allreduce)"
        )
    })?;
    let p = args.usize_or("procs", 24)?;
    let m = args.u64_or("bytes", 64 * 1024)?;
    let t0 = std::time::Instant::now();
    let d = coord.decision(op, &name, p, m)?;
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _ = coord.decision(op, &name, p, m)?;
    let repeat = t1.elapsed();
    println!("cluster   : {name} ({nodes} nodes, backend {})", coord.backend_name());
    println!("query     : {} @ (P={p}, m={})", op.name(), fmt_bytes(m as f64));
    println!(
        "decision  : {} (segment {}, predicted {})",
        d.strategy.name(),
        d.segment.map(|s| fmt_bytes(s as f64)).unwrap_or_else(|| "-".into()),
        fmt_time(d.predicted)
    );
    println!(
        "latency   : first {:.2} ms, repeat {:.1} us (cache hit)",
        first.as_secs_f64() * 1e3,
        repeat.as_secs_f64() * 1e6
    );
    let st = coord.stats();
    println!(
        "service   : {} cached signature(s), {} hit(s) / {} miss(es), {} tuner run(s)",
        st.cache.entries, st.cache.hits, st.cache.misses, st.tunes
    );
    if args.flag("stats") {
        println!("stats     : {}", coord.stats_json());
        println!("obs       : {}", obs::registry().snapshot_json());
    }
    if let Some(dir) = args.get("save") {
        let n = coord.persist_to(Path::new(dir))?;
        println!("persisted {n} table set(s) to {dir}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    let metrics_interval = args.u64_or("metrics-interval", 0)?;
    let k = args.usize_or("clusters", 3)?.max(1);
    let nodes = args.usize_or("nodes", 16)?.max(2);
    let threads = args.usize_or("threads", 8)?.max(1);
    let requests = args.usize_or("requests", 10_000)?;
    let coord = coordinator_from_args(args)?;
    if let Some(dir) = args.get("warm") {
        let n = coord.warm_start_from(Path::new(dir))?;
        println!("warm start: loaded {n} table set(s) from {dir}");
    }

    // Alternate hardware classes across islands: distinct signatures
    // exist, and once k exceeds the preset count, islands *share*
    // signatures — exercising both the miss and the dedup path.
    let presets = [
        NetConfig::fast_ethernet_icluster1(),
        NetConfig::gigabit_ethernet(),
        NetConfig::myrinet_like(),
    ];
    let grid = GridSpec::new(
        (0..k)
            .map(|i| {
                ClusterSpec::new(
                    format!("island-{i}"),
                    nodes,
                    presets[i % presets.len()].clone(),
                )
            })
            .collect(),
        NetConfig::wan_link(),
    );
    let t_reg = std::time::Instant::now();
    coord.register_islands(&grid)?;
    println!(
        "registered {k} island(s) of {nodes} nodes (backend {}) in {:.2} ms",
        coord.backend_name(),
        t_reg.elapsed().as_secs_f64() * 1e3
    );

    let names: Vec<String> = coord.clusters().iter().map(|c| c.name.clone()).collect();
    let served = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let t0 = std::time::Instant::now();
    // Workers report failures (e.g. a query against an unregistered
    // cluster) as `Result`s joined below: a structured nonzero exit,
    // never a worker-thread panic.
    let worker_result: Result<()> = std::thread::scope(|s| {
        let done = &done;
        if metrics_interval > 0 {
            // Periodic snapshot printer: one line per interval while the
            // load threads run. Polls `done` at a finer grain than the
            // interval so shutdown never waits a full period.
            s.spawn(move || {
                let tick = std::time::Duration::from_millis(50);
                let period = std::time::Duration::from_secs(metrics_interval);
                let mut last = std::time::Instant::now();
                while !done.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    if last.elapsed() >= period {
                        println!("metrics: {}", obs::registry().snapshot_json());
                        last = std::time::Instant::now();
                    }
                }
            });
        }
        let workers: Vec<_> = (0..threads)
            .map(|t| {
                let coord = &coord;
                let names = &names;
                let served = &served;
                s.spawn(move || -> Result<()> {
                    let mut rng = Prng::new(0xC0DE_5EED ^ t as u64);
                    for _ in 0..requests {
                        let name = rng.pick(names);
                        let op = *rng.pick(&Op::ALL);
                        let p = rng.range_usize(2, nodes.max(3));
                        let m = rng.range(1, 1 << 20);
                        let d = coord
                            .decision(op, name, p, m)
                            .with_context(|| format!("serving cluster '{name}'"))?;
                        std::hint::black_box(d);
                        served.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(())
                })
            })
            .collect();
        let mut first_err: Result<()> = Ok(());
        for w in workers {
            let outcome = match w.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("serve worker panicked")),
            };
            if first_err.is_ok() {
                first_err = outcome;
            }
        }
        done.store(true, Ordering::Relaxed);
        first_err
    });
    worker_result?;
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    let total = served.load(Ordering::Relaxed);
    let st = coord.stats();
    println!(
        "served {total} queries from {threads} thread(s) in {:.2} s ({:.0} kq/s)",
        dt,
        total as f64 / dt / 1e3
    );
    println!(
        "cache: {} entries, {} hits / {} misses / {} evictions; {} tuner run(s) for {k} island(s)",
        st.cache.entries, st.cache.hits, st.cache.misses, st.cache.evictions, st.tunes
    );
    if args.flag("stats") {
        println!("stats: {}", coord.stats_json());
    }
    if obs::enabled() {
        println!("obs: {}", obs::registry().snapshot_json());
        let fr = obs::flight();
        println!(
            "flight recorder: {} event(s), {} dropped, {} total",
            fr.len(),
            fr.dropped(),
            fr.total()
        );
        print!("{}", fr.to_tsv());
    }

    // The multi-level construction both companion papers need: build a
    // grid-wide broadcast whose per-island strategies come from the
    // coordinator's cached tables, and execute it on the simulator.
    let sched = multilevel::tuned_bcast(&grid, 64 * 1024, &coord)?;
    let mut world = World::new(grid.build_sim());
    let rep = world.run(&sched);
    println!(
        "multilevel broadcast over {} nodes: completion {}, verified {}",
        grid.total_nodes(),
        fmt_time(rep.completion.as_secs()),
        if rep.verify(&sched).is_empty() { "ok" } else { "FAILED" }
    );

    // One refresh pass: re-probe every island's current parameters.
    let outcomes = coord.refresh_all(
        |name| {
            let spec = grid.clusters.iter().find(|c| c.name == name);
            Netsim::new(
                2,
                spec.map(|c| c.net.clone())
                    .unwrap_or_else(NetConfig::fast_ethernet_icluster1),
            )
        },
        &RefreshPolicy::default(),
    )?;
    for (name, outcome) in &outcomes {
        println!(
            "refresh {name}: drift {:.2}% -> {}",
            outcome.drift() * 100.0,
            if outcome.refreshed() { "re-tuned" } else { "table unchanged" }
        );
    }

    if let Some(dir) = args.get("save") {
        let n = coord.persist_to(Path::new(dir))?;
        println!("persisted {n} table set(s) to {dir}");
    }
    Ok(())
}

/// `coordd` — the coordinator as a network service: register demo
/// islands (the same mixed-hardware layout `serve` uses), bind the
/// `ct/1` TCP server (docs/PROTOCOL.md), and run until a remote
/// `SHUTDOWN` arrives (only honored with `--allow-remote-shutdown`) or
/// the process is killed. `--churn-ms` runs a background drift loop so
/// subscribed clients observe real `INVALIDATE`/`TABLEUPDATE` pushes.
fn cmd_coordd(args: &Args) -> Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    use collective_tuner::coordinator::net::{CoordServer, ServerOptions};

    let listen = args.get_or("listen", "127.0.0.1:7177");
    let k = args.usize_or("clusters", 3)?.max(1);
    let nodes = args.usize_or("nodes", 16)?.max(2);
    let metrics_interval = args.u64_or("metrics-interval", 0)?;
    let churn_ms = args.u64_or("churn-ms", 0)?;
    // Chaos hook for the CI smoke: arm one injected tuner failure just
    // before the Nth churn pass. Passes 1..N-1 publish and shelve
    // tables, so the armed failure deterministically lands on a
    // signature with a stale-shelf entry — exercising the stale-serve
    // rung of the degradation ladder end-to-end over the wire.
    let inject_at = args.u64_or("inject-tune-failure-at", 0)?;
    if inject_at > 0 && churn_ms == 0 {
        bail!("--inject-tune-failure-at needs --churn-ms (the churn loop consumes the failure)");
    }

    let coord = Arc::new(coordinator_from_args(args)?);
    if let Some(dir) = args.get("warm") {
        let n = coord.warm_start_from(Path::new(dir))?;
        println!("warm start: loaded {n} table set(s) from {dir}");
    }
    let presets = [
        NetConfig::fast_ethernet_icluster1(),
        NetConfig::gigabit_ethernet(),
        NetConfig::myrinet_like(),
    ];
    let grid = GridSpec::new(
        (0..k)
            .map(|i| {
                ClusterSpec::new(
                    format!("island-{i}"),
                    nodes,
                    presets[i % presets.len()].clone(),
                )
            })
            .collect(),
        NetConfig::wan_link(),
    );
    coord.register_islands(&grid)?;
    println!(
        "registered {k} island(s) of {nodes} nodes (backend {})",
        coord.backend_name()
    );

    let defaults = ServerOptions::default();
    let idle_secs = args.u64_or("idle-timeout", 0)?;
    let server = CoordServer::start(
        Arc::clone(&coord),
        &listen,
        ServerOptions {
            banner: format!("collective-tuner coordd ({k} island(s))"),
            allow_remote_shutdown: args.flag("allow-remote-shutdown"),
            idle_timeout: if idle_secs > 0 { Some(Duration::from_secs(idle_secs)) } else { None },
            max_connections: args
                .usize_or("max-connections", defaults.max_connections)?
                .max(1),
            ..defaults
        },
    )?;
    // The machine-readable line launchers parse for the ephemeral port.
    println!("COORDD_LISTENING {}", server.local_addr());

    let stop_churn = Arc::new(AtomicBool::new(false));
    let churn = if churn_ms > 0 {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop_churn);
        Some(std::thread::spawn(move || {
            // Alternate island-0 between two hardware classes: each flip
            // drifts far past the default tolerance, so every pass
            // re-tunes and re-publishes — subscribers see live pushes.
            let policy = RefreshPolicy::default();
            let mut flip = true;
            let mut pass = 0u64;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(churn_ms));
                pass += 1;
                if pass == inject_at {
                    coord.inject_tune_failures(1);
                    log::warn!(
                        "coordd: chaos hook armed at churn pass {pass} — next tuner run fails"
                    );
                }
                let cfg = if flip {
                    NetConfig::gigabit_ethernet()
                } else {
                    NetConfig::fast_ethernet_icluster1()
                };
                flip = !flip;
                let mut sim = Netsim::new(2, cfg);
                if let Err(e) = coord.refresh("island-0", &mut sim, &policy) {
                    log::warn!("coordd: churn refresh failed: {e:#}");
                }
            }
        }))
    } else {
        None
    };

    let tick = Duration::from_millis(100);
    let period = Duration::from_secs(metrics_interval.max(1));
    let mut last = std::time::Instant::now();
    while !server.shutdown_requested() {
        std::thread::sleep(tick);
        if metrics_interval > 0 && last.elapsed() >= period {
            println!("metrics: {}", obs::registry().snapshot_json());
            last = std::time::Instant::now();
        }
    }
    println!("coordd: remote shutdown requested, draining");
    stop_churn.store(true, Ordering::Relaxed);
    if let Some(h) = churn {
        let _ = h.join();
    }
    server.shutdown();
    // Machine-readable final snapshot (the CI socket smoke's artifact).
    println!("OBS_SNAPSHOT_JSON {}", obs::registry().snapshot_json());
    println!("coordd: shut down cleanly");
    Ok(())
}

/// `query --connect` — the same one-shot query surface, answered by a
/// running `coordd` over `ct/1` instead of an in-process coordinator.
/// `--procs` accepts a comma list and becomes one batched request; any
/// per-query error frame makes the exit status nonzero.
fn cmd_query_net(args: &Args) -> Result<()> {
    use std::time::Duration;

    use collective_tuner::coordinator::net::{ClientOptions, NetClient, Point, Push, Query, RemoteError};

    let addr = args.get("connect").expect("routed here on --connect");
    // --resilient turns on socket deadlines plus bounded-backoff
    // retries (rides out a coordd restart); the default stays fail-fast.
    let opts = if args.flag("resilient") {
        ClientOptions::resilient()
    } else {
        ClientOptions::default()
    };
    let client =
        NetClient::connect_with(addr, opts).with_context(|| format!("connecting to {addr}"))?;
    println!("connected : {addr} ({})", client.banner());
    if args.flag("shutdown") {
        client.shutdown_server()?;
        println!("server acknowledged shutdown");
        return Ok(());
    }
    let name = args.get_or("cluster", "island-0");
    let op_name = args.get_or("op", "bcast");
    let op = Op::from_name(&op_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown --op '{op_name}' (bcast, scatter, gather, reduce, barrier, \
             allgather, allreduce)"
        )
    })?;
    let p_list = args.usize_list("procs")?.unwrap_or_else(|| vec![24]);
    let m = args.u64_or("bytes", 64 * 1024)?;
    let queries: Vec<Query> = p_list
        .iter()
        .map(|&p| Query { op, cluster: name.clone(), p, m })
        .collect();
    // --repeat loops the batch (one round-trip per round, --interval-ms
    // apart): with --resilient this is the CI chaos smoke's client,
    // riding a server kill/restart mid-loop on transparent reconnects.
    let repeat = args.usize_or("repeat", 1)?.max(1);
    let interval_ms = args.u64_or("interval-ms", 0)?;
    for round in 0..repeat {
        if round > 0 && interval_ms > 0 {
            std::thread::sleep(Duration::from_millis(interval_ms));
        }
        let t0 = std::time::Instant::now();
        let replies = client.query_batch(&queries)?;
        let dt = t0.elapsed();
        let mut failed = 0usize;
        let mut first_err: Option<RemoteError> = None;
        for (q, r) in queries.iter().zip(&replies) {
            match r {
                Ok(d) => println!(
                    "decision  : {} P={} m={} -> {} (segment {}, predicted {})",
                    q.op.name(),
                    q.p,
                    fmt_bytes(q.m as f64),
                    d.strategy.name(),
                    d.segment.map(|s| fmt_bytes(s as f64)).unwrap_or_else(|| "-".into()),
                    fmt_time(d.predicted)
                ),
                Err(e) => {
                    failed += 1;
                    if first_err.is_none() {
                        first_err = Some(e.clone());
                    }
                    println!("error     : {} P={} -> {e}", q.op.name(), q.p);
                }
            }
        }
        println!(
            "latency   : {} quer(ies) in {:.2} ms over one round-trip",
            replies.len(),
            dt.as_secs_f64() * 1e3
        );
        if let Some(e) = first_err {
            // Keep the typed error in the chain so `classify_failure`
            // can map it to the documented exit code.
            return Err(anyhow::Error::new(e)
                .context(format!("{failed} of {} remote queries failed", replies.len())));
        }
    }
    if args.flag("subscribe") || args.get("wait-pushes").is_some() {
        let points: Vec<Point> = p_list.iter().map(|&p| Point { op, p, m }).collect();
        let (sig, epoch) = client.subscribe(&name, &points)?;
        println!("subscribed: {name} (signature {sig}) at epoch {epoch}");
        let want = args.usize_or("wait-pushes", 0)?;
        if want > 0 {
            let timeout = Duration::from_secs(args.u64_or("push-timeout", 10)?);
            let pushes = client.wait_pushes(want, timeout)?;
            for p in &pushes {
                match p {
                    Push::Invalidate { epoch, cluster } => {
                        println!("push      : INVALIDATE {cluster} @ epoch {epoch}")
                    }
                    Push::TableUpdate { epoch, cluster, rows } => println!(
                        "push      : TABLEUPDATE {cluster} @ epoch {epoch} ({} row(s))",
                        rows.len()
                    ),
                }
            }
            if pushes.len() < want {
                bail!("expected {want} push(es), got {} before the deadline", pushes.len());
            }
        }
    }
    println!(
        "reconnects: {} transparent reconnect(s) over the session",
        client.reconnects()
    );
    client.close();
    if obs::enabled() {
        // Machine-readable client-side snapshot (net.reconnects et al.)
        // for the CI chaos smoke — same marker line as coordd's.
        println!("OBS_SNAPSHOT_JSON {}", obs::registry().snapshot_json());
    }
    Ok(())
}

/// `obs <subcommand>` — the observability layer's own CLI surface.
fn cmd_obs(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("dump") => cmd_obs_dump(args),
        Some(other) => bail!("unknown obs subcommand '{other}' (try: obs dump)"),
        None => bail!("obs needs a subcommand (try: obs dump)"),
    }
}

/// A fresh process starts with an empty registry, so `obs dump` first
/// exercises a miniature coordinator workload — register, decide across
/// three op families and a spread of sizes — and then prints all three
/// export surfaces: the JSON snapshot, the Prometheus text exposition,
/// and the decision flight-recorder ring as TSV.
fn cmd_obs_dump(args: &Args) -> Result<()> {
    obs::set_enabled(true);
    let cfg = args.net_config()?;
    let coord = coordinator_from_args(args)?;
    let mut sim = Netsim::new(2, cfg);
    let net = plogp::bench::measure(&mut sim);
    coord.register("obs-demo", 8, net)?;
    for op in [Op::Bcast, Op::Scatter, Op::AllReduce] {
        for m in [1024u64, 64 * 1024, 1 << 20] {
            let _ = coord.decision(op, "obs-demo", 8, m)?;
        }
    }
    println!("== registry snapshot (json) ==");
    println!("{}", obs::registry().snapshot_json());
    println!();
    println!("== prometheus exposition ==");
    print!("{}", obs::registry().prometheus());
    println!();
    let fr = obs::flight();
    println!("== decision flight recorder ({} event(s), {} dropped) ==", fr.len(), fr.dropped());
    print!("{}", fr.to_tsv());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(TunerArtifact::default_dir);
    println!("artifact dir: {}", dir.display());
    match TunerArtifact::load(&dir) {
        Ok(a) => {
            println!(
                "tuner artifact: {} strategies, table {}, P-grid {}, m-grid {}, s-grid {}",
                a.meta.num_strategies,
                a.meta.table_len,
                a.meta.p_grid_len,
                a.meta.m_grid_len,
                a.meta.s_grid_len
            );
            for (i, n) in a.meta.strategy_names.iter().enumerate() {
                println!("  [{i:2}] {n}");
            }
        }
        Err(e) => println!("tuner artifact: unavailable ({e:#})"),
    }
    println!("\npresets: icluster1 (paper testbed), ideal, gigabit, myrinet");
    println!("ops: bcast scatter gather reduce barrier allgather allreduce");
    Ok(())
}
