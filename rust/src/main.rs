//! `collective-tuner` — the L3 coordinator binary.
//!
//! Subcommands: `bench-plogp`, `tune`, `run`, `experiment`, `info`.
//! See `cli::USAGE` or run with `help`.

use std::path::PathBuf;

use anyhow::{bail, Result};

use collective_tuner::collectives::{composed, Strategy};
use collective_tuner::harness::experiments;
use collective_tuner::mpi::World;
use collective_tuner::netsim::Netsim;
use collective_tuner::plogp;
use collective_tuner::runtime::TunerArtifact;
use collective_tuner::topology::discover;
use collective_tuner::tuner::ext::{build_ext_schedule, ExtOp, ExtTuner};
use collective_tuner::tuner::{grids, persist, Tuner};
use collective_tuner::util::table::{fmt_bytes, fmt_time, Table};

use collective_tuner::cli::{self, Args};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match Args::parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::USAGE);
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&parsed) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "bench-plogp" => cmd_bench_plogp(args),
        "tune" => cmd_tune(args),
        "run" => cmd_run(args),
        "experiment" => cmd_experiment(args),
        "discover" => cmd_discover(args),
        "info" => cmd_info(args),
        "help" | "--help" | "-h" => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{}", cli::USAGE),
    }
}

fn cmd_bench_plogp(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let mut sim = Netsim::new(2, cfg);
    let net = plogp::bench::measure(&mut sim);
    println!("{}", net.summary());
    let mut t = Table::new(vec!["size", "g(m)"]);
    for (s, g) in net.table.sizes().iter().zip(net.table.gaps()) {
        t.row(vec![fmt_bytes(*s), fmt_time(*g)]);
    }
    println!("{}", t.to_ascii());
    println!("L = {}", fmt_time(net.l));
    Ok(())
}

fn backend_tuner(args: &Args) -> Result<Tuner> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(TunerArtifact::default_dir);
    Ok(match args.get_or("backend", "auto").as_str() {
        "auto" => Tuner::auto(&dir),
        "native" => Tuner::native(),
        "artifact" => Tuner::with_artifact(&dir)?,
        other => bail!("unknown --backend '{other}' (auto, native, artifact)"),
    })
}

fn cmd_tune(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let mut sim = Netsim::new(2, cfg);
    let net = plogp::bench::measure(&mut sim);
    println!("measured {}", net.summary());

    let tuner = backend_tuner(args)?;
    println!("backend: {}", tuner.backend.name());
    let p_grid = args
        .usize_list("procs")?
        .unwrap_or_else(grids::default_p_grid);
    let m_grid = grids::default_m_grid();
    let t0 = std::time::Instant::now();
    let (b, s) = tuner.tune(&net, &p_grid, &m_grid)?;
    let dt = t0.elapsed();
    if let Some(dir) = args.get("save") {
        let dir = PathBuf::from(dir);
        persist::save(&b, &dir.join("bcast.table.tsv"))?;
        persist::save(&s, &dir.join("scatter.table.tsv"))?;
        println!("saved decision tables to {}", dir.display());
    }
    println!(
        "tuned {} grid points in {:.2} ms\n",
        2 * p_grid.len() * m_grid.len(),
        dt.as_secs_f64() * 1e3
    );

    for table in [&b, &s] {
        println!("== {} decision table ==", table.op.name());
        let mut t = Table::new(vec!["P", "m", "strategy", "segment", "predicted"]);
        for (qi, &p) in table.p_grid.iter().enumerate() {
            for (mi, &m) in table.m_grid.iter().enumerate() {
                // compact: only print every 4th m column
                if mi % 4 != 0 {
                    continue;
                }
                let d = table.at(qi, mi);
                t.row(vec![
                    p.to_string(),
                    fmt_bytes(m as f64),
                    d.strategy.name().to_string(),
                    d.segment.map(|x| fmt_bytes(x as f64)).unwrap_or_else(|| "-".into()),
                    fmt_time(d.predicted),
                ]);
            }
        }
        println!("{}", t.to_ascii());
        let mut share = Table::new(vec!["strategy", "share"]);
        for (st, frac) in table.share() {
            share.row(vec![st.name().to_string(), format!("{:.0}%", frac * 100.0)]);
        }
        println!("{}", share.to_ascii());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let p = args.usize_or("procs", 24)?;
    let m = args.u64_or("bytes", 64 * 1024)?;
    let op = args.get_or("op", "bcast");
    let seg = args.get("segment").map(cli::parse_size).transpose()?;

    let sched = match op.as_str() {
        "bcast" | "scatter" => {
            let strategy_name = args.get_or("strategy", "auto");
            if strategy_name == "auto" {
                // measure + tune + look up
                let mut sim = Netsim::new(2, cfg.clone());
                let net = plogp::bench::measure(&mut sim);
                let tuner = backend_tuner(args)?;
                let (b, s) =
                    tuner.tune(&net, &grids::default_p_grid(), &grids::default_m_grid())?;
                let table = if op == "bcast" { b } else { s };
                let d = *table.lookup(p, m);
                println!(
                    "tuned choice: {} (segment {:?}, predicted {})",
                    d.strategy.name(),
                    d.segment,
                    fmt_time(d.predicted)
                );
                return run_strategy(&cfg, d.strategy, p, m, d.segment);
            }
            let full = if strategy_name.contains('/') {
                strategy_name.clone()
            } else {
                format!("{op}/{strategy_name}")
            };
            let strategy = Strategy::from_name(&full)
                .ok_or_else(|| anyhow::anyhow!("unknown strategy '{full}'"))?;
            return run_strategy(&cfg, strategy, p, m, seg);
        }
        "reduce" => composed::reduce_binomial(p, 0, m),
        "gather" | "barrier" | "allgather" | "allreduce" => {
            let family = match op.as_str() {
                "gather" => ExtOp::Gather,
                "barrier" => ExtOp::Barrier,
                "allgather" => ExtOp::AllGather,
                _ => ExtOp::AllReduce,
            };
            if args.get_or("strategy", "auto") == "auto" {
                let mut sim = Netsim::new(2, cfg.clone());
                let net = plogp::bench::measure(&mut sim);
                let dir = args
                    .get("artifacts")
                    .map(PathBuf::from)
                    .unwrap_or_else(TunerArtifact::default_dir);
                let tuner = ExtTuner::auto(&dir);
                let tables =
                    tuner.tune(&net, &grids::default_p_grid(), &grids::default_m_grid())?;
                let d = *tables[family as usize].lookup(p, m);
                println!(
                    "tuned choice: {} (predicted {})",
                    d.strategy.name(),
                    fmt_time(d.predicted)
                );
                build_ext_schedule(family, d.strategy, p, m)
            } else {
                match args.get_or("strategy", "auto").as_str() {
                    "flat" => composed::gather_flat(p, 0, m),
                    "binomial" if op == "gather" => composed::gather_binomial(p, 0, m),
                    "tree" => composed::barrier_binomial(p),
                    "dissemination" => {
                        collective_tuner::collectives::extended::barrier_dissemination(p)
                    }
                    "ring" => collective_tuner::collectives::extended::allgather_ring(p, m),
                    "rec_doubling" if op == "allgather" => {
                        collective_tuner::collectives::extended::allgather_recursive_doubling(
                            p, m,
                        )
                    }
                    "rec_doubling" => {
                        collective_tuner::collectives::extended::allreduce_recursive_doubling(
                            p, m,
                        )
                    }
                    "gather+bcast" => composed::allgather(p, 0, m),
                    "reduce+bcast" => composed::allreduce(p, 0, m),
                    other => bail!("unknown {op} strategy '{other}'"),
                }
            }
        }
        other => bail!("unknown --op '{other}'"),
    };
    run_schedule(&cfg, &sched, p)
}

fn run_strategy(
    cfg: &collective_tuner::netsim::NetConfig,
    strategy: Strategy,
    p: usize,
    m: u64,
    seg: Option<u64>,
) -> Result<()> {
    let sched = strategy.build(p, 0, m, seg);
    run_schedule(cfg, &sched, p)
}

fn run_schedule(
    cfg: &collective_tuner::netsim::NetConfig,
    sched: &collective_tuner::mpi::CommSchedule,
    p: usize,
) -> Result<()> {
    let mut world = World::new(Netsim::new(p, cfg.clone()));
    let rep = world.run(sched);
    let problems = rep.verify(sched);
    println!("operation : {}", sched.name);
    println!("ranks     : {p}");
    println!("messages  : {} ({} data bytes)", rep.messages, rep.data_bytes);
    println!("ack stalls: {}", rep.ack_stalls);
    println!("completion: {}", fmt_time(rep.completion.as_secs()));
    println!("verified  : {}", if problems.is_empty() { "ok" } else { "FAILED" });
    for pr in &problems {
        println!("  ! {pr}");
    }
    if !problems.is_empty() {
        bail!("payload verification failed");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let cfg = args.net_config()?;
    let id = args.get_or("id", "all");
    let out_dir = args.get("out").map(PathBuf::from);
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL_IDS.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let result = experiments::run(id, &cfg)
            .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}'"))?;
        println!("{}", result.render());
        if let Some(dir) = &out_dir {
            let path = result.write_csv(dir)?;
            println!("wrote {}\n", path.display());
        }
    }
    Ok(())
}

fn cmd_discover(args: &Args) -> Result<()> {
    use collective_tuner::topology::{ClusterSpec, GridSpec};
    // Demo topology: N nodes split across --clusters islands over a WAN;
    // the discovery procedure must recover the layout blind.
    let total = args.usize_or("nodes", 12)?;
    let k = args.usize_or("clusters", 2)?.max(1).min(total);
    let base = total / k;
    let mut sizes = vec![base; k];
    sizes[0] += total - base * k;
    let grid = GridSpec::new(
        sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| ClusterSpec::new(format!("c{i}"), n, args.net_config().unwrap()))
            .collect(),
        collective_tuner::netsim::NetConfig::wan_link(),
    );
    let mut sim = grid.build_sim();
    let d = discover::discover(&mut sim, 3.0);
    println!("probed {total} nodes: found {} islands", d.num_clusters);
    for c in 0..d.num_clusters {
        println!("  island {c}: nodes {:?} (root {})", d.members(c), d.roots()[c]);
    }
    let ok = d.num_clusters == k;
    println!("planted layout {:?} -> {}", sizes, if ok { "RECOVERED" } else { "MISSED" });
    if !ok {
        bail!("discovery failed");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(TunerArtifact::default_dir);
    println!("artifact dir: {}", dir.display());
    match TunerArtifact::load(&dir) {
        Ok(a) => {
            println!(
                "tuner artifact: {} strategies, table {}, P-grid {}, m-grid {}, s-grid {}",
                a.meta.num_strategies,
                a.meta.table_len,
                a.meta.p_grid_len,
                a.meta.m_grid_len,
                a.meta.s_grid_len
            );
            for (i, n) in a.meta.strategy_names.iter().enumerate() {
                println!("  [{i:2}] {n}");
            }
        }
        Err(e) => println!("tuner artifact: unavailable ({e:#})"),
    }
    println!("\npresets: icluster1 (paper testbed), ideal, gigabit, myrinet");
    println!("ops: bcast scatter gather reduce barrier allgather allreduce");
    Ok(())
}
