//! Automatic topology discovery — the paper's §5 future work: "our
//! research will also include the automatic discovery of the network
//! topology".
//!
//! Procedure (the standard latency-clustering approach, cf. Lowekamp's
//! thesis, the paper's ref [11]): probe pairwise one-way latencies with
//! 1-byte messages, then group nodes whose mutual latency is within a
//! multiplicative factor of the global minimum — intra-cluster links on
//! a LAN are an order of magnitude faster than WAN links, so a single
//! threshold separates the islands.

use crate::netsim::{Netsim, NodeId, SimTime};

/// A discovered partition of the nodes into islands of fast mutual
/// connectivity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Discovery {
    /// `cluster[i]` = island index of node `i`.
    pub cluster: Vec<usize>,
    /// Number of islands found.
    pub num_clusters: usize,
}

impl Discovery {
    /// Node ids of island `c`.
    pub fn members(&self, c: usize) -> Vec<NodeId> {
        self.cluster
            .iter()
            .enumerate()
            .filter(|(_, &ci)| ci == c)
            .map(|(i, _)| i as NodeId)
            .collect()
    }

    /// The first node of each island (the natural coordinator choice).
    pub fn roots(&self) -> Vec<NodeId> {
        (0..self.num_clusters)
            .map(|c| self.members(c)[0])
            .collect()
    }
}

/// Probe the full pairwise latency matrix (seconds) with 1-byte messages
/// on an otherwise idle network.
pub fn probe_latency_matrix(sim: &mut Netsim) -> Vec<Vec<f64>> {
    let n = sim.num_nodes();
    let mut matrix = vec![vec![0.0; n]; n];
    let mut t = 0.0f64;
    for a in 0..n as NodeId {
        for b in 0..n as NodeId {
            if a == b {
                continue;
            }
            // space probes out so they never queue behind each other
            t += 1.0;
            let out = sim.send(SimTime::from_secs(t), a, b, 1);
            matrix[a as usize][b as usize] =
                out.delivered.saturating_sub(out.tx_start).as_secs();
        }
    }
    sim.reset();
    matrix
}

/// Cluster nodes by latency: links faster than `threshold_factor` × the
/// global minimum latency are "intra-cluster"; islands are the connected
/// components of the fast-link graph.
pub fn discover(sim: &mut Netsim, threshold_factor: f64) -> Discovery {
    assert!(threshold_factor >= 1.0);
    let matrix = probe_latency_matrix(sim);
    let n = matrix.len();
    if n == 1 {
        return Discovery { cluster: vec![0], num_clusters: 1 };
    }
    let min = matrix
        .iter()
        .flat_map(|row| row.iter().copied())
        .filter(|&x| x > 0.0)
        .fold(f64::MAX, f64::min);
    let threshold = min * threshold_factor;

    // union-find over fast links
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, x: usize) -> usize {
        if parent[x] != x {
            let root = find(parent, parent[x]);
            parent[x] = root;
        }
        parent[x]
    }
    for a in 0..n {
        for b in (a + 1)..n {
            if matrix[a][b] <= threshold && matrix[b][a] <= threshold {
                let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
                if ra != rb {
                    parent[ra.max(rb)] = ra.min(rb);
                }
            }
        }
    }
    // compact island labels in first-seen order
    let mut label = std::collections::BTreeMap::new();
    let mut cluster = vec![0usize; n];
    for i in 0..n {
        let root = find(&mut parent, i);
        let next = label.len();
        let c = *label.entry(root).or_insert(next);
        cluster[i] = c;
    }
    Discovery { num_clusters: label.len(), cluster }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetConfig;
    use crate::topology::{ClusterSpec, GridSpec};

    fn grid(sizes: &[usize]) -> GridSpec {
        GridSpec::new(
            sizes
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    ClusterSpec::new(format!("c{i}"), n, NetConfig::fast_ethernet_ideal())
                })
                .collect(),
            NetConfig::wan_link(),
        )
    }

    #[test]
    fn single_cluster_is_one_island() {
        let mut sim = Netsim::new(8, NetConfig::fast_ethernet_ideal());
        let d = discover(&mut sim, 3.0);
        assert_eq!(d.num_clusters, 1);
        assert!(d.cluster.iter().all(|&c| c == 0));
    }

    #[test]
    fn two_planted_clusters_recovered() {
        let g = grid(&[5, 4]);
        let mut sim = g.build_sim();
        let d = discover(&mut sim, 3.0);
        assert_eq!(d.num_clusters, 2);
        for node in 0..9u32 {
            assert_eq!(
                d.cluster[node as usize],
                g.cluster_of(node),
                "node {node}"
            );
        }
        assert_eq!(d.roots(), vec![0, 5]);
    }

    #[test]
    fn three_planted_clusters_recovered() {
        let g = grid(&[3, 4, 2]);
        let mut sim = g.build_sim();
        let d = discover(&mut sim, 3.0);
        assert_eq!(d.num_clusters, 3);
        assert_eq!(d.members(0).len(), 3);
        assert_eq!(d.members(1).len(), 4);
        assert_eq!(d.members(2).len(), 2);
    }

    #[test]
    fn latency_matrix_is_symmetric_on_homogeneous_grid() {
        let g = grid(&[3, 3]);
        let mut sim = g.build_sim();
        let m = probe_latency_matrix(&mut sim);
        for a in 0..6 {
            for b in 0..6 {
                if a != b {
                    assert!((m[a][b] - m[b][a]).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn discovery_then_multilevel_bcast_composes() {
        use crate::collectives::{multilevel, Strategy};
        use crate::mpi::World;
        // discover the islands, rebuild a GridSpec-shaped plan, run a
        // two-level broadcast with per-island binomial
        let g = grid(&[4, 4]);
        let mut sim = g.build_sim();
        let d = discover(&mut sim, 3.0);
        assert_eq!(d.num_clusters, 2);
        let sched = multilevel::bcast(
            &g,
            8192,
            &vec![(Strategy::BcastBinomial, None); d.num_clusters],
        );
        let mut world = World::new(g.build_sim());
        let rep = world.run(&sched);
        assert!(rep.verify(&sched).is_empty());
    }

    #[test]
    fn single_node_world() {
        let mut sim = Netsim::new(1, NetConfig::fast_ethernet_ideal());
        let d = discover(&mut sim, 2.0);
        assert_eq!(d.num_clusters, 1);
    }
}
