//! Cluster and grid topology descriptions.
//!
//! The paper's motivation (§1) is the "islands of homogeneous clusters"
//! view of a grid: optimise inter-cluster communication with topology-
//! aware trees, and *intra*-cluster communication with the tuned static
//! strategies this crate implements. [`GridSpec`] describes such a grid;
//! [`GridSpec::build_sim`] realizes it as one flat [`Netsim`] with WAN
//! bandwidth/latency overrides on every cross-cluster link.
//! [`discover`] recovers the islands automatically from latency probes
//! (the paper's §5 future work).

pub mod discover;

use crate::netsim::{NetConfig, Netsim, NodeId};

/// One homogeneous cluster.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Number of nodes.
    pub nodes: usize,
    /// Intra-cluster network parameters.
    pub net: NetConfig,
    /// Human-readable name for reports.
    pub name: String,
}

impl ClusterSpec {
    pub fn new(name: impl Into<String>, nodes: usize, net: NetConfig) -> ClusterSpec {
        assert!(nodes >= 1);
        ClusterSpec { nodes, net, name: name.into() }
    }

    /// The paper's testbed: 50 nodes of switched Fast Ethernet.
    pub fn icluster1() -> ClusterSpec {
        ClusterSpec::new("icluster-1", 50, NetConfig::fast_ethernet_icluster1())
    }

    pub fn build_sim(&self) -> Netsim {
        Netsim::new(self.nodes, self.net.clone())
    }
}

/// A grid of clusters joined by a WAN.
///
/// The flat-simulator realization uses the *first* cluster's `NetConfig`
/// as the base (all clusters in the paper's scenarios share a technology
/// class) and overrides every cross-cluster link with the WAN bandwidth
/// and latency. Node ids are assigned cluster-by-cluster, in order.
#[derive(Debug, Clone)]
pub struct GridSpec {
    pub clusters: Vec<ClusterSpec>,
    /// WAN parameters between clusters (bandwidth bytes/s + one-way
    /// latency seconds are taken from this config).
    pub wan: NetConfig,
}

impl GridSpec {
    pub fn new(clusters: Vec<ClusterSpec>, wan: NetConfig) -> GridSpec {
        assert!(!clusters.is_empty());
        GridSpec { clusters, wan }
    }

    /// Total node count.
    pub fn total_nodes(&self) -> usize {
        self.clusters.iter().map(|c| c.nodes).sum()
    }

    /// Global node-id range `[lo, hi)` of cluster `i`.
    pub fn cluster_range(&self, i: usize) -> (NodeId, NodeId) {
        let lo: usize = self.clusters[..i].iter().map(|c| c.nodes).sum();
        (lo as NodeId, (lo + self.clusters[i].nodes) as NodeId)
    }

    /// Which cluster a global node id belongs to.
    pub fn cluster_of(&self, node: NodeId) -> usize {
        let mut acc = 0usize;
        for (i, c) in self.clusters.iter().enumerate() {
            acc += c.nodes;
            if (node as usize) < acc {
                return i;
            }
        }
        panic!("node {node} out of range");
    }

    /// The designated coordinator (root) node of cluster `i`: its first
    /// node.
    pub fn cluster_root(&self, i: usize) -> NodeId {
        self.cluster_range(i).0
    }

    /// Realize the grid as one flat simulator with WAN overrides on
    /// cross-cluster links.
    pub fn build_sim(&self) -> Netsim {
        let n = self.total_nodes();
        let mut sim = Netsim::new(n, self.clusters[0].net.clone());
        let extra_delay =
            (self.wan.prop_delay - self.clusters[0].net.prop_delay).max(0.0);
        for a in 0..n as NodeId {
            for b in 0..n as NodeId {
                if a != b && self.cluster_of(a) != self.cluster_of(b) {
                    sim.set_link_bandwidth(a, b, self.wan.bandwidth_bps);
                    sim.inject_link_delay(a, b, extra_delay);
                }
            }
        }
        sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::SimTime;

    fn two_cluster_grid() -> GridSpec {
        GridSpec::new(
            vec![
                ClusterSpec::new("a", 4, NetConfig::fast_ethernet_ideal()),
                ClusterSpec::new("b", 3, NetConfig::fast_ethernet_ideal()),
            ],
            NetConfig::wan_link(),
        )
    }

    #[test]
    fn ranges_partition_nodes() {
        let g = two_cluster_grid();
        assert_eq!(g.total_nodes(), 7);
        assert_eq!(g.cluster_range(0), (0, 4));
        assert_eq!(g.cluster_range(1), (4, 7));
        for n in 0..4 {
            assert_eq!(g.cluster_of(n), 0);
        }
        for n in 4..7 {
            assert_eq!(g.cluster_of(n), 1);
        }
    }

    #[test]
    fn cluster_roots_are_first_nodes() {
        let g = two_cluster_grid();
        assert_eq!(g.cluster_root(0), 0);
        assert_eq!(g.cluster_root(1), 4);
    }

    #[test]
    fn wan_links_are_slower() {
        let g = two_cluster_grid();
        let mut sim = g.build_sim();
        let intra = sim.send(SimTime::ZERO, 0, 1, 1 << 16).delivered;
        let inter = sim.send(SimTime::ZERO, 1, 4, 1 << 16).delivered;
        assert!(
            inter.as_secs() > 2.0 * intra.as_secs(),
            "inter={} intra={}",
            inter.as_secs(),
            intra.as_secs()
        );
    }

    #[test]
    fn icluster1_preset_is_paper_sized() {
        let c = ClusterSpec::icluster1();
        assert_eq!(c.nodes, 50);
        assert_eq!(c.build_sim().num_nodes(), 50);
    }

    #[test]
    #[should_panic]
    fn cluster_of_out_of_range_panics() {
        two_cluster_grid().cluster_of(99);
    }
}
