//! The paper's experiments, one function per figure.
//!
//! All experiments run on the simulated icluster-1 (50× Fast Ethernet,
//! Linux-2.2 TCP behaviours on — the anomalies of §4 are part of the
//! reproduction) and compare *measured* collective completion times
//! against the *model* predictions fed by pLogP parameters measured with
//! the benchmark tool, exactly the paper's methodology.

use std::sync::Arc;

use crate::collectives::Strategy;
use crate::eval::{SimEval, TraceRecorder};
use crate::models;
use crate::netsim::{NetConfig, TraceSet};
use crate::plogp::PLogP;
use crate::tuner::validate::{validate_selection, ValidateOptions};
use crate::tuner::{grids, Op};
use crate::util::table::{fmt_bytes, fmt_time, Table};

use super::{ExperimentResult, Series};

/// Measure pLogP parameters of a config (the experiments' common
/// setup). Strategy measurements go through [`SimEval`] — the harness
/// no longer carries its own measurement helpers.
pub fn measure_net(cfg: &NetConfig) -> PLogP {
    SimEval::new(cfg.clone()).measure_net()
}

/// The harness's record mode: execute every strategy of every listed
/// op at every `(P, m)` grid cell on a traced simulator and return one
/// [`crate::netsim::TraceRecord`] per cell (segmented strategies run
/// their model-tuned segment — the schedule a deployed runtime would
/// execute, and what [`crate::eval::ReplayEval`] replays as an exact
/// cell). Also returns the captured network's pLogP parameters.
pub fn record_traces(
    cfg: &NetConfig,
    ops: &[Op],
    p_grid: &[usize],
    m_grid: &[u64],
    s_grid: &[u64],
    capacity: usize,
) -> (TraceSet, PLogP) {
    let recorder = Arc::new(TraceRecorder::new(cfg, capacity));
    let net = recorder.net().clone();
    let eval = SimEval::new(cfg.clone()).with_recorder(Arc::clone(&recorder));
    for &op in ops {
        for &strategy in op.family() {
            for &p in p_grid {
                for &m in m_grid {
                    let seg = if strategy.is_segmented() {
                        Some(models::best_segment(strategy, &net, p, m, s_grid).1)
                    } else {
                        None
                    };
                    // unschedulable points score +inf and record nothing
                    let _ = eval.measure(strategy, p, m, seg);
                }
            }
        }
    }
    (recorder.take(), net)
}

/// Shared driver: measured-vs-predicted sweep over message sizes for one
/// strategy at fixed P.
fn sweep_m(
    eval: &SimEval,
    net: &PLogP,
    strategy: Strategy,
    p: usize,
    m_grid: &[u64],
    s_grid: &[u64],
) -> (Series, Series, Table) {
    let mut meas = Series::new(format!("{} measured", strategy.name()));
    let mut pred = Series::new(format!("{} predicted", strategy.name()));
    let mut tab = Table::new(vec!["P", "m", "segment", "measured", "predicted", "rel_err"]);
    for &m in m_grid {
        let (t_pred, seg) = if strategy.is_segmented() {
            let (t, s) = models::best_segment(strategy, net, p, m, s_grid);
            (t, Some(s))
        } else {
            (models::predict(strategy, net, p, m, None), None)
        };
        let t_meas = eval.measure(strategy, p, m, seg);
        meas.push(m as f64, t_meas);
        pred.push(m as f64, t_pred);
        tab.row(vec![
            p.to_string(),
            m.to_string(),
            seg.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            format!("{t_meas:.6}"),
            format!("{t_pred:.6}"),
            format!("{:.3}", (t_pred - t_meas).abs() / t_meas),
        ]);
    }
    (meas, pred, tab)
}

fn merge_tables(mut a: Table, b: &Table) -> Table {
    // tables share the header; append rows via CSV round trip
    for line in b.to_csv().lines().skip(1) {
        let cells: Vec<String> = line.split(',').map(|s| s.to_string()).collect();
        a.row(cells);
    }
    a
}

/// Fig 1(a): Binomial Broadcast, measured vs predicted, m-sweep at two
/// cluster sizes.
pub fn fig1a(cfg: &NetConfig) -> ExperimentResult {
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    let m_grid = grids::log_grid(1 << 10, 1 << 20, 11);
    let s_grid = grids::default_s_grid();
    let (m24, p24, t1) = sweep_m(&eval, &net, Strategy::BcastBinomial, 24, &m_grid, &s_grid);
    let (m48, p48, t2) = sweep_m(&eval, &net, Strategy::BcastBinomial, 48, &m_grid, &s_grid);
    let table = merge_tables(t1, &t2);
    let notes = vec![
        note_rel_err("P=24", &m24, &p24),
        note_rel_err("P=48", &m48, &p48),
        "expected small-message deviation: TCP delayed-ACK stalls (paper §4.1)".into(),
    ];
    ExperimentResult {
        id: "fig1a".into(),
        title: "Binomial Broadcast: model vs measurement".into(),
        table,
        series: vec![m24, p24, m48, p48],
        notes,
    }
}

/// Fig 1(b): Segmented Chain Broadcast, measured vs predicted.
pub fn fig1b(cfg: &NetConfig) -> ExperimentResult {
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    let m_grid = grids::log_grid(1 << 10, 1 << 20, 11);
    let s_grid = grids::default_s_grid();
    let (m24, p24, t1) = sweep_m(&eval, &net, Strategy::BcastSegChain, 24, &m_grid, &s_grid);
    let (m48, p48, t2) = sweep_m(&eval, &net, Strategy::BcastSegChain, 48, &m_grid, &s_grid);
    let table = merge_tables(t1, &t2);
    let notes = vec![
        note_rel_err("P=24", &m24, &p24),
        note_rel_err("P=48", &m48, &p48),
        "segment trains pay the ACK stall once, then stream (paper §4.1)".into(),
    ];
    ExperimentResult {
        id: "fig1b".into(),
        title: "Segmented Chain Broadcast: model vs measurement".into(),
        table,
        series: vec![m24, p24, m48, p48],
        notes,
    }
}

/// Fig 2: Chain vs Binomial Broadcast and their predictions at fixed P.
pub fn fig2(cfg: &NetConfig) -> ExperimentResult {
    let p = 24;
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    let m_grid = grids::log_grid(1 << 10, 1 << 20, 13);
    let s_grid = grids::default_s_grid();
    let (sc_m, sc_p, t1) = sweep_m(&eval, &net, Strategy::BcastSegChain, p, &m_grid, &s_grid);
    let (bi_m, bi_p, t2) = sweep_m(&eval, &net, Strategy::BcastBinomial, p, &m_grid, &s_grid);
    let table = merge_tables(t1, &t2);

    // crossover: below it binomial wins, above it the segmented chain
    let mut crossover = None;
    for (i, &m) in m_grid.iter().enumerate() {
        if sc_m.ys[i] < bi_m.ys[i] {
            crossover = Some(m);
            break;
        }
    }
    let notes = vec![
        match crossover {
            Some(m) => format!(
                "measured crossover at m ≈ {} — binomial wins below, segmented chain above",
                fmt_bytes(m as f64)
            ),
            None => "no crossover in range: one strategy dominates".into(),
        },
        format!(
            "models pick the measured winner at {}/{} points",
            m_grid
                .iter()
                .enumerate()
                .filter(|&(i, _)| {
                    (sc_p.ys[i] < bi_p.ys[i]) == (sc_m.ys[i] < bi_m.ys[i])
                })
                .count(),
            m_grid.len()
        ),
    ];
    ExperimentResult {
        id: "fig2".into(),
        title: format!("Chain vs Binomial Broadcast, P={p}"),
        table,
        series: vec![sc_m, sc_p, bi_m, bi_p],
        notes,
    }
}

/// Fig 3(a): Flat vs Binomial Scatter, m-sweep at fixed P.
pub fn fig3a(cfg: &NetConfig) -> ExperimentResult {
    let p = 32;
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    let m_grid = grids::log_grid(1 << 10, 1 << 17, 9);
    let s_grid = grids::default_s_grid();
    let (fl_m, fl_p, t1) = sweep_m(&eval, &net, Strategy::ScatterFlat, p, &m_grid, &s_grid);
    let (bi_m, bi_p, t2) = sweep_m(&eval, &net, Strategy::ScatterBinomial, p, &m_grid, &s_grid);
    let table = merge_tables(t1, &t2);
    let wins = m_grid
        .iter()
        .enumerate()
        .filter(|&(i, _)| bi_m.ys[i] < fl_m.ys[i])
        .count();
    let notes = vec![
        format!("binomial scatter wins {wins}/{} measured points at P={p}", m_grid.len()),
        note_rel_err("flat", &fl_m, &fl_p),
        note_rel_err("binomial", &bi_m, &bi_p),
    ];
    ExperimentResult {
        id: "fig3a".into(),
        title: format!("Flat vs Binomial Scatter: model vs measurement, P={p}"),
        table,
        series: vec![fl_m, fl_p, bi_m, bi_p],
        notes,
    }
}

/// Fig 3(b): Flat vs Binomial Scatter, P-sweep at fixed m.
pub fn fig3b(cfg: &NetConfig) -> ExperimentResult {
    let m = 32 * 1024;
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    let p_grid: Vec<usize> = vec![2, 4, 8, 12, 16, 24, 32, 40, 48];
    let mut fl_m = Series::new("scatter/flat measured");
    let mut fl_p = Series::new("scatter/flat predicted");
    let mut bi_m = Series::new("scatter/binomial measured");
    let mut bi_p = Series::new("scatter/binomial predicted");
    let mut table =
        Table::new(vec!["P", "m", "strategy", "measured", "predicted", "rel_err"]);
    for &p in &p_grid {
        for (strategy, ms, ps) in [
            (Strategy::ScatterFlat, &mut fl_m, &mut fl_p),
            (Strategy::ScatterBinomial, &mut bi_m, &mut bi_p),
        ] {
            let t_pred = models::predict(strategy, &net, p, m, None);
            let t_meas = eval.measure(strategy, p, m, None);
            ms.push(p as f64, t_meas);
            ps.push(p as f64, t_pred);
            table.row(vec![
                p.to_string(),
                m.to_string(),
                strategy.name().to_string(),
                format!("{t_meas:.6}"),
                format!("{t_pred:.6}"),
                format!("{:.3}", (t_pred - t_meas).abs() / t_meas),
            ]);
        }
    }
    let mut crossover = None;
    for (i, &p) in p_grid.iter().enumerate() {
        if bi_m.ys[i] < fl_m.ys[i] {
            crossover = Some(p);
            break;
        }
    }
    let notes = vec![match crossover {
        Some(p) => format!(
            "binomial scatter overtakes flat from P ≈ {p} (m = {})",
            fmt_bytes(m as f64)
        ),
        None => "flat scatter dominates the whole P range at this m".into(),
    }];
    ExperimentResult {
        id: "fig3b".into(),
        title: format!("Flat vs Binomial Scatter across P, m={}", fmt_bytes(m as f64)),
        table,
        series: vec![fl_m, fl_p, bi_m, bi_p],
        notes,
    }
}

/// Fig 4: Flat vs Binomial Scatter at fixed P with the TCP bulk effect —
/// the measured flat scatter beats its own model ("bulk transmission",
/// §4.2) while binomial follows its model.
pub fn fig4(cfg: &NetConfig) -> ExperimentResult {
    let p = 24;
    let eval = SimEval::new(cfg.clone());
    let net = eval.measure_net();
    let m_grid = grids::log_grid(1 << 10, 1 << 17, 9);
    let s_grid = grids::default_s_grid();
    let (fl_m, fl_p, t1) = sweep_m(&eval, &net, Strategy::ScatterFlat, p, &m_grid, &s_grid);
    let (bi_m, bi_p, t2) = sweep_m(&eval, &net, Strategy::ScatterBinomial, p, &m_grid, &s_grid);
    let table = merge_tables(t1, &t2);
    // quantify the bulk effect: measured/predicted ratio per strategy
    let ratio = |m: &Series, pr: &Series| {
        let r: f64 = m
            .ys
            .iter()
            .zip(&pr.ys)
            .map(|(a, b)| a / b)
            .sum::<f64>()
            / m.ys.len() as f64;
        r
    };
    let rf = ratio(&fl_m, &fl_p);
    let rb = ratio(&bi_m, &bi_p);
    let notes = vec![
        format!("flat scatter measured/model ratio = {rf:.3} (bulk effect: < 1 when the root's back-to-back sends coalesce)"),
        format!("binomial scatter measured/model ratio = {rb:.3} (individual transmissions: follows its model)"),
        "the pLogP benchmark measures individual sends, so it cannot see the flat root's streaming behaviour — paper §4.2".into(),
    ];
    ExperimentResult {
        id: "fig4".into(),
        title: format!("Flat vs Binomial Scatter with TCP bulk effect, P={p}"),
        table,
        series: vec![fl_m, fl_p, bi_m, bi_p],
        notes,
    }
}

/// The headline validation: does model-driven selection pick the
/// empirically best strategy across the whole grid?
pub fn validate(cfg: &NetConfig) -> ExperimentResult {
    let net = measure_net(cfg);
    let opts = ValidateOptions::default();
    let p_list = [4usize, 8, 16, 24, 32, 48];
    let m_list = [256u64, 4096, 65536, 1 << 18, 1 << 20];
    let mut table = Table::new(vec![
        "op", "points", "correct", "meaningful", "correct_meaningful",
        "mean_rel_err", "max_regret",
    ]);
    let mut notes = Vec::new();
    for (op, family) in [(Op::Bcast, &Strategy::BCAST[..]), (Op::Scatter, &Strategy::SCATTER[..])] {
        let rep = validate_selection(cfg, &net, family, &p_list, &m_list, &opts);
        table.row(vec![
            op.name().to_string(),
            rep.points.to_string(),
            rep.correct.to_string(),
            rep.meaningful.to_string(),
            rep.correct_meaningful.to_string(),
            format!("{:.3}", rep.mean_rel_err),
            format!("{:.3}", rep.max_regret),
        ]);
        notes.push(format!(
            "{}: {:.0}% overall, {:.0}% where it matters (>10% margin), worst regret {:.1}%",
            op.name(),
            rep.accuracy() * 100.0,
            rep.meaningful_accuracy() * 100.0,
            rep.max_regret * 100.0
        ));
    }
    ExperimentResult {
        id: "validate".into(),
        title: "Model-driven selection vs exhaustive empirical search".into(),
        table,
        series: vec![],
        notes,
    }
}

/// Tables 1 & 2 as a decision matrix: predicted time of every strategy
/// at representative (P, m) points, with the tuned segment sizes.
pub fn tables(cfg: &NetConfig) -> ExperimentResult {
    let net = measure_net(cfg);
    let s_grid = grids::default_s_grid();
    let mut table = Table::new(vec!["strategy", "P", "m", "segment", "predicted"]);
    for &p in &[8usize, 24, 48] {
        for &m in &[1024u64, 65536, 1 << 20] {
            for strat in Strategy::ALL {
                let (t, seg) = if strat.is_segmented() {
                    let (t, s) = models::best_segment(strat, &net, p, m, &s_grid);
                    (t, Some(s))
                } else {
                    (models::predict(strat, &net, p, m, None), None)
                };
                table.row(vec![
                    strat.name().to_string(),
                    p.to_string(),
                    m.to_string(),
                    seg.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
                    fmt_time(t),
                ]);
            }
        }
    }
    ExperimentResult {
        id: "tables".into(),
        title: "Tables 1 & 2 + extended ops: every model at representative points".into(),
        table,
        series: vec![],
        notes: vec![],
    }
}

fn note_rel_err(label: &str, meas: &Series, pred: &Series) -> String {
    let errs: Vec<f64> = meas
        .ys
        .iter()
        .zip(&pred.ys)
        .map(|(m, p)| (p - m).abs() / m)
        .collect();
    let mean = errs.iter().sum::<f64>() / errs.len() as f64;
    let max = errs.iter().cloned().fold(0.0, f64::max);
    format!("{label}: mean rel err {:.1}%, max {:.1}%", mean * 100.0, max * 100.0)
}

/// Run an experiment by id.
pub fn run(id: &str, cfg: &NetConfig) -> Option<ExperimentResult> {
    Some(match id {
        "fig1a" => fig1a(cfg),
        "fig1b" => fig1b(cfg),
        "fig2" => fig2(cfg),
        "fig3a" => fig3a(cfg),
        "fig3b" => fig3b(cfg),
        "fig4" => fig4(cfg),
        "validate" => validate(cfg),
        "tables" => tables(cfg),
        _ => return None,
    })
}

/// All experiment ids in paper order.
pub const ALL_IDS: [&str; 8] =
    ["tables", "fig1a", "fig1b", "fig2", "fig3a", "fig3b", "fig4", "validate"];

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NetConfig {
        NetConfig::fast_ethernet_icluster1()
    }

    #[test]
    fn fig2_models_pick_measured_winner_mostly() {
        let r = fig2(&cfg());
        // the "models pick the measured winner at N/M points" note
        let note = &r.notes[1];
        let frac: Vec<usize> = note
            .split(['/', ' '])
            .filter_map(|t| t.parse().ok())
            .collect();
        assert!(frac[0] * 10 >= frac[1] * 8, "{note}");
    }

    #[test]
    fn fig2_has_crossover_on_fast_ethernet() {
        let r = fig2(&cfg());
        assert!(
            r.notes[0].contains("crossover at"),
            "expected a chain/binomial crossover: {}",
            r.notes[0]
        );
    }

    #[test]
    fn fig4_flat_scatter_beats_its_model() {
        let r = fig4(&cfg());
        // flat ratio < binomial ratio: the bulk effect helps flat only
        let rf: f64 = r.notes[0]
            .split('=')
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let rb: f64 = r.notes[1]
            .split('=')
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(rf < rb, "flat ratio {rf} should be below binomial ratio {rb}");
        assert!(rf < 1.0, "flat scatter should outperform its model, ratio {rf}");
    }

    #[test]
    fn validate_experiment_reports_high_meaningful_accuracy() {
        let r = validate(&cfg());
        for note in &r.notes {
            let pct: f64 = note
                .split("% where it matters")
                .next()
                .unwrap()
                .rsplit(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap();
            assert!(pct >= 90.0, "{note}");
        }
    }

    #[test]
    fn record_mode_captures_every_schedulable_cell() {
        let (set, net) = record_traces(
            &NetConfig::fast_ethernet_ideal(),
            &[Op::Bcast, Op::AllReduce],
            &[2, 4],
            &[64, 4096],
            &[1024, 8192],
            1 << 14,
        );
        // every (strategy, p, m) cell of both families is schedulable
        // at these scales, so every cell has exactly one record
        let cells = (Strategy::BCAST.len() + Strategy::ALLREDUCE.len()) * 2 * 2;
        assert_eq!(set.len(), cells);
        assert_eq!(set.ops(), ["allreduce", "bcast"]);
        assert_eq!(set.p_values(), [2, 4]);
        assert_eq!(set.m_values(), [64, 4096]);
        for r in set.records() {
            assert_eq!(r.meta.plogp_l, net.l);
            assert!(r.critical_path().as_secs() > 0.0);
        }
    }

    #[test]
    fn all_ids_dispatch() {
        // fig1a etc. are exercised above; here just check dispatch works
        for id in ["tables"] {
            assert!(run(id, &cfg()).is_some());
        }
        assert!(run("nope", &cfg()).is_none());
    }
}
