//! Experiment harness: regenerates every figure of the paper's §4.
//!
//! Each experiment returns an [`ExperimentResult`] containing the same
//! series the paper plots (measured vs model-predicted completion times),
//! as a CSV-able table plus ASCII plots for the terminal. The experiment
//! ids match DESIGN.md's per-experiment index.

pub mod experiments;

use crate::util::table::Table;

/// One plotted series (a line in a paper figure).
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Series {
        Series { label: label.into(), xs: Vec::new(), ys: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.xs.push(x);
        self.ys.push(y);
    }
}

/// The output of one experiment.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// Paper-anchored id ("fig1a", "fig2", "validate", ...).
    pub id: String,
    pub title: String,
    /// The data in tabular form (one row per grid point).
    pub table: Table,
    /// The paper-figure series.
    pub series: Vec<Series>,
    /// Free-form findings (who wins, crossovers, anomalies).
    pub notes: Vec<String>,
}

impl ExperimentResult {
    /// Render the full terminal report.
    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n\n", self.id, self.title);
        out.push_str(&self.table.to_ascii());
        if !self.series.is_empty() {
            let xs = &self.series[0].xs;
            let plot_series: Vec<(&str, Vec<f64>)> = self
                .series
                .iter()
                .map(|s| (s.label.as_str(), s.ys.clone()))
                .collect();
            out.push('\n');
            out.push_str(&crate::util::table::ascii_plot(
                &self.title,
                xs,
                &plot_series,
                16,
            ));
        }
        if !self.notes.is_empty() {
            out.push_str("\nFindings:\n");
            for n in &self.notes {
                out.push_str(&format!("  * {n}\n"));
            }
        }
        out
    }

    /// Write the CSV next to a given directory, named `<id>.csv`.
    pub fn write_csv(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        std::fs::write(&path, self.table.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_accumulates() {
        let mut s = Series::new("x");
        s.push(1.0, 2.0);
        s.push(2.0, 3.0);
        assert_eq!(s.xs, vec![1.0, 2.0]);
        assert_eq!(s.ys, vec![2.0, 3.0]);
    }

    #[test]
    fn render_contains_everything() {
        let mut t = Table::new(vec!["m", "t"]);
        t.row(vec!["1", "2"]);
        let mut s = Series::new("measured");
        s.push(1.0, 2.0);
        let r = ExperimentResult {
            id: "figX".into(),
            title: "demo".into(),
            table: t,
            series: vec![s],
            notes: vec!["note one".into()],
        };
        let txt = r.render();
        assert!(txt.contains("figX"));
        assert!(txt.contains("note one"));
        assert!(txt.contains("measured"));
    }

    #[test]
    fn csv_written() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["1"]);
        let r = ExperimentResult {
            id: "t".into(),
            title: "t".into(),
            table: t,
            series: vec![],
            notes: vec![],
        };
        let dir = std::env::temp_dir().join("ct-harness-test");
        let p = r.write_csv(&dir).unwrap();
        assert!(p.exists());
        std::fs::remove_file(p).ok();
    }
}
