//! [`SimEval`] — the empirical backend: build the schedule and execute
//! it on a fresh simulated cluster. This is the exhaustive benchmarking
//! the paper's fast tuning replaces; the validation layer keeps it as
//! ground truth, and it is the reference side of every
//! `cross_validate` run.
//!
//! The sweep context ([`super::CellCtx`]) is deliberately *not* used
//! here: this backend measures schedules rather than evaluating cost
//! models, so the m-aware model bounds cannot soundly prune it, the gap
//! cache has nothing to feed it, and its runs never count as model
//! invocations in [`super::EvalStats`] — `best_in` falls through to the
//! default exhaustive [`super::Evaluator::best`].

use std::sync::{Arc, Mutex};

use crate::collectives::Strategy;
use crate::models;
use crate::mpi::World;
use crate::netsim::{FaultPlan, NetConfig, Netsim, TraceMeta, TraceRecord, TraceSet};
use crate::plogp::{self, PLogP};
use crate::tuner::decision::Op;

use super::Evaluator;

/// Capture sink for [`SimEval`]'s record mode: every measured run's
/// message trace is drained into a shared [`TraceSet`], keyed by the
/// `(op, strategy, p, m, segment)` point it executed and stamped with
/// the pLogP signature of the captured network (measured once, at
/// construction, on a two-node probe of the same configuration). The
/// interior mutex keeps the recorder shareable across the tuner's sweep
/// workers — contention is irrelevant next to the simulation itself.
#[derive(Debug)]
pub struct TraceRecorder {
    net: PLogP,
    capacity: usize,
    set: Mutex<TraceSet>,
}

/// Default per-run ring capacity: enough for every non-degenerate
/// schedule at paper scale; heavily-segmented giants drop their oldest
/// events (counted in the record's metadata, harmless to replay — the
/// critical path lives in the newest events).
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

impl TraceRecorder {
    /// Probe `cfg`'s pLogP parameters and build an empty recorder whose
    /// per-run ring buffers hold `capacity` events.
    pub fn new(cfg: &NetConfig, capacity: usize) -> TraceRecorder {
        assert!(capacity > 0);
        let mut sim = Netsim::new(2, cfg.clone());
        let net = plogp::bench::measure(&mut sim);
        TraceRecorder { net, capacity, set: Mutex::new(TraceSet::new()) }
    }

    /// The captured network's pLogP parameters (stamped on every record).
    pub fn net(&self) -> &PLogP {
        &self.net
    }

    /// Records captured so far.
    pub fn len(&self) -> usize {
        self.set.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.lock().unwrap().is_empty()
    }

    /// Drain the captured set (the recorder keeps recording afterwards).
    pub fn take(&self) -> TraceSet {
        std::mem::take(&mut *self.set.lock().unwrap())
    }

    fn store(&self, rec: TraceRecord) {
        self.set.lock().unwrap().insert(rec);
    }
}

/// Scores strategies by actually running them on a simulated cluster of
/// the given configuration. Construction is cheap (the simulator is
/// built per measurement, so `&self` stays shareable across the tuner's
/// worker threads). With [`SimEval::with_recorder`] attached, every
/// measured run additionally drains its message trace into the shared
/// [`TraceRecorder`] — the capture side of the trace-replay pipeline.
#[derive(Debug, Clone)]
pub struct SimEval {
    cfg: NetConfig,
    recorder: Option<Arc<TraceRecorder>>,
    faults: Option<FaultPlan>,
}

impl SimEval {
    pub fn new(cfg: NetConfig) -> SimEval {
        SimEval { cfg, recorder: None, faults: None }
    }

    /// Record mode: attach a trace to every measured run and file the
    /// result in `recorder`.
    pub fn with_recorder(mut self, recorder: Arc<TraceRecorder>) -> SimEval {
        self.recorder = Some(recorder);
        self
    }

    /// Degraded mode: apply `plan` to every measured run's simulator
    /// (an empty plan is normalized away). Captured records carry the
    /// plan in their metadata, so faulted traces replay byte-stably.
    /// The pLogP probe ([`SimEval::measure_net`] and the recorder's
    /// stamp) intentionally stays *healthy*: faults are deviations from
    /// the network the models were calibrated on.
    pub fn with_faults(mut self, plan: FaultPlan) -> SimEval {
        self.faults = if plan.is_empty() { None } else { Some(plan) };
        self
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    /// The fault plan applied to measured runs, if any.
    pub fn faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Measure the cluster's pLogP parameters on a fresh two-node probe
    /// simulator (the experiments' common setup).
    pub fn measure_net(&self) -> PLogP {
        let mut sim = Netsim::new(2, self.cfg.clone());
        plogp::bench::measure(&mut sim)
    }

    /// Run one strategy empirically at `(p, m)` on a fresh cluster and
    /// return its completion time in (simulated) seconds. A strategy
    /// that cannot be scheduled at this scale (the extended reduction
    /// trees beyond [`crate::mpi::Payload::MAX_MASK_RANKS`] ranks)
    /// scores `+inf`, so the argmin never selects it.
    pub fn measure(&self, strategy: Strategy, p: usize, m: u64, seg: Option<u64>) -> f64 {
        let sched = match strategy.try_build(p, 0, m, seg) {
            Ok(s) => s,
            Err(e) => {
                log::warn!(
                    "{}: cannot schedule at p={p} ({e:#}); scoring as +inf",
                    strategy.name()
                );
                return f64::INFINITY;
            }
        };
        let mut sim = Netsim::new(p, self.cfg.clone());
        if let Some(plan) = &self.faults {
            sim.apply_faults(plan);
        }
        if let Some(rec) = &self.recorder {
            sim.enable_trace(rec.capacity);
        }
        let mut world = World::new(sim);
        let rep = world.run(&sched);
        let blackholed = world.sim().stats().blackholed;
        if blackholed == 0 {
            debug_assert!(rep.verify(&sched).is_empty(), "{:?}", rep.verify(&sched));
        }
        if let Some(rec) = &self.recorder {
            let trace = world.sim().trace().expect("trace was enabled above");
            rec.store(TraceRecord {
                meta: TraceMeta {
                    op: Op::of(strategy).name().to_string(),
                    strategy: strategy.name().to_string(),
                    p,
                    m,
                    segment: seg,
                    completion_ns: rep.completion.0,
                    dropped: trace.dropped(),
                    plogp_l: rec.net.l,
                    plogp_sizes: rec.net.table.sizes().to_vec(),
                    plogp_gaps: rec.net.table.gaps().to_vec(),
                    fault_plan: self.faults.clone(),
                },
                events: trace.events(),
            });
        }
        if blackholed > 0 {
            // A dead participant starves the collective: it never
            // semantically completes, so it can never win an argmin.
            return f64::INFINITY;
        }
        rep.completion.as_secs()
    }
}

impl Evaluator for SimEval {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn predict(
        &self,
        _op: Op,
        strategy: Strategy,
        p: usize,
        m: u64,
        seg: Option<u64>,
        _net: &PLogP,
    ) -> f64 {
        self.measure(strategy, p, m, seg)
    }

    /// Segments are tuned *analytically*, then that one schedule is
    /// measured — a deployed runtime executes the model-tuned segment,
    /// and measuring every candidate segment empirically would be
    /// exactly the exhaustive sweep the paper replaces.
    fn tune_segment(
        &self,
        strategy: Strategy,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> (f64, u64) {
        let (_, seg) = models::best_segment(strategy, net, p, m, s_grid);
        (self.measure(strategy, p, m, Some(seg)), seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_is_deterministic_and_positive() {
        let e = SimEval::new(NetConfig::fast_ethernet_ideal());
        let a = e.measure(Strategy::BcastBinomial, 8, 4096, None);
        let b = e.measure(Strategy::BcastBinomial, 8, 4096, None);
        assert!(a > 0.0 && a.is_finite());
        assert_eq!(a, b, "fresh simulators must reproduce bit-identical runs");
    }

    #[test]
    fn rank_uses_model_tuned_segments() {
        let e = SimEval::new(NetConfig::fast_ethernet_ideal());
        let net = e.measure_net();
        let s_grid = [1024u64, 8192, 65536];
        let ranked = e.rank(&Strategy::BCAST, &net, 8, 1 << 18, &s_grid);
        assert_eq!(ranked.len(), 10);
        for (s, t, seg) in &ranked {
            assert!(*t > 0.0);
            if s.is_segmented() {
                let want = models::best_segment(*s, &net, 8, 1 << 18, &s_grid).1;
                assert_eq!(*seg, Some(want), "{}", s.name());
            }
        }
    }

    #[test]
    fn ext_strategies_measure_and_score() {
        let e = SimEval::new(NetConfig::fast_ethernet_ideal());
        for s in Strategy::EXT {
            let t = e.measure(s, 8, 4096, None);
            assert!(t > 0.0 && t.is_finite(), "{}: {t}", s.name());
        }
        // beyond the contributor-mask capacity the reduction trees score
        // +inf instead of panicking, so the argmin skips them
        let over = crate::mpi::Payload::MAX_MASK_RANKS + 1;
        assert!(e.measure(Strategy::AllReduceRecDoubling, over, 64, None).is_infinite());
    }

    #[test]
    fn recorder_captures_one_record_per_measured_cell() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let rec = Arc::new(TraceRecorder::new(&cfg, 1 << 12));
        let e = SimEval::new(cfg).with_recorder(Arc::clone(&rec));
        let t = e.measure(Strategy::BcastBinomial, 8, 4096, None);
        assert_eq!(rec.len(), 1);
        let set = rec.take();
        let r = set.at_cell("bcast", "bcast/binomial", 8, 4096).unwrap();
        assert_eq!(r.meta.dropped, 0);
        assert!(!r.events.is_empty());
        // the recorded critical path IS the measurement
        assert_eq!(r.critical_path().as_secs(), t);
        assert_eq!(r.meta.completion_ns, r.critical_path().0);
        // the pLogP stamp matches the probe
        assert_eq!(r.meta.plogp_l, rec.net().l);
        // unschedulable points run nothing and record nothing
        let over = crate::mpi::Payload::MAX_MASK_RANKS + 1;
        assert!(e.measure(Strategy::AllReduceRecDoubling, over, 64, None).is_infinite());
        assert!(rec.is_empty(), "take() drained and the bad point added nothing");
    }

    #[test]
    fn recorder_survives_ring_wraparound() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let rec = Arc::new(TraceRecorder::new(&cfg, 2));
        let e = SimEval::new(cfg).with_recorder(Arc::clone(&rec));
        e.measure(Strategy::BcastBinomial, 16, 4096, None);
        let set = rec.take();
        let r = set.at_cell("bcast", "bcast/binomial", 16, 4096).unwrap();
        assert!(r.meta.dropped > 0, "16 ranks cannot fit a 2-event ring");
        assert_eq!(r.events.len(), 2);
        // drops lose the oldest events, so the critical path survives
        assert_eq!(r.critical_path().0, r.meta.completion_ns);
    }

    #[test]
    fn faults_slow_the_measurement_and_stamp_the_record() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let plan = FaultPlan::new().slow_node(0, 8.0);
        let rec = Arc::new(TraceRecorder::new(&cfg, 1 << 12));
        let healthy = SimEval::new(cfg.clone());
        let faulted = SimEval::new(cfg)
            .with_faults(plan.clone())
            .with_recorder(Arc::clone(&rec));
        let th = healthy.measure(Strategy::BcastBinomial, 8, 4096, None);
        let tf = faulted.measure(Strategy::BcastBinomial, 8, 4096, None);
        assert!(tf > th, "a slow root must slow the broadcast: {tf} vs {th}");
        // the captured record carries the plan and round-trips bytes
        let set = rec.take();
        let r = set.at_cell("bcast", "bcast/binomial", 8, 4096).unwrap();
        assert_eq!(r.meta.fault_plan.as_ref(), Some(&plan));
        let text = r.to_tsv();
        let back = crate::netsim::TraceRecord::from_tsv(&text).unwrap();
        assert_eq!(&back, r);
        assert_eq!(back.to_tsv(), text);
        // and the measurement is still deterministic
        assert_eq!(tf, faulted.measure(Strategy::BcastBinomial, 8, 4096, None));
    }

    #[test]
    fn dead_node_scores_infinite() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let e = SimEval::new(cfg).with_faults(FaultPlan::new().dead_node(3));
        let t = e.measure(Strategy::BcastBinomial, 8, 4096, None);
        assert!(t.is_infinite(), "a dead participant must never win: {t}");
        // empty plans are normalized away
        let none = SimEval::new(NetConfig::fast_ethernet_ideal())
            .with_faults(FaultPlan::new());
        assert!(none.faults().is_none());
    }

    #[test]
    fn faster_network_measures_faster() {
        let fe = SimEval::new(NetConfig::fast_ethernet_ideal());
        let ge = SimEval::new(NetConfig::gigabit_ethernet());
        let m = 1 << 18;
        assert!(
            ge.measure(Strategy::BcastBinomial, 16, m, None)
                < fe.measure(Strategy::BcastBinomial, 16, m, None)
        );
    }
}
