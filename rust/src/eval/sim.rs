//! [`SimEval`] — the empirical backend: build the schedule and execute
//! it on a fresh simulated cluster. This is the exhaustive benchmarking
//! the paper's fast tuning replaces; the validation layer keeps it as
//! ground truth, and it is the reference side of every
//! `cross_validate` run.
//!
//! The sweep context ([`super::CellCtx`]) is deliberately *not* used
//! here: this backend measures schedules rather than evaluating cost
//! models, so the m-aware model bounds cannot soundly prune it, the gap
//! cache has nothing to feed it, and its runs never count as model
//! invocations in [`super::EvalStats`] — `best_in` falls through to the
//! default exhaustive [`super::Evaluator::best`].

use crate::collectives::Strategy;
use crate::models;
use crate::mpi::World;
use crate::netsim::{NetConfig, Netsim};
use crate::plogp::{self, PLogP};
use crate::tuner::decision::Op;

use super::Evaluator;

/// Scores strategies by actually running them on a simulated cluster of
/// the given configuration. Construction is cheap (the simulator is
/// built per measurement, so `&self` stays shareable across the tuner's
/// worker threads).
#[derive(Debug, Clone)]
pub struct SimEval {
    cfg: NetConfig,
}

impl SimEval {
    pub fn new(cfg: NetConfig) -> SimEval {
        SimEval { cfg }
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    /// Measure the cluster's pLogP parameters on a fresh two-node probe
    /// simulator (the experiments' common setup).
    pub fn measure_net(&self) -> PLogP {
        let mut sim = Netsim::new(2, self.cfg.clone());
        plogp::bench::measure(&mut sim)
    }

    /// Run one strategy empirically at `(p, m)` on a fresh cluster and
    /// return its completion time in (simulated) seconds. A strategy
    /// that cannot be scheduled at this scale (the extended reduction
    /// trees beyond [`crate::mpi::Payload::MAX_MASK_RANKS`] ranks)
    /// scores `+inf`, so the argmin never selects it.
    pub fn measure(&self, strategy: Strategy, p: usize, m: u64, seg: Option<u64>) -> f64 {
        let sched = match strategy.try_build(p, 0, m, seg) {
            Ok(s) => s,
            Err(e) => {
                log::warn!(
                    "{}: cannot schedule at p={p} ({e:#}); scoring as +inf",
                    strategy.name()
                );
                return f64::INFINITY;
            }
        };
        let mut world = World::new(Netsim::new(p, self.cfg.clone()));
        let rep = world.run(&sched);
        debug_assert!(rep.verify(&sched).is_empty(), "{:?}", rep.verify(&sched));
        rep.completion.as_secs()
    }
}

impl Evaluator for SimEval {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn predict(
        &self,
        _op: Op,
        strategy: Strategy,
        p: usize,
        m: u64,
        seg: Option<u64>,
        _net: &PLogP,
    ) -> f64 {
        self.measure(strategy, p, m, seg)
    }

    /// Segments are tuned *analytically*, then that one schedule is
    /// measured — a deployed runtime executes the model-tuned segment,
    /// and measuring every candidate segment empirically would be
    /// exactly the exhaustive sweep the paper replaces.
    fn tune_segment(
        &self,
        strategy: Strategy,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> (f64, u64) {
        let (_, seg) = models::best_segment(strategy, net, p, m, s_grid);
        (self.measure(strategy, p, m, Some(seg)), seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_is_deterministic_and_positive() {
        let e = SimEval::new(NetConfig::fast_ethernet_ideal());
        let a = e.measure(Strategy::BcastBinomial, 8, 4096, None);
        let b = e.measure(Strategy::BcastBinomial, 8, 4096, None);
        assert!(a > 0.0 && a.is_finite());
        assert_eq!(a, b, "fresh simulators must reproduce bit-identical runs");
    }

    #[test]
    fn rank_uses_model_tuned_segments() {
        let e = SimEval::new(NetConfig::fast_ethernet_ideal());
        let net = e.measure_net();
        let s_grid = [1024u64, 8192, 65536];
        let ranked = e.rank(&Strategy::BCAST, &net, 8, 1 << 18, &s_grid);
        assert_eq!(ranked.len(), 10);
        for (s, t, seg) in &ranked {
            assert!(*t > 0.0);
            if s.is_segmented() {
                let want = models::best_segment(*s, &net, 8, 1 << 18, &s_grid).1;
                assert_eq!(*seg, Some(want), "{}", s.name());
            }
        }
    }

    #[test]
    fn ext_strategies_measure_and_score() {
        let e = SimEval::new(NetConfig::fast_ethernet_ideal());
        for s in Strategy::EXT {
            let t = e.measure(s, 8, 4096, None);
            assert!(t > 0.0 && t.is_finite(), "{}: {t}", s.name());
        }
        // beyond the contributor-mask capacity the reduction trees score
        // +inf instead of panicking, so the argmin skips them
        let over = crate::mpi::Payload::MAX_MASK_RANKS + 1;
        assert!(e.measure(Strategy::AllReduceRecDoubling, over, 64, None).is_infinite());
    }

    #[test]
    fn faster_network_measures_faster() {
        let fe = SimEval::new(NetConfig::fast_ethernet_ideal());
        let ge = SimEval::new(NetConfig::gigabit_ethernet());
        let m = 1 << 18;
        assert!(
            ge.measure(Strategy::BcastBinomial, 16, m, None)
                < fe.measure(Strategy::BcastBinomial, 16, m, None)
        );
    }
}
