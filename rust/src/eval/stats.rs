//! [`EvalStats`] — cheap shared counters for the tuning sweep, so the
//! prune/warm-start/cache pipeline's effectiveness is asserted on
//! deterministic numbers instead of flaky wall time.
//!
//! The counters are relaxed atomics: the engine's worker threads share
//! one [`EvalStats`] through [`super::CellCtx`], each cell accumulates
//! its deltas locally and flushes once, and a [`EvalCounts`] snapshot
//! is read by `tune --stats`, `query --stats`, the benches
//! (`BENCH_tuner.json`), and the eval-count regression tests in
//! `rust/tests/evaluator.rs`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::collectives::Strategy;
use crate::util::json::Json;

/// Shared sweep counters (see the module docs). Construction is free;
/// every method takes `&self`.
#[derive(Debug, Default)]
pub struct EvalStats {
    cells: AtomicU64,
    model_invocations: AtomicU64,
    bound_evals: AtomicU64,
    strategies_pruned: AtomicU64,
    seg_searches_pruned: AtomicU64,
    seg_points_skipped: AtomicU64,
    warm_hits: AtomicU64,
    warm_misses: AtomicU64,
}

/// One point-in-time reading of [`EvalStats`] (plain integers), plus
/// derived rates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EvalCounts {
    /// Grid cells evaluated.
    pub cells: u64,
    /// Full cost-model evaluations (the paper's unit of sweep cost).
    pub model_invocations: u64,
    /// O(1) lower-bound evaluations ([`crate::models::LOWER_BOUNDS`]).
    pub bound_evals: u64,
    /// Unsegmented strategies skipped because their bound lost.
    pub strategies_pruned: u64,
    /// Whole segment-grid searches skipped because their bound lost.
    pub seg_searches_pruned: u64,
    /// Individual segment candidates skipped inside surviving searches
    /// (clamp duplicates and per-candidate bound losers), plus the
    /// candidates of pruned searches.
    pub seg_points_skipped: u64,
    /// Cells whose warm-start hint was the final winner.
    pub warm_hits: u64,
    /// Cells with a hint that did not win.
    pub warm_misses: u64,
}

impl EvalStats {
    pub fn new() -> EvalStats {
        EvalStats::default()
    }

    /// Fold one cell's locally-accumulated deltas in.
    pub fn add(&self, d: &EvalCounts) {
        self.cells.fetch_add(d.cells, Ordering::Relaxed);
        self.model_invocations.fetch_add(d.model_invocations, Ordering::Relaxed);
        self.bound_evals.fetch_add(d.bound_evals, Ordering::Relaxed);
        self.strategies_pruned.fetch_add(d.strategies_pruned, Ordering::Relaxed);
        self.seg_searches_pruned.fetch_add(d.seg_searches_pruned, Ordering::Relaxed);
        self.seg_points_skipped.fetch_add(d.seg_points_skipped, Ordering::Relaxed);
        self.warm_hits.fetch_add(d.warm_hits, Ordering::Relaxed);
        self.warm_misses.fetch_add(d.warm_misses, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> EvalCounts {
        EvalCounts {
            cells: self.cells.load(Ordering::Relaxed),
            model_invocations: self.model_invocations.load(Ordering::Relaxed),
            bound_evals: self.bound_evals.load(Ordering::Relaxed),
            strategies_pruned: self.strategies_pruned.load(Ordering::Relaxed),
            seg_searches_pruned: self.seg_searches_pruned.load(Ordering::Relaxed),
            seg_points_skipped: self.seg_points_skipped.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            warm_misses: self.warm_misses.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.cells.store(0, Ordering::Relaxed);
        self.model_invocations.store(0, Ordering::Relaxed);
        self.bound_evals.store(0, Ordering::Relaxed);
        self.strategies_pruned.store(0, Ordering::Relaxed);
        self.seg_searches_pruned.store(0, Ordering::Relaxed);
        self.seg_points_skipped.store(0, Ordering::Relaxed);
        self.warm_hits.store(0, Ordering::Relaxed);
        self.warm_misses.store(0, Ordering::Relaxed);
    }
}

impl EvalCounts {
    /// Mean full model evaluations per grid cell.
    pub fn invocations_per_cell(&self) -> f64 {
        if self.cells == 0 {
            0.0
        } else {
            self.model_invocations as f64 / self.cells as f64
        }
    }

    /// Fraction of hinted cells whose hint won.
    pub fn warm_hit_rate(&self) -> f64 {
        let hinted = self.warm_hits + self.warm_misses;
        if hinted == 0 {
            0.0
        } else {
            self.warm_hits as f64 / hinted as f64
        }
    }

    /// How many times fewer model invocations than `exhaustive`
    /// (the unpruned baseline) this run used.
    pub fn reduction_vs(&self, exhaustive: u64) -> f64 {
        exhaustive as f64 / self.model_invocations.max(1) as f64
    }

    /// Flat JSON object (counters plus derived rates) as a [`Json`]
    /// value, so callers can embed it in larger documents without
    /// string splicing. Rates keep the original rounding (2 and 4
    /// decimal places).
    pub fn to_json_value(&self) -> Json {
        let round = |x: f64, scale: f64| (x * scale).round() / scale;
        Json::obj(vec![
            ("cells", Json::from(self.cells)),
            ("model_invocations", Json::from(self.model_invocations)),
            ("invocations_per_cell", Json::from(round(self.invocations_per_cell(), 100.0))),
            ("bound_evals", Json::from(self.bound_evals)),
            ("strategies_pruned", Json::from(self.strategies_pruned)),
            ("seg_searches_pruned", Json::from(self.seg_searches_pruned)),
            ("seg_points_skipped", Json::from(self.seg_points_skipped)),
            ("warm_hits", Json::from(self.warm_hits)),
            ("warm_misses", Json::from(self.warm_misses)),
            ("warm_hit_rate", Json::from(round(self.warm_hit_rate(), 10_000.0))),
        ])
    }

    /// [`EvalCounts::to_json_value`] rendered through the shared
    /// `util::json` writer, for `--stats` output and the bench JSONs.
    pub fn to_json(&self) -> String {
        self.to_json_value().to_string()
    }
}

/// Model invocations one *unpruned* cell costs: every segmented
/// strategy scans the full segment grid plus the `s = m` seed, every
/// unsegmented strategy is a single evaluation. This is the baseline
/// the measured counters are compared against (the pre-pruning sweep
/// evaluated exactly this many models per cell).
pub fn exhaustive_invocations_per_cell(family: &[Strategy], s_grid_len: usize) -> u64 {
    family
        .iter()
        .map(|s| if s.is_segmented() { s_grid_len as u64 + 1 } else { 1 })
        .sum()
}

/// The unpruned baseline for a whole sweep: the per-cell exhaustive
/// count summed over every tuned family, times the grid cells per
/// family. One definition shared by `tune --stats`, the tuner bench,
/// and the ≥5× reduction test, so the baseline cannot silently diverge
/// between them.
pub fn exhaustive_invocations(families: &[&[Strategy]], cells: u64, s_grid_len: usize) -> u64 {
    families
        .iter()
        .map(|f| cells * exhaustive_invocations_per_cell(f, s_grid_len))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_snapshot_reset_roundtrip() {
        let s = EvalStats::new();
        let d = EvalCounts {
            cells: 2,
            model_invocations: 10,
            bound_evals: 20,
            strategies_pruned: 3,
            seg_searches_pruned: 4,
            seg_points_skipped: 50,
            warm_hits: 1,
            warm_misses: 1,
        };
        s.add(&d);
        s.add(&d);
        let got = s.snapshot();
        assert_eq!(got.cells, 4);
        assert_eq!(got.model_invocations, 20);
        assert_eq!(got.seg_points_skipped, 100);
        assert_eq!(got.warm_hit_rate(), 0.5);
        assert_eq!(got.invocations_per_cell(), 5.0);
        assert_eq!(got.reduction_vs(200), 10.0);
        s.reset();
        assert_eq!(s.snapshot(), EvalCounts::default());
    }

    #[test]
    fn exhaustive_baseline_counts_segment_grids() {
        // bcast: 7 unsegmented + 3 segmented * (32 + 1)
        assert_eq!(exhaustive_invocations_per_cell(&Strategy::BCAST, 32), 106);
        assert_eq!(exhaustive_invocations_per_cell(&Strategy::SCATTER, 32), 3);
        assert_eq!(exhaustive_invocations_per_cell(&Strategy::BARRIER, 32), 2);
        // the default bcast+scatter tune on the default 16x48 grid —
        // the number committed in BENCH_tuner.json's metric baseline
        let families = [&Strategy::BCAST[..], &Strategy::SCATTER[..]];
        assert_eq!(exhaustive_invocations(&families, 768, 32), 83_712);
    }

    #[test]
    fn empty_counts_have_safe_rates() {
        let c = EvalCounts::default();
        assert_eq!(c.invocations_per_cell(), 0.0);
        assert_eq!(c.warm_hit_rate(), 0.0);
        assert!(c.to_json().contains("\"cells\":0"));
    }
}
