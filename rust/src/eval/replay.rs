//! [`ReplayEval`] — the trace-replay backend: score strategies from a
//! recorded [`TraceSet`] instead of a live simulator.
//!
//! This is the repeatable-regression half of the paper's methodology:
//! capture one empirical sweep (`SimEval`'s record mode, the `record`
//! CLI subcommand, or — eventually — a real-MPI run emitting the same
//! format), commit the traces, and every later tuning or validation run
//! replays the *fixed* workload deterministically. Scoring works at
//! three levels of fidelity:
//!
//! * **exact** — the queried `(op, strategy, P, m, segment)` point was
//!   captured: the score is the record's reconstructed critical path
//!   (the last recorded delivery — equal to the executor's reported
//!   completion, and robust to ring-buffer drops, which only lose the
//!   oldest events). A segment-less query against a captured cell
//!   resolves to the cell's tuned-segment run, exactly the schedule a
//!   deployed runtime would execute.
//! * **interpolated** — `m` falls between two captured sizes of the
//!   same `(op, strategy, P)` column: the score is interpolated between
//!   the bracketing records *in gap-model coordinates* — linear in the
//!   captured network's `g(m)` rather than in raw `m`, because
//!   per-message cost grows with the pLogP gap, not linearly in bytes —
//!   clamped to the bracketing scores (degenerate gap spans fall back
//!   to log-`m` interpolation).
//! * **miss** — the strategy/P was never captured, or `m` lies outside
//!   the captured range: the score is `+inf` (the argmin can never
//!   select an unobserved strategy) and the miss is counted in
//!   [`ReplayStats`], the replay analogue of the sweep's
//!   [`super::EvalStats`] counters.
//!
//! Like every backend, `ReplayEval` is a plain [`Evaluator`]: the
//! tuner's sweep, `cross_validate`, and the coordinator consume it with
//! zero signature changes (asserted in `rust/tests/replay_golden.rs`).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::collectives::Strategy;
use crate::models;
use crate::netsim::{FaultPlan, TraceKey, TraceSet};
use crate::plogp::{GapTable, PLogP};
use crate::tuner::decision::Op;

use super::Evaluator;

/// Relaxed-atomic replay counters (shared by clones of one
/// [`ReplayEval`], mirroring the [`super::EvalStats`] idiom).
#[derive(Debug, Default)]
struct Counters {
    exact: AtomicU64,
    interpolated: AtomicU64,
    misses: AtomicU64,
}

/// One point-in-time reading of a replay's coverage counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplayStats {
    /// Records in the backing trace set.
    pub records: u64,
    /// Events across those records.
    pub events: u64,
    /// Queries answered from a captured cell.
    pub exact_hits: u64,
    /// Queries answered by gap-model interpolation between captured m's.
    pub interp_hits: u64,
    /// Queries outside the captured workload (scored `+inf`).
    pub misses: u64,
}

impl ReplayStats {
    /// Fraction of queries answered from the capture (exact or
    /// interpolated).
    pub fn hit_rate(&self) -> f64 {
        let total = self.exact_hits + self.interp_hits + self.misses;
        if total == 0 {
            0.0
        } else {
            (self.exact_hits + self.interp_hits) as f64 / total as f64
        }
    }

    /// Flat JSON object for `replay`/`validate` CLI output, rendered
    /// through the shared `util::json` writer (hit_rate keeps the
    /// original 4-decimal rounding).
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        Json::obj(vec![
            ("records", Json::from(self.records)),
            ("events", Json::from(self.events)),
            ("exact_hits", Json::from(self.exact_hits)),
            ("interp_hits", Json::from(self.interp_hits)),
            ("misses", Json::from(self.misses)),
            ("hit_rate", Json::from((self.hit_rate() * 10_000.0).round() / 10_000.0)),
        ])
        .to_string()
    }
}

/// The trace-replay evaluator. Cheap to clone (the set and counters are
/// shared), so a caller can keep a handle for [`ReplayEval::stats`]
/// after boxing a clone into a [`crate::tuner::Tuner`].
#[derive(Debug, Clone)]
pub struct ReplayEval {
    set: Arc<TraceSet>,
    net: PLogP,
    faults: Option<FaultPlan>,
    counters: Arc<Counters>,
}

impl ReplayEval {
    /// Build over a captured set. Fails on an empty set and on a set
    /// whose records disagree about the network they were captured on
    /// (mixed-network merges have no single replay signature) — the
    /// fault plan is part of that identity: a faulted capture replays
    /// only against records of the *same* degraded environment.
    pub fn new(set: TraceSet) -> Result<ReplayEval> {
        let first = match set.records().next() {
            Some(r) => r.meta.clone(),
            None => bail!("empty trace set: nothing to replay"),
        };
        for r in set.records() {
            if r.meta.plogp_l != first.plogp_l
                || r.meta.plogp_sizes != first.plogp_sizes
                || r.meta.plogp_gaps != first.plogp_gaps
            {
                bail!(
                    "trace set mixes networks: '{}' and '{}' carry different pLogP \
                     signatures",
                    first.key().file_name(),
                    r.meta.key().file_name()
                );
            }
            if r.meta.fault_plan != first.fault_plan {
                bail!(
                    "trace set mixes environments: '{}' and '{}' were captured under \
                     different fault plans",
                    first.key().file_name(),
                    r.meta.key().file_name()
                );
            }
        }
        let net = PLogP::new(
            first.plogp_l,
            GapTable::new(first.plogp_sizes.clone(), first.plogp_gaps.clone()),
        );
        Ok(ReplayEval {
            set: Arc::new(set),
            net,
            faults: first.fault_plan,
            counters: Arc::new(Counters::default()),
        })
    }

    /// Load every trace under `dir` and build the evaluator.
    pub fn load(dir: &Path) -> Result<ReplayEval> {
        ReplayEval::new(
            TraceSet::load_dir(dir)
                .with_context(|| format!("loading trace directory {}", dir.display()))?,
        )
    }

    /// The backing trace set.
    pub fn set(&self) -> &TraceSet {
        &self.set
    }

    /// The pLogP parameters the traces were captured under (drives the
    /// gap-model interpolation and stands in for a fresh measurement).
    pub fn net(&self) -> &PLogP {
        &self.net
    }

    /// The fault plan every record in the set was captured under, if
    /// any (the set is environment-homogeneous by construction).
    pub fn faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Snapshot of the replay coverage counters.
    pub fn stats(&self) -> ReplayStats {
        ReplayStats {
            records: self.set.len() as u64,
            events: self.set.total_events() as u64,
            exact_hits: self.counters.exact.load(Ordering::Relaxed),
            interp_hits: self.counters.interpolated.load(Ordering::Relaxed),
            misses: self.counters.misses.load(Ordering::Relaxed),
        }
    }

    /// Zero the coverage counters.
    pub fn reset_stats(&self) {
        self.counters.exact.store(0, Ordering::Relaxed);
        self.counters.interpolated.store(0, Ordering::Relaxed);
        self.counters.misses.store(0, Ordering::Relaxed);
    }

    /// Score one point from the capture (see the module docs for the
    /// exact / interpolated / miss ladder).
    fn score(&self, op: Op, strategy: Strategy, p: usize, m: u64, seg: Option<u64>) -> f64 {
        let op_name = op.name();
        let strat_name = strategy.name();
        if let Some(s) = seg {
            let key = TraceKey {
                op: op_name.to_string(),
                strategy: strat_name.to_string(),
                p,
                m,
                segment: Some(s),
            };
            if let Some(rec) = self.set.get(&key) {
                self.counters.exact.fetch_add(1, Ordering::Relaxed);
                return rec.critical_path().as_secs();
            }
        }
        // a captured cell answers any segment variant with its tuned run
        if let Some(rec) = self.set.at_cell(op_name, strat_name, p, m) {
            self.counters.exact.fetch_add(1, Ordering::Relaxed);
            return rec.critical_path().as_secs();
        }
        if let Some((t, exact)) = self.interpolate(op_name, strat_name, p, m) {
            let counter = if exact { &self.counters.exact } else { &self.counters.interpolated };
            counter.fetch_add(1, Ordering::Relaxed);
            return t;
        }
        self.counters.misses.fetch_add(1, Ordering::Relaxed);
        f64::INFINITY
    }

    /// Gap-model interpolation between the two captured sizes
    /// bracketing `m` in the `(op, strategy, p)` column. A query whose
    /// `m` exactly equals a captured size resolves to that record and
    /// reports `exact = true` — the keyed lookups in [`Self::score`]
    /// normally answer captured points first, but the column scan must
    /// never re-classify one as interpolated. `None` when no bracket
    /// exists (uncaptured column, or `m` outside its range — replay
    /// never extrapolates an unobserved regime).
    fn interpolate(&self, op: &str, strategy: &str, p: usize, m: u64) -> Option<(f64, bool)> {
        let column = self.set.cells_for(op, strategy, p);
        if let Some(rec) = column.iter().find(|r| r.meta.m == m) {
            return Some((rec.critical_path().as_secs(), true));
        }
        let hi = column.iter().position(|r| r.meta.m > m)?;
        if hi == 0 {
            return None; // m below the captured range
        }
        let (lo_rec, hi_rec) = (column[hi - 1], column[hi]);
        let (t0, t1) = (lo_rec.critical_path().as_secs(), hi_rec.critical_path().as_secs());
        let (x0, x1) = (self.net.gap(lo_rec.meta.m as f64), self.net.gap(hi_rec.meta.m as f64));
        // degenerate-span test scaled by the larger endpoint magnitude:
        // scaling by `x1` alone turned the threshold into 0 whenever
        // `x1 == 0` (a faulted / degenerate gap model), sending flat
        // spans down the linear path to divide by a vanishing span
        let span = x1 - x0;
        let frac = if span.abs() > f64::EPSILON * x0.abs().max(x1.abs()) {
            (self.net.gap(m as f64) - x0) / span
        } else {
            // flat gap span: fall back to log-m interpolation
            ((m as f64) / (lo_rec.meta.m as f64)).ln()
                / ((hi_rec.meta.m as f64) / (lo_rec.meta.m as f64)).ln()
        };
        let t = t0 + frac * (t1 - t0);
        // stay inside the observed bracket even on a non-monotone gap
        Some((t.clamp(t0.min(t1), t0.max(t1)), false))
    }
}

impl Evaluator for ReplayEval {
    fn name(&self) -> &'static str {
        "replay"
    }

    fn predict(
        &self,
        op: Op,
        strategy: Strategy,
        p: usize,
        m: u64,
        seg: Option<u64>,
        _net: &PLogP,
    ) -> f64 {
        self.score(op, strategy, p, m, seg)
    }

    /// Captured cells return their tuned segment's recorded run (the
    /// capture already executed the model-tuned segment — same policy
    /// as [`super::SimEval`]); uncaptured cells tune the segment
    /// analytically against the captured network and score the result
    /// through the interpolation/miss ladder.
    fn tune_segment(
        &self,
        strategy: Strategy,
        _net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> (f64, u64) {
        let op = Op::of(strategy);
        if let Some(rec) = self.set.at_cell(op.name(), strategy.name(), p, m) {
            self.counters.exact.fetch_add(1, Ordering::Relaxed);
            return (rec.critical_path().as_secs(), rec.meta.segment.unwrap_or(m));
        }
        let (_, seg) = models::best_segment(strategy, &self.net, p, m, s_grid);
        (self.score(op, strategy, p, m, Some(seg)), seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{SimEval, TraceRecorder};
    use crate::netsim::NetConfig;

    /// Capture a small bcast+scatter sweep on the ideal network.
    fn captured() -> (TraceSet, NetConfig) {
        let cfg = NetConfig::fast_ethernet_ideal();
        let rec = Arc::new(TraceRecorder::new(&cfg, 1 << 14));
        let eval = SimEval::new(cfg.clone()).with_recorder(Arc::clone(&rec));
        let net = rec.net().clone();
        let s_grid = [1024u64, 8192];
        for op in [Op::Bcast, Op::Scatter] {
            for &strategy in op.family() {
                for p in [4usize, 8] {
                    for m in [256u64, 65536] {
                        let mut seg = None;
                        if strategy.is_segmented() {
                            seg = Some(models::best_segment(strategy, &net, p, m, &s_grid).1);
                        }
                        eval.measure(strategy, p, m, seg);
                    }
                }
            }
        }
        (rec.take(), cfg)
    }

    #[test]
    fn empty_and_mixed_sets_are_rejected() {
        assert!(ReplayEval::new(TraceSet::new()).is_err());
        let (set, _) = captured();
        let mut mixed = set.clone();
        let mut alien = set.records().next().unwrap().clone();
        alien.meta.plogp_l *= 2.0;
        alien.meta.p += 1;
        mixed.insert(alien);
        assert!(ReplayEval::new(mixed).is_err());
    }

    #[test]
    fn exact_cells_reproduce_the_simulator_bit_for_bit() {
        let (set, cfg) = captured();
        let replay = ReplayEval::new(set).unwrap();
        let sim = SimEval::new(cfg);
        let net = replay.net().clone();
        for op in [Op::Bcast, Op::Scatter] {
            for &strategy in op.family() {
                if strategy.is_segmented() {
                    continue; // exercised via tune_segment below
                }
                for p in [4usize, 8] {
                    for m in [256u64, 65536] {
                        let r = replay.predict(op, strategy, p, m, None, &net);
                        let s = sim.predict(op, strategy, p, m, None, &net);
                        assert_eq!(r, s, "{} p={p} m={m}", strategy.name());
                    }
                }
            }
        }
        let st = replay.stats();
        assert!(st.exact_hits > 0 && st.misses == 0, "{st:?}");
    }

    #[test]
    fn captured_cells_answer_segment_queries_with_the_tuned_run() {
        let (set, _) = captured();
        let replay = ReplayEval::new(set).unwrap();
        let net = replay.net().clone();
        let (t, seg) = replay.tune_segment(Strategy::BcastSegChain, &net, 8, 65536, &[1024, 8192]);
        assert!(t.is_finite() && t > 0.0);
        let want = models::best_segment(Strategy::BcastSegChain, &net, 8, 65536, &[1024, 8192]).1;
        assert_eq!(seg, want, "capture ran the model-tuned segment");
    }

    #[test]
    fn in_between_sizes_interpolate_within_the_bracket() {
        let (set, _) = captured();
        let replay = ReplayEval::new(set).unwrap();
        let net = replay.net().clone();
        let t_lo = replay.predict(Op::Bcast, Strategy::BcastBinomial, 8, 256, None, &net);
        let t_hi = replay.predict(Op::Bcast, Strategy::BcastBinomial, 8, 65536, None, &net);
        let t_mid = replay.predict(Op::Bcast, Strategy::BcastBinomial, 8, 4096, None, &net);
        assert!(t_mid.is_finite());
        assert!(t_mid >= t_lo.min(t_hi) && t_mid <= t_lo.max(t_hi), "{t_lo} {t_mid} {t_hi}");
        assert_eq!(replay.stats().interp_hits, 1);
    }

    #[test]
    fn exact_m_with_a_non_tuned_segment_counts_exact() {
        let (set, _) = captured();
        let replay = ReplayEval::new(set).unwrap();
        let net = replay.net().clone();
        let tuned = models::best_segment(Strategy::BcastSegChain, &net, 8, 65536, &[1024, 8192]).1;
        let offbeat = if tuned == 3 { 5 } else { 3 }; // never the captured segment
        let want = replay
            .set()
            .at_cell("bcast", "bcast/seg_chain", 8, 65536)
            .unwrap()
            .critical_path()
            .as_secs();
        let t =
            replay.predict(Op::Bcast, Strategy::BcastSegChain, 8, 65536, Some(offbeat), &net);
        assert_eq!(t, want, "explicit non-tuned segment resolves to the captured cell");
        let st = replay.stats();
        assert_eq!((st.exact_hits, st.interp_hits, st.misses), (1, 0, 0), "{st:?}");
    }

    #[test]
    fn interpolate_resolves_exact_m_to_the_record() {
        // defense in depth on the column scan itself: even if the keyed
        // lookups were bypassed, an exactly-captured m must come back as
        // the record's score, flagged exact rather than interpolated
        let (set, _) = captured();
        let replay = ReplayEval::new(set).unwrap();
        let want = replay
            .set()
            .at_cell("bcast", "bcast/binomial", 8, 65536)
            .unwrap()
            .critical_path()
            .as_secs();
        let (t, exact) = replay.interpolate("bcast", "bcast/binomial", 8, 65536).unwrap();
        assert!(exact);
        assert_eq!(t, want);
        let (_, exact) = replay.interpolate("bcast", "bcast/binomial", 8, 4096).unwrap();
        assert!(!exact, "a genuinely in-between m still interpolates");
    }

    /// A hand-built record on a constant-gap network (`g(m)` identical
    /// at every size, so every bracket has a zero gap span).
    fn flat_gap_record(m: u64, secs: f64) -> crate::netsim::TraceRecord {
        crate::netsim::TraceRecord {
            meta: crate::netsim::TraceMeta {
                op: "bcast".to_string(),
                strategy: "bcast/flat".to_string(),
                p: 4,
                m,
                segment: None,
                completion_ns: (secs * 1e9).round() as u64,
                dropped: 0,
                plogp_l: 1e-4,
                plogp_sizes: vec![1.0, (1u64 << 20) as f64],
                plogp_gaps: vec![5e-6, 5e-6],
                fault_plan: None,
            },
            events: Vec::new(),
        }
    }

    #[test]
    fn zero_gap_span_brackets_fall_back_to_log_m_and_stay_bracketed() {
        let mut set = TraceSet::new();
        set.insert(flat_gap_record(256, 1.0));
        set.insert(flat_gap_record(65536, 3.0));
        let replay = ReplayEval::new(set).unwrap();
        let net = replay.net().clone();
        let t = replay.predict(Op::Bcast, Strategy::BcastFlat, 4, 4096, None, &net);
        // x0 == x1, so the gap-coordinate path would divide by zero;
        // log-m interpolation gives ln(4096/256)/ln(65536/256) = 1/2
        assert!((t - 2.0).abs() < 1e-9, "log-m midpoint expected, got {t}");
        assert!(t >= 1.0 && t <= 3.0, "must stay inside the bracket");
        let st = replay.stats();
        assert_eq!((st.exact_hits, st.interp_hits, st.misses), (0, 1, 0), "{st:?}");
    }

    #[test]
    fn uncaptured_points_miss_with_infinite_score() {
        let (set, _) = captured();
        let replay = ReplayEval::new(set).unwrap();
        let net = replay.net().clone();
        // never-captured family
        let t = replay.predict(Op::Gather, Strategy::GatherFlat, 8, 256, None, &net);
        assert!(t.is_infinite());
        // captured strategy, uncaptured P
        let t = replay.predict(Op::Bcast, Strategy::BcastBinomial, 12, 256, None, &net);
        assert!(t.is_infinite());
        // m outside the captured range is a miss, not an extrapolation
        let t = replay.predict(Op::Bcast, Strategy::BcastBinomial, 8, 1 << 20, None, &net);
        assert!(t.is_infinite());
        let st = replay.stats();
        assert_eq!(st.misses, 3);
        assert!(st.hit_rate() < 1.0);
        assert!(st.to_json().contains("\"misses\":3"));
    }

    #[test]
    fn best_never_selects_an_unobserved_strategy() {
        let (set, _) = captured();
        // drop every binomial bcast record: the argmin must fall back
        // to an observed strategy rather than score the hole
        let mut pruned = TraceSet::new();
        for r in set.records() {
            if r.meta.strategy != "bcast/binomial" {
                pruned.insert(r.clone());
            }
        }
        let replay = ReplayEval::new(pruned).unwrap();
        let net = replay.net().clone();
        let d = replay.best(Op::Bcast, &net, 8, 256, &[1024, 8192]);
        assert_ne!(d.strategy, Strategy::BcastBinomial);
        assert!(d.predicted.is_finite());
    }

    #[test]
    fn faulted_captures_replay_bit_for_bit_and_never_mix() {
        let cfg = NetConfig::fast_ethernet_ideal();
        let plan = FaultPlan::new().slow_node(1, 4.0).degrade_link(0, 2, 2e-3, None);
        let rec = Arc::new(TraceRecorder::new(&cfg, 1 << 14));
        let eval = SimEval::new(cfg.clone())
            .with_faults(plan.clone())
            .with_recorder(Arc::clone(&rec));
        for m in [256u64, 65536] {
            eval.measure(Strategy::BcastBinomial, 8, m, None);
        }
        let replay = ReplayEval::new(rec.take()).unwrap();
        assert_eq!(replay.faults(), Some(&plan));
        let net = replay.net().clone();
        for m in [256u64, 65536] {
            assert_eq!(
                replay.predict(Op::Bcast, Strategy::BcastBinomial, 8, m, None, &net),
                eval.measure(Strategy::BcastBinomial, 8, m, None),
                "faulted replay must reproduce the faulted run"
            );
        }
        // healthy records must not merge into a faulted replay set
        let (healthy, _) = captured();
        let mut mixed = TraceSet::new();
        for r in healthy.records().take(1) {
            mixed.insert(r.clone());
        }
        let mut faulted = healthy.records().nth(1).unwrap().clone();
        faulted.meta.fault_plan = Some(plan);
        mixed.insert(faulted);
        let err = ReplayEval::new(mixed).unwrap_err().to_string();
        assert!(err.contains("different fault plans"), "{err}");
    }

    #[test]
    fn clones_share_the_set_and_counters() {
        let (set, _) = captured();
        let replay = ReplayEval::new(set).unwrap();
        let clone = replay.clone();
        let net = replay.net().clone();
        clone.predict(Op::Bcast, Strategy::BcastFlat, 8, 256, None, &net);
        assert_eq!(replay.stats().exact_hits, 1, "counters are shared");
        replay.reset_stats();
        assert_eq!(clone.stats().exact_hits, 0);
    }
}
