//! [`ModelEval`] — the analytic backend: Tables 1 and 2 as closed-form
//! pLogP cost models, via the strategy-indexed registry in
//! [`crate::models`].
//!
//! This is the sweep's hot backend, so [`Evaluator::best_in`] carries
//! the whole prune-and-warm-start pipeline: the adjacent cell's winner
//! is scored first, every other strategy is screened by its m-aware
//! [`crate::models::LOWER_BOUNDS`] entry (in ascending-bound order, so
//! the incumbent is tightest when the expensive candidates are
//! screened), surviving segment searches read their gaps from the
//! per-tune [`crate::plogp::GapCache`] and skip candidates a
//! per-candidate `k·gap_min` bound already rules out. None of that may
//! change the argmin: every skip requires a *strictly* losing bound
//! (plus [`crate::models::PRUNE_MARGIN`]), so the produced tables are
//! byte-identical to the exhaustive ranking.

use std::sync::Arc;

use anyhow::Result;

use crate::collectives::Strategy;
use crate::models::correct::CorrectionTable;
use crate::models::{self, BoundInputs, CostInputs};
use crate::obs::Span;
use crate::plogp::{CachedRow, GapCache, PLogP};
use crate::tuner::decision::{Decision, Op};

use super::{CellCtx, EvalCounts, Evaluator};

/// The native model evaluator, optionally carrying a trace-fitted
/// [`CorrectionTable`] whose per-(strategy, m-octave) multipliers are
/// applied on top of the analytic models. Cheap to construct and
/// `Clone` (the table is shared through an `Arc`); the tuner's parallel
/// sweep shares one across all workers.
///
/// Corrections never disturb the pruning exactness argument: inside one
/// `(p, m)` cell a strategy's factor is a single known positive
/// constant, so its corrected cost is exactly `factor × uncorrected`
/// and its screening bound scales by the same factor (multiplication by
/// a positive constant is monotone in IEEE arithmetic, so `bound <=
/// cost` survives the scaling bit-for-bit).
#[derive(Debug, Clone, Default)]
pub struct ModelEval {
    corrections: Option<Arc<CorrectionTable>>,
}

impl ModelEval {
    pub fn new() -> ModelEval {
        ModelEval::default()
    }

    /// Attach trace-fitted correction factors (an empty table is the
    /// identity and is dropped).
    pub fn with_corrections(mut self, table: CorrectionTable) -> ModelEval {
        self.corrections = if table.is_empty() { None } else { Some(Arc::new(table)) };
        self
    }

    /// The multiplier applied to `strategy` at message size `m`
    /// (`1.0` when uncorrected).
    pub fn factor(&self, strategy: Strategy, m: u64) -> f64 {
        self.corrections.as_ref().map_or(1.0, |c| c.factor(strategy, m))
    }

    /// The attached correction table, if any.
    pub fn corrections(&self) -> Option<&CorrectionTable> {
        self.corrections.as_deref()
    }
}

/// One cell's evaluation state: the `(P, m)` point, the optional cache
/// row, and locally-accumulated counters (flushed to the shared
/// [`super::EvalStats`] once per cell).
struct Cell<'a> {
    net: &'a PLogP,
    p: usize,
    m: u64,
    s_grid: &'a [u64],
    cached: Option<(&'a GapCache, &'a CachedRow)>,
    n: EvalCounts,
}

impl Cell<'_> {
    /// One unsegmented model evaluation (bit-identical to
    /// [`models::predict`] with `seg = None`).
    fn predict_unseg(&mut self, strategy: Strategy) -> f64 {
        self.n.model_invocations += 1;
        match self.cached {
            Some((c, r)) => {
                let x =
                    CostInputs::from_parts(self.net, self.p, self.m, self.m, r.g_m, r.g_m, c.rdv());
                models::cost_fn(strategy)(&x)
            }
            None => models::predict(strategy, self.net, self.p, self.m, None),
        }
    }

    /// Mirror of [`models::best_segment`] with two exact skips: grid
    /// candidates that clamp onto the already-seeded `s = m` point
    /// (bit-identical value, so the strict-`<` argmin cannot change),
    /// and candidates whose `k`-scaled min-gap bound already loses to
    /// the search incumbent (strictly worse, so they cannot win or
    /// tie). Gaps come from the cache when one is attached.
    fn best_segment(&mut self, strategy: Strategy, bi: &BoundInputs) -> (f64, u64) {
        let mf = self.m as f64;
        // `s = m` degenerates to the unsegmented model (`CostInputs`
        // clamps `seg` to `m` either way), so the seed IS the
        // unsegmented evaluation
        let mut best = (self.predict_unseg(strategy), self.m);
        for (i, &s) in self.s_grid.iter().enumerate() {
            let sc = s.clamp(1, self.m);
            if sc == self.m {
                // duplicates the seed candidate bit-for-bit
                self.n.seg_points_skipped += 1;
                continue;
            }
            let k = (mf / sc as f64).ceil();
            if models::prunes(candidate_lower_bound(strategy, bi, k), best.0) {
                self.n.seg_points_skipped += 1;
                continue;
            }
            self.n.model_invocations += 1;
            let t = match self.cached {
                Some((c, r)) => {
                    let g_s = if sc == s {
                        c.gap_at_segment(i)
                    } else {
                        self.net.gap(sc as f64)
                    };
                    let x =
                        CostInputs::from_parts(self.net, self.p, self.m, sc, r.g_m, g_s, c.rdv());
                    models::cost_fn(strategy)(&x)
                }
                None => models::predict(strategy, self.net, self.p, self.m, Some(sc)),
            };
            if t < best.0 {
                best = (t, sc);
            }
        }
        best
    }

    /// Score one strategy fully (segment search for segmented ones).
    fn eval(&mut self, strategy: Strategy, bi: &BoundInputs) -> (f64, Option<u64>) {
        if strategy.is_segmented() {
            let (t, seg) = self.best_segment(strategy, bi);
            (t, Some(seg))
        } else {
            (self.predict_unseg(strategy), None)
        }
    }
}

/// Per-candidate lower bound of a segmented strategy at segment count
/// `k`: every model term scales either with `k·g(s) >= k·gap_min` or
/// with `g(s) >= gap_min`, and `k` is known without interpolating a
/// single gap — so small-segment candidates (huge `k`) are skipped for
/// the price of one multiply.
fn candidate_lower_bound(strategy: Strategy, b: &BoundInputs, k: f64) -> f64 {
    match strategy {
        Strategy::BcastSegFlat => (b.p - 1.0) * k * b.gap_min + b.l,
        // (P-1)(g+L) + (k-1) g = (P+k-2) g + (P-1) L, coefficient >= 0
        Strategy::BcastSegChain => (b.p + k - 2.0) * b.gap_min + (b.p - 1.0) * b.l,
        Strategy::BcastSegBinomial => b.fl * k * b.gap_min + b.ce * b.l,
        _ => f64::NEG_INFINITY,
    }
}

impl Evaluator for ModelEval {
    fn name(&self) -> &'static str {
        // historical CLI name for the pure-Rust model backend
        "native"
    }

    fn predict(
        &self,
        _op: Op,
        strategy: Strategy,
        p: usize,
        m: u64,
        seg: Option<u64>,
        net: &PLogP,
    ) -> f64 {
        self.factor(strategy, m) * models::predict(strategy, net, p, m, seg)
    }

    /// Delegated to [`models::best_segment`] so the pruned
    /// [`Self::best_in`] can never drift from `rank()[0]`. The factor
    /// is constant across a cell's segment candidates (it depends only
    /// on `octave(m)`), so the segment argmin is taken uncorrected and
    /// the winning time scaled once.
    fn tune_segment(
        &self,
        strategy: Strategy,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> (f64, u64) {
        let (t, seg) = models::best_segment(strategy, net, p, m, s_grid);
        (self.factor(strategy, m) * t, seg)
    }

    /// Delegated to [`models::rank_strategies`] (same reason); with
    /// corrections attached, each family member's time is scaled by its
    /// factor *before* the stable family-order sort, so tie-breaking
    /// matches [`Self::best_in`] exactly.
    fn rank(
        &self,
        family: &[Strategy],
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> Vec<(Strategy, f64, Option<u64>)> {
        match &self.corrections {
            None => models::rank_strategies(family, net, p, m, s_grid),
            Some(c) => {
                let mut out: Vec<(Strategy, f64, Option<u64>)> = family
                    .iter()
                    .map(|&s| {
                        if s.is_segmented() {
                            let (t, seg) = models::best_segment(s, net, p, m, s_grid);
                            (s, c.factor(s, m) * t, Some(seg))
                        } else {
                            (s, c.factor(s, m) * models::predict(s, net, p, m, None), None)
                        }
                    })
                    .collect();
                out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                out
            }
        }
    }

    /// The context-free pruned argmin (still bound-pruned — just
    /// without a warm-start hint or gap cache).
    fn best(&self, op: Op, net: &PLogP, p: usize, m: u64, s_grid: &[u64]) -> Decision {
        self.best_in(op, net, p, m, s_grid, &CellCtx::default())
    }

    /// The warm-started, bound-pruned, gap-cached argmin. Exactness
    /// argument: a strategy (or segment candidate) is skipped only when
    /// its lower bound strictly exceeds a cost some other candidate
    /// *achieved* — so it can neither win nor tie — and every scored
    /// value is computed with arithmetic bit-identical to the
    /// exhaustive path. The final selection takes the minimum over the
    /// scored strategies with earliest-family-index tie-breaking, which
    /// is exactly `rank(..)[0]`.
    fn best_in(
        &self,
        op: Op,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
        ctx: &CellCtx<'_>,
    ) -> Decision {
        let family = op.family();
        let cached = ctx
            .cache
            .filter(|c| c.covers(s_grid))
            .and_then(|c| c.row(m).map(|r| (c, r)));
        let mut cell = Cell {
            net,
            p,
            m,
            s_grid,
            cached,
            n: EvalCounts { cells: 1, ..EvalCounts::default() },
        };
        let bi = match cell.cached {
            Some((c, r)) => BoundInputs::from_stats(p, m, c.l(), c.g1(), r.range, c.gap_floor()),
            None => BoundInputs::new(net, p, m),
        };

        // Scored strategies, indexed in family order.
        let mut results: Vec<Option<(f64, Option<u64>)>> = vec![None; family.len()];
        // The best cost *achieved* so far — the pruning threshold.
        let mut threshold = f64::INFINITY;

        // Stage timing (no-op unless `obs` is enabled): full scoring is
        // attributed to segment_search for segmented strategies and
        // model_eval for unsegmented ones.
        let timed_eval = |cell: &mut Cell<'_>, s: Strategy, bi: &BoundInputs| {
            let _stage = if s.is_segmented() {
                Span::start("tuner.stage.segment_search_ns")
            } else {
                Span::start("tuner.stage.model_eval_ns")
            };
            cell.eval(s, bi)
        };

        // The cell's per-strategy correction factor: `m` is fixed here,
        // so this is a known positive constant per strategy. Corrected
        // cost = factor × uncorrected cost, and the screening bound
        // scales by the same factor — positive-constant multiplication
        // is monotone in IEEE arithmetic, so `bound <= cost` (and every
        // strict comparison below) survives the scaling exactly.
        let factor = |s: Strategy| -> f64 {
            self.corrections.as_ref().map_or(1.0, |c| c.factor(s, m))
        };

        // 1. Warm start: score the adjacent cell's winner first so the
        //    threshold is tight before anything else is screened.
        let hint_idx = ctx.hint.and_then(|h| family.iter().position(|&s| s == h));
        if let Some(idx) = hint_idx {
            let r = timed_eval(&mut cell, family[idx], &bi);
            let r = (factor(family[idx]) * r.0, r.1);
            threshold = r.0;
            results[idx] = Some(r);
        }

        // 2. Screen every remaining strategy by its (corrected) lower
        //    bound, in ascending-bound order: likely winners are scored
        //    first, so the expensive losers face the tightest threshold.
        let order: Vec<(f64, usize)> = {
            let _screen = Span::start("tuner.stage.bound_screen_ns");
            let mut order: Vec<(f64, usize)> = family
                .iter()
                .enumerate()
                .filter(|(idx, _)| results[*idx].is_none())
                .map(|(idx, &s)| {
                    cell.n.bound_evals += 1;
                    (factor(s) * models::lower_bound(s, &bi), idx)
                })
                .collect();
            order.sort_by(|a, b| a.partial_cmp(b).expect("bounds are finite"));
            order
        };
        for (lb, idx) in order {
            let s = family[idx];
            if models::prunes(lb, threshold) {
                if s.is_segmented() {
                    cell.n.seg_searches_pruned += 1;
                    cell.n.seg_points_skipped += s_grid.len() as u64 + 1;
                } else {
                    cell.n.strategies_pruned += 1;
                }
                continue;
            }
            let r = timed_eval(&mut cell, s, &bi);
            let r = (factor(s) * r.0, r.1);
            if r.0 < threshold {
                threshold = r.0;
            }
            results[idx] = Some(r);
        }

        // 3. Argmin over the scored strategies, earliest family index
        //    on exact ties — identical to `rank(..)[0]`.
        let mut win: Option<(usize, (f64, Option<u64>))> = None;
        for (idx, r) in results.iter().enumerate() {
            if let Some(r) = *r {
                let better = match win {
                    None => true,
                    Some((_, b)) => r.0 < b.0,
                };
                if better {
                    win = Some((idx, r));
                }
            }
        }
        let (idx, (t, seg)) = win.expect("op families are non-empty and ties are never pruned");
        if hint_idx.is_some() {
            if hint_idx == Some(idx) {
                cell.n.warm_hits += 1;
            } else {
                cell.n.warm_misses += 1;
            }
        }
        if let Some(stats) = ctx.stats {
            stats.add(&cell.n);
        }
        Decision { strategy: family[idx], segment: seg, predicted: t }
    }

    /// Whole-grid sweep with per-row gap reuse: one [`GapCache`] per
    /// call, so each m-row's interpolated gaps and bound statistics
    /// (`GapTable::range_stats`) are computed once instead of once per
    /// cell, and each cell warm-starts from its predecessor's winner.
    /// Output is byte-identical to the default per-cell loop — hint and
    /// cache independence is proven by
    /// `best_in_is_hint_and_cache_independent` below. This is the path
    /// `ArtifactEval` falls back to when no artifact covers a grid.
    fn predict_grid(
        &self,
        op: Op,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
        s_grid: &[u64],
    ) -> Result<Vec<Decision>> {
        let cache = GapCache::new(net, m_grid, s_grid);
        let mut out = Vec::with_capacity(p_grid.len() * m_grid.len());
        let mut hint: Option<Strategy> = None;
        for &p in p_grid {
            for &m in m_grid {
                let ctx = CellCtx { hint, cache: Some(&cache), stats: None };
                let d = self.best_in(op, net, p, m, s_grid, &ctx);
                hint = Some(d.strategy);
                out.push(d);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::EvalStats;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn predict_grid_override_matches_the_per_cell_loop() {
        let net = measured();
        let s_grid = crate::tuner::grids::default_s_grid();
        let p_grid = [2usize, 8, 48];
        let m_grid = [1u64, 8192, 1 << 20];
        for op in [Op::Bcast, Op::Scatter, Op::AllReduce] {
            let grid = ModelEval::new()
                .predict_grid(op, &net, &p_grid, &m_grid, &s_grid)
                .unwrap();
            let mut i = 0;
            for &p in &p_grid {
                for &m in &m_grid {
                    let want = ModelEval::new().best(op, &net, p, m, &s_grid);
                    assert_eq!(grid[i], want, "{op:?} P={p} m={m}");
                    i += 1;
                }
            }
        }
    }

    #[test]
    fn predict_delegates_to_models() {
        let net = measured();
        for s in Strategy::ALL {
            let seg = s.is_segmented().then_some(4096u64);
            assert_eq!(
                ModelEval::new().predict(Op::of(s), s, 24, 65536, seg, &net),
                models::predict(s, &net, 24, 65536, seg),
                "{}",
                s.name()
            );
        }
    }

    #[test]
    fn pruned_best_matches_exhaustive_argmin_over_a_grid() {
        let net = measured();
        let s_grid: Vec<u64> = crate::tuner::grids::default_s_grid();
        for op in [Op::Bcast, Op::Scatter] {
            for p in [2usize, 5, 16, 48] {
                for m in [1u64, 256, 8192, 1 << 17, 1 << 20] {
                    let d = ModelEval::new().best(op, &net, p, m, &s_grid);
                    let want = models::rank_strategies(op.family(), &net, p, m, &s_grid);
                    assert_eq!(d.strategy, want[0].0, "{op:?} P={p} m={m}");
                    assert_eq!(d.predicted, want[0].1);
                    assert_eq!(d.segment, want[0].2);
                }
            }
        }
    }

    #[test]
    fn best_in_is_hint_and_cache_independent() {
        let net = measured();
        let s_grid = crate::tuner::grids::default_s_grid();
        let m_grid = [64u64, 8192, 1 << 20];
        let cache = GapCache::new(&net, &m_grid, &s_grid);
        let stats = EvalStats::new();
        for op in Op::ALL {
            for p in [2usize, 24, 48] {
                for m in m_grid {
                    let bare = ModelEval::new().best(op, &net, p, m, &s_grid);
                    // every hint, with and without the cache
                    for hint in op.family() {
                        for cache_ref in [None, Some(&cache)] {
                            let ctx = CellCtx {
                                hint: Some(*hint),
                                cache: cache_ref,
                                stats: Some(&stats),
                            };
                            let d = ModelEval::new().best_in(op, &net, p, m, &s_grid, &ctx);
                            assert_eq!(d.strategy, bare.strategy, "{op:?} P={p} m={m} {hint:?}");
                            assert_eq!(d.predicted, bare.predicted);
                            assert_eq!(d.segment, bare.segment);
                        }
                    }
                    // a hint from the wrong family is ignored
                    let foreign = if op == Op::Bcast {
                        Strategy::ScatterFlat
                    } else {
                        Strategy::BcastFlat
                    };
                    let ctx = CellCtx { hint: Some(foreign), cache: Some(&cache), stats: None };
                    let d = ModelEval::new().best_in(op, &net, p, m, &s_grid, &ctx);
                    assert_eq!(d.strategy, bare.strategy);
                }
            }
        }
        let counts = stats.snapshot();
        assert!(counts.cells > 0 && counts.model_invocations > 0);
        assert_eq!(counts.warm_hits + counts.warm_misses, counts.cells);
    }

    /// A deliberately lopsided correction table: factors above and
    /// below 1 across several strategies and octaves, so corrected
    /// argmins genuinely differ from uncorrected ones.
    fn skewed_corrections() -> CorrectionTable {
        let mut t = CorrectionTable::identity();
        for (i, s) in Strategy::ALL.iter().enumerate() {
            for octave in [0u32, 6, 13, 17, 20] {
                // deterministic spread over [0.4, 2.4]
                let f = 0.4 + ((i as u32 * 7 + octave * 3) % 21) as f64 * 0.1;
                t.set(*s, octave, f);
            }
        }
        t
    }

    #[test]
    fn corrected_predict_scales_by_the_cell_factor() {
        let net = measured();
        let table = skewed_corrections();
        let ev = ModelEval::new().with_corrections(table.clone());
        for s in [Strategy::BcastFlat, Strategy::BcastSegChain, Strategy::AllGatherRing] {
            for m in [1u64, 100, 65536, 1 << 20] {
                let seg = s.is_segmented().then_some(4096u64);
                assert_eq!(
                    ev.predict(Op::of(s), s, 24, m, seg, &net),
                    table.factor(s, m) * models::predict(s, &net, 24, m, seg),
                    "{} m={m}",
                    s.name()
                );
            }
        }
        // an empty table is dropped: identical to the bare evaluator
        let bare = ModelEval::new().with_corrections(CorrectionTable::identity());
        assert!(bare.corrections().is_none());
    }

    /// The tentpole's exactness property: with corrections attached,
    /// the pruned, warm-started, gap-cached `best_in` still equals the
    /// exhaustive corrected argmin (`rank()[0]`), for every hint and
    /// cache combination.
    #[test]
    fn corrected_best_matches_exhaustive_corrected_argmin() {
        let net = measured();
        let s_grid = crate::tuner::grids::default_s_grid();
        let m_grid = [1u64, 64, 8192, 1 << 17, 1 << 20];
        let cache = GapCache::new(&net, &m_grid, &s_grid);
        let ev = ModelEval::new().with_corrections(skewed_corrections());
        for op in Op::ALL {
            for p in [2usize, 5, 24, 48] {
                for m in m_grid {
                    let want = ev.rank(op.family(), &net, p, m, &s_grid);
                    let d = ev.best(op, &net, p, m, &s_grid);
                    assert_eq!(d.strategy, want[0].0, "{op:?} P={p} m={m}");
                    assert_eq!(d.predicted, want[0].1);
                    assert_eq!(d.segment, want[0].2);
                    for hint in op.family() {
                        for cache_ref in [None, Some(&cache)] {
                            let ctx =
                                CellCtx { hint: Some(*hint), cache: cache_ref, stats: None };
                            let got = ev.best_in(op, &net, p, m, &s_grid, &ctx);
                            assert_eq!(got, d, "{op:?} P={p} m={m} hint={hint:?}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn corrections_change_the_winner_when_they_should() {
        let net = measured();
        let s_grid = crate::tuner::grids::default_s_grid();
        let bare = ModelEval::new().best(Op::Bcast, &net, 24, 65536, &s_grid);
        // make the uncorrected winner 100x slower in its octave
        let mut t = CorrectionTable::identity();
        t.set(bare.strategy, crate::models::correct::octave(65536), 100.0);
        let ev = ModelEval::new().with_corrections(t);
        let corrected = ev.best(Op::Bcast, &net, 24, 65536, &s_grid);
        assert_ne!(corrected.strategy, bare.strategy);
    }

    #[test]
    fn stats_count_pruned_work() {
        let net = measured();
        let s_grid = crate::tuner::grids::default_s_grid();
        let stats = EvalStats::new();
        let ctx = CellCtx { hint: None, cache: None, stats: Some(&stats) };
        let _ = ModelEval::new().best_in(Op::Bcast, &net, 48, 256, &s_grid, &ctx);
        let c = stats.snapshot();
        assert_eq!(c.cells, 1);
        assert_eq!(c.bound_evals, Strategy::BCAST.len() as u64);
        // pruning must save real work on a mid-size cell at P=48
        let exhaustive =
            crate::eval::exhaustive_invocations_per_cell(&Strategy::BCAST, s_grid.len());
        assert!(
            c.model_invocations < exhaustive,
            "no savings: {} vs {exhaustive}",
            c.model_invocations
        );
    }
}
