//! [`ModelEval`] — the analytic backend: Tables 1 and 2 as closed-form
//! pLogP cost models, via the strategy-indexed registry in
//! [`crate::models`].

use crate::collectives::Strategy;
use crate::models;
use crate::plogp::PLogP;
use crate::tuner::decision::{Decision, Op};

use super::Evaluator;

/// The native model evaluator. Stateless and free to construct; the
/// tuner's parallel sweep shares one across all workers.
#[derive(Debug, Clone, Copy, Default)]
pub struct ModelEval;

impl ModelEval {
    pub fn new() -> ModelEval {
        ModelEval
    }
}

impl Evaluator for ModelEval {
    fn name(&self) -> &'static str {
        // historical CLI name for the pure-Rust model backend
        "native"
    }

    fn predict(
        &self,
        _op: Op,
        strategy: Strategy,
        p: usize,
        m: u64,
        seg: Option<u64>,
        net: &PLogP,
    ) -> f64 {
        models::predict(strategy, net, p, m, seg)
    }

    /// Delegated to [`models::best_segment`] so the pruned [`Self::best`]
    /// (which uses the same function) can never drift from `rank()[0]`.
    fn tune_segment(
        &self,
        strategy: Strategy,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> (f64, u64) {
        models::best_segment(strategy, net, p, m, s_grid)
    }

    /// Delegated to [`models::rank_strategies`] (same reason).
    fn rank(
        &self,
        family: &[Strategy],
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> Vec<(Strategy, f64, Option<u64>)> {
        models::rank_strategies(family, net, p, m, s_grid)
    }

    /// Argmin with early pruning: a segmented strategy whose
    /// segment-size-independent lower bound already loses to the best
    /// unpruned candidate skips its whole segment-grid search. Exact
    /// ties are never pruned (strict `>`), so the winner is identical to
    /// `rank(..)[0]` — first in family order among the minima.
    fn best(&self, op: Op, net: &PLogP, p: usize, m: u64, s_grid: &[u64]) -> Decision {
        let mut best: Option<Decision> = None;
        for &s in op.family() {
            if s.is_segmented() {
                if let Some(b) = &best {
                    if models::segmented_lower_bound(s, net, p) > b.predicted {
                        continue;
                    }
                }
                let (t, seg) = models::best_segment(s, net, p, m, s_grid);
                if best.as_ref().map_or(true, |b| t < b.predicted) {
                    best = Some(Decision { strategy: s, segment: Some(seg), predicted: t });
                }
            } else {
                let t = models::predict(s, net, p, m, None);
                if best.as_ref().map_or(true, |b| t < b.predicted) {
                    best = Some(Decision { strategy: s, segment: None, predicted: t });
                }
            }
        }
        best.expect("op families are non-empty")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_icluster1());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn predict_delegates_to_models() {
        let net = measured();
        for s in Strategy::ALL {
            let seg = s.is_segmented().then_some(4096u64);
            assert_eq!(
                ModelEval.predict(Op::of(s), s, 24, 65536, seg, &net),
                models::predict(s, &net, 24, 65536, seg),
                "{}",
                s.name()
            );
        }
    }

    #[test]
    fn pruned_best_matches_exhaustive_argmin_over_a_grid() {
        let net = measured();
        let s_grid: Vec<u64> = crate::tuner::grids::default_s_grid();
        for op in [Op::Bcast, Op::Scatter] {
            for p in [2usize, 5, 16, 48] {
                for m in [1u64, 256, 8192, 1 << 17, 1 << 20] {
                    let d = ModelEval.best(op, &net, p, m, &s_grid);
                    let want = models::rank_strategies(op.family(), &net, p, m, &s_grid);
                    assert_eq!(d.strategy, want[0].0, "{op:?} P={p} m={m}");
                    assert_eq!(d.predicted, want[0].1);
                    assert_eq!(d.segment, want[0].2);
                }
            }
        }
    }
}
