//! [`ArtifactEval`] — the AOT backend: one PJRT execution of the
//! compiled XLA tuner kernel evaluates the whole decision tensor (all 13
//! core strategies × P-grid × m-grid × segment grid) at once. The
//! extended collectives go through the second artifact
//! (`tuner_ext.hlo.txt`), loaded from the same directory when present —
//! one device execution serves all four extended ops — and fall back to
//! the native models when it is absent.

use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::collectives::Strategy;
use crate::plogp::PLogP;
use crate::runtime::{
    pad_grid_f32, ArtifactMeta, ExtArtifact, ExtOutput, TunerArtifact, TunerOutput,
};
use crate::tuner::decision::{Decision, Op};

use super::{Evaluator, ModelEval};

/// Memo of the last whole-grid execution: a tune() evaluates the same
/// grid once for broadcast and once for scatter, and both must come
/// from a single device execution.
struct GridMemo {
    net: PLogP,
    p_grid: Vec<usize>,
    m_grid: Vec<u64>,
    s_grid: Vec<u64>,
    out: TunerOutput,
}

/// Memo of the last extended-artifact execution: one device run serves
/// the gather, barrier, allgather, and allreduce passes of a tune.
struct ExtGridMemo {
    net: PLogP,
    p_grid: Vec<usize>,
    m_grid: Vec<u64>,
    out: ExtOutput,
}

/// Scores strategies through the AOT-compiled tuner artifacts (core +
/// optional extended). Segment sizes come from the kernel's baked
/// segment-grid search; an explicit `seg` argument to
/// [`Evaluator::predict`] cannot be forced through the compiled graph
/// and is ignored (documented contract; `tune_segment` reads the
/// kernel's tuned segment instead).
pub struct ArtifactEval {
    art: TunerArtifact,
    /// The extended-collectives artifact, when `tuner_ext.hlo.txt` is
    /// present next to the core one; `None` falls back to [`ModelEval`]
    /// for the extended ops.
    ext: Option<ExtArtifact>,
    /// Whole-grid executions (one per `tune`, serving both ops).
    memo_grid: Mutex<Option<GridMemo>>,
    /// Single-cell point queries (`predict`/`rank`/`tune_segment`) — a
    /// separate slot so point queries never clobber the full-grid memo
    /// between a tune's broadcast and scatter passes.
    memo_point: Mutex<Option<GridMemo>>,
    /// Whole-grid / point memos for the extended artifact (same split).
    ext_memo_grid: Mutex<Option<ExtGridMemo>>,
    ext_memo_point: Mutex<Option<ExtGridMemo>>,
}

impl ArtifactEval {
    /// Load `tuner.hlo.txt` + `tuner.meta.json` from `dir` and compile;
    /// also picks up the extended artifact (`tuner_ext.*`) when present.
    pub fn load(dir: &Path) -> Result<ArtifactEval> {
        let mut eval = ArtifactEval::new(TunerArtifact::load(dir)?);
        eval.ext = match ExtArtifact::load(dir) {
            Ok(a) => Some(a),
            Err(e) => {
                log::info!("ext artifact unavailable ({e:#}); ext ops use native models");
                None
            }
        };
        Ok(eval)
    }

    /// Wrap an already-loaded core artifact (no extended artifact; the
    /// extended ops fall back to the native models).
    pub fn new(art: TunerArtifact) -> ArtifactEval {
        ArtifactEval {
            art,
            ext: None,
            memo_grid: Mutex::new(None),
            memo_point: Mutex::new(None),
            ext_memo_grid: Mutex::new(None),
            ext_memo_point: Mutex::new(None),
        }
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.art.meta
    }

    /// Is the extended-collectives artifact loaded?
    pub fn has_ext(&self) -> bool {
        self.ext.is_some()
    }

    /// Execute the artifact over the given grids (padding every input to
    /// the baked shapes), memoizing the last execution in `memo`.
    fn execute_grid_memo(
        &self,
        memo_slot: &Mutex<Option<GridMemo>>,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
        s_grid: &[u64],
    ) -> Result<TunerOutput> {
        {
            let memo = memo_slot.lock().unwrap();
            if let Some(m) = &*memo {
                if m.net == *net
                    && m.p_grid == p_grid
                    && m.m_grid == m_grid
                    && m.s_grid == s_grid
                {
                    return Ok(m.out.clone());
                }
            }
        }
        let meta = &self.art.meta;
        if p_grid.len() > meta.p_grid_len || m_grid.len() > meta.m_grid_len {
            bail!(
                "grid larger than artifact shape ({} x {} vs {} x {})",
                p_grid.len(),
                m_grid.len(),
                meta.p_grid_len,
                meta.m_grid_len
            );
        }
        let sizes: Vec<f32> = net.table.sizes().iter().map(|&x| x as f32).collect();
        let gaps: Vec<f32> = net.table.gaps().iter().map(|&x| x as f32).collect();
        if sizes.len() != meta.table_len {
            bail!(
                "gap table has {} samples but the artifact expects {} — \
                 measure with plogp::default_size_grid({})",
                sizes.len(),
                meta.table_len,
                meta.table_len
            );
        }
        let pf = pad_grid_f32(p_grid.iter().map(|&p| p as f32).collect(), meta.p_grid_len);
        let mf = pad_grid_f32(m_grid.iter().map(|&m| m as f32).collect(), meta.m_grid_len);
        let sf = pad_grid_f32(s_grid.iter().map(|&s| s as f32).collect(), meta.s_grid_len);
        let out = self.art.execute(&sizes, &gaps, net.l as f32, &pf, &mf, &sf)?;
        *memo_slot.lock().unwrap() = Some(GridMemo {
            net: net.clone(),
            p_grid: p_grid.to_vec(),
            m_grid: m_grid.to_vec(),
            s_grid: s_grid.to_vec(),
            out: out.clone(),
        });
        Ok(out)
    }

    /// One single-cell execution (point-query memo slot).
    fn execute_point(&self, net: &PLogP, p: usize, m: u64, s_grid: &[u64]) -> Result<TunerOutput> {
        let (pg, mg) = Self::point_grids(p, m);
        self.execute_grid_memo(&self.memo_point, net, &pg, &mg, s_grid)
    }

    /// Two-point grids around a single query (the padder needs at least
    /// two strictly increasing entries to continue a step).
    fn point_grids(p: usize, m: u64) -> (Vec<usize>, Vec<u64>) {
        (vec![p, p + 1], vec![m, m.saturating_add(1)])
    }

    /// Execute the *extended* artifact over the given grids (padding to
    /// its baked shapes), memoizing the last execution in `memo_slot`.
    fn execute_ext_memo(
        &self,
        memo_slot: &Mutex<Option<ExtGridMemo>>,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
    ) -> Result<ExtOutput> {
        let ext = self
            .ext
            .as_ref()
            .ok_or_else(|| anyhow!("extended artifact is not loaded"))?;
        {
            let memo = memo_slot.lock().unwrap();
            if let Some(m) = &*memo {
                if m.net == *net && m.p_grid == p_grid && m.m_grid == m_grid {
                    return Ok(m.out.clone());
                }
            }
        }
        let meta = &ext.meta;
        if p_grid.len() > meta.p_grid_len || m_grid.len() > meta.m_grid_len {
            bail!(
                "grid larger than ext artifact shape ({} x {} vs {} x {})",
                p_grid.len(),
                m_grid.len(),
                meta.p_grid_len,
                meta.m_grid_len
            );
        }
        let sizes: Vec<f32> = net.table.sizes().iter().map(|&x| x as f32).collect();
        let gaps: Vec<f32> = net.table.gaps().iter().map(|&x| x as f32).collect();
        if sizes.len() != meta.table_len {
            bail!(
                "gap table has {} samples but the ext artifact expects {}",
                sizes.len(),
                meta.table_len
            );
        }
        let pf = pad_grid_f32(p_grid.iter().map(|&p| p as f32).collect(), meta.p_grid_len);
        let mf = pad_grid_f32(m_grid.iter().map(|&m| m as f32).collect(), meta.m_grid_len);
        let out = ext.execute(&sizes, &gaps, net.l as f32, &pf, &mf)?;
        *memo_slot.lock().unwrap() = Some(ExtGridMemo {
            net: net.clone(),
            p_grid: p_grid.to_vec(),
            m_grid: m_grid.to_vec(),
            out: out.clone(),
        });
        Ok(out)
    }

    /// One single-cell extended execution (ext point-query memo slot).
    fn execute_ext_point(&self, net: &PLogP, p: usize, m: u64) -> Result<ExtOutput> {
        let (pg, mg) = Self::point_grids(p, m);
        self.execute_ext_memo(&self.ext_memo_point, net, &pg, &mg)
    }

    /// Row of `strategy` in the extended artifact's times tensor.
    fn ext_row(strategy: Strategy) -> usize {
        debug_assert!(strategy.is_ext());
        strategy.index() - Strategy::EXT_BASE
    }
}

impl Evaluator for ArtifactEval {
    fn name(&self) -> &'static str {
        "artifact"
    }

    fn batched(&self) -> bool {
        true
    }

    /// Single-point query through the compiled kernel. For segmented
    /// strategies the returned time is the kernel's best-over-segments
    /// (an explicit `seg` cannot be forced through the baked graph). A
    /// failed execution falls back to the native model with a warning.
    fn predict(
        &self,
        op: Op,
        strategy: Strategy,
        p: usize,
        m: u64,
        _seg: Option<u64>,
        net: &PLogP,
    ) -> f64 {
        if strategy.is_ext() {
            // the extended artifact (or the native ext models when it is
            // absent — same formulas, so silently equivalent)
            return match &self.ext {
                Some(_) => match self.execute_ext_point(net, p, m) {
                    Ok(out) => out.time(Self::ext_row(strategy), 0, 0) as f64,
                    Err(e) => {
                        log::warn!("ext artifact predict failed ({e:#}); using native model");
                        ModelEval::new().predict(op, strategy, p, m, None, net)
                    }
                },
                None => ModelEval::new().predict(op, strategy, p, m, None, net),
            };
        }
        let s_grid = crate::tuner::grids::default_s_grid();
        match self.execute_point(net, p, m, &s_grid) {
            Ok(out) => out.time(strategy.index(), 0, 0) as f64,
            Err(e) => {
                log::warn!("artifact predict failed ({e:#}); using native model");
                // keep the artifact's documented semantics in the
                // fallback too: segmented strategies report their
                // best-over-segment-grid time, never an explicit seg
                if strategy.is_segmented() {
                    ModelEval::new().tune_segment(strategy, net, p, m, &s_grid).0
                } else {
                    ModelEval::new().predict(op, strategy, p, m, None, net)
                }
            }
        }
    }

    /// The kernel's segment search is baked into the compiled graph, so
    /// the default predict-per-candidate loop cannot work here (predict
    /// ignores the explicit segment). Read the tuned segment and its
    /// time straight off the output tensors instead.
    fn tune_segment(
        &self,
        strategy: Strategy,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> (f64, u64) {
        match self.execute_point(net, p, m, s_grid) {
            Ok(out) => {
                let t = out.time(strategy.index(), 0, 0) as f64;
                let sg = out.seg(strategy.index(), 0, 0);
                let seg = if sg > 0.0 { sg as u64 } else { m };
                (t, seg.clamp(1, m))
            }
            Err(e) => {
                log::warn!("artifact tune_segment failed ({e:#}); using native model");
                ModelEval::new().tune_segment(strategy, net, p, m, s_grid)
            }
        }
    }

    /// Cell ranking read straight off the artifact's times/segments
    /// tensors (falling back to the native models on execution failure).
    fn rank(
        &self,
        family: &[Strategy],
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> Vec<(Strategy, f64, Option<u64>)> {
        if family.iter().all(|s| s.is_ext()) {
            if self.ext.is_none() {
                return ModelEval::new().rank(family, net, p, m, s_grid);
            }
            return match self.execute_ext_point(net, p, m) {
                Ok(out) => {
                    let mut ranked: Vec<(Strategy, f64, Option<u64>)> = family
                        .iter()
                        .map(|&s| (s, out.time(Self::ext_row(s), 0, 0) as f64, None))
                        .collect();
                    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                    ranked
                }
                Err(e) => {
                    log::warn!("ext artifact rank failed ({e:#}); using native models");
                    ModelEval::new().rank(family, net, p, m, s_grid)
                }
            };
        }
        let out = match self.execute_point(net, p, m, s_grid) {
            Ok(out) => out,
            Err(e) => {
                log::warn!("artifact rank failed ({e:#}); using native models");
                return ModelEval::new().rank(family, net, p, m, s_grid);
            }
        };
        let mut ranked: Vec<(Strategy, f64, Option<u64>)> = family
            .iter()
            .map(|&s| {
                let t = out.time(s.index(), 0, 0) as f64;
                let sg = out.seg(s.index(), 0, 0);
                let segment = if s.is_segmented() && sg > 0.0 { Some(sg as u64) } else { None };
                (s, t, segment)
            })
            .collect();
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        ranked
    }

    /// The batched fast path: one device execution covers the whole
    /// grid; winners and tuned segments are read off the output tensors.
    /// Extended ops run through the ext artifact (one execution serves
    /// all four ext ops of a tune); without it — and for Reduce, whose
    /// single-strategy family has no artifact row — they sweep the
    /// native models instead.
    fn predict_grid(
        &self,
        op: Op,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
        s_grid: &[u64],
    ) -> Result<Vec<Decision>> {
        if op.is_ext() {
            let row = op.ext_artifact_row();
            if self.ext.is_none() || row.is_none() {
                return ModelEval::new().predict_grid(op, net, p_grid, m_grid, s_grid);
            }
            let row = row.unwrap();
            let out = self.execute_ext_memo(&self.ext_memo_grid, net, p_grid, m_grid)?;
            let mut entries = Vec::with_capacity(p_grid.len() * m_grid.len());
            for qi in 0..p_grid.len() {
                for mi in 0..m_grid.len() {
                    let widx = out.winner(row, qi, mi);
                    let strategy = Strategy::from_index(Strategy::EXT_BASE + widx)
                        .filter(|s| op.family().contains(s))
                        .with_context(|| {
                            format!("ext winner index {widx} invalid for {}", op.name())
                        })?;
                    entries.push(Decision {
                        strategy,
                        segment: None,
                        predicted: out.time(widx, qi, mi) as f64,
                    });
                }
            }
            return Ok(entries);
        }
        let out = self.execute_grid_memo(&self.memo_grid, net, p_grid, m_grid, s_grid)?;
        let mut entries = Vec::with_capacity(p_grid.len() * m_grid.len());
        for qi in 0..p_grid.len() {
            for mi in 0..m_grid.len() {
                let widx = match op {
                    Op::Bcast => out.bcast_win(qi, mi),
                    Op::Scatter => out.scatter_win(qi, mi),
                    _ => unreachable!("extended ops returned above"),
                };
                let strategy = Strategy::from_index(widx)
                    .with_context(|| format!("artifact winner index {widx} out of range"))?;
                let sg = out.seg(widx, qi, mi);
                let segment = if strategy.is_segmented() && sg > 0.0 {
                    Some(sg as u64)
                } else {
                    None
                };
                entries.push(Decision {
                    strategy,
                    segment,
                    predicted: out.time(widx, qi, mi) as f64,
                });
            }
        }
        Ok(entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = match ArtifactEval::load(Path::new("/nonexistent")) {
            Err(e) => e,
            Ok(_) => panic!("load of missing artifact succeeded"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn point_grids_are_strictly_increasing() {
        let (pg, mg) = ArtifactEval::point_grids(24, 65536);
        assert!(pg.windows(2).all(|w| w[0] < w[1]));
        assert!(mg.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn ext_rows_match_strategy_layout() {
        for (w, s) in Strategy::EXT.iter().enumerate() {
            assert_eq!(ArtifactEval::ext_row(*s), w);
        }
    }
}
