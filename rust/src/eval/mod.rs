//! The evaluation layer: one trait, four ways to score a collective.
//!
//! The paper's entire premise is that a `(strategy, P, m, segment)`
//! point can be scored several interchangeable ways:
//!
//! * **analytically** — the closed-form pLogP cost models of Tables 1
//!   and 2 ([`ModelEval`], wrapping the strategy-indexed registry in
//!   [`crate::models`]); this is the "fast" in *Fast Tuning*;
//! * **empirically** — build the schedule and run it on the simulated
//!   cluster ([`SimEval`], wrapping [`crate::mpi::World`] over
//!   [`crate::netsim::Netsim`]); this is the exhaustive benchmarking
//!   the paper replaces, kept as ground truth for validation. With a
//!   [`TraceRecorder`] attached it doubles as the capture path: every
//!   run's message trace is persisted in the versioned format of
//!   [`crate::netsim::TraceSet`];
//! * **by replaying captured traces** — [`ReplayEval`] scores from a
//!   recorded [`crate::netsim::TraceSet`]: exact lookups for captured
//!   cells, gap-model interpolation between captured sizes, `+inf` plus
//!   a counted miss ([`ReplayStats`]) for everything unobserved — the
//!   fixed-workload regression backend the golden-trace CI suite runs;
//! * **via the AOT artifact** — one PJRT execution of the compiled XLA
//!   kernel evaluates the whole decision tensor at once
//!   ([`ArtifactEval`], wrapping [`crate::runtime::TunerArtifact`]).
//!
//! Everything above this layer — the tuner's grid sweep, the
//! model-vs-simulation cross-check in [`crate::tuner::validate`], the
//! coordinator's cold-miss tuning — talks to the [`Evaluator`] trait
//! only, so new backends (a real-MPI runner emitting the same trace
//! format) drop in without touching the tuner. The trait is
//! `Send + Sync`: the tuner's parallel sweep shares one evaluator
//! across its worker threads.
//!
//! The trait covers *every* collective family, not just the paper's
//! broadcast and scatter: the extended ops (gather / reduce / barrier /
//! allgather / allreduce) score through the same three backends — the
//! unified [`crate::models::COST_MODELS`] registry, schedule-building
//! simulation, and the second AOT artifact (`tuner_ext.hlo.txt`).
//!
//! The sweep hot path is instrumented and pruned: the engine threads a
//! [`CellCtx`] (warm-start hint + per-tune [`crate::plogp::GapCache`] +
//! shared [`EvalStats`] counters) through [`Evaluator::best_in`], and
//! [`ModelEval`] uses the m-aware [`crate::models::LOWER_BOUNDS`] to
//! skip strategies and whole segment-grid searches that provably cannot
//! win — while producing tables byte-identical to the exhaustive
//! argmin (`rust/tests/evaluator.rs`).

mod artifact;
mod model;
mod replay;
mod sim;
mod stats;

pub use artifact::ArtifactEval;
pub use model::ModelEval;
pub use replay::{ReplayEval, ReplayStats};
pub use sim::{SimEval, TraceRecorder, DEFAULT_TRACE_CAPACITY};
pub use stats::{exhaustive_invocations, exhaustive_invocations_per_cell, EvalCounts, EvalStats};

use anyhow::Result;

use crate::collectives::Strategy;
use crate::plogp::{GapCache, PLogP};
use crate::tuner::decision::{Decision, Op};

/// Optional per-cell sweep context the tuning engine threads through
/// [`Evaluator::best_in`]: a warm-start hint (the winning strategy of
/// an adjacent cell — adjacent `(P, m)` cells almost always share an
/// argmin, so scoring the hint first makes the pruning threshold tight
/// before the family scan begins), the per-tune [`GapCache`], and the
/// shared [`EvalStats`] counters. Everything is optional —
/// `CellCtx::default()` makes [`Evaluator::best_in`] equivalent to
/// [`Evaluator::best`] — and none of it may change the result: backends
/// use the context only to *order and prune* the search, never to alter
/// the argmin (exactness is asserted in `rust/tests/evaluator.rs`).
#[derive(Clone, Copy, Default)]
pub struct CellCtx<'a> {
    /// An adjacent cell's winning strategy, scored first when it
    /// belongs to the op family being tuned.
    pub hint: Option<Strategy>,
    /// Pre-interpolated gaps + bound statistics for this tune's grids.
    pub cache: Option<&'a GapCache>,
    /// Shared sweep counters (one flush per cell).
    pub stats: Option<&'a EvalStats>,
}

/// A way to score collective-communication strategies on one network.
///
/// Implementations must be cheap to share across threads (`&self`
/// methods only); the tuner's parallel sweep calls [`Evaluator::best`]
/// concurrently from its worker pool.
pub trait Evaluator: Send + Sync {
    /// Short backend name for logs and CLI output.
    fn name(&self) -> &'static str;

    /// Predicted (or measured) completion time, in seconds, of one
    /// explicit `(strategy, p, m, segment)` point. `net` carries the
    /// measured pLogP parameters; backends that re-measure instead of
    /// predicting (the simulator) may ignore it.
    fn predict(
        &self,
        op: Op,
        strategy: Strategy,
        p: usize,
        m: u64,
        seg: Option<u64>,
        net: &PLogP,
    ) -> f64;

    /// Whether [`Evaluator::predict_grid`] evaluates the whole grid in
    /// one backend call (the AOT artifact does); the tuner then hands it
    /// the full grid instead of sweeping cells across threads.
    fn batched(&self) -> bool {
        false
    }

    /// Search the segment grid (plus `m` itself, the unsegmented
    /// degenerate) for the best segment of one segmented strategy.
    /// Returns `(best_time, best_segment)`.
    fn tune_segment(
        &self,
        strategy: Strategy,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> (f64, u64) {
        let op = Op::of(strategy);
        let mut best = (self.predict(op, strategy, p, m, Some(m), net), m);
        for &s in s_grid {
            let s = s.clamp(1, m);
            let t = self.predict(op, strategy, p, m, Some(s), net);
            if t < best.0 {
                best = (t, s);
            }
        }
        best
    }

    /// Score every strategy of `family` at one grid cell and return
    /// `(strategy, time, segment)` sorted ascending by time (stable, so
    /// exact ties keep family order). Segmented entries carry their
    /// tuned segment.
    fn rank(
        &self,
        family: &[Strategy],
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
    ) -> Vec<(Strategy, f64, Option<u64>)> {
        let mut out: Vec<(Strategy, f64, Option<u64>)> = family
            .iter()
            .map(|&s| {
                if s.is_segmented() {
                    let (t, seg) = self.tune_segment(s, net, p, m, s_grid);
                    (s, t, Some(seg))
                } else {
                    (s, self.predict(Op::of(s), s, p, m, None, net), None)
                }
            })
            .collect();
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        out
    }

    /// The argmin decision at one grid cell (equal to `rank(..)[0]`;
    /// backends may override with a pruned search as long as exact ties
    /// still resolve to the earliest strategy in family order).
    fn best(&self, op: Op, net: &PLogP, p: usize, m: u64, s_grid: &[u64]) -> Decision {
        let ranked = self.rank(op.family(), net, p, m, s_grid);
        let (strategy, predicted, segment) = ranked[0];
        Decision { strategy, segment, predicted }
    }

    /// [`Evaluator::best`] with sweep context: the engine's per-cell
    /// entry point. The context is advisory — the returned decision
    /// must be identical to [`Evaluator::best`] for every hint and
    /// cache state. The default ignores it; [`ModelEval`] overrides
    /// with the warm-started, bound-pruned, gap-cached search.
    fn best_in(
        &self,
        op: Op,
        net: &PLogP,
        p: usize,
        m: u64,
        s_grid: &[u64],
        ctx: &CellCtx<'_>,
    ) -> Decision {
        let _ = ctx;
        self.best(op, net, p, m, s_grid)
    }

    /// Batched whole-grid evaluation: the best [`Decision`] for every
    /// `(p, m)` cell, row-major `[p_grid.len() × m_grid.len()]`. The
    /// default sweeps cells through [`Evaluator::best`]; batched
    /// backends override this with one backend execution, and
    /// [`ModelEval`] overrides it with a gap-cached, warm-started sweep
    /// that reuses each m-row's range statistics across cells (same
    /// bytes out, far fewer interpolations).
    fn predict_grid(
        &self,
        op: Op,
        net: &PLogP,
        p_grid: &[usize],
        m_grid: &[u64],
        s_grid: &[u64],
    ) -> Result<Vec<Decision>> {
        let mut out = Vec::with_capacity(p_grid.len() * m_grid.len());
        for &p in p_grid {
            for &m in m_grid {
                out.push(self.best(op, net, p, m, s_grid));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, Netsim};
    use crate::plogp;

    fn measured() -> PLogP {
        let mut sim = Netsim::new(2, NetConfig::fast_ethernet_ideal());
        plogp::bench::measure(&mut sim)
    }

    #[test]
    fn evaluators_are_send_and_sync() {
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<ModelEval>();
        assert_ss::<SimEval>();
        assert_ss::<ArtifactEval>();
        assert_ss::<ReplayEval>();
        assert_ss::<Box<dyn Evaluator>>();
    }

    #[test]
    fn trait_objects_score_points() {
        let net = measured();
        let evals: Vec<Box<dyn Evaluator>> = vec![
            Box::new(ModelEval::new()),
            Box::new(SimEval::new(NetConfig::fast_ethernet_ideal())),
        ];
        for e in &evals {
            let t = e.predict(Op::Bcast, Strategy::BcastBinomial, 8, 4096, None, &net);
            assert!(t > 0.0 && t.is_finite(), "{}: {t}", e.name());
            let d = e.best(Op::Scatter, &net, 8, 4096, &[512, 1024]);
            assert!(d.strategy.is_scatter());
            assert!(d.predicted > 0.0);
        }
    }

    #[test]
    fn default_rank_is_sorted_and_complete() {
        let net = measured();
        let ranked = ModelEval::new().rank(&Strategy::BCAST, &net, 8, 65536, &[1024, 8192]);
        assert_eq!(ranked.len(), 10);
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        for (s, _, seg) in &ranked {
            assert_eq!(seg.is_some(), s.is_segmented());
        }
    }

    #[test]
    fn best_matches_rank_head_for_every_family() {
        let net = measured();
        let s_grid = [256u64, 4096, 65536];
        for op in Op::ALL {
            for p in [2usize, 8, 24] {
                for m in [64u64, 8192, 1 << 20] {
                    let d = ModelEval::new().best(op, &net, p, m, &s_grid);
                    let ranked = ModelEval::new().rank(op.family(), &net, p, m, &s_grid);
                    assert_eq!(d.strategy, ranked[0].0, "{op:?} P={p} m={m}");
                    assert_eq!(d.predicted, ranked[0].1);
                    assert_eq!(d.segment, ranked[0].2);
                }
            }
        }
    }

    #[test]
    fn ext_ops_score_through_the_trait() {
        let net = measured();
        let evals: Vec<Box<dyn Evaluator>> = vec![
            Box::new(ModelEval::new()),
            Box::new(SimEval::new(NetConfig::fast_ethernet_ideal())),
        ];
        for e in &evals {
            for op in Op::EXT {
                let d = e.best(op, &net, 8, 4096, &[]);
                assert!(op.family().contains(&d.strategy), "{}: {d:?}", e.name());
                assert!(d.segment.is_none(), "ext strategies never segment");
                assert!(d.predicted > 0.0 && d.predicted.is_finite(), "{}", e.name());
            }
        }
    }
}
