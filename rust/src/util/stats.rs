//! Summary statistics for the bench harness (criterion substitute).

/// Summary of a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Relative error |a - b| / max(|b|, eps).
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-30)
}

/// Geometric mean; panics if any sample is non-positive.
pub fn geo_mean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let s: f64 = samples
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geo_mean needs positive samples, got {x}");
            x.ln()
        })
        .sum();
    (s / samples.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[3.0; 10]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.min, 3.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn rel_err_zero_for_equal() {
        assert_eq!(rel_err(5.0, 5.0), 0.0);
        assert!((rel_err(1.1, 1.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn geo_mean_of_powers() {
        assert!((geo_mean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        Summary::of(&[]);
    }
}
