//! A miniature property-based testing harness (proptest is unavailable
//! offline). Properties are closures over a [`Prng`]; on failure the
//! harness reports the failing case number and the seed that reproduces it.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't get the xla rpath link flag)
//! use collective_tuner::util::check::property;
//! property("addition commutes", 100, |rng| {
//!     let (a, b) = (rng.range(0, 1000) as i64, rng.range(0, 1000) as i64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::Prng;

/// Base seed; override with `CHECK_SEED=<u64>` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00_DEAD_0001)
}

/// Run `cases` random cases of `prop`. Each case gets an independent PRNG
/// derived from the base seed; panics are caught, annotated with the
/// reproduction seed, and re-raised.
pub fn property<F: Fn(&mut Prng) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Prng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with CHECK_SEED={base} or seed {seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::sync::atomic::AtomicU64::new(0);
        property("count", 17, |_| {
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(*count.get_mut(), 17);
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            property("always-fails", 5, |_| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("CHECK_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_see_different_randomness() {
        use std::sync::Mutex;
        let seen = Mutex::new(Vec::new());
        property("collect", 8, |rng| {
            seen.lock().unwrap().push(rng.next_u64());
        });
        let v = seen.into_inner().unwrap();
        let mut uniq = v.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), v.len());
    }
}
