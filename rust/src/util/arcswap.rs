//! A hand-rolled atomic `Arc` swap — the publication primitive behind
//! the coordinator's lock-free read path (vendored-deps-only, so we
//! cannot reach for the `arc-swap` crate).
//!
//! [`ArcSwap<T>`] holds an `Arc<T>` that readers borrow through a
//! single atomic index load and writers replace wholesale. It is a
//! two-slot *left-right* scheme:
//!
//! * two value slots, one **active** (named by an atomic index) and one
//!   spare;
//! * readers load the active index, announce themselves on that slot's
//!   reader counter, then re-load the index to verify it did not move
//!   underneath them — on the (rare) race with a concurrent publish
//!   they retract and retry;
//! * a writer (serialized by an internal mutex) installs the new value
//!   into the *inactive* slot — after waiting for that slot's reader
//!   count to drain to zero — and then flips the active index.
//!
//! ## Guarantees
//!
//! * **No reader locks.** [`ArcSwap::load`] is two atomic loads and one
//!   atomic increment on the fast path; it never touches a mutex, never
//!   allocates, and never blocks on a writer (it can *retry* around a
//!   concurrent flip, which the coordinator counts as
//!   `coordinator.snapshot_read_retries`). Reads are lock-free, not
//!   wait-free.
//! * **Torn reads are impossible.** A verified guard pins a slot whose
//!   value was fully written before the flip that made it active, and a
//!   writer never touches a slot while its reader count is non-zero:
//!   every read observes exactly one published `Arc<T>`, old or new.
//! * **Publication ordering.** The index flip is the release-store that
//!   publishes the new value; the reader's verified index load is the
//!   matching acquire. (The implementation uses `SeqCst` throughout —
//!   a strict superset of the acquire/release protocol — to keep the
//!   invariants easy to audit and sanitizer-friendly.)
//!
//! ## Hazards (for callers)
//!
//! * A [`Guard`] pins its slot: a thread that calls [`ArcSwap::store`]
//!   twice while holding one deadlocks itself (the second store drains
//!   the slot the guard pins). Keep guards short; never publish while
//!   holding one.
//! * The value published two stores ago is dropped inside the third
//!   [`ArcSwap::store`]; a retired `Arc<T>` therefore survives one
//!   extra publish cycle.

use std::cell::UnsafeCell;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

struct Slot<T> {
    readers: AtomicUsize,
    value: UnsafeCell<Arc<T>>,
}

impl<T> Slot<T> {
    fn new(value: Arc<T>) -> Slot<T> {
        Slot { readers: AtomicUsize::new(0), value: UnsafeCell::new(value) }
    }
}

/// An atomically swappable `Arc<T>`. See the module docs for the
/// protocol and its guarantees.
pub struct ArcSwap<T> {
    /// Index (0 or 1) of the slot readers should pin.
    active: AtomicUsize,
    slots: [Slot<T>; 2],
    /// Serializes writers; readers never touch it.
    writer: Mutex<()>,
    /// Reads that had to retry around a concurrent flip (diagnostic).
    retries: AtomicU64,
    /// Optional obs counter name bumped on each retry (obs-gated).
    retry_metric: Option<&'static str>,
}

// SAFETY: the UnsafeCell is only written inside `store` while holding
// the writer mutex *and* after the slot's reader count drained to
// zero, so `&Arc<T>` borrows handed to readers never alias a write.
// Sharing therefore only requires the usual `Arc` bounds on `T`.
unsafe impl<T: Send + Sync> Send for ArcSwap<T> {}
unsafe impl<T: Send + Sync> Sync for ArcSwap<T> {}

/// A pinned borrow of the currently published value. Dereferences to
/// `T`; dropping it releases the pin. Do not hold one across
/// [`ArcSwap::store`] (see the module hazards).
pub struct Guard<'a, T> {
    slot: &'a Slot<T>,
    arc: &'a Arc<T>,
}

impl<T> Deref for Guard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.arc.as_ref()
    }
}

impl<T> Guard<'_, T> {
    /// Clone the pinned `Arc` (to outlive the guard).
    pub fn cloned(&self) -> Arc<T> {
        Arc::clone(self.arc)
    }
}

impl<T> Drop for Guard<'_, T> {
    fn drop(&mut self) {
        self.slot.readers.fetch_sub(1, Ordering::SeqCst);
    }
}

impl<T> ArcSwap<T> {
    pub fn new(initial: Arc<T>) -> ArcSwap<T> {
        ArcSwap {
            active: AtomicUsize::new(0),
            slots: [Slot::new(Arc::clone(&initial)), Slot::new(initial)],
            writer: Mutex::new(()),
            retries: AtomicU64::new(0),
            retry_metric: None,
        }
    }

    /// Count read retries into the named obs counter as well as the
    /// local [`ArcSwap::read_retries`] total (builder-style).
    pub fn with_retry_metric(mut self, name: &'static str) -> ArcSwap<T> {
        self.retry_metric = Some(name);
        self
    }

    /// Pin and borrow the currently published value. Lock-free: two
    /// atomic loads and one increment when no publish races, a bounded
    /// retry loop when one does.
    pub fn load(&self) -> Guard<'_, T> {
        loop {
            let i = self.active.load(Ordering::SeqCst);
            let slot = &self.slots[i];
            slot.readers.fetch_add(1, Ordering::SeqCst);
            if self.active.load(Ordering::SeqCst) == i {
                // Verified: either the slot's value was complete before
                // the flip that activated it, or our count now blocks
                // any writer from touching it. Safe to borrow.
                let arc = unsafe { &*slot.value.get() };
                return Guard { slot, arc };
            }
            // A publish moved the active index between our two loads;
            // retract the announcement and retry on the new slot.
            slot.readers.fetch_sub(1, Ordering::SeqCst);
            self.retries.fetch_add(1, Ordering::Relaxed);
            if let Some(name) = self.retry_metric {
                if crate::obs::enabled() {
                    crate::obs::registry().counter(name).inc();
                }
            }
        }
    }

    /// Clone the currently published `Arc` (pin released on return).
    pub fn load_full(&self) -> Arc<T> {
        self.load().cloned()
    }

    /// Publish a new value: install into the inactive slot once its
    /// readers drain, then flip the active index. Never blocks readers;
    /// blocks (briefly) on stragglers still pinning the *previous*
    /// publish's retired slot, and on other writers.
    pub fn store(&self, new: Arc<T>) {
        let _w = self.writer.lock().unwrap();
        let inactive = 1 - self.active.load(Ordering::SeqCst);
        let slot = &self.slots[inactive];
        let mut spins = 0u32;
        while slot.readers.load(Ordering::SeqCst) != 0 {
            spins += 1;
            if spins > 64 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        // SAFETY: writer mutex held and the slot's reader count is
        // zero; late readers that increment it now will fail the index
        // verification (active still names the other slot) and retract.
        // This drops the Arc published two stores ago.
        unsafe {
            *slot.value.get() = new;
        }
        self.active.store(inactive, Ordering::SeqCst);
    }

    /// Total reads that retried around a concurrent publish.
    pub fn read_retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn store_then_load_returns_latest() {
        let s = ArcSwap::new(Arc::new(1u64));
        assert_eq!(*s.load(), 1);
        s.store(Arc::new(2));
        assert_eq!(*s.load(), 2);
        assert_eq!(*s.load_full(), 2);
        s.store(Arc::new(3));
        s.store(Arc::new(4));
        assert_eq!(*s.load(), 4);
        assert_eq!(s.read_retries(), 0, "no contention, no retries");
    }

    #[test]
    fn guards_pin_their_value_across_a_publish() {
        let s = ArcSwap::new(Arc::new(10u64));
        let g1 = s.load();
        s.store(Arc::new(20));
        let g2 = s.load();
        // the old guard still reads the value it pinned; the new one
        // reads the fresh publish — both alive at once
        assert_eq!(*g1, 10);
        assert_eq!(*g2, 20);
        drop(g1);
        drop(g2);
        s.store(Arc::new(30));
        assert_eq!(*s.load(), 30);
    }

    #[test]
    fn concurrent_readers_never_see_torn_pairs() {
        // Publish (k, 7k) pairs from one writer while readers verify
        // the invariant on every load: any torn mix of two publishes
        // breaks it. cfg(stress) raises the iteration count in CI's
        // concurrency step.
        let writes: u64 = if cfg!(stress) { 200_000 } else { 20_000 };
        let s = ArcSwap::new(Arc::new((0u64, 0u64)));
        let done = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let (s, done) = (&s, &done);
            scope.spawn(move || {
                for k in 1..=writes {
                    s.store(Arc::new((k, k * 7)));
                }
                done.store(true, Ordering::SeqCst);
            });
            for _ in 0..4 {
                scope.spawn(move || {
                    let mut last = 0u64;
                    while !done.load(Ordering::SeqCst) {
                        let g = s.load();
                        let (a, b) = *g;
                        assert_eq!(b, a * 7, "torn read: ({a}, {b})");
                        assert!(a >= last, "went backwards: {a} after {last}");
                        last = a;
                    }
                });
            }
        });
        assert_eq!(*s.load(), (writes, writes * 7));
    }
}
