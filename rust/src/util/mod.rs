//! Small self-contained utilities standing in for crates that are not
//! available in this offline build (rand, serde_json, proptest, prettytable).

pub mod arcswap;
pub mod benchkit;
pub mod check;
pub mod json;
pub mod prng;
pub mod stats;
pub mod table;
