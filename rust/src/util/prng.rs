//! SplitMix64 PRNG: tiny, fast, and good enough for test-case generation
//! and workload synthesis. Deterministic across platforms.

/// SplitMix64 generator (Steele, Lea, Flood 2014). Not cryptographic.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Log-uniform f64 in [lo, hi); lo must be > 0.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Uniform integer in [lo, hi) (half-open). Panics if lo >= hi.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        // Rejection-free modulo; bias is negligible for our span sizes.
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a statistically independent child stream.
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut p = Prng::new(9);
        for _ in 0..1000 {
            let x = p.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut p = Prng::new(3);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[p.range(0, 8) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn uniform_mean_is_plausible() {
        let mut p = Prng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.uniform(0.0, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn log_uniform_in_bounds() {
        let mut p = Prng::new(13);
        for _ in 0..1000 {
            let x = p.log_uniform(1.0, 1e6);
            assert!((1.0..1e6).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut p = Prng::new(17);
        let mut a = p.fork();
        let mut b = p.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
