//! ASCII tables and CSV emission for experiment reports.

use std::fmt::Write as _;

/// A simple column-aligned ASCII table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_ascii(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for wi in &w {
                let _ = write!(out, "+{}", "-".repeat(wi + 2));
            }
            out.push_str("+\n");
        };
        sep(&mut out);
        for (i, h) in self.header.iter().enumerate() {
            let _ = write!(out, "| {:<width$} ", h, width = w[i]);
        }
        out.push_str("|\n");
        sep(&mut out);
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                let _ = write!(out, "| {:<width$} ", c, width = w[i]);
            }
            out.push_str("|\n");
        }
        sep(&mut out);
        out
    }

    /// Tab-separated rendering: header line then one line per row.
    /// Cells must not contain tabs or newlines (they are replaced with
    /// spaces — TSV has no quoting); used by the netsim trace format,
    /// which is numeric throughout.
    pub fn to_tsv(&self) -> String {
        let esc = |s: &str| s.replace(['\t', '\n'], " ");
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join("\t"));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join("\t"));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds human-readably (ns/us/ms/s).
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if a < 1e-3 {
        format!("{:.2} us", secs * 1e6)
    } else if a < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Format a byte count (B/kB/MB).
pub fn fmt_bytes(bytes: f64) -> String {
    if bytes < 1024.0 {
        format!("{bytes:.0} B")
    } else if bytes < 1024.0 * 1024.0 {
        format!("{:.1} kB", bytes / 1024.0)
    } else {
        format!("{:.2} MB", bytes / (1024.0 * 1024.0))
    }
}

/// A crude ASCII line plot: one char column per x sample, `series` of
/// (label, ys). Used to render the figures in terminal reports.
pub fn ascii_plot(title: &str, xs: &[f64], series: &[(&str, Vec<f64>)], height: usize) -> String {
    assert!(!xs.is_empty() && !series.is_empty());
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::MIN, f64::max);
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::MAX, f64::min);
    let span = (ymax - ymin).max(1e-30);
    let mut grid = vec![vec![' '; xs.len()]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (xi, &y) in ys.iter().enumerate() {
            let row = ((ymax - y) / span * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][xi] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}\n");
    let _ = writeln!(out, "  y: [{:.3e} .. {:.3e}]", ymin, ymax);
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    let _ = writeln!(out, "  +{}", "-".repeat(xs.len()));
    let _ = writeln!(out, "  x: [{:.3e} .. {:.3e}]", xs[0], xs[xs.len() - 1]);
    for (si, (label, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "  {} = {}", marks[si % marks.len()], label);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(vec!["a", "long-header"]);
        t.row(vec!["x", "1"]);
        t.row(vec!["yyyy", "2"]);
        let s = t.to_ascii();
        assert!(s.contains("| a    "));
        assert!(s.contains("| long-header |"));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        Table::new(vec!["a"]).row(vec!["1", "2"]);
    }

    #[test]
    fn tsv_renders_header_and_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "x\ty"]);
        let tsv = t.to_tsv();
        assert_eq!(tsv, "a\tb\n1\tx y\n");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn time_formats() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert!(fmt_time(0.0025).contains("ms"));
        assert!(fmt_time(2.5e-6).contains("us"));
        assert!(fmt_time(2.5e-9).contains("ns"));
    }

    #[test]
    fn byte_formats() {
        assert_eq!(fmt_bytes(100.0), "100 B");
        assert!(fmt_bytes(2048.0).contains("kB"));
        assert!(fmt_bytes(3.0 * 1024.0 * 1024.0).contains("MB"));
    }

    #[test]
    fn plot_renders_all_series() {
        let xs = [1.0, 2.0, 3.0];
        let s = ascii_plot(
            "t",
            &xs,
            &[("up", vec![1.0, 2.0, 3.0]), ("down", vec![3.0, 2.0, 1.0])],
            5,
        );
        assert!(s.contains('*'));
        assert!(s.contains('o'));
        assert!(s.contains("up"));
    }
}
