//! Criterion-style micro-benchmark harness (criterion is unavailable in
//! this offline build). Used by the `rust/benches/*` targets
//! (`harness = false`).
//!
//! Protocol: warm up, then run timed iterations until both a minimum
//! iteration count and a minimum wall-time are reached; report mean,
//! median, p95 and throughput.

use std::time::Instant;

use super::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary (seconds).
    pub summary: Summary,
    /// Iterations executed.
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>12}/iter  (median {:>12}, p95 {:>12}, n={})",
            self.name,
            super::table::fmt_time(s.mean),
            super::table::fmt_time(s.p50),
            super::table::fmt_time(s.p95),
            self.iters
        )
    }
}

/// Benchmark options.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_seconds: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 3, min_iters: 10, max_iters: 10_000, min_seconds: 0.5 }
    }
}

/// Time `f` under the default protocol and print the report line.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_with(name, &BenchOpts::default(), f)
}

/// Time `f` with explicit options and print the report line.
pub fn bench_with<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < opts.min_iters
        || (start.elapsed().as_secs_f64() < opts.min_seconds
            && samples.len() < opts.max_iters)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples),
        iters: samples.len(),
    };
    println!("{}", result.report());
    result
}

/// Print a section header for a bench binary.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut count = 0usize;
        let opts = BenchOpts {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 5,
            min_seconds: 0.0,
        };
        let r = bench_with("t", &opts, || count += 1);
        assert_eq!(r.iters, 5);
        assert_eq!(count, 6); // 1 warmup + 5 timed
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn report_contains_name() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 2,
            min_seconds: 0.0,
        };
        let r = bench_with("my-bench", &opts, || {});
        assert!(r.report().contains("my-bench"));
    }
}
