//! Minimal JSON reader *and writer* — the reader is just enough to
//! parse the artifact metadata sidecar (`artifacts/tuner.meta.json`)
//! written by `python/compile/aot.py`; the writer ([`Json`]'s
//! [`fmt::Display`] impl) is the shared serializer behind every JSON
//! blob the crate emits (`Coordinator::stats_json`, the `obs` registry
//! snapshot, `EvalCounts::to_json`), so a renamed field can no longer
//! silently produce malformed output the way hand-rolled `format!`
//! strings could. serde_json is not available in this offline build.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed (or built) JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from `(key, value)` pairs. Keys are emitted in
    /// sorted order (the `BTreeMap` invariant) — stable output for
    /// golden tests and diffs.
    pub fn obj<K: Into<String>>(pairs: Vec<(K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

/// Escape a string body per RFC 8259 (quotes are the caller's job).
fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    Ok(())
}

/// Compact (single-line) JSON serialization. Numbers use Rust's
/// shortest-roundtrip float formatting (`1500.0` prints as `1500`);
/// non-finite numbers — which JSON cannot represent — serialize as
/// `null` rather than producing an unparseable document.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) if !x.is_finite() => f.write_str("null"),
            Json::Num(x) => write!(f, "{x}"),
            Json::Str(s) => {
                f.write_str("\"")?;
                write_escaped(f, s)?;
                f.write_str("\"")
            }
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    f.write_str("\"")?;
                    write_escaped(f, k)?;
                    f.write_str("\":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl fmt::Display) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|e| self.err(e))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| self.err(e))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| self.err(e))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\ bA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ bA"));
    }

    #[test]
    fn parses_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn parses_meta_shaped_document() {
        let doc = r#"{
          "table_len": 32, "p_grid_len": 16, "m_grid_len": 48,
          "s_grid_len": 32, "num_strategies": 13,
          "strategy_names": ["bcast/flat", "bcast/chain"]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("num_strategies").unwrap().as_usize(), Some(13));
        assert_eq!(
            v.get("strategy_names").unwrap().as_arr().unwrap()[1].as_str(),
            Some("bcast/chain")
        );
    }

    #[test]
    fn whitespace_everywhere() {
        let v = parse(" \n{ \"a\" :\t[ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn writes_scalars_compactly() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.25).to_string(), "0.25");
        assert_eq!(Json::Num(-1500.0).to_string(), "-1500");
        assert_eq!(Json::str("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
    }

    #[test]
    fn writes_escaped_strings_that_reparse() {
        let original = "a\n\t\"\\ b\u{8}\u{c}\u{1}";
        let text = Json::str(original).to_string();
        assert_eq!(parse(&text).unwrap().as_str(), Some(original));
    }

    #[test]
    fn write_parse_roundtrip_for_nested_values() {
        let v = Json::obj(vec![
            ("name", Json::str("warm_hit")),
            ("count", Json::from(3u64)),
            ("rates", Json::Arr(vec![Json::from(0.5), Json::Null])),
            ("inner", Json::obj(vec![("ok", Json::from(true))])),
        ]);
        let text = v.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back, v);
        // keys are emitted sorted: stable output for substring asserts
        assert!(text.starts_with("{\"count\":3,"), "{text}");
    }
}
