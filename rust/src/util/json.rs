//! Minimal JSON reader — just enough to parse the artifact metadata
//! sidecar (`artifacts/tuner.meta.json`) written by `python/compile/aot.py`.
//! serde_json is not available in this offline build.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl fmt::Display) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|e| self.err(e))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| self.err(e))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| self.err(e))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\ bA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ bA"));
    }

    #[test]
    fn parses_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn parses_meta_shaped_document() {
        let doc = r#"{
          "table_len": 32, "p_grid_len": 16, "m_grid_len": 48,
          "s_grid_len": 32, "num_strategies": 13,
          "strategy_names": ["bcast/flat", "bcast/chain"]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("num_strategies").unwrap().as_usize(), Some(13));
        assert_eq!(
            v.get("strategy_names").unwrap().as_arr().unwrap()[1].as_str(),
            Some("bcast/chain")
        );
    }

    #[test]
    fn whitespace_everywhere() {
        let v = parse(" \n{ \"a\" :\t[ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
