//! # collective-tuner
//!
//! A full reproduction of *Fast Tuning of Intra-Cluster Collective
//! Communications* (Barchet-Estefanel & Mounié, 2004).
//!
//! The paper replaces empirical benchmark sweeps with closed-form pLogP
//! cost models: measure the network's pLogP parameters once, evaluate an
//! analytic model for every candidate implementation of a collective
//! operation, and run the argmin. This crate builds the complete system
//! that idea needs:
//!
//! * [`netsim`] — a discrete-event simulator of a switched-Ethernet
//!   cluster (the stand-in for the paper's 50-node icluster-1 testbed),
//!   including the Linux TCP delayed-ACK and buffer-coalescing behaviours
//!   the paper's §4 anomalies trace back to, plus the per-message trace
//!   layer ([`netsim::Trace`] ring buffer, [`netsim::TraceSet`]
//!   versioned on-disk capture format) that feeds trace replay.
//! * [`mpi`] — an MPI-like point-to-point runtime (eager + rendezvous
//!   protocols) over the simulator, executing declarative communication
//!   schedules.
//! * [`collectives`] — every implementation strategy of the paper's
//!   Tables 1 and 2 (ten Broadcasts, three Scatters) plus the extended
//!   operations (Gather, Reduce, Barrier, AllGather, AllReduce) and
//!   MagPIe-style multi-level variants — all addressed through one
//!   [`collectives::Strategy`] enum, so the tuner selects among
//!   implementations of *every* collective.
//! * [`plogp`] — the pLogP parameter model and the measurement procedure
//!   of Kielmann et al.'s LogP benchmark, run against the simulator.
//! * [`models`] — the analytic cost models of Tables 1 and 2 in Rust
//!   plus the extended-op models derived the same way, as one
//!   strategy-indexed registry of closed-form cost functions.
//! * [`eval`] — the evaluation layer: the [`eval::Evaluator`] trait with
//!   four interchangeable backends — analytic models
//!   ([`eval::ModelEval`]), empirical simulation ([`eval::SimEval`],
//!   whose record mode captures per-message traces), captured-trace
//!   replay ([`eval::ReplayEval`], scoring against a fixed recorded
//!   workload — the golden-trace regression backend) and the
//!   AOT-compiled XLA artifact ([`eval::ArtifactEval`]). Everything
//!   that scores a `(strategy, P, m, segment)` point goes through it,
//!   and the sweep's cost is observable through the [`eval::EvalStats`]
//!   counters (model invocations, pruned searches, warm-start hits).
//! * [`tuner`] — the paper's contribution: strategy selection and
//!   segment-size search over any [`eval::Evaluator`] for all seven
//!   operation families ([`tuner::Op::ALL`]), swept in parallel across
//!   worker threads (`tune --jobs N`) with m-aware bound pruning
//!   ([`models::LOWER_BOUNDS`]), incumbent warm-starting, and a
//!   per-tune gap cache ([`plogp::GapCache`]) — byte-identical to the
//!   exhaustive argmin at a fraction of the model evaluations — with
//!   the AOT artifacts (see `python/compile/`, loaded through
//!   [`runtime`]) as the batched fast path.
//! * [`coordinator`] — the L3 service layer on top of the tuner: a
//!   long-running, thread-safe decision-table service. Clusters are
//!   fingerprinted by quantized pLogP signatures so equivalent networks
//!   share tables; a sharded LRU cache keeps lookups off the tuning
//!   path; concurrent cold misses coalesce into one tuner run; a
//!   refresh policy re-probes for parameter drift and swaps tables
//!   atomically. `topology::discover` feeds its registry and
//!   `collectives::multilevel` consumes its per-island decisions.
//!   [`coordinator::net`] puts the service on the wire: the `ct/1`
//!   TSV-over-TCP protocol (`docs/PROTOCOL.md`), the `coordd` server
//!   with server-initiated invalidation/table-update pushes, the
//!   [`coordinator::net::NetClient`] remote query surface, and an
//!   in-process loopback transport for tests.
//! * [`harness`] — experiment drivers that regenerate every figure of
//!   the paper's evaluation (measured vs predicted).
//! * [`obs`] — first-class observability over all of the above: a
//!   global registry of counters/gauges/log-linear histograms, RAII
//!   [`obs::Span`] timers on the coordinator/tuner/eval hot paths, a
//!   decision flight recorder, and JSON/Prometheus export. Off by
//!   default; disabled call sites cost one relaxed atomic load.
//!
//! The Python under `python/` is build-time only: it authors and lowers
//! the tuner kernel to `artifacts/tuner.hlo.txt`; the binary is
//! self-contained afterwards.

pub mod collectives;
pub mod coordinator;
pub mod eval;
pub mod harness;
pub mod models;
pub mod mpi;
pub mod netsim;
pub mod obs;
pub mod plogp;
pub mod runtime;
pub mod topology;
pub mod tuner;
pub mod util;
pub mod cli;
