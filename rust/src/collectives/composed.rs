//! Collectives constructed from the same building blocks — the paper's
//! §3 observation: "practical implementations of MPI usually construct
//! other collective operations (Barrier, Reduce, Gather) in a very
//! similar way", and its AllGather example (§3: MagPIe's Gather +
//! AllGatherv + Broadcast decomposition).
//!
//! * [`gather_flat`] / [`gather_binomial`] — reversed scatter trees.
//! * [`reduce_binomial`] — binomial fan-in combining contributor masks.
//! * [`barrier_binomial`] — fan-in + fan-out of control tokens.
//! * [`allgather`] — Gather to root + Broadcast of the full buffer.
//! * [`allreduce`] — Reduce to root + Broadcast of the result.

use anyhow::Result;

use crate::mpi::{CommSchedule, Payload, Protocol, Rank, SendSpec, Tag, Trigger};

use super::tree;

/// Tag-space bases so composed phases never collide on a receiver.
const GATHER_BASE: u64 = 1 << 32;
const BCAST_BASE: u64 = 2 << 32;

/// Flat gather: every rank sends its `bytes`-sized contribution straight
/// to the root. (Reverse of flat scatter; cost symmetric under pLogP.)
pub fn gather_flat(p: usize, root: Rank, bytes: u64) -> CommSchedule {
    let mut s = CommSchedule::new(p, "gather/flat");
    for vr in 1..p as Rank {
        let src = tree::to_real(vr, root, p);
        s.ranks[src as usize].sends.push(SendSpec {
            to: root,
            tag: Tag(GATHER_BASE + vr as u64),
            bytes,
            payload: Payload::range(vr as u64 * bytes, bytes),
            trigger: Trigger::AtStart,
            protocol: Protocol::Eager,
        });
        s.ranks[root as usize]
            .expected
            .push(Payload::range(vr as u64 * bytes, bytes));
    }
    s
}

/// Binomial gather: leaves send up; each internal node forwards its
/// combined subtree block once all children have arrived.
pub fn gather_binomial(p: usize, root: Rank, bytes: u64) -> CommSchedule {
    let mut s = CommSchedule::new(p, "gather/binomial");
    for vr in 1..p as Rank {
        let src = tree::to_real(vr, root, p);
        let parent = tree::binomial_parent(vr);
        let dst = tree::to_real(parent, root, p);
        let sub = tree::binomial_subtree_size(vr, p) as u64;
        let children = tree::binomial_children(vr, p);
        let trigger = if children.is_empty() {
            Trigger::AtStart
        } else {
            Trigger::OnRecvAll(
                children.iter().map(|c| Tag(GATHER_BASE + *c as u64)).collect(),
            )
        };
        let payload = Payload::range(vr as u64 * bytes, sub * bytes);
        s.ranks[src as usize].sends.push(SendSpec {
            to: dst,
            tag: Tag(GATHER_BASE + vr as u64),
            bytes: sub * bytes,
            payload,
            trigger,
            protocol: Protocol::Eager,
        });
        s.ranks[dst as usize].expected.push(payload);
    }
    s
}

/// Binomial reduce: same fan-in tree as [`gather_binomial`], but the
/// combined traffic stays `bytes` long (element-wise reduction) and the
/// payloads are contributor bitmasks — a structured error (not a wrong
/// mask) when `p` exceeds [`Payload::MAX_MASK_RANKS`].
pub fn reduce_binomial(p: usize, root: Rank, bytes: u64) -> Result<CommSchedule> {
    Payload::check_mask_capacity(p)?;
    let mut s = CommSchedule::new(p, "reduce/binomial");
    // mask of all virtual ranks in vr's subtree
    fn subtree_mask(vr: Rank, p: usize) -> u64 {
        let mut m = 1u64 << vr;
        for c in tree::binomial_children(vr, p) {
            m |= subtree_mask(c, p);
        }
        m
    }
    for vr in 1..p as Rank {
        let src = tree::to_real(vr, root, p);
        let parent = tree::binomial_parent(vr);
        let dst = tree::to_real(parent, root, p);
        let children = tree::binomial_children(vr, p);
        let trigger = if children.is_empty() {
            Trigger::AtStart
        } else {
            Trigger::OnRecvAll(
                children.iter().map(|c| Tag(GATHER_BASE + *c as u64)).collect(),
            )
        };
        let payload = Payload::Ranks(subtree_mask(vr, p));
        s.ranks[src as usize].sends.push(SendSpec {
            to: dst,
            tag: Tag(GATHER_BASE + vr as u64),
            bytes,
            payload,
            trigger,
            protocol: Protocol::Eager,
        });
        s.ranks[dst as usize].expected.push(payload);
    }
    Ok(s)
}

/// Binomial barrier: control-token fan-in to the root, then fan-out.
/// (The classic dissemination barrier is lower-latency; this is the
/// LAM-style tree barrier the paper's §3 refers to.)
pub fn barrier_binomial(p: usize) -> CommSchedule {
    let root: Rank = 0;
    let mut s = CommSchedule::new(p, "barrier/binomial");
    // fan-in
    for vr in 1..p as Rank {
        let children = tree::binomial_children(vr, p);
        let trigger = if children.is_empty() {
            Trigger::AtStart
        } else {
            Trigger::OnRecvAll(
                children.iter().map(|c| Tag(GATHER_BASE + *c as u64)).collect(),
            )
        };
        s.ranks[vr as usize].sends.push(SendSpec {
            to: tree::binomial_parent(vr),
            tag: Tag(GATHER_BASE + vr as u64),
            bytes: 1,
            payload: Payload::Control,
            trigger,
            protocol: Protocol::Eager,
        });
        s.ranks[tree::binomial_parent(vr) as usize]
            .expected
            .push(Payload::Control);
    }
    // fan-out
    for vr in 0..p as Rank {
        let children = tree::binomial_children(vr, p);
        let trigger = if vr == root {
            // root releases once every direct child token arrived
            let direct: Vec<Tag> = children
                .iter()
                .map(|c| Tag(GATHER_BASE + *c as u64))
                .collect();
            if direct.is_empty() {
                Trigger::AtStart
            } else {
                Trigger::OnRecvAll(direct)
            }
        } else {
            Trigger::OnRecv(Tag(BCAST_BASE + vr as u64))
        };
        for c in children {
            s.ranks[vr as usize].sends.push(SendSpec {
                to: c,
                tag: Tag(BCAST_BASE + c as u64),
                bytes: 1,
                payload: Payload::Control,
                trigger: trigger.clone(),
                protocol: Protocol::Eager,
            });
            s.ranks[c as usize].expected.push(Payload::Control);
        }
    }
    s
}

/// AllGather as Gather-to-root + Broadcast-of-everything — exactly the
/// intra-cluster phases MagPIe composes (§3). The broadcast payload is
/// the concatenated `P·bytes` buffer.
pub fn allgather(p: usize, root: Rank, bytes: u64) -> CommSchedule {
    let mut s = gather_binomial(p, root, bytes);
    s.name = "allgather/gather+bcast".into();
    let total = p as u64 * bytes;
    // Broadcast phase down the binomial tree, root gated on the gather.
    let root_children: Vec<Tag> = tree::binomial_children(0, p)
        .iter()
        .map(|c| Tag(GATHER_BASE + *c as u64))
        .collect();
    for vr in 0..p as Rank {
        let src = tree::to_real(vr, root, p);
        let trigger = if vr == 0 {
            if root_children.is_empty() {
                Trigger::AtStart
            } else {
                Trigger::OnRecvAll(root_children.clone())
            }
        } else {
            Trigger::OnRecv(Tag(BCAST_BASE))
        };
        for c in tree::binomial_children(vr, p) {
            let dst = tree::to_real(c, root, p);
            s.ranks[src as usize].sends.push(SendSpec {
                to: dst,
                tag: Tag(BCAST_BASE),
                bytes: total,
                payload: Payload::range(0, total),
                trigger: trigger.clone(),
                protocol: Protocol::Eager,
            });
            s.ranks[dst as usize].expected.push(Payload::range(0, total));
        }
    }
    s
}

/// AllReduce as Reduce-to-root + Broadcast-of-result. Errors like
/// [`reduce_binomial`] when `p` exceeds the contributor-mask capacity.
pub fn allreduce(p: usize, root: Rank, bytes: u64) -> Result<CommSchedule> {
    let mut s = reduce_binomial(p, root, bytes)?;
    s.name = "allreduce/reduce+bcast".into();
    let full: u64 = Payload::all_ranks_mask(p)?;
    let root_children: Vec<Tag> = tree::binomial_children(0, p)
        .iter()
        .map(|c| Tag(GATHER_BASE + *c as u64))
        .collect();
    for vr in 0..p as Rank {
        let src = tree::to_real(vr, root, p);
        let trigger = if vr == 0 {
            if root_children.is_empty() {
                Trigger::AtStart
            } else {
                Trigger::OnRecvAll(root_children.clone())
            }
        } else {
            Trigger::OnRecv(Tag(BCAST_BASE))
        };
        for c in tree::binomial_children(vr, p) {
            let dst = tree::to_real(c, root, p);
            s.ranks[src as usize].sends.push(SendSpec {
                to: dst,
                tag: Tag(BCAST_BASE),
                bytes,
                payload: Payload::Ranks(full),
                trigger: trigger.clone(),
                protocol: Protocol::Eager,
            });
            s.ranks[dst as usize].expected.push(Payload::Ranks(full));
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::{RunReport, World};
    use crate::netsim::{NetConfig, Netsim};

    fn run(sched: &CommSchedule, p: usize) -> RunReport {
        let mut w = World::new(Netsim::new(p, NetConfig::fast_ethernet_ideal()));
        let rep = w.run(sched);
        assert!(rep.verify(sched).is_empty(), "{}: {:?}", sched.name, rep.verify(sched));
        rep
    }

    #[test]
    fn gathers_collect_every_contribution() {
        for p in [2usize, 3, 5, 8, 13] {
            for sched in [gather_flat(p, 0, 512), gather_binomial(p, 0, 512)] {
                let rep = run(&sched, p);
                assert!(rep.completion.as_secs() > 0.0);
            }
        }
    }

    #[test]
    fn gather_binomial_root_receives_direct_children_blocks() {
        let p = 8;
        let rep = run(&gather_binomial(p, 0, 100), p);
        // root's received payloads = blocks of its direct children 1,2,4
        let mut lens: Vec<u64> = rep.received[0]
            .iter()
            .map(|pl| match pl {
                Payload::Range { len, .. } => *len,
                _ => 0,
            })
            .collect();
        lens.sort();
        assert_eq!(lens, vec![100, 200, 400]);
    }

    #[test]
    fn gather_nonzero_root() {
        for root in 0..5 {
            run(&gather_flat(5, root, 64), 5);
            run(&gather_binomial(5, root, 64), 5);
        }
    }

    #[test]
    fn reduce_combines_all_ranks() {
        for p in [2usize, 5, 8, 16] {
            let rep = run(&reduce_binomial(p, 0, 1024).unwrap(), p);
            // union of masks delivered to root + root's own = all ranks
            let mut mask = 1u64; // root vr 0
            for pl in &rep.received[0] {
                if let Payload::Ranks(m) = pl {
                    mask |= m;
                }
            }
            assert_eq!(mask, (1u64 << p) - 1, "p={p}");
        }
    }

    #[test]
    fn reduce_traffic_is_message_sized() {
        let p = 8;
        let s = reduce_binomial(p, 0, 4096).unwrap();
        for spec in s.ranks.iter().flat_map(|r| &r.sends) {
            assert_eq!(spec.bytes, 4096);
        }
        assert_eq!(s.total_sends(), p - 1);
    }

    #[test]
    fn barrier_completes_and_reaches_everyone() {
        for p in [2usize, 3, 5, 8, 13, 16] {
            let rep = run(&barrier_binomial(p), p);
            assert!(rep.completion.as_secs() > 0.0, "p={p}");
            // every non-root rank got a release token
            for r in 1..p {
                assert!(
                    rep.received[r].contains(&Payload::Control),
                    "rank {r} never released"
                );
            }
        }
    }

    #[test]
    fn barrier_latency_scales_logarithmically() {
        let t4 = run(&barrier_binomial(4), 4).completion.as_secs();
        let t16 = run(&barrier_binomial(16), 16).completion.as_secs();
        let t32 = run(&barrier_binomial(32), 32).completion.as_secs();
        // 4 -> 16 doubles the rounds (2->4+); 16->32 adds ~1 round
        assert!(t16 > t4);
        assert!(t32 > t16);
        assert!((t32 - t16) < (t16 - t4) * 2.0);
    }

    #[test]
    fn allgather_delivers_full_buffer_everywhere() {
        let p = 8;
        let bytes = 256;
        let rep = run(&allgather(p, 0, bytes), p);
        let total = p as u64 * bytes;
        for r in 1..p {
            assert!(
                rep.received[r].contains(&Payload::range(0, total)),
                "rank {r} missing full buffer"
            );
        }
    }

    #[test]
    fn allreduce_delivers_full_reduction_everywhere() {
        let p = 8;
        let rep = run(&allreduce(p, 0, 1024).unwrap(), p);
        let full = (1u64 << p) - 1;
        for r in 1..p {
            assert!(
                rep.received[r].contains(&Payload::Ranks(full)),
                "rank {r} missing reduced value"
            );
        }
    }

    #[test]
    fn allgather_costs_more_than_gather() {
        let p = 8;
        let g = run(&gather_binomial(p, 0, 1024), p);
        let ag = run(&allgather(p, 0, 1024), p);
        assert!(ag.completion > g.completion);
    }

    #[test]
    fn reductions_reject_more_than_64_ranks() {
        // regression: Payload::Ranks is a u64 bitmask — p > 64 used to
        // silently wrap into wrong masks; now it is a structured error
        let err = reduce_binomial(65, 0, 8).unwrap_err();
        assert!(err.to_string().contains("64"), "{err}");
        assert!(allreduce(100, 0, 8).is_err());
        // 64 is the boundary: the full mask must not overflow
        let rep = run(&allreduce(64, 0, 8).unwrap(), 64);
        for r in 1..64usize {
            assert!(rep.received[r].contains(&Payload::Ranks(u64::MAX)), "rank {r}");
        }
    }
}
