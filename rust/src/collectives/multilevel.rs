//! Multi-level (grid-aware) collectives over islands of clusters —
//! the composition the paper is building towards (§1: "the construction
//! of multi-level collective operations"; §5 future work).
//!
//! A multi-level broadcast runs an inter-cluster phase among the cluster
//! roots (over the WAN) and then, inside each cluster, whichever tuned
//! intra-cluster strategy the tuner selected for that cluster's pLogP
//! parameters. The whole thing is still one [`CommSchedule`] executed by
//! the same deterministic executor.

use anyhow::Result;

use crate::coordinator::Coordinator;
use crate::mpi::{CommSchedule, Payload, Protocol, Rank, SendSpec, Tag, Trigger};
use crate::topology::GridSpec;
use crate::tuner::Op;

use super::{tree, Strategy};

/// Tag base for the inter-cluster phase (must not collide with the
/// intra-cluster strategies' segment tags, which start at 0).
const WAN_BASE: u64 = 7 << 40;

/// Multi-level broadcast:
///   phase 1 — binomial broadcast of `bytes` among cluster roots
///             (WAN links);
///   phase 2 — per-cluster intra broadcast with the given strategy,
///             gated on the cluster root's phase-1 receive.
///
/// `intra` gives the strategy (and segment size) per cluster, as chosen
/// by the tuner for each cluster's own network parameters.
pub fn bcast(
    grid: &GridSpec,
    bytes: u64,
    intra: &[(Strategy, Option<u64>)],
) -> CommSchedule {
    let nc = grid.clusters.len();
    assert_eq!(intra.len(), nc, "one intra strategy per cluster");
    let total = grid.total_nodes();
    let mut s = CommSchedule::new(total, "multilevel/bcast");

    // --- phase 1: binomial over cluster roots --------------------------
    for vc in 0..nc as Rank {
        let src = grid.cluster_root(vc as usize);
        let trigger = if vc == 0 {
            Trigger::AtStart
        } else {
            Trigger::OnRecv(Tag(WAN_BASE))
        };
        for c in tree::binomial_children(vc, nc) {
            let dst = grid.cluster_root(c as usize);
            s.ranks[src as usize].sends.push(SendSpec {
                to: dst,
                tag: Tag(WAN_BASE),
                bytes,
                payload: Payload::range(0, bytes),
                trigger: trigger.clone(),
                protocol: Protocol::Eager,
            });
            s.ranks[dst as usize].expected.push(Payload::range(0, bytes));
        }
    }

    // --- phase 2: tuned intra-cluster broadcasts ------------------------
    for (ci, &(strategy, seg)) in intra.iter().enumerate() {
        assert!(strategy.is_bcast(), "cluster {ci}: {strategy:?} is not a broadcast");
        let (lo, hi) = grid.cluster_range(ci);
        let p = (hi - lo) as usize;
        if p == 1 {
            continue;
        }
        let sub = strategy.build(p, 0, bytes, seg);
        // splice, relocating ranks by +lo and gating the cluster root
        for (local, rs) in sub.ranks.iter().enumerate() {
            let global = lo as usize + local;
            for spec in &rs.sends {
                let mut spec = spec.clone();
                spec.to += lo;
                // cluster 0's root already has the data at start; other
                // cluster roots wait for the WAN delivery.
                if ci != 0 && local == 0 && spec.trigger == Trigger::AtStart {
                    spec.trigger = Trigger::OnRecv(Tag(WAN_BASE));
                }
                s.ranks[global].sends.push(spec);
            }
            s.ranks[global].expected.extend(rs.expected.iter().copied());
        }
    }
    s
}

/// Multi-level broadcast with the per-island strategy of every cluster
/// fetched from the [`Coordinator`] — the construction both companion
/// papers require: inter-cluster phase over the WAN, intra-cluster phase
/// with whatever the tuner chose for *that island's* network.
///
/// The clusters must be registered with the coordinator under the names
/// in `grid` (e.g. via [`Coordinator::register_islands`]); tables are
/// tuned once per distinct signature and served from the cache on every
/// subsequent schedule build — the coordinator is the only component
/// that ever runs the tuner.
pub fn tuned_bcast(
    grid: &GridSpec,
    bytes: u64,
    coord: &Coordinator,
) -> Result<CommSchedule> {
    let mut intra = Vec::with_capacity(grid.clusters.len());
    for c in &grid.clusters {
        let d = coord.decision(Op::Bcast, &c.name, c.nodes, bytes)?;
        intra.push((d.strategy, d.segment));
    }
    Ok(bcast(grid, bytes, &intra))
}

/// Multi-level barrier: intra-cluster fan-in to each cluster root,
/// binomial barrier among roots, intra-cluster fan-out. Built from the
/// same primitives; exercised by the grid examples.
pub fn barrier(grid: &GridSpec) -> CommSchedule {
    let nc = grid.clusters.len();
    let total = grid.total_nodes();
    let mut s = CommSchedule::new(total, "multilevel/barrier");
    const IN_BASE: u64 = 8 << 40;
    const ROOTS_BASE: u64 = 9 << 40;
    const OUT_BASE: u64 = 10 << 40;

    // intra fan-in
    for ci in 0..nc {
        let (lo, hi) = grid.cluster_range(ci);
        let p = (hi - lo) as usize;
        for vr in 1..p as Rank {
            let src = lo + vr;
            let dst = lo + tree::binomial_parent(vr);
            let children = tree::binomial_children(vr, p);
            let trigger = if children.is_empty() {
                Trigger::AtStart
            } else {
                Trigger::OnRecvAll(
                    children.iter().map(|c| Tag(IN_BASE + (lo + *c) as u64)).collect(),
                )
            };
            s.ranks[src as usize].sends.push(SendSpec {
                to: dst,
                tag: Tag(IN_BASE + src as u64),
                bytes: 1,
                payload: Payload::Control,
                trigger,
                protocol: Protocol::Eager,
            });
            s.ranks[dst as usize].expected.push(Payload::Control);
        }
    }

    // binomial barrier among roots (fan-in then fan-out over WAN)
    for vc in 1..nc as Rank {
        let src = grid.cluster_root(vc as usize);
        let dst = grid.cluster_root(tree::binomial_parent(vc) as usize);
        let mut waits: Vec<Tag> = {
            let (lo, hi) = grid.cluster_range(vc as usize);
            let p = (hi - lo) as usize;
            tree::binomial_children(0, p)
                .iter()
                .map(|c| Tag(IN_BASE + (lo + *c) as u64))
                .collect()
        };
        waits.extend(
            tree::binomial_children(vc, nc)
                .iter()
                .map(|c| Tag(ROOTS_BASE + *c as u64)),
        );
        let trigger = if waits.is_empty() {
            Trigger::AtStart
        } else {
            Trigger::OnRecvAll(waits)
        };
        s.ranks[src as usize].sends.push(SendSpec {
            to: dst,
            tag: Tag(ROOTS_BASE + vc as u64),
            bytes: 1,
            payload: Payload::Control,
            trigger,
            protocol: Protocol::Eager,
        });
        s.ranks[dst as usize].expected.push(Payload::Control);
    }

    // release: binomial fan-out over roots, then intra fan-out
    for vc in 0..nc as Rank {
        let src = grid.cluster_root(vc as usize);
        let root_release_trigger = if vc == 0 {
            // global root releases once its cluster fan-in + root fan-in done
            let (lo, hi) = grid.cluster_range(0);
            let p = (hi - lo) as usize;
            let mut waits: Vec<Tag> = tree::binomial_children(0, p)
                .iter()
                .map(|c| Tag(IN_BASE + (lo + *c) as u64))
                .collect();
            waits.extend(
                tree::binomial_children(0, nc)
                    .iter()
                    .map(|c| Tag(ROOTS_BASE + *c as u64)),
            );
            if waits.is_empty() {
                Trigger::AtStart
            } else {
                Trigger::OnRecvAll(waits)
            }
        } else {
            Trigger::OnRecv(Tag(OUT_BASE + src as u64))
        };
        // WAN release to child roots
        for c in tree::binomial_children(vc, nc) {
            let dst = grid.cluster_root(c as usize);
            s.ranks[src as usize].sends.push(SendSpec {
                to: dst,
                tag: Tag(OUT_BASE + dst as u64),
                bytes: 1,
                payload: Payload::Control,
                trigger: root_release_trigger.clone(),
                protocol: Protocol::Eager,
            });
            s.ranks[dst as usize].expected.push(Payload::Control);
        }
        // intra release down the local binomial tree
        let (lo, hi) = grid.cluster_range(vc as usize);
        let p = (hi - lo) as usize;
        for vr in 0..p as Rank {
            let gsrc = lo + vr;
            let trig = if vr == 0 {
                root_release_trigger.clone()
            } else {
                Trigger::OnRecv(Tag(OUT_BASE + gsrc as u64))
            };
            for c in tree::binomial_children(vr, p) {
                let gdst = lo + c;
                s.ranks[gsrc as usize].sends.push(SendSpec {
                    to: gdst,
                    tag: Tag(OUT_BASE + gdst as u64),
                    bytes: 1,
                    payload: Payload::Control,
                    trigger: trig.clone(),
                    protocol: Protocol::Eager,
                });
                s.ranks[gdst as usize].expected.push(Payload::Control);
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::World;
    use crate::netsim::NetConfig;
    use crate::topology::ClusterSpec;

    fn grid(na: usize, nb: usize) -> GridSpec {
        GridSpec::new(
            vec![
                ClusterSpec::new("a", na, NetConfig::fast_ethernet_ideal()),
                ClusterSpec::new("b", nb, NetConfig::fast_ethernet_ideal()),
            ],
            NetConfig::wan_link(),
        )
    }

    #[test]
    fn multilevel_bcast_reaches_every_node() {
        let g = grid(5, 4);
        let sched = bcast(
            &g,
            8192,
            &[
                (Strategy::BcastBinomial, None),
                (Strategy::BcastSegChain, Some(1024)),
            ],
        );
        assert!(sched.validate().is_empty(), "{:?}", sched.validate());
        let mut w = World::new(g.build_sim());
        let rep = w.run(&sched);
        assert!(rep.verify(&sched).is_empty(), "{:?}", rep.verify(&sched));
        // every non-global-root rank received the payload at least once
        for r in 1..g.total_nodes() {
            assert!(
                !rep.received[r].is_empty(),
                "rank {r} received nothing"
            );
        }
    }

    #[test]
    fn multilevel_bcast_crosses_wan_once_per_cluster() {
        let g = grid(4, 4);
        let sched = bcast(
            &g,
            1 << 16,
            &[(Strategy::BcastBinomial, None), (Strategy::BcastBinomial, None)],
        );
        // exactly one WAN data transfer (root 0 -> root 4)
        let wan_sends: Vec<_> = sched
            .ranks
            .iter()
            .enumerate()
            .flat_map(|(r, rs)| rs.sends.iter().map(move |s| (r, s)))
            .filter(|(r, s)| g.cluster_of(*r as u32) != g.cluster_of(s.to))
            .collect();
        assert_eq!(wan_sends.len(), 1);
        assert_eq!(wan_sends[0].0, 0);
        assert_eq!(wan_sends[0].1.to, 4);
    }

    #[test]
    fn multilevel_beats_naive_flat_over_wan() {
        // A flat broadcast from node 0 pays the WAN once *per remote
        // node*; the multi-level broadcast pays it once per cluster.
        let g = grid(6, 6);
        let m = 1 << 18;
        let ml = bcast(
            &g,
            m,
            &[(Strategy::BcastBinomial, None), (Strategy::BcastBinomial, None)],
        );
        let naive = Strategy::BcastFlat.build(g.total_nodes(), 0, m, None);
        let mut w1 = World::new(g.build_sim());
        let mut w2 = World::new(g.build_sim());
        let t_ml = w1.run(&ml).completion;
        let t_naive = w2.run(&naive).completion;
        assert!(
            t_ml < t_naive,
            "multilevel {} vs naive flat {}",
            t_ml,
            t_naive
        );
    }

    #[test]
    fn multilevel_barrier_completes() {
        for (na, nb) in [(2usize, 2usize), (5, 3), (8, 8)] {
            let g = grid(na, nb);
            let sched = barrier(&g);
            assert!(sched.validate().is_empty(), "{:?}", sched.validate());
            let mut w = World::new(g.build_sim());
            let rep = w.run(&sched);
            assert!(rep.verify(&sched).is_empty(), "{:?}", rep.verify(&sched));
        }
    }

    #[test]
    fn tuned_bcast_fetches_per_island_tables_from_coordinator() {
        use crate::coordinator::{Coordinator, CoordinatorConfig};
        use crate::tuner::grids;
        let g = GridSpec::new(
            vec![
                ClusterSpec::new("fast", 5, NetConfig::fast_ethernet_icluster1()),
                ClusterSpec::new("giga", 4, NetConfig::gigabit_ethernet()),
            ],
            NetConfig::wan_link(),
        );
        let coord = Coordinator::new(CoordinatorConfig {
            p_grid: vec![2, 8, 24],
            m_grid: grids::log_grid(1, 1 << 20, 6),
            ..CoordinatorConfig::default()
        });
        coord.register_islands(&g).unwrap();
        let sched = tuned_bcast(&g, 1 << 16, &coord).unwrap();
        assert!(sched.validate().is_empty(), "{:?}", sched.validate());
        let mut w = World::new(g.build_sim());
        let rep = w.run(&sched);
        assert!(rep.verify(&sched).is_empty(), "{:?}", rep.verify(&sched));
        assert_eq!(coord.tune_count(), 2, "one tune per distinct island signature");
        // a second schedule build is pure cache hits — no inline tuning
        let _ = tuned_bcast(&g, 1 << 10, &coord).unwrap();
        assert_eq!(coord.tune_count(), 2);
    }

    #[test]
    fn tuned_bcast_unregistered_island_is_an_error() {
        let g = grid(3, 3);
        let coord = crate::coordinator::Coordinator::with_defaults();
        assert!(tuned_bcast(&g, 4096, &coord).is_err());
    }

    #[test]
    fn three_cluster_bcast() {
        let g = GridSpec::new(
            vec![
                ClusterSpec::new("a", 3, NetConfig::fast_ethernet_ideal()),
                ClusterSpec::new("b", 4, NetConfig::fast_ethernet_ideal()),
                ClusterSpec::new("c", 2, NetConfig::fast_ethernet_ideal()),
            ],
            NetConfig::wan_link(),
        );
        let sched = bcast(
            &g,
            4096,
            &[
                (Strategy::BcastBinomial, None),
                (Strategy::BcastChain, None),
                (Strategy::BcastFlat, None),
            ],
        );
        let mut w = World::new(g.build_sim());
        let rep = w.run(&sched);
        assert!(rep.verify(&sched).is_empty(), "{:?}", rep.verify(&sched));
    }
}
