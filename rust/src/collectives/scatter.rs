//! Scatter schedule builders — the three strategies of the paper's
//! Table 2.
//!
//! Scatter semantics: the root holds `P · m` bytes; virtual rank `v`
//! must end up with the chunk `[v·m, (v+1)·m)`. Chunk addressing is in
//! virtual-rank (root-relative) order, the convention LAM/MPICH use
//! internally when the root is relabelled.
//!
//! For the chain and binomial strategies the payload a rank *receives* is
//! the combined block it is responsible for (its own chunk plus
//! everything it must forward), which is what the expected-payload
//! verification checks.

use crate::mpi::{CommSchedule, Payload, Protocol, Rank, SendSpec, Tag, Trigger};

use super::tree;

/// Flat-tree scatter: the root sends each rank its chunk directly.
/// Model: `(P-1) g(m) + L`. This is the default in most MPI
/// implementations ("optimal algorithms for homogeneous networks use flat
/// trees", §3.2).
pub fn flat(p: usize, root: Rank, bytes: u64) -> CommSchedule {
    let mut s = CommSchedule::new(p, "scatter/flat");
    for vr in 1..p as Rank {
        let dst = tree::to_real(vr, root, p);
        s.ranks[root as usize].sends.push(SendSpec {
            to: dst,
            tag: Tag(vr as u64),
            bytes,
            payload: Payload::range(vr as u64 * bytes, bytes),
            trigger: Trigger::AtStart,
            protocol: Protocol::Eager,
        });
        s.ranks[dst as usize]
            .expected
            .push(Payload::range(vr as u64 * bytes, bytes));
    }
    s
}

/// Chain scatter: the root ships the whole remainder down the chain; each
/// hop keeps its chunk and forwards the rest.
/// Model: `sum_{j=1}^{P-1} g(j·m) + (P-1) L`.
pub fn chain(p: usize, root: Rank, bytes: u64) -> CommSchedule {
    let mut s = CommSchedule::new(p, "scatter/chain");
    for vr in 0..(p - 1) as Rank {
        let src = tree::to_real(vr, root, p);
        let dst = tree::to_real(vr + 1, root, p);
        let off = (vr as u64 + 1) * bytes;
        let len = (p as u64 - 1 - vr as u64) * bytes;
        let trigger = if vr == 0 {
            Trigger::AtStart
        } else {
            Trigger::OnRecv(Tag(vr as u64))
        };
        s.ranks[src as usize].sends.push(SendSpec {
            to: dst,
            tag: Tag(vr as u64 + 1),
            bytes: len,
            payload: Payload::range(off, len),
            trigger,
            protocol: Protocol::Eager,
        });
        s.ranks[dst as usize].expected.push(Payload::range(off, len));
    }
    s
}

/// Binomial scatter: recursive halving. The root keeps the lower half of
/// the rank range and ships the upper half (one combined message) to that
/// half's lowest rank; recurse. Model:
/// `sum_{j=0}^{ceil(log2 P)-1} g(2^j·m) + ceil(log2 P) L`.
pub fn binomial(p: usize, root: Rank, bytes: u64) -> CommSchedule {
    let mut s = CommSchedule::new(p, "scatter/binomial");
    // Recursively assign block transfers. `owner` holds [lo, hi) and its
    // incoming tag is `in_tag` (None for the root).
    fn split(
        s: &mut CommSchedule,
        p: usize,
        root: Rank,
        bytes: u64,
        owner: Rank,
        lo: Rank,
        hi: Rank,
        in_tag: Option<Tag>,
    ) {
        if hi - lo <= 1 {
            return;
        }
        let mid = tree::scatter_mid(lo, hi);
        let src = tree::to_real(owner, root, p);
        let dst = tree::to_real(mid, root, p);
        let off = mid as u64 * bytes;
        let len = (hi - mid) as u64 * bytes;
        let tag = Tag(mid as u64);
        let trigger = match in_tag {
            None => Trigger::AtStart,
            Some(t) => Trigger::OnRecv(t),
        };
        s.ranks[src as usize].sends.push(SendSpec {
            to: dst,
            tag,
            bytes: len,
            payload: Payload::range(off, len),
            trigger,
            protocol: Protocol::Eager,
        });
        s.ranks[dst as usize].expected.push(Payload::range(off, len));
        // owner recurses on the lower part, receiver on the upper part
        split(s, p, root, bytes, owner, lo, mid, in_tag);
        split(s, p, root, bytes, mid, mid, hi, Some(tag));
    }
    split(&mut s, p, root, bytes, 0, 0, p as Rank, None);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::{RunReport, World};
    use crate::netsim::{NetConfig, Netsim};

    fn run(sched: &CommSchedule, p: usize) -> RunReport {
        let mut w = World::new(Netsim::new(p, NetConfig::fast_ethernet_ideal()));
        let rep = w.run(sched);
        assert!(rep.verify(sched).is_empty(), "{}: {:?}", sched.name, rep.verify(sched));
        rep
    }

    /// Every rank must end up owning its chunk `[v·m, (v+1)·m)` — either
    /// received directly or inside a combined block.
    fn assert_chunks_reachable(sched: &CommSchedule, p: usize, m: u64) {
        let rep = run(sched, p);
        for (r, payloads) in rep.received.iter().enumerate() {
            let root_real = sched
                .ranks
                .iter()
                .enumerate()
                .find(|(_, rs)| rs.sends.iter().any(|s| s.trigger == Trigger::AtStart))
                .map(|(i, _)| i as Rank)
                .unwrap_or(0);
            let vr = tree::to_virtual(r as Rank, root_real, p) as u64;
            if vr == 0 {
                continue; // root keeps its chunk locally
            }
            let want_lo = vr * m;
            let want_hi = want_lo + m;
            let covered = payloads.iter().any(|pl| match pl {
                Payload::Range { offset, len } => {
                    *offset <= want_lo && offset + len >= want_hi
                }
                _ => false,
            });
            assert!(covered, "rank {r} (vr {vr}) never got chunk [{want_lo},{want_hi})");
        }
    }

    #[test]
    fn all_scatters_deliver_every_chunk() {
        let m = 2048;
        for p in [2usize, 3, 5, 8, 13, 16] {
            assert_chunks_reachable(&flat(p, 0, m), p, m);
            assert_chunks_reachable(&chain(p, 0, m), p, m);
            assert_chunks_reachable(&binomial(p, 0, m), p, m);
        }
    }

    #[test]
    fn scatter_nonzero_root() {
        let m = 1024;
        for root in 0..5 {
            assert_chunks_reachable(&flat(5, root, m), 5, m);
            assert_chunks_reachable(&chain(5, root, m), 5, m);
            assert_chunks_reachable(&binomial(5, root, m), 5, m);
        }
    }

    #[test]
    fn flat_bytes_on_wire() {
        let s = flat(9, 0, 100);
        assert_eq!(s.total_sends(), 8);
        assert_eq!(s.total_send_bytes(), 800);
    }

    #[test]
    fn chain_bytes_on_wire_are_triangular() {
        // sends of sizes (P-1)m, (P-2)m, ..., m
        let p = 6;
        let m = 10;
        let s = chain(p, 0, m);
        assert_eq!(s.total_sends(), p - 1);
        assert_eq!(s.total_send_bytes(), (1..=5).sum::<u64>() * m);
    }

    #[test]
    fn binomial_bytes_power_of_two() {
        // P=8: blocks of 4m, 2m, m from root + 2m, m, m + m = total 12m?
        // Exactly: every rank's combined incoming block sums to
        // sum over non-root vr of (subtree block length) = sum sizes.
        let p = 8;
        let m = 10;
        let s = binomial(p, 0, m);
        assert_eq!(s.total_sends(), p - 1);
        // root ships 4m + 2m + m; vr4 ships 2m+m... total = 17m for P=8
        // (4+2+1) + (2+1) + (1) ... compute: known value 4+2+1+2+1+1+1=12
        let total: u64 = s.total_send_bytes();
        assert_eq!(total, 12 * m);
    }

    #[test]
    fn binomial_root_sends_biggest_block_first() {
        let s = binomial(8, 0, 100);
        let root_sends = &s.ranks[0].sends;
        assert_eq!(root_sends[0].bytes, 400);
        assert_eq!(root_sends[1].bytes, 200);
        assert_eq!(root_sends[2].bytes, 100);
    }

    #[test]
    fn flat_faster_than_binomial_small_p() {
        // tiny clusters: one direct send beats forwarding
        let m = 64 * 1024;
        let rf = run(&flat(3, 0, m), 3);
        let rb = run(&binomial(3, 0, m), 3);
        assert!(rf.completion <= rb.completion);
    }

    #[test]
    fn binomial_beats_flat_at_scale_power_of_two() {
        // the paper's §4.2 conclusion, at P=32 where wire bytes match
        let p = 32;
        let m = 64 * 1024;
        let rf = run(&flat(p, 0, m), p);
        let rb = run(&binomial(p, 0, m), p);
        assert!(
            rb.completion < rf.completion,
            "binomial {} vs flat {}",
            rb.completion,
            rf.completion
        );
    }

    #[test]
    fn chain_is_worst_at_scale() {
        let p = 16;
        let m = 32 * 1024;
        let rf = run(&flat(p, 0, m), p);
        let rc = run(&chain(p, 0, m), p);
        assert!(rc.completion > rf.completion);
    }

    #[test]
    fn p2_flat_equals_binomial() {
        let m = 4096;
        let rf = run(&flat(2, 0, m), 2);
        let rb = run(&binomial(2, 0, m), 2);
        assert_eq!(rf.completion, rb.completion);
    }
}
